"""Fig. 11: migration time vs number of QPs (ib_send_bw-style container
with n_qps channels, migrated mid-stream; total time + image size)."""
from repro.runtime.cluster import SimCluster
from repro.runtime.apps import SendBwApp
from repro.runtime.collectives import connect_pair


def main():
    for n_qps in (1, 4, 16, 64):
        cl = SimCluster(3)
        A = cl.launch("send", 0)
        B = cl.launch("recv", 1)
        aa = SendBwApp(msg_size=4096, window=4, n_qps=n_qps)
        aa.attach(A, sender=True)
        A.app = aa
        ab = SendBwApp(msg_size=4096, window=4, n_qps=n_qps)
        ab.attach(B, sender=False)
        B.app = ab
        for i in range(n_qps):
            connect_pair(aa.channels[i], ab.channels[i])
        for _ in range(30):
            cl.step_all()
        rep = cl.migrate("recv", 2)
        for _ in range(300):
            cl.step_all()
        print(f"fig11_migration[{n_qps}qps],{rep.total_s*1e6:.0f},"
              f"image_KiB={rep.image_bytes/1024:.0f},"
              f"ckpt_us={rep.checkpoint_s*1e6:.0f},"
              f"restore_us={rep.restore_s*1e6:.0f},resumed={ab.received>0}")


if __name__ == "__main__":
    main()
