"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. sys.path is extended so the
suite runs as ``PYTHONPATH=src python -m benchmarks.run`` from the repo
root (the fabric benchmarks also import tests.helpers).

``--json [PATH]`` additionally writes a machine-readable summary
(default ``BENCH_summary.json``): per figure, whether it passed, its
wall-clock wall_s, and the headline metrics dict its ``main()`` returned
(the fabric figures return their sim-clock durations and counters; mains
that return nothing contribute ``metrics: null``). CI archives this so
headline numbers are diffable across commits without parsing CSV.
"""
import argparse
import json
import os
import sys
import time
import traceback

ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, ROOT)

from benchmarks import (fig7_overhead, fig8_shadow, fig9_creation,  # noqa
                        fig10_mr_reg, fig11_qps, fig13_training_migration,
                        fig_contention, fig_delta, fig_downtime, fig_ecn,
                        fig_incast, fig_pfc, fig_qos, roofline_table,
                        table1_sloc, table2_dump_sizes)

MODULES = [
    ("table1_sloc", table1_sloc),
    ("table2_dump_sizes", table2_dump_sizes),
    ("fig7_overhead", fig7_overhead),
    ("fig8_shadow", fig8_shadow),
    ("fig9_creation", fig9_creation),
    ("fig10_mr_reg", fig10_mr_reg),
    ("fig11_qps", fig11_qps),
    ("fig13_training_migration", fig13_training_migration),
    ("fig_downtime", fig_downtime),
    ("fig_contention", fig_contention),
    ("fig_qos", fig_qos),
    ("fig_incast", fig_incast),
    ("fig_ecn", fig_ecn),
    ("fig_pfc", fig_pfc),
    ("fig_delta", fig_delta),
    ("roofline_table", roofline_table),
]


def run_modules(modules) -> dict:
    """Run each (name, module) pair; returns the summary dict. A module's
    ``main()`` return value rides along as its headline metrics when it
    is a dict (the fabric figures), else null."""
    summary = {}
    for name, mod in modules:
        t0 = time.time()
        entry = {"ok": False, "wall_s": None, "metrics": None}
        try:
            result = mod.main()
            entry["ok"] = True
            if isinstance(result, dict):
                entry["metrics"] = result
            print(f"# {name} done in {time.time()-t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            entry["error"] = str(e)
            print(f"# {name} FAILED: {e}")
            traceback.print_exc()
        entry["wall_s"] = round(time.time() - t0, 3)
        summary[name] = entry
    return summary


def write_summary(summary: dict, path: str) -> str:
    with open(path, "w") as f:
        json.dump(summary, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", nargs="?", const="BENCH_summary.json",
                    default=None, metavar="PATH",
                    help="write a per-figure JSON summary "
                         "(default PATH: BENCH_summary.json)")
    ap.add_argument("--only", action="append", default=None,
                    metavar="NAME",
                    help="run only the named figure(s); repeatable")
    args = ap.parse_args(argv)
    modules = MODULES
    if args.only:
        known = {name for name, _ in MODULES}
        unknown = set(args.only) - known
        if unknown:
            ap.error(f"unknown figure(s) {sorted(unknown)}; "
                     f"have {sorted(known)}")
        modules = [(n, m) for n, m in MODULES if n in args.only]
    summary = run_modules(modules)
    if args.json:
        print(f"# summary -> {write_summary(summary, args.json)}")
    if any(not e["ok"] for e in summary.values()):
        sys.exit(1)


if __name__ == '__main__':
    main()
