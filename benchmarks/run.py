"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. sys.path is extended so the
suite runs as ``PYTHONPATH=src python -m benchmarks.run`` from the repo
root (the fabric benchmarks also import tests.helpers).
"""
import os
import sys
import time
import traceback

ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, ROOT)

from benchmarks import (fig7_overhead, fig8_shadow, fig9_creation,  # noqa
                        fig10_mr_reg, fig11_qps, fig13_training_migration,
                        fig_contention, fig_downtime, fig_ecn, fig_incast,
                        fig_qos, roofline_table, table1_sloc,
                        table2_dump_sizes)

MODULES = [
    ("table1_sloc", table1_sloc),
    ("table2_dump_sizes", table2_dump_sizes),
    ("fig7_overhead", fig7_overhead),
    ("fig8_shadow", fig8_shadow),
    ("fig9_creation", fig9_creation),
    ("fig10_mr_reg", fig10_mr_reg),
    ("fig11_qps", fig11_qps),
    ("fig13_training_migration", fig13_training_migration),
    ("fig_downtime", fig_downtime),
    ("fig_contention", fig_contention),
    ("fig_qos", fig_qos),
    ("fig_incast", fig_incast),
    ("fig_ecn", fig_ecn),
    ("roofline_table", roofline_table),
]


def main() -> None:
    failures = 0
    for name, mod in MODULES:
        t0 = time.time()
        try:
            mod.main()
            print(f"# {name} done in {time.time()-t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED: {e}")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
