"""Fig. 8: DMTCP-style shadow-object interposition overhead vs native,
across message sizes (bandwidth drop / latency increase)."""
import time

from repro.core.shadow import ShadowVerbs
from repro.core.verbs import RecvWR, SGE, SendWR
from repro.core.packets import Op
from repro.runtime.cluster import SimCluster
from repro.runtime.collectives import Channel, connect_pair


def _run(msg_size, n_msgs, shadowed):
    cl = SimCluster(2)
    ca = cl.launch("a", 0)
    cb = cl.launch("b", 1)
    c1 = Channel(ca.ctx, msg_size * 2)
    c2 = Channel(cb.ctx, msg_size * 2)
    connect_pair(c1, c2)
    sh = ShadowVerbs(ca.ctx) if shadowed else None
    if sh is not None:
        # shadow the existing MRs the DMTCP way: bounce buffers
        pd = ca.ctx.pds[0]
        from repro.core.shadow import _ShadowMR
        for mrn in (c1.mrn_send, c1.mrn_recv):
            user = c1.h.mr(mrn)
            sh._mrs[user.mrn] = _ShadowMR(user, pd.reg_mr(user.size))
    qp1 = c1.h.qp(c1.qpn)
    mr1 = c1.h.mr(c1.mrn_send)
    data = b"q" * msg_size
    t0 = time.perf_counter()
    done = 0
    wrid = 0
    while done < n_msgs:
        c2.post_recv(msg_size)
        mr1.write(0, data)
        wrid += 1
        wr = SendWR(wrid, Op.SEND, SGE(mr1, 0, msg_size))
        if sh is not None:
            sh.post_send(qp1, wr)
        else:
            qp1.post_send(wr)
        cl.run_until_idle()
        if sh is not None:
            sh.poll(c1.h.cq(c1.cqn), 8)
        else:
            c1.poll(8)
        c2.poll(8)
        done += 1
    dt = time.perf_counter() - t0
    return dt / n_msgs * 1e6, msg_size * n_msgs / dt / 1e6


def main():
    for size in (1024, 4096, 16384, 65536):
        lat_n, bw_n = _run(size, 40, shadowed=False)
        lat_s, bw_s = _run(size, 40, shadowed=True)
        print(f"fig8_native[{size}B],{lat_n:.1f},MBps={bw_n:.1f}")
        print(f"fig8_shadow[{size}B],{lat_s:.1f},MBps={bw_s:.1f},"
              f"overhead_pct={(lat_s-lat_n)/lat_n*100:.1f}")


if __name__ == "__main__":
    main()
