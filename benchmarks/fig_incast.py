"""Incast collapse and RNR backoff at a bounded ingress port.

Eight sendbw pairs converge on one receiver node (8:1 incast). With the
default unlimited ingress, receive processing is free and every sender
runs at its own egress rate — the failure mode the receiver-side port
model exists to expose (receive-processing cost is where kernel-path
RDMA designs pay; the migration protocol's RNR/retry machinery, paper
§3.4, is what keeps senders honest when the receiver can't keep up).
Bounding the receiver's ingress to one sender's rate makes the 8 flows
share it: per-sender goodput collapses (the incast signature), while
ingress-overflow RNR NAKs push senders into min_rnr_timer backoff so
the receiver's processing capacity stays busy with *useful* bytes —
aggregate goodput holds ≥90% of capacity instead of drowning in
retransmission duplicates.

Prints one CSV line per configuration plus per-sender goodput, then
asserts the acceptance bar: ≥2x per-sender collapse under bounded
ingress, ≥90% aggregate efficiency, and bit-identical results across
two bounded runs (rx_dropped and per-sender goodput).
"""
from repro.runtime.apps import SendBwApp
from repro.runtime.cluster import SimCluster
from repro.runtime.collectives import connect_pair

LINK_BPS = 2e8          # 200 B/step egress per node
RX_BPS = 2e8            # bounded run: receiver processes 1 sender's worth
QUEUE_BYTES = 64 * 1024  # bounded ingress queue shared by all senders
N_SENDERS = 8
MSG = 4096
WARMUP = 1000
MEASURE = 4000


def build(bounded: bool):
    cl = SimCluster(N_SENDERS + 1, link_bandwidth_Bps=LINK_BPS)
    if bounded:
        cl.configure_ingress(rx_bandwidth_Bps=RX_BPS,
                             queue_bytes=QUEUE_BYTES, node=0)
    receivers = []
    for i in range(N_SENDERS):
        A = cl.launch(f"s{i}", i + 1)
        B = cl.launch(f"r{i}", 0)
        aa = SendBwApp(msg_size=MSG, window=8)
        aa.attach(A, sender=True)
        A.app = aa
        ab = SendBwApp(msg_size=MSG, window=8)
        ab.attach(B, sender=False)
        B.app = ab
        connect_pair(aa.channels[0], ab.channels[0])
        receivers.append(ab)
    return cl, receivers


def run(bounded: bool):
    cl, receivers = build(bounded)
    for _ in range(WARMUP):
        cl.step_all()
    base = [r.received for r in receivers]
    t0 = cl.fabric.now
    for _ in range(MEASURE):
        cl.step_all()
    elapsed = cl.fabric.now - t0
    goodput = [r.received - b for r, b in zip(receivers, base)]
    # goodput measured on the wire: payload + per-MTU-packet headers
    wire_bytes_per_msg = MSG + (MSG // 1024) * 64
    agg_bytes = sum(goodput) * wire_bytes_per_msg
    capacity = elapsed * RX_BPS * cl.fabric.step_s()
    stats = cl.fabric.stats
    return {
        "goodput": goodput,
        "efficiency": agg_bytes / capacity,
        "rx_dropped": stats.get("rx_dropped@0", 0),
        "rx_queued": stats.get("rx_queued@0", 0),
        "rnr_naks": stats.get("rnr_naks@0", 0),
        "dup_acked": stats.get("rx_dup_acked@0", 0),
    }


def main():
    free = run(bounded=False)
    bound = run(bounded=True)
    bound2 = run(bounded=True)          # determinism witness

    print(f"fig_incast[unlimited],{min(free['goodput'])},"
          f"per_sender_msgs=min,max={max(free['goodput'])},"
          f"rnr_naks={free['rnr_naks']}")
    print(f"fig_incast[bounded],{min(bound['goodput'])},"
          f"per_sender_msgs=min,max={max(bound['goodput'])},"
          f"agg_efficiency={bound['efficiency']:.3f},"
          f"rx_dropped={bound['rx_dropped']},"
          f"rnr_naks={bound['rnr_naks']},"
          f"dup_acked={bound['dup_acked']}")
    worst_drop = min(free["goodput"]) / max(max(bound["goodput"]), 1)
    print(f"# 8:1 incast: per-sender goodput {min(free['goodput'])} -> "
          f"[{min(bound['goodput'])}, {max(bound['goodput'])}] msgs "
          f"(>= {worst_drop:.1f}x collapse); receiver kept "
          f"{bound['efficiency']:.0%} of ingress capacity busy with "
          f"useful bytes via RNR backoff")

    assert free["rnr_naks"] == 0 and free["rx_dropped"] == 0, \
        "unlimited ingress must never drop or NAK"
    assert all(g > 0 for g in bound["goodput"]), \
        "RNR backoff must shape senders, not starve them"
    # the incast signature: every sender loses >= 2x vs free receive
    assert max(bound["goodput"]) * 2 <= min(free["goodput"]), \
        f"expected >=2x per-sender collapse: {bound['goodput']} " \
        f"vs {free['goodput']}"
    # ... while RNR backoff keeps the receiver's capacity doing useful
    # work instead of processing retransmission duplicates
    assert bound["efficiency"] >= 0.9, \
        f"aggregate goodput {bound['efficiency']:.2%} of capacity < 90%"
    assert bound["rx_dropped"] > 0 and bound["rnr_naks"] > 0, \
        "bounded incast must exercise the overflow/RNR path"
    assert bound == bound2, "incast run must be deterministic"
    return {"efficiency": bound["efficiency"],
            "rx_dropped": bound["rx_dropped"],
            "rnr_naks": bound["rnr_naks"],
            "goodput_min": min(bound["goodput"]),
            "goodput_max": max(bound["goodput"]),
            "free_goodput_min": min(free["goodput"])}


if __name__ == "__main__":
    main()
