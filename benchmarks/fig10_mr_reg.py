"""Fig. 10: MR registration time vs region size (the OS-side pinning cost
scales with size; SoftRoCE skips the NIC-side mapping cost)."""
import time

from repro.runtime.cluster import SimCluster


def main():
    cl = SimCluster(1)
    ctx = cl.nodes[0].device.open_context()
    pd = ctx.alloc_pd()
    for size in (1 << 12, 1 << 16, 1 << 20, 1 << 24):
        n = 20 if size >= 1 << 20 else 200
        t0 = time.perf_counter()
        for _ in range(n):
            pd.reg_mr(size)
        us = (time.perf_counter() - t0) / n * 1e6
        print(f"fig10_mr_reg[{size}B],{us:.2f},us")


if __name__ == "__main__":
    main()
