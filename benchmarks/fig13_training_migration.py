"""Fig. 13: live migration of a distributed training job (the paper's NPB
MPI benchmarks): latency breakdown checkpoint/transfer/restore across
model sizes, plus the transparency check (loss unchanged)."""
import numpy as np

from repro.runtime.trainer import FabricTrainer


def main():
    # model size classes stand in for NPB size A/B/C
    for name, d_h in (("size_A", 64), ("size_B", 512), ("size_C", 2048)):
        ref = FabricTrainer(4, seed=5, d_h=d_h)
        l_ref = ref.train(6)

        mig = FabricTrainer(4, seed=5, d_h=d_h)
        for s in range(3):
            mig.step()
        rep = mig.cluster.migrate("rank1", len(mig.cluster.nodes) - 1)
        l_mig = [mig.step() for _ in range(3)]
        identical = l_ref[3:] == l_mig
        print(f"fig13_migration[{name}],{rep.total_s*1e6:.0f},"
              f"ckpt_us={rep.checkpoint_s*1e6:.0f},"
              f"xfer_sim_us={rep.simulated_transfer_s*1e6:.1f},"
              f"restore_us={rep.restore_s*1e6:.0f},"
              f"image_KiB={rep.image_bytes/1024:.0f},"
              f"bitwise_transparent={identical}")


if __name__ == "__main__":
    main()
