"""Table 1: magnitude of changes (SLOC). The paper counts diff lines per
component; we mark every migration-specific line with ``# [MIGR]`` and
count them against each component's total — same methodology, plus the
paper's key claim that QP-task (fast-path) changes are a tiny fraction.
"""
import os

SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")

COMPONENTS = {
    "verbs (kernel-level)": ["core/verbs.py", "core/states.py",
                             "core/packets.py"],
    "QP tasks": ["core/tasks.py"],
    "transport (SoftRoCE)": ["core/transport.py"],
    "C/R API (ibv dump/restore)": ["core/dump.py"],
    "CRIU (migration controller)": ["core/migration.py",
                                    "core/namespace.py"],
    "container runtime": ["runtime/cluster.py"],
    "user library (channels)": ["runtime/collectives.py"],
}


def count(path):
    total = migr = 0
    with open(path) as f:
        for line in f:
            s = line.strip()
            if not s or s.startswith("#"):
                continue
            total += 1
            if "[MIGR]" in line:
                migr += 1
    return total, migr


def rows():
    out = []
    for comp, files in COMPONENTS.items():
        t = m = 0
        for fn in files:
            a, b = count(os.path.join(SRC, fn))
            t += a
            m += b
        out.append((comp, t, m))
    return out


def main():
    rs = rows()
    total_t = sum(t for _, t, _ in rs)
    total_m = sum(m for _, _, m in rs)
    for comp, t, m in rs:
        print(f"table1_sloc[{comp}],{t},migr_delta={m}")
    qp_m = dict((c, m) for c, _, m in
                [(c, t, m) for c, t, m in rs])["QP tasks"]
    print(f"table1_sloc[TOTAL],{total_t},migr_delta={total_m},"
          f"qp_task_share={qp_m/max(total_m,1):.3f}")


if __name__ == "__main__":
    main()
