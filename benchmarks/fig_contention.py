"""Migration/application bandwidth contention on a shared link.

A sendbw pair streams node 0 -> node 1 at link saturation. Mid-run, a
bulk container (512 KiB of MRs) on node 0 is live-migrated to node 1:
its pre-copy page stream crosses the *same* (0, 1) link as the
application traffic, so app throughput dips while the migration streams
and recovers once it completes — the converged-dataplane behaviour the
in-fabric migration data plane exists to make visible (CoRD's argument;
paper §4 Fig. 12 moves images over the app links for the same reason).

Prints one CSV line per sampling window (msgs/kstep) tagged with its
phase, then the per-phase means. The assertions at the bottom are the
acceptance bar: a real dip (>20%) during the stream, recovery (>90% of
the pre-migration rate) after.
"""
from repro.core.verbs import PAGE_SIZE
from repro.runtime.apps import SendBwApp
from repro.runtime.cluster import SimCluster
from repro.runtime.collectives import connect_pair

LINK_BPS = 2e8          # 200 B/step: the app alone saturates the link
BULK_PAGES = 128        # 512 KiB container footprint to migrate
WIN = 200               # sampling window (fabric steps)


def _saturating_pair(cl):
    A = cl.launch("send", 0)
    B = cl.launch("recv", 1)
    aa = SendBwApp(msg_size=4096, window=8)
    aa.attach(A, sender=True)
    A.app = aa
    ab = SendBwApp(msg_size=4096, window=8)
    ab.attach(B, sender=False)
    B.app = ab
    connect_pair(aa.channels[0], ab.channels[0])
    return aa, ab


def run():
    cl = SimCluster(3, link_bandwidth_Bps=LINK_BPS)
    aa, ab = _saturating_pair(cl)
    bulk = cl.launch("bulk", 0)
    mr = bulk.ctx.alloc_pd().reg_mr(BULK_PAGES * PAGE_SIZE)
    for pg in range(BULK_PAGES):
        mr.write(pg * PAGE_SIZE, bytes([pg % 251]) * PAGE_SIZE)

    samples = []
    state = {"t": 0, "recv": 0}

    def record():
        t = cl.fabric.now
        if t - state["t"] >= WIN:
            samples.append((t, (ab.received - state["recv"])
                            / (t - state["t"])))
            state["t"], state["recv"] = t, ab.received

    def tick():
        cl.step_all()
        record()

    for _ in range(1500):                    # warm up to steady state
        tick()
    t_mig0 = cl.fabric.now
    cl.orchestrator.background = tick        # sample through the live phase
    rep = cl.migrate("bulk", 1, strategy="pre_copy")
    assert rep.ok
    t_mig1 = cl.fabric.now
    for _ in range(3000):
        tick()

    def phase(t):
        if t <= t_mig0:
            return "before"
        return "during" if t <= t_mig1 else "after"

    rates = {"before": [], "during": [], "after": []}
    for t, r in samples:
        rates[phase(t)].append(r)
    return cl, rep, rates, (t_mig0, t_mig1), samples


def main():
    cl, rep, rates, (t0, t1), samples = run()
    for t, r in samples:
        ph = "before" if t <= t0 else ("during" if t <= t1 else "after")
        print(f"fig_contention[{ph}@{t}],{r*1000:.1f},msgs_per_kstep")
    mean = {ph: sum(v) / max(len(v), 1) for ph, v in rates.items()}
    dip = min(rates["during"]) if rates["during"] else 0.0
    print(f"# before={mean['before']*1000:.1f} during={mean['during']*1000:.1f} "
          f"after={mean['after']*1000:.1f} dip={dip*1000:.1f} msgs/kstep; "
          f"migration {t1-t0} steps, {rep.pages_sent} pages, "
          f"mig_bytes={cl.fabric.stats['mig_tx_bytes']}")
    # skip the first post-migration window: it straddles the cutover
    settled = rates["after"][1:] or rates["after"]
    recovered = sum(settled) / len(settled)
    assert rates["during"], "migration finished without sampling a window"
    assert dip < 0.8 * mean["before"], \
        "migration stream should visibly dent app throughput"
    assert recovered > 0.9 * mean["before"], \
        "app throughput should recover after the migration"
    return {"rate_before": mean["before"], "rate_during": mean["during"],
            "rate_after": mean["after"], "dip": dip,
            "migration_steps": t1 - t0, "pages_sent": rep.pages_sent,
            "mig_tx_bytes": cl.fabric.stats["mig_tx_bytes"]}


if __name__ == "__main__":
    main()
