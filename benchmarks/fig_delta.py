"""Migration wire bytes with the delta-aware page codec on vs off.

A sparse-dirtying container — a 256 KiB MR whose footprint is mostly
zero pages plus a band of identical (dedupable) pages and a band of
pseudorandom pages that keep taking small in-place writes — is migrated
with pre-copy under both codec settings. The codec-off run ships every
page in full every round; the codec-on run elides the zero region,
dedups the identical band, and ships re-dirtied pages as XOR+zlib
deltas, so the migration-class wire bytes (``mig_tx_bytes``) and the
sim-clock ``transfer_s`` both drop.

The assertions at the bottom are the acceptance bar: >= 3x wire-byte
reduction, strictly lower ``transfer_s``, the ``sum(name@gid) == name``
counter-twin invariant on the new codec counters, and run-twice
determinism of the codec-on run (bit-identical wire bytes, counters,
and report floats).
"""
import random

from repro.core.verbs import PAGE_SIZE
from repro.runtime.cluster import SimCluster

LINK_BPS = 1e8
N_PAGES = 64            # 256 KiB MR
DUP_PAGES = range(8, 24)     # identical content, any-offset dedup
HOT_PAGES = range(24, 40)    # pseudorandom content, sparse re-dirtying
#   pages 0..8 and 40..64 stay all-zero -> PAGE_ZERO elision

_DUP_BLOCK = bytes(range(256)) * (PAGE_SIZE // 256)


class SparseWriter:
    """Sparse-dirtying workload: every step rewrites a handful of bytes
    inside the hot band (through ``mr.write`` so dirty tracking sees
    it), leaving each touched page one tiny XOR-delta away from its
    last-sent snapshot."""

    def __init__(self, seed: int = 42):
        self.container = None
        self.mr = None
        self.mrn = None
        self.ticks = 0
        self._hot = {pg: random.Random(seed + pg).randbytes(PAGE_SIZE)
                     for pg in HOT_PAGES}

    def attach(self, container):
        self.container = container
        pd = container.ctx.alloc_pd()
        self.mr = pd.reg_mr(N_PAGES * PAGE_SIZE)
        self.mrn = self.mr.mrn
        for pg in DUP_PAGES:
            self.mr.write(pg * PAGE_SIZE, _DUP_BLOCK)
        for pg, blob in self._hot.items():
            self.mr.write(pg * PAGE_SIZE, blob)

    def rebind(self, container, session):
        self.mr = session.mr_by_n[self.mrn]

    def step(self):
        self.ticks += 1
        for i in range(4):
            pg = HOT_PAGES.start + (self.ticks + i * 5) % len(HOT_PAGES)
            off = pg * PAGE_SIZE + (self.ticks * 17 + i * 64) % \
                (PAGE_SIZE - 8)
            self.mr.write(off, self.ticks.to_bytes(8, "little"))

    def checkpoint(self) -> bytes:
        return self.ticks.to_bytes(8, "little")

    def restore(self, blob: bytes):
        self.ticks = int.from_bytes(blob, "little")

    def verify(self):
        """Installed image must equal the source pattern: the zero and
        dup bands are never written after attach, so any codec slip
        (stale dedup hit, bad delta base) shows up here."""
        buf = self.mr.buf
        assert bytes(buf[:8 * PAGE_SIZE]) == bytes(8 * PAGE_SIZE)
        assert bytes(buf[40 * PAGE_SIZE:]) == bytes(24 * PAGE_SIZE)
        for pg in DUP_PAGES:
            assert bytes(buf[pg * PAGE_SIZE:(pg + 1) * PAGE_SIZE]) \
                == _DUP_BLOCK, f"dup page {pg} corrupted"


def run_once(codec: bool):
    cl = SimCluster(3, link_bandwidth_Bps=LINK_BPS)
    if codec:
        cl.configure_codec(enabled=True)
    c = cl.launch("sparse", 0)
    app = SparseWriter()
    app.attach(c)
    c.app = app
    for _ in range(30):
        cl.step_all()
    w0 = cl.fabric.stats.get("mig_tx_bytes", 0)
    rep = cl.migrate("sparse", 1, strategy="pre_copy")
    wire = cl.fabric.stats.get("mig_tx_bytes", 0) - w0
    for _ in range(40):
        cl.step_all()
    assert rep.ok, "migration failed"
    app.verify()
    counters = {k: v for k, v in cl.fabric.stats.items()
                if k.startswith(("pages_zero_elided", "pages_dedup_hits",
                                 "delta_bytes_saved", "codec_cutovers"))}
    sums = cl.fabric.metrics.node_twin_sums()
    for name, (bare, twin) in sums.items():
        assert bare == twin, f"twin invariant broken for {name}"
    return {"wire_bytes": wire, "transfer_s": rep.transfer_s,
            "downtime_s": rep.downtime_s, "rounds": len(rep.rounds),
            "pages_sent": rep.pages_sent, "counters": counters}


def main():
    off = run_once(codec=False)
    on = run_once(codec=True)
    again = run_once(codec=True)
    assert on == again, "codec-on run is not deterministic across runs"
    ratio = off["wire_bytes"] / max(on["wire_bytes"], 1)
    print(f"fig_delta[off],{off['wire_bytes']},"
          f"transfer_us={off['transfer_s']*1e6:.0f},"
          f"rounds={off['rounds']},pages={off['pages_sent']}")
    print(f"fig_delta[on],{on['wire_bytes']},"
          f"transfer_us={on['transfer_s']*1e6:.0f},"
          f"rounds={on['rounds']},pages={on['pages_sent']},"
          f"zero={on['counters'].get('pages_zero_elided', 0)},"
          f"dup={on['counters'].get('pages_dedup_hits', 0)},"
          f"delta_saved={on['counters'].get('delta_bytes_saved', 0)}")
    print(f"# wire reduction {ratio:.1f}x")
    assert ratio >= 3.0, \
        f"codec must cut migration wire bytes >=3x (got {ratio:.2f}x)"
    assert on["transfer_s"] < off["transfer_s"], \
        "encoded rounds must serialise strictly faster"
    assert on["counters"].get("pages_zero_elided", 0) > 0
    assert on["counters"].get("pages_dedup_hits", 0) > 0
    return {"wire_bytes_off": off["wire_bytes"],
            "wire_bytes_on": on["wire_bytes"],
            "reduction_x": round(ratio, 2),
            "transfer_s_off": off["transfer_s"],
            "transfer_s_on": on["transfer_s"],
            "counters_on": on["counters"]}


if __name__ == "__main__":
    main()
