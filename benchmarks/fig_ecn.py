"""DCQCN congestion control taming the 8:1 incast.

Same setup as ``fig_incast`` — eight sendbw pairs converge on one
receiver whose ingress processes one sender's worth of bytes — run in
three regimes:

* ``no_ecn``      — loss-driven feedback only (the fig_incast regime,
                    IBA retry-forever): the queue overflows, RNR NAKs
                    park senders, and the NAK count grows linearly for
                    as long as the workload runs.
* ``no_ecn_ff``   — same, but with the finite RNR retry budget a
                    fail-fast operator would set: incast losers whose
                    windows keep dropping at admission burn their
                    budget and die with ``RNR_RETRY_EXC_ERR``.
* ``dcqcn``       — ECN enabled (default knobs): the ingress queue
                    RED-marks ECT packets at ~80% occupancy, responders
                    answer marked arrivals with CNPs, every sender's
                    reaction point cuts multiplicatively and recovers
                    on the DCQCN timers — and an RNR NAK counts as the
                    *severe* congestion cut, so admission-dropped flows
                    get feedback too. Senders converge to stable rates
                    near the fair share, the RNR machinery goes nearly
                    silent, and the same tight retry budget never
                    exhausts.

Prints one CSV line per regime, then asserts the acceptance bar: with
ECN the incast emits >=5x fewer RNR NAKs than the retry-forever
baseline, zero retry exhaustion (vs real exhaustion without ECN),
per-sender reaction-point rates converge below line rate while summing
to roughly the receiver's capacity — and two ECN runs are bit-identical
(marking rides per-port rngs seeded off the fabric seed).
"""
from repro.core.states import QPState
from repro.runtime.apps import SendBwApp
from repro.runtime.cluster import SimCluster
from repro.runtime.collectives import connect_pair

LINK_BPS = 2e8          # 200 B/step egress per node
RX_BPS = 2e8            # receiver processes one sender's worth
QUEUE_BYTES = 64 * 1024  # bounded ingress queue shared by all senders
N_SENDERS = 8
MSG = 4096
RNR_RETRY = 4           # finite budget: exhaustion is reachable
STEPS = 8000


def build(ecn: bool, rnr_retry: int):
    cl = SimCluster(N_SENDERS + 1, link_bandwidth_Bps=LINK_BPS)
    cl.configure_ingress(rx_bandwidth_Bps=RX_BPS,
                         queue_bytes=QUEUE_BYTES, node=0)
    if ecn:
        cl.configure_ecn(enabled=True)
    receivers = []
    for i in range(N_SENDERS):
        A = cl.launch(f"s{i}", i + 1)
        B = cl.launch(f"r{i}", 0)
        aa = SendBwApp(msg_size=MSG, window=8)
        aa.attach(A, sender=True)
        A.app = aa
        ab = SendBwApp(msg_size=MSG, window=8)
        ab.attach(B, sender=False)
        B.app = ab
        connect_pair(aa.channels[0], ab.channels[0])
        receivers.append(ab)
    cl.configure_rnr(rnr_retry=rnr_retry)
    return cl, receivers


def run(ecn: bool, rnr_retry: int = RNR_RETRY):
    cl, receivers = build(ecn, rnr_retry)
    containers = list(cl.containers.values())
    error = QPState.ERROR
    for _ in range(STEPS):
        # a real application stops touching a QP once RNR_RETRY_EXC_ERR
        # errors it — fence dead senders instead of re-posting into them
        for c in containers:
            for qp in c.ctx.qps:
                if qp.state == error:
                    break
            else:
                c.step()
        cl.pump()
    stats = cl.fabric.stats
    # reaction-point rates of the eight sender QPs (bytes/step)
    rates = []
    for i in range(N_SENDERS):
        qp = cl.containers[f"s{i}"].ctx.qps[0]
        rates.append(qp.cc.rc if qp.cc is not None else None)
    return {
        "goodput": [r.received for r in receivers],
        "rnr_naks": stats.get("rnr_naks@0", 0),
        "rx_dropped": stats.get("rx_dropped@0", 0),
        "exhausted": stats.get("rnr_retries_exhausted", 0),
        "ecn_marked": stats.get("ecn_marked", 0),
        "cnps_sent": stats.get("cnps_sent", 0),
        "cnps_handled": stats.get("cnps_handled", 0),
        "rates": rates,
        "now": cl.fabric.now,
        # the fabric's own step conversion, so the rate assertions
        # cannot silently disagree with a retuned transport.STEP_S
        "line": cl.fabric.bytes_per_step,
        "rx_per_step": RX_BPS * cl.fabric.step_s(),
    }


def _line(tag, r, extra=""):
    print(f"fig_ecn[{tag}],{r['rnr_naks']},rnr_naks,"
          f"rx_dropped={r['rx_dropped']},exhausted={r['exhausted']},"
          f"goodput={min(r['goodput'])}-{max(r['goodput'])}{extra}")


def main():
    base = run(ecn=False, rnr_retry=7)      # IBA retry forever
    ff = run(ecn=False)                     # fail-fast budget, no ECN
    ecn = run(ecn=True)                     # same budget, DCQCN
    ecn2 = run(ecn=True)                    # determinism witness

    line_rate = ecn["line"]                 # bytes/step
    fair = ecn["rx_per_step"] / N_SENDERS
    _line("no_ecn", base)
    _line("no_ecn_ff", ff)
    rates = [f"{r:.1f}" for r in ecn["rates"]]
    _line("dcqcn", ecn,
          extra=f",marked={ecn['ecn_marked']},cnps={ecn['cnps_handled']},"
                f"rates_Bstep=[{','.join(rates)}]")
    ratio = base["rnr_naks"] / max(ecn["rnr_naks"], 1)
    print(f"# DCQCN: {base['rnr_naks']} -> {ecn['rnr_naks']} RNR NAKs "
          f"({ratio:.1f}x fewer); retry budget {RNR_RETRY} exhausts "
          f"{ff['exhausted']} times without ECN, 0 with; per-sender "
          f"rates converged to {min(ecn['rates']):.1f}-"
          f"{max(ecn['rates']):.1f} B/step "
          f"(fair share {fair:.1f}, line {line_rate:.0f})")

    assert base["exhausted"] == 0 and base["ecn_marked"] == 0
    assert ff["exhausted"] > 0, \
        "a finite RNR budget must be exhaustible under raw incast " \
        "(otherwise the DCQCN run proves nothing)"
    # ECN resolves the congestion the RNR machinery otherwise absorbs
    assert ecn["ecn_marked"] > 0 and ecn["cnps_handled"] > 0, \
        "the incast must exercise the marking/CNP path"
    assert ecn["rnr_naks"] * 5 <= base["rnr_naks"], \
        f"expected >=5x fewer RNR NAKs: {base['rnr_naks']} -> " \
        f"{ecn['rnr_naks']}"
    assert ecn["exhausted"] == 0, \
        "DCQCN must keep every sender inside its RNR retry budget"
    assert all(g > 0 for g in ecn["goodput"]), \
        "rate control must pace senders, not starve them"
    # converged: every reaction point learned a rate well below line,
    # and the aggregate lands near the receiver's capacity
    assert all(r is not None and 0 < r < line_rate / 2
               for r in ecn["rates"]), \
        f"per-sender rates must converge below line rate: {ecn['rates']}"
    agg = sum(ecn["rates"])
    assert 0.4 * ecn["rx_per_step"] <= agg <= 2.0 * ecn["rx_per_step"], \
        f"aggregate learned rate {agg:.1f} B/step far from capacity"
    assert ecn == ecn2, "ECN run must be deterministic"
    return {"base_rnr_naks": base["rnr_naks"],
            "ecn_rnr_naks": ecn["rnr_naks"],
            "ecn_marked": ecn["ecn_marked"],
            "cnps_handled": ecn["cnps_handled"],
            "agg_rate_B_per_step": sum(ecn["rates"]),
            "rx_per_step": ecn["rx_per_step"]}


if __name__ == "__main__":
    main()
