"""Table 2: per-object dump sizes (bytes) for PD/MR/CQ/SRQ/QP/QP-with-SRQ,
plus the full container checkpoint image raw vs codec-encoded (what a
``configure_codec``-enabled migration actually puts on the wire)."""
import msgpack

from repro.core import dump as dumplib
from repro.core import pagecodec
from repro.core.pagecodec import CodecConfig
from repro.core.verbs import RecvWR, SGE
from repro.runtime.cluster import SimCluster
from tests.helpers import make_channel_pair


def main():
    cl = SimCluster(2)
    c1, c2, ca, cb = make_channel_pair(cl)
    # put a QP mid-message so "current WQE state" is populated
    c2.post_recv(4096)
    c1.post_send_bytes(b"z" * 4096)
    cl.pump(2)
    ctx = ca.ctx
    srq = ctx.create_srq()
    mr = ctx.mrs[0]
    srq.post(RecvWR(1, SGE(mr, 0, 128)))
    pd2 = ctx.alloc_pd()
    cq2 = ctx.create_cq()
    qp_srq = pd2.create_qp(cq2, cq2, srq)

    sizes = {
        "PD": len(msgpack.packb(dumplib.dump_object(ctx.pds[0]))),
        "MR": len(msgpack.packb(dumplib.dump_object(ctx.mrs[0]))),
        "CQ": len(msgpack.packb(dumplib.dump_object(ctx.cqs[0]))),
        "SRQ": len(msgpack.packb(dumplib.dump_object(srq))),
        "QP": len(msgpack.packb(dumplib.dump_object(ctx.qps[0]))),
        "QP_w_SRQ": len(msgpack.packb(dumplib.dump_object(qp_srq))),
    }
    # whole-container checkpoint image: raw (what the codec-less stream
    # serialises) vs encoded (zlib via pagecodec.encode_image — the
    # MIG_STATE payload under configure_codec)
    image = cl.migrator._checkpoint(cl.containers["a"])
    encoded = pagecodec.encode_image(image, CodecConfig(enabled=True))
    sizes["image"] = len(image)
    sizes["image_encoded"] = len(encoded)
    for k, v in sizes.items():
        print(f"table2_dump_size[{k}],{v},bytes")
    return sizes


if __name__ == "__main__":
    main()
