"""§Roofline summary from the dry-run JSONL (benchmarks view of the
40-cell × 2-mesh table)."""
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(path="dryrun_baseline.jsonl"):
    fn = os.path.join(RESULTS, path)
    if not os.path.exists(fn):
        return []
    return [json.loads(l) for l in open(fn)]


def main():
    recs = [r for r in load() if "error" not in r]
    if not recs:
        print("roofline_table[missing],0,run_dryrun_first")
        return
    for r in recs:
        if r["mesh"] != "16x16":
            continue
        rl = r["roofline"]
        print(f"roofline[{r['arch']}|{r['shape']}],"
              f"{rl['step_s']*1e6:.0f},"
              f"compute_ms={rl['compute_s']*1e3:.2f},"
              f"memory_ms={rl['memory_s']*1e3:.2f},"
              f"collective_ms={rl['collective_s']*1e3:.2f},"
              f"bottleneck={rl['bottleneck']},"
              f"useful={rl['useful_ratio']:.2f}")
    mp = sum(1 for r in recs if r["mesh"] == "2x16x16")
    sp = sum(1 for r in recs if r["mesh"] == "16x16")
    print(f"roofline[dryrun_cells],{sp+mp},single_pod={sp},multi_pod={mp}")


if __name__ == "__main__":
    main()
