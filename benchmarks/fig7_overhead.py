"""Fig. 7: does migratability cost anything when NOT migrating?

Measures fabric message throughput/latency with (a) the migratable QP
tasks and (b) stripped variants with every # [MIGR] branch removed, on the
same workload. The paper's claim: indistinguishable.
"""
import time

from repro.core import tasks as T
from repro.core.packets import NakCode, Op
from repro.core.states import QPState, can_receive, can_send
from repro.runtime.cluster import SimCluster
from tests.helpers import make_sendbw_pair


def _requester_stripped(qp):
    """requester() with the migration branches removed."""
    now = qp.device.fabric.now
    if not can_send(qp.state):
        return
    if qp.inflight and now - qp.last_progress > qp.rto:
        for pkt in qp.inflight:
            T._retx(qp, pkt)
        qp.last_progress = now
        qp.rto = min(qp.rto * 2, qp.RETRANS_TIMEOUT * 64)
        return
    budget = qp.WINDOW - len(qp.inflight)
    while budget > 0:
        if qp.cur_wqe is None:
            if not qp.sq:
                return
            qp.cur_wqe = qp.sq.popleft()
            qp.cur_wqe.first_psn = qp.sq_psn
        wr = qp.cur_wqe
        chunk = min(qp.MTU, wr.sge.length - wr.sent)
        payload = wr.sge.mr.read(wr.sge.offset + wr.sent, chunk)
        first = wr.sent == 0
        last = wr.sent + chunk >= wr.sge.length
        pkt = T._mk(qp, wr.opcode, psn=qp.sq_psn, payload=payload,
                    first=first, last=last, wr_id=wr.wr_id,
                    raddr=wr.raddr + wr.sent, rkey=wr.rkey,
                    length=wr.sge.length)
        wr.sent += chunk
        wr.last_psn = qp.sq_psn
        qp.sq_psn += 1
        qp.inflight.append(pkt)
        T._emit(qp, pkt)
        budget -= 1
        if last:
            qp.pending_comp.append((wr.last_psn, wr.wr_id,
                                    wr.opcode.value, wr.sge.length))
            qp.cur_wqe = None


def _bench(steps=1500):
    cl = SimCluster(2)
    aa, ab = make_sendbw_pair(cl, msg_size=2048, window=16)
    t0 = time.perf_counter()
    for _ in range(steps):
        cl.step_all()
    dt = time.perf_counter() - t0
    return ab.received / dt, dt / max(ab.received, 1) * 1e6


def main():
    orig = T.requester
    msgs_m, lat_m = _bench()
    T.requester = _requester_stripped
    try:
        msgs_s, lat_s = _bench()
    finally:
        T.requester = orig
    over = (lat_m - lat_s) / lat_s * 100
    print(f"fig7_throughput[migratable],{lat_m:.2f},msgs_per_s={msgs_m:.0f}")
    print(f"fig7_throughput[stripped],{lat_s:.2f},msgs_per_s={msgs_s:.0f}")
    print(f"fig7_overhead_pct,{over:.2f},claim=no_measurable_overhead")


if __name__ == "__main__":
    main()
