"""Noisy-neighbor isolation on a shared NIC port (QoS scheduler figure).

The scenario the per-node egress-port model exists to expose: a bursting
tenant streams node 0 -> node 2 at port saturation while a container on
node 0 is live-migrated to node 1. Under the old per-(src,dest) link
model these two flows never met; on a real NIC they share node 0's
egress port, so the burst steals bandwidth from the migration stream —
the *Noisy Neighbor* failure mode (arXiv:2510.12629).

Three runs, identical except for contention and the scheduler:

  base    — migration alone (uncontended): transfer time T0.
  noisy   — burst + migration, QoS disabled: the burst and the stream
            split the FIFO port, migration slows unboundedly (nothing
            stops N tenants from making it N+1 times slower).
  qos     — burst + migration, QoS enabled: the bursting tenant is
            token-bucketed to a fraction of the port and the migration
            class carries a bandwidth guarantee; migration time must stay
            within 1.5x of the uncontended run (the acceptance bar),
            while the tenant keeps making progress (bounded, not
            starved).

All times are fabric sim-clock deltas (deterministic across runs).
"""
from repro.core.qos import QoSConfig
from repro.core.transport import STEP_S
from repro.core.verbs import PAGE_SIZE
from repro.runtime.apps import SendBwApp
from repro.runtime.cluster import SimCluster
from repro.runtime.collectives import connect_pair

LINK_BPS = 2e8          # 200 B/step egress port; the burst saturates it
BULK_PAGES = 128        # 512 KiB container footprint to migrate
NOISY_RATE = 0.15 * LINK_BPS    # tenant bucket: 15% of the port
MIG_GUARANTEE = 0.8             # migration class floor when backlogged


def _burst_pair(cl):
    """Bursting tenant: node 0 -> node 2, windowed at saturation."""
    A = cl.launch("noisy", 0)
    B = cl.launch("noisy-sink", 2)
    aa = SendBwApp(msg_size=4096, window=16)
    aa.attach(A, sender=True)
    A.app = aa
    ab = SendBwApp(msg_size=4096, window=16)
    ab.attach(B, sender=False)
    B.app = ab
    connect_pair(aa.channels[0], ab.channels[0])
    return aa, ab


def run(*, contended: bool, qos: bool):
    cfg = None
    if qos:
        cfg = QoSConfig(enabled=True, migration_guarantee=MIG_GUARANTEE,
                        tenant_rate_Bps={"noisy": NOISY_RATE})
    cl = SimCluster(3, link_bandwidth_Bps=LINK_BPS, qos=cfg)
    ab = None
    if contended:
        aa, ab = _burst_pair(cl)
    bulk = cl.launch("bulk", 0)
    mr = bulk.ctx.alloc_pd().reg_mr(BULK_PAGES * PAGE_SIZE)
    for pg in range(BULK_PAGES):
        mr.write(pg * PAGE_SIZE, bytes([pg % 251]) * PAGE_SIZE)

    for _ in range(500):                     # warm the burst to saturation
        cl.step_all()
    recv_before = ab.received if ab else 0
    t0 = cl.fabric.now
    cl.orchestrator.background = cl.step_all   # burst runs through the live phase
    rep = cl.migrate("bulk", 1, strategy="pre_copy")
    assert rep.ok, f"migration failed: {rep}"
    transfer_s = (cl.fabric.now - t0) * STEP_S
    recv_during = (ab.received - recv_before) if ab else 0
    for _ in range(300):
        cl.step_all()
    return cl, rep, transfer_s, recv_during


def main():
    _, _, t_base, _ = run(contended=False, qos=False)
    cl_no, _, t_noqos, recv_noqos = run(contended=True, qos=False)
    cl_q, _, t_qos, recv_qos = run(contended=True, qos=True)

    print(f"fig_qos[base],{t_base*1e6:.0f},transfer_us")
    print(f"fig_qos[noisy_no_qos],{t_noqos*1e6:.0f},transfer_us,"
          f"x{t_noqos/t_base:.2f},tenant_msgs={recv_noqos}")
    print(f"fig_qos[noisy_qos],{t_qos*1e6:.0f},transfer_us,"
          f"x{t_qos/t_base:.2f},tenant_msgs={recv_qos}")
    print(f"# bucket_deferrals={cl_q.fabric.stats['qos_bucket_deferrals']}"
          f" app_tx={cl_q.fabric.stats['app_tx_bytes']}"
          f" mig_tx={cl_q.fabric.stats['mig_tx_bytes']}")

    # the problem is real: an unscheduled burst slows the migration well
    # past the isolation bar
    assert t_noqos > 1.5 * t_base, \
        f"burst should visibly slow the unscheduled migration " \
        f"({t_noqos/t_base:.2f}x)"
    # the acceptance bar: buckets + guarantee bound the burst's impact
    assert t_qos <= 1.5 * t_base, \
        f"QoS must bound migration slowdown to 1.5x " \
        f"(got {t_qos/t_base:.2f}x)"
    # bounded, not starved: the throttled tenant still makes progress
    assert recv_qos > 0, "token bucket must shape, not starve, the tenant"
    return {"base_transfer_s": t_base, "noqos_transfer_s": t_noqos,
            "qos_transfer_s": t_qos,
            "bucket_deferrals": cl_q.fabric.stats["qos_bucket_deferrals"],
            "tenant_msgs": recv_qos}


if __name__ == "__main__":
    main()
