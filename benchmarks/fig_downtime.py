"""Downtime vs total migration time across live-migration strategies.

A write-heavy streaming pair (ib_send_bw-style: the receiver's MR is
continuously written by inbound traffic) is migrated mid-stream under each
strategy. Stop-and-copy pays the full MR footprint inside the
stop-the-world window; pre-copy moves the footprint while the app keeps
running and stops only for the residual dirty set + verbs state; post-copy
stops only for the verbs image and faults pages in afterwards.

Columns: downtime vs total, both read off the fabric sim clock — the
stop window and every byte of checkpoint/page traffic is measured as it
streams over the bandwidth-limited links (deterministic across runs).
The assertion at the bottom is the acceptance bar: pre-copy downtime
strictly below stop-and-copy's total.
"""
from repro.core.transport import STEP_S
from repro.runtime.apps import SendBwApp
from repro.runtime.cluster import SimCluster
from repro.runtime.collectives import connect_pair

LINK_BPS = 1e8          # 100 MB/s link: bandwidth dominates, deterministic
BUF_KIB = 256           # per-MR footprint of the migrated container


def _write_heavy_pair(cl):
    A = cl.launch("send", 0)
    B = cl.launch("recv", 1)
    aa = SendBwApp(msg_size=4096, window=16, buf_size=BUF_KIB * 1024)
    aa.attach(A, sender=True)
    A.app = aa
    ab = SendBwApp(msg_size=4096, window=16, buf_size=BUF_KIB * 1024)
    ab.attach(B, sender=False)
    B.app = ab
    connect_pair(aa.channels[0], ab.channels[0])
    return aa, ab


def run_strategy(strategy, trace=False):
    """One migration scenario. ``trace=True`` enables the fabric tracer
    and grows the return tuple with the cluster, so callers (the obs
    tests, ``tools/trace_report.py``) can read the event stream; the
    default 4-tuple is unchanged for existing callers."""
    cl = SimCluster(3, link_bandwidth_Bps=LINK_BPS)
    if trace:
        cl.configure_tracing(True)
    aa, ab = _write_heavy_pair(cl)
    for _ in range(80):
        cl.step_all()
    rep = cl.migrate("recv", 2, strategy=strategy)
    for _ in range(300):
        cl.step_all()
    post_pull_s = 0.0
    if rep.pager is not None:              # drain post-copy in background
        t0 = cl.fabric.now
        while rep.pager.remaining_pages:
            rep.pager.prefetch(16)
            cl.fabric.pump()               # pulls serialise on the wire
        cl.run_until_idle(max_steps=500_000)
        post_pull_s = (cl.fabric.now - t0) * STEP_S
    downtime = rep.downtime_s              # sim clock, stop window only
    total = rep.downtime_s + rep.live_s + post_pull_s
    if trace:
        return rep, downtime, total, ab, cl
    return rep, downtime, total, ab


def main():
    results = {}
    for name in ("stop_and_copy", "pre_copy", "post_copy"):
        rep, downtime, total, ab = run_strategy(name)
        results[name] = (rep, downtime, total)
        print(f"fig_downtime[{name}],{downtime*1e6:.0f},"
              f"total_us={total*1e6:.0f},"
              f"image_KiB={rep.image_bytes/1024:.0f},"
              f"rounds={len(rep.rounds)},"
              f"pages_sent={rep.pages_sent},"
              f"received_after={ab.received}")
    sc_total = results["stop_and_copy"][2]
    pre_down = results["pre_copy"][1]
    post_down = results["post_copy"][1]
    print(f"# pre_copy downtime {pre_down*1e6:.0f}us vs "
          f"stop_and_copy total {sc_total*1e6:.0f}us "
          f"({sc_total/pre_down:.1f}x); post_copy downtime "
          f"{post_down*1e6:.0f}us")
    assert pre_down < sc_total, \
        "pre-copy downtime must beat stop-and-copy total"
    assert post_down < sc_total
    return {name: {"downtime_s": downtime, "total_s": total,
                   "image_bytes": rep.image_bytes,
                   "rounds": len(rep.rounds),
                   "pages_sent": rep.pages_sent}
            for name, (rep, downtime, total) in results.items()}


if __name__ == "__main__":
    main()
