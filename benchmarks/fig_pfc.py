"""PFC lossless fabric taming the 8:1 incast, with per-priority ECN.

Same incast as ``fig_incast``/``fig_ecn`` — eight sendbw pairs converge
on one receiver whose bounded ingress processes one sender's worth of
bytes — run in three regimes:

* ``lossy``     — the fig_incast baseline: the shared ingress queue
                  overflows, reliable requests drop, RNR NAKs park the
                  senders (loss-driven feedback).
* ``lossless``  — PFC enabled: the queue crossing a class's XOFF
                  watermark broadcasts PAUSE frames, senders latch the
                  pause per (destination, class) and hold off the wire
                  until XON (or the latch lifetime). Nothing reliable
                  drops, no RNR NAK fires, and the receiver still runs
                  at full processing capacity — the pause/resume duty
                  cycle never lets the queue empty.
* ``lossless_prio`` — PFC + QoS classes + *per-priority* knobs: shallow
                  PFC watermarks and early RED thresholds for app
                  flows, deep ones for migration bulk — while a
                  pre-copy migration streams its image into the
                  congested receiver. Each class polices its own
                  backlog share, so DCQCN + the shallow band hold the
                  app class to a short standing queue while the
                  migration class absorbs its burst in a deep one —
                  the per-priority deployment stack real RoCE fabrics
                  run.

Prints one CSV line per regime, then asserts the acceptance bar:
lossless records zero reliable-request drops and zero RNR NAKs with
aggregate receiver goodput >= 90% of processing capacity; per-priority
ECN keeps the app class's p99 ingress queue occupancy below the
migration class's; and the lossless_prio run is bit-reproducible.
"""
from repro.runtime.apps import SendBwApp
from repro.runtime.cluster import SimCluster
from repro.runtime.collectives import connect_pair
from repro.core.qos import QoSConfig

LINK_BPS = 2e8          # 200 B/step egress per node
RX_BPS = 2e8            # receiver processes one sender's worth
QUEUE_BYTES = 64 * 1024  # bounded ingress queue shared by all senders
N_SENDERS = 8
MSG = 4096
STEPS = 8000
WARMUP = 2000           # goodput is measured on the steady-state tail
BULK_BYTES = 256 * 1024  # migrated container's memory (mig-class bytes)
# per-priority RED thresholds: mark app flows early (short queue), let
# migration bulk ride a deep standing queue
PER_CLASS = {"app": (0.10, 0.50, 0.30), "mig": (0.70, 1.00, 0.10)}
# per-priority PFC watermarks to match: the app class pauses off a
# shallow band, the migration class absorbs its pre-copy burst in a
# deep one (each class polices its own backlog share of the queue)
XOFF = {"app": 0.30, "mig": 0.85}
XON = {"app": 0.12, "mig": 0.55}


class _ClassOccupancySampler:
    """Container app for the receiver node: samples the per-class
    ingress backlog each driver step (the orchestrator's background
    step_all keeps it sampling *during* the migrate call too)."""

    def __init__(self, fabric, gid: int):
        self.fabric = fabric
        self.gid = gid
        self.samples = {"app": [], "mig": []}

    def step(self):
        iport = self.fabric.ingress_port(self.gid)
        for cls in ("app", "mig"):
            cq = iport.classes.get(cls)
            occ = 0.0 if cq is None \
                else cq.backlog_bytes / iport.cfg.queue_bytes
            self.samples[cls].append(occ)


def _p99(values):
    s = sorted(values)
    return s[int(0.99 * (len(s) - 1))] if s else 0.0


def build(mode: str):
    cl = SimCluster(N_SENDERS + 2, link_bandwidth_Bps=LINK_BPS)
    cl.configure_ingress(rx_bandwidth_Bps=RX_BPS,
                         queue_bytes=QUEUE_BYTES, node=0)
    if mode == "lossless":
        cl.configure_pfc(enabled=True)
    elif mode == "lossless_prio":
        cl.configure_pfc(enabled=True, xoff=dict(XOFF), xon=dict(XON))
        # per-class ingress queues need the QoS class machinery on
        cl.configure_qos(QoSConfig(enabled=True))
        cl.configure_ecn(enabled=True, per_class=dict(PER_CLASS))
    receivers = []
    for i in range(N_SENDERS):
        A = cl.launch(f"s{i}", i + 1)
        B = cl.launch(f"r{i}", 0)
        aa = SendBwApp(msg_size=MSG, window=8)
        aa.attach(A, sender=True)
        A.app = aa
        ab = SendBwApp(msg_size=MSG, window=8)
        ab.attach(B, sender=False)
        B.app = ab
        connect_pair(aa.channels[0], ab.channels[0])
        receivers.append(ab)
    return cl, receivers


def run(mode: str):
    cl, receivers = build(mode)
    sampler = None
    if mode == "lossless_prio":
        probe = cl.launch("probe", 0)
        sampler = _ClassOccupancySampler(cl.fabric, cl.nodes[0].gid)
        probe.app = sampler
        bulk = cl.launch("bulk", N_SENDERS + 1)
        bulk.ctx.alloc_pd().reg_mr(BULK_BYTES)
    iport = cl.fabric.ingress_port(cl.nodes[0].gid)
    for _ in range(WARMUP):
        cl.step_all()
    t0, rx0 = cl.fabric.now, iport.rx_bytes
    if sampler is not None:
        # p99 is a steady-state claim: drop the pre-convergence ramp
        # (the queue fills to XOFF before DCQCN's first cuts land)
        sampler.samples = {"app": [], "mig": []}
    migrated = False
    for s in range(STEPS - WARMUP):
        if mode == "lossless_prio" and s == 500:
            # pre-copy the bulk container *into* the congested node:
            # its MIG_PAGE/MIG_STATE stream shares the bounded ingress
            # with the incast (deep-threshold class)
            rep = cl.migrate("bulk", 0, strategy="pre_copy")
            migrated = rep.ok
        cl.step_all()
    stats = cl.fabric.stats
    elapsed = cl.fabric.now - t0
    out = {
        "goodput_Bps_frac": (iport.rx_bytes - rx0)
        / (elapsed * RX_BPS * cl.fabric.step_s()),
        "rx_dropped": stats.get("rx_dropped", 0),
        "wire_dropped": stats.get("dropped", 0),
        "rnr_naks": stats.get("rnr_naks", 0),
        "pause_frames": stats.get("pfc_pause_frames", 0),
        "resume_frames": stats.get("pfc_resume_frames", 0),
        "paused_steps": stats.get("pfc_paused_steps", 0),
        "headroom_admits": stats.get("pfc_headroom_admits", 0),
        "ecn_marked": stats.get("ecn_marked", 0),
        "received": [r.received for r in receivers],
        "migrated": migrated,
        "now": cl.fabric.now,
    }
    if sampler is not None:
        out["p99_app"] = _p99(sampler.samples["app"])
        out["p99_mig"] = _p99(sampler.samples["mig"])
    return out


def _line(tag, r):
    extra = ""
    if "p99_app" in r:
        extra = (f",p99_app={r['p99_app']:.3f}"
                 f",p99_mig={r['p99_mig']:.3f}")
    print(f"fig_pfc[{tag}],{r['rnr_naks']},rnr_naks,"
          f"rx_dropped={r['rx_dropped']},pauses={r['pause_frames']},"
          f"paused_steps={r['paused_steps']},"
          f"goodput={r['goodput_Bps_frac']:.3f}{extra}")


def main():
    lossy = run("lossy")
    lossless = run("lossless")
    prio = run("lossless_prio")
    prio2 = run("lossless_prio")            # determinism witness

    _line("lossy", lossy)
    _line("lossless", lossless)
    _line("lossless_prio", prio)
    print(f"# PFC: {lossy['rx_dropped']} overflow drops / "
          f"{lossy['rnr_naks']} RNR NAKs -> 0/0 lossless; goodput "
          f"{lossless['goodput_Bps_frac']:.1%} of rx capacity; "
          f"per-priority ECN p99 occupancy app "
          f"{prio['p99_app']:.3f} < mig {prio['p99_mig']:.3f}")

    assert lossy["rx_dropped"] > 0 and lossy["rnr_naks"] > 0, \
        "the lossy baseline must actually overflow, or lossless " \
        "mode proves nothing"
    for tag, r in (("lossless", lossless), ("lossless_prio", prio)):
        assert r["rx_dropped"] == 0 and r["wire_dropped"] == 0, \
            f"{tag}: a lossless fabric dropped reliable packets"
        assert r["rnr_naks"] == 0, \
            f"{tag}: RNR NAKs on a lossless fabric"
        assert r["pause_frames"] > 0 and r["paused_steps"] > 0, \
            f"{tag}: the incast must exercise the PFC pause machinery"
    assert lossless["goodput_Bps_frac"] >= 0.90, \
        f"lossless goodput {lossless['goodput_Bps_frac']:.3f} below " \
        f"90% of receiver capacity"
    assert all(g > 0 for g in lossless["received"]), \
        "pause/resume must share the receiver, not starve a sender"
    assert prio["migrated"], "the pre-copy into the incast must land"
    assert prio["ecn_marked"] > 0, \
        "per-priority thresholds must actually mark inside the " \
        "PFC-governed occupancy band"
    assert prio["p99_app"] < prio["p99_mig"], \
        f"per-priority ECN must keep the app class's p99 queue below " \
        f"the migration class's: app={prio['p99_app']:.3f} " \
        f"mig={prio['p99_mig']:.3f}"
    assert prio == prio2, "lossless run must be deterministic"
    return {"lossy_rx_dropped": lossy["rx_dropped"],
            "lossy_rnr_naks": lossy["rnr_naks"],
            "lossless_goodput_frac": lossless["goodput_Bps_frac"],
            "pause_frames": lossless["pause_frames"],
            "paused_steps": lossless["paused_steps"],
            "p99_app": prio["p99_app"],
            "p99_mig": prio["p99_mig"]}


if __name__ == "__main__":
    main()
