"""Fig. 9: verbs object creation time (PD, CQ, MR, QP incl. the mandatory
Reset->Init->RTR->RTS walk)."""
import time

from repro.core.states import QPState
from repro.runtime.cluster import SimCluster


def _t(fn, n=200):
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def main():
    cl = SimCluster(2)
    ctx = cl.nodes[0].device.open_context()
    pd = ctx.alloc_pd()
    cq = ctx.create_cq()

    print(f"fig9_create[PD],{_t(ctx.alloc_pd):.2f},us")
    print(f"fig9_create[CQ],{_t(lambda: ctx.create_cq()):.2f},us")
    print(f"fig9_create[MR_1MiB],{_t(lambda: pd.reg_mr(1 << 20), 50):.2f},us")

    def qp_to_rts():
        qp = pd.create_qp(cq, cq)
        qp.modify(QPState.INIT)
        qp.modify(QPState.RTR, dest_gid=1, dest_qpn=1, rq_psn=0)
        qp.modify(QPState.RTS, sq_psn=0)
    print(f"fig9_create[QP_to_RTS],{_t(qp_to_rts):.2f},us")


if __name__ == "__main__":
    main()
