"""Batched serving with continuous batching + engine state dump/restore
(the serving-side analogue of container migration: the whole engine state —
KV caches, lengths, in-flight requests — moves between 'nodes').

    PYTHONPATH=src python examples/serve_batch.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models.model import LM
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = get_smoke_config("gemma3-1b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    eng = ServingEngine(lm, params, slots=4, capacity=128)

    rng = np.random.RandomState(0)
    reqs = [Request(i, rng.randint(0, cfg.vocab_size, 16).astype(np.int32),
                    max_new=8) for i in range(6)]
    pending = list(reqs)
    submitted = []
    while pending or any(eng.active):
        while pending and eng.submit(pending[0]):
            submitted.append(pending.pop(0))
        eng.step()
        if eng.steps == 3:
            # live-migrate the engine: dump state, rebuild, restore
            blob = eng.state_dict()
            eng2 = ServingEngine(lm, params, slots=4, capacity=128)
            eng2.load_state_dict(blob)
            eng2.active = eng.active
            eng = eng2
            print("[engine migrated at step 3]")
    for r in reqs:
        print(f"req {r.rid}: prompt[:4]={r.prompt[:4].tolist()} "
              f"-> {r.out}")
    assert all(len(r.out) >= r.max_new for r in reqs)
    print("OK: all requests served (through a mid-flight engine migration)")


if __name__ == "__main__":
    main()
