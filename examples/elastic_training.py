"""Elastic scaling + straggler mitigation by live migration.

Part 1: a sharded train state is re-meshed 4 -> 2 devices mid-run
(simulating node loss) and training continues from the same state.
Part 2: the straggler policy detects a persistently slow rank and the
scheduler live-migrates its container — the paper's HPC-scheduling use
case for migration.

    PYTHONPATH=src python examples/elastic_training.py
"""
import os
import sys

if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.mesh import make_mesh
from repro.models.model import LM
from repro.optim import adamw
from repro.runtime.elastic import remesh_state
from repro.runtime.ft import FailureDetector, MigrationPolicy
from repro.runtime.trainer import FabricTrainer
from repro.sharding import partition as part


def part1_elastic_remesh():
    print("== part 1: elastic re-mesh 4 -> 2 devices mid-run ==")
    cfg = get_smoke_config("stablelm-1.6b")
    lm = LM(cfg)
    opt = adamw.OptConfig(lr=1e-3)
    pipe = TokenPipeline(DataConfig(cfg.vocab_size, 64, 8))
    state_logical = adamw.state_logical(lm.specs())

    mesh4 = make_mesh((4,), ("data",))
    with part.activate(mesh4):
        params = lm.init(jax.random.PRNGKey(0))
        state = adamw.init_state(params)
        state = remesh_state(state, state_logical, None, mesh4)
        step_fn = jax.jit(adamw.make_train_step(lm, opt))
        for i in range(4):
            batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
            state, m = step_fn(state, batch)
        print(f"  4-dev mesh: step 4 loss={float(m['loss']):.4f}")

    mesh2 = make_mesh((2,), ("data",))   # two devices lost
    with part.activate(mesh2):
        state = remesh_state(state, state_logical, mesh4, mesh2)
        step_fn2 = jax.jit(adamw.make_train_step(lm, opt))
        for i in range(4):
            batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
            state, m = step_fn2(state, batch)
        print(f"  2-dev mesh: step 8 loss={float(m['loss']):.4f} "
              f"(state re-sharded, no restart)")


def part2_straggler_migration():
    print("== part 2: straggler mitigation by live migration ==")
    tr = FabricTrainer(4, n_nodes=6, seed=2)
    det = FailureDetector(timeout_s=10)
    pol = MigrationPolicy(det, factor=1.5, patience=2)
    slow_rank = 2
    migrated = False
    for s in range(8):
        tr.step()
        for r in range(4):
            # node 2 is degraded; once rank2 leaves it, it runs at speed
            t = 2.5 if (r == slow_rank and not migrated) else 1.0
            det.heartbeat(r, step_time=t, now=float(s))
        for r in pol.stragglers():
            rep = tr.cluster.migrate(f"rank{r}", 5)
            migrated = True
            print(f"  step {s}: rank{r} flagged as straggler -> "
                  f"live-migrated to node 5 "
                  f"(image {rep.image_bytes//1024} KiB)")
            det.health[r].step_times.clear()
    loss = tr.step()
    print(f"  training healthy after migration: loss={loss:.4f}")


if __name__ == "__main__":
    part1_elastic_remesh()
    part2_straggler_migration()
    print("OK")
