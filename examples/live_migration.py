"""Live migration of a containerised distributed training job — the
paper's headline demo, end to end:

  1. 4 data-parallel ranks train over verbs RC connections (ring
     all-reduce on the software RoCEv2 fabric).
  2. Mid-run, rank 1's container is live-migrated to a spare node:
     QPs stop, peers get NAK_STOPPED and pause, the image moves, the
     restored QPs send resume messages with their new address, peers
     retransmit exactly the lost packets.
  3. The loss trajectory is bitwise identical to a run that never
     migrated — transparency, verified.

    PYTHONPATH=src python examples/live_migration.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.runtime.trainer import FabricTrainer


def main():
    print("reference run (no migration):")
    ref = FabricTrainer(4, seed=11)
    l_ref = ref.train(12)
    for i in (0, 5, 11):
        print(f"  step {i:2d} loss={l_ref[i]:.6f}")

    print("\nmigrated run (rank1 -> spare node at step 6):")
    mig = FabricTrainer(4, seed=11)
    l_mig = []
    for s in range(12):
        if s == 6:
            rep = mig.cluster.migrate("rank1",
                                      len(mig.cluster.nodes) - 1)
            print(f"  [migration: image={rep.image_bytes/1024:.0f} KiB "
                  f"ckpt={rep.checkpoint_s*1e3:.2f}ms "
                  f"restore={rep.restore_s*1e3:.2f}ms]")
        l_mig.append(mig.step())
    for i in (0, 5, 6, 11):
        print(f"  step {i:2d} loss={l_mig[i]:.6f}")

    same_losses = l_ref == l_mig
    same_weights = all(np.array_equal(ref.weights(r), mig.weights(r))
                       for r in range(4))
    print(f"\nloss trajectories bitwise identical: {same_losses}")
    print(f"final weights bitwise identical:     {same_weights}")
    assert same_losses and same_weights
    print("MigrOS transparency: VERIFIED")

    print("\npre-copy run (orchestrator: dirty-page rounds, short stop):")
    pre = FabricTrainer(4, seed=11)
    l_pre = []
    for s in range(12):
        if s == 6:
            rep = pre.cluster.migrate("rank1",
                                      len(pre.cluster.nodes) - 1,
                                      strategy="pre_copy")
            print(f"  [pre-copy: rounds={len(rep.rounds)} "
                  f"residual={rep.image_bytes/1024:.0f} KiB "
                  f"downtime={rep.downtime_s*1e3:.2f}ms]")
        l_pre.append(pre.step())
    assert l_pre == l_ref
    print("pre-copy transparency: VERIFIED")


if __name__ == "__main__":
    main()
