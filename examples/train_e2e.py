"""End-to-end driver: train a ~100M-parameter LM with the full substrate
(config -> model -> sharded AdamW -> checkpointable data pipeline ->
periodic checkpoints + simulated failure restart mid-run).

    PYTHONPATH=src python examples/train_e2e.py --steps 300   # full run
    PYTHONPATH=src python examples/train_e2e.py --steps 20    # quick
"""
import argparse
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import LM
from repro.optim import adamw

# ~100M params: 12 x 768 with a 32k vocab
CFG = ModelConfig(
    name="lm-100m", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=12, head_dim=64, d_ff=2048,
    vocab_size=32_000, layer_pattern=("attn",), mlp_kind="swiglu",
    tie_embeddings=True, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="simulate a crash+restart at this step")
    args = ap.parse_args()

    lm = LM(CFG)
    params = lm.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n/1e6:.1f}M params")

    state = adamw.init_state(params)
    opt = adamw.OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(adamw.make_train_step(lm, opt))
    pipe = TokenPipeline(DataConfig(CFG.vocab_size, args.seq, args.batch))

    fail_at = args.fail_at or (args.steps // 2 if args.steps >= 40 else None)
    ckpt_dir = tempfile.mkdtemp(prefix="repro_e2e_")
    t0 = time.time()
    s = 0
    while s < args.steps:
        if fail_at is not None and s == fail_at:
            print(f"-- simulated failure at step {s}: restarting from "
                  f"latest checkpoint --")
            latest = ckpt.latest(ckpt_dir)
            state = ckpt.restore(latest, state)
            extra = ckpt.manifest_extra(latest)
            pipe.load_state_dict(extra["data"])
            s = int(extra["step"])
            fail_at = None
            continue
        batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        state, metrics = step_fn(state, batch)
        if s % 10 == 0:
            dt = time.time() - t0
            print(f"step {s:4d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"({dt/(s+1):.2f}s/step)")
        if s % 25 == 0 and s > 0:
            ckpt.save(ckpt_dir, state, step=s,
                      extra={"step": s, "data": pipe.state_dict()})
        s += 1
    print(f"done: {args.steps} steps in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
