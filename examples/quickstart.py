"""Quickstart: train a small LM for a few steps on CPU, checkpoint it,
restore it, and keep training — the 60-second tour of the public API.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs.base import get_smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import LM
from repro.optim import adamw


def main():
    cfg = get_smoke_config("deepseek-7b").replace(num_layers=2)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    state = adamw.init_state(params)
    opt = adamw.OptConfig(lr=3e-3, warmup_steps=5, total_steps=100)
    step_fn = jax.jit(adamw.make_train_step(lm, opt))

    pipe = TokenPipeline(DataConfig(cfg.vocab_size, seq_len=64,
                                    global_batch=8))
    print("training deepseek-7b (smoke config) for 20 steps...")
    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        state, metrics = step_fn(state, batch)
        if i % 5 == 0:
            print(f"  step {i:3d} loss={float(metrics['loss']):.4f} "
                  f"grad_norm={float(metrics['grad_norm']):.3f}")

    with tempfile.TemporaryDirectory() as d:
        path = ckpt.save(d, state, step=20,
                         extra={"data": pipe.state_dict()})
        print(f"checkpointed to {path}")
        state2 = ckpt.restore(path, state)
        pipe2 = TokenPipeline(DataConfig(cfg.vocab_size, 64, 8))
        pipe2.load_state_dict(ckpt.manifest_extra(path)["data"])
        batch = {k: jnp.asarray(v) for k, v in pipe2.next().items()}
        state2, m2 = step_fn(state2, batch)
        print(f"restored + stepped: loss={float(m2['loss']):.4f}")
    print("OK")


if __name__ == "__main__":
    main()
