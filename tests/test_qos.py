"""NIC-port QoS scheduler tests: the node-level egress port (capacity
summed over destinations), weighted-fair class arbitration with a
migration cap/guarantee, per-tenant token buckets, per-class fabric.stats
counters, detach draining, and the RFC 6298-style adaptive RTO."""
import pytest

from repro.core.packets import Op, Packet
from repro.core.qos import (CLASS_APP, CLASS_MIG, QoSConfig, TokenBucket,
                            classify)
from repro.core.transport import Fabric, STEP_S
from repro.core.verbs import PAGE_SIZE, QueuePair
from repro.runtime.apps import SendBwApp
from repro.runtime.cluster import SimCluster
from repro.runtime.collectives import connect_pair
from tests.helpers import make_sendbw_pair

BPS = 2e8        # 200 B/step ports: a windowed sender saturates one


def _run(cl, n):
    for _ in range(n):
        cl.step_all()


def _pair(cl, name, src, dst, *, window=16):
    """Named sendbw pair so tenant attribution is observable."""
    A = cl.launch(name, src)
    B = cl.launch(name + "-sink", dst)
    aa = SendBwApp(msg_size=4096, window=window)
    aa.attach(A, sender=True)
    A.app = aa
    ab = SendBwApp(msg_size=4096, window=window)
    ab.attach(B, sender=False)
    B.app = ab
    connect_pair(aa.channels[0], ab.channels[0])
    return aa, ab


def _mig_backlog(cl, src, dst, nbytes=400_000):
    """Park a large fire-and-forget service message so the mig class on
    ``src``'s port stays backlogged while the fabric pumps."""
    svc = cl.nodes[src].device.service
    svc.post(dst, Op.MIG_STATE, {"kind": "fill", "noack": True},
             b"m" * nbytes)
    return svc


# ---------------------------------------------------------------------------
# the port model: capacity is per node, summed over destinations
# ---------------------------------------------------------------------------


def test_port_capacity_is_shared_across_destinations():
    """Two flows leaving node 0 for *different* peers: under the old
    per-(src,dest) link model each had full bandwidth; a NIC port sums
    over flows, so their combined delivery is bounded by one port."""
    cl = SimCluster(3, link_bandwidth_Bps=BPS)
    a1, b1 = _pair(cl, "t1", 0, 1)
    a2, b2 = _pair(cl, "t2", 0, 2)
    t0 = cl.fabric.now
    port = cl.fabric.port(0)
    tx0 = port.tx_bytes
    _run(cl, 3000)
    transmitted = port.tx_bytes - tx0
    capacity = (cl.fabric.now - t0) * cl.fabric.bytes_per_step
    assert transmitted <= capacity * 1.01 + 4096
    assert transmitted > 0.5 * capacity            # and the port is busy
    assert b1.received > 0 and b2.received > 0     # neither flow starved


def test_work_conservation_single_backlogged_class():
    """QoS enabled but only the app class offers load: it gets the whole
    port (bandwidth reserved for migration is not wasted while no
    migration happens — the paper's no-overhead claim for scheduling)."""
    cl = SimCluster(2, link_bandwidth_Bps=BPS,
                    qos=QoSConfig(enabled=True, migration_guarantee=0.7))
    aa, ab = _pair(cl, "only", 0, 1)
    t0 = cl.fabric.now
    port = cl.fabric.port(0)
    _run(cl, 3000)
    transmitted = port.classes[CLASS_APP].tx_bytes
    capacity = (cl.fabric.now - t0) * cl.fabric.bytes_per_step
    assert transmitted > 0.9 * capacity


def test_weight_ratio_under_saturation():
    """Both classes saturating one port: transmitted bytes split by the
    configured weights (3:1 here) within scheduler quantisation."""
    cl = SimCluster(2, link_bandwidth_Bps=BPS,
                    qos=QoSConfig(enabled=True, app_weight=1.0,
                                  mig_weight=3.0))
    aa, ab = _pair(cl, "app", 0, 1)
    _run(cl, 200)                                  # app reaches saturation
    _mig_backlog(cl, 0, 1)
    port = cl.fabric.port(0)
    m0 = port.classes[CLASS_MIG].tx_bytes
    a0 = port.classes[CLASS_APP].tx_bytes
    _run(cl, 1500)                                 # both classes backlogged
    mig = port.classes[CLASS_MIG].tx_bytes - m0
    app = port.classes[CLASS_APP].tx_bytes - a0
    assert mig > 0 and app > 0
    ratio = mig / app
    assert 2.0 < ratio < 4.5, f"expected ~3:1 split, got {ratio:.2f}"


def test_migration_guarantee_floors_share_and_cap_ceils_it():
    """guarantee: a backlogged mig class gets at least its floor under app
    saturation. cap: mig never exceeds its ceiling even on an idle port
    (non-work-conserving by design)."""
    # -- guarantee ---------------------------------------------------------
    cl = SimCluster(2, link_bandwidth_Bps=BPS,
                    qos=QoSConfig(enabled=True, migration_guarantee=0.6))
    aa, ab = _pair(cl, "app", 0, 1)
    _run(cl, 200)
    _mig_backlog(cl, 0, 1)
    port = cl.fabric.port(0)
    m0, t0, now0 = port.classes[CLASS_MIG].tx_bytes, port.tx_bytes, \
        cl.fabric.now
    _run(cl, 1200)
    mig = port.classes[CLASS_MIG].tx_bytes - m0
    total = port.tx_bytes - t0
    assert mig / total > 0.55, f"guarantee not honoured: {mig/total:.2f}"
    # -- cap ---------------------------------------------------------------
    cl = SimCluster(2, link_bandwidth_Bps=BPS,
                    qos=QoSConfig(enabled=True, migration_cap=0.3))
    _mig_backlog(cl, 0, 1)
    port = cl.fabric.port(0)
    now0 = cl.fabric.now
    _run(cl, 2000)
    mig = port.classes[CLASS_MIG].tx_bytes
    capacity = (cl.fabric.now - now0) * cl.fabric.bytes_per_step
    # ceiling plus the cap bucket's burst depth and one packet of slack
    assert mig <= 0.3 * capacity + 8192 + 2048, \
        f"cap exceeded: {mig} of {capacity}"
    assert mig > 0.15 * capacity                   # but it does flow


def test_tenant_token_bucket_bounds_rate_without_starving_others():
    """A bucketed tenant is held to its sustained rate (+burst); the
    co-located unthrottled tenant absorbs the freed bandwidth."""
    rate = 0.2 * BPS
    cl = SimCluster(3, link_bandwidth_Bps=BPS,
                    qos=QoSConfig(enabled=True,
                                  tenant_rate_Bps={"greedy": rate}))
    g_tx, g_rx = _pair(cl, "greedy", 0, 1)
    p_tx, p_rx = _pair(cl, "polite", 0, 2)
    _run(cl, 1500)            # burn greedy's initial burst; settle RTTs
    g0, p0, t0 = g_rx.received, p_rx.received, cl.fabric.now
    _run(cl, 4000)
    elapsed = cl.fabric.now - t0
    greedy_bytes = (g_rx.received - g0) * 4096
    allowed = rate * STEP_S * elapsed               # sustained rate
    assert greedy_bytes <= allowed * 1.2 + 64 * 1024, \
        f"bucket leaked: {greedy_bytes} > {allowed}"
    assert g_rx.received > g0                       # shaped, not starved
    # freed bandwidth crossed to the unthrottled tenant
    assert p_rx.received - p0 > 2 * (g_rx.received - g0)


def test_bucket_refill_determinism():
    """Token refill is a pure function of the step delta: identical runs
    yield identical stats, clocks, and per-tenant progress; and the
    arithmetic refills exactly rate_per_step * elapsed."""
    b = TokenBucket(rate_per_step=10.0, burst=100.0, now=0)
    b.take(100.0)
    assert not b.peek(51, now=5)                   # 5 steps -> 50 tokens
    assert b.peek(50, now=5) and b.tokens == 50.0
    assert b.peek(100, now=1000) and b.tokens == 100.0   # capped at burst

    def one():
        cl = SimCluster(3, link_bandwidth_Bps=BPS,
                        qos=QoSConfig(enabled=True,
                                      tenant_rate_Bps={"greedy": 0.3 * BPS}))
        g_tx, g_rx = _pair(cl, "greedy", 0, 1)
        p_tx, p_rx = _pair(cl, "polite", 0, 2)
        _run(cl, 1500)
        return (g_rx.received, p_rx.received, cl.fabric.now,
                dict(cl.fabric.stats))

    assert one() == one()


def test_per_class_stats_counters():
    """fabric.stats splits the wire into exactly two classes: app_* and
    mig_* sum to the totals, and MIG bytes only appear when the
    migration data plane actually runs."""
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 100)
    s = cl.fabric.stats
    assert s["mig_tx_bytes"] == 0 and s["mig_tx_packets"] == 0
    assert s["app_tx_bytes"] == s["tx_bytes"]
    assert s["app_tx_packets"] == s["tx_packets"]
    assert cl.migrate("recv", 2, strategy="pre_copy").ok
    _run(cl, 200)
    s = cl.fabric.stats
    assert s["mig_tx_bytes"] > 0
    assert s["app_tx_bytes"] + s["mig_tx_bytes"] == s["tx_bytes"]
    assert s["app_tx_packets"] + s["mig_tx_packets"] == s["tx_packets"]


def test_packets_carry_tenant_attribution():
    """Send-time attribution: app packets are stamped with the owning
    container's name, service-channel packets with the kernel tenant."""
    cl = SimCluster(3)
    cl.fabric.trace = []
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 20)
    _mig_backlog(cl, 0, 2, nbytes=10_000)
    _run(cl, 50)
    tenants = {p.tenant for p in cl.fabric.trace if classify(p) == CLASS_APP
               and p.op in (Op.SEND, Op.WRITE)}
    assert "send" in tenants
    mig_tenants = {p.tenant for p in cl.fabric.trace
                   if classify(p) == CLASS_MIG}
    assert mig_tenants == {"_kernel@0"}


def test_qos_config_validation():
    with pytest.raises(ValueError, match="cap below"):
        QoSConfig(enabled=True, migration_cap=0.2,
                  migration_guarantee=0.5).validate()
    with pytest.raises(ValueError, match="weights"):
        QoSConfig(enabled=True, app_weight=0.0).validate()
    with pytest.raises(ValueError, match="migration_cap"):
        QoSConfig(enabled=True, migration_cap=1.5).validate()
    with pytest.raises(ValueError, match="rate"):
        Fabric().set_tenant_rate("t", 0.0)


def test_default_rate_exempts_kernel_and_unattributed():
    """A blanket default_tenant_rate_Bps throttles containers, never the
    migration data plane's kernel tenants (that's what the class
    cap/guarantee knobs are for) — unless named explicitly."""
    from repro.core.qos import UNATTRIBUTED
    cfg = QoSConfig(enabled=True, default_tenant_rate_Bps=1e6).validate()
    assert cfg.bucket_for("some-container") is not None
    assert cfg.bucket_for("_kernel@0") is None
    assert cfg.bucket_for(UNATTRIBUTED) is None
    explicit = QoSConfig(enabled=True,
                         tenant_rate_Bps={"_kernel@0": 1e6}).validate()
    assert explicit.bucket_for("_kernel@0") is not None


def test_disabled_qos_is_single_fifo():
    """Default config: one class, one queue, no buckets consulted — the
    scheduler must add nothing when not asked for."""
    cl = SimCluster(2, link_bandwidth_Bps=BPS)
    aa, ab = _pair(cl, "a", 0, 1)
    _run(cl, 500)
    port = cl.fabric.port(0)
    assert set(port.classes) == {CLASS_APP}
    assert all(b is None for b in port.buckets.values())
    assert cl.fabric.stats["qos_bucket_deferrals"] == 0


# ---------------------------------------------------------------------------
# detach: undelivered packets drain into stats["unroutable"]
# ---------------------------------------------------------------------------


def test_detach_drains_queued_packets_to_unroutable():
    """Packets queued toward a departing gid are counted and dropped at
    detach time, so in_flight() can quiesce instead of carrying a
    backlog no delivery loop will ever claim."""
    fab = Fabric(bandwidth_Bps=1e8)          # 100 B/step: queues build up

    class _Sink:
        def receive(self, pkt):
            pass

        def run_tasks(self):
            pass

        def idle(self):
            return True

    fab.attach(0, _Sink())
    fab.attach(1, _Sink())
    fab.attach(2, _Sink())
    for i in range(10):
        fab.send(Packet(op=Op.SEND, src_gid=0, src_qpn=1, dest_gid=1,
                        dest_qpn=2, psn=i, payload=b"x" * 1024))
        fab.send(Packet(op=Op.SEND, src_gid=0, src_qpn=1, dest_gid=2,
                        dest_qpn=2, psn=i, payload=b"x" * 1024))
    fab.pump(3)                              # a few transmit, most queue
    assert fab.in_flight() > 0
    before = fab.in_flight()
    fab.detach(1)
    assert fab.stats["unroutable"] > 0
    assert fab.in_flight() < before
    # nothing addressed to gid 1 survives anywhere in the fabric
    fab.run_until_idle()
    assert fab.in_flight() == 0


def test_detach_keeps_other_destinations_flowing():
    cl = SimCluster(3, link_bandwidth_Bps=BPS)
    aa, ab = _pair(cl, "keep", 0, 2)
    _run(cl, 200)
    got = ab.received
    cl.fabric.detach(1)                      # unrelated node departs
    _run(cl, 200)
    assert ab.received > got


# ---------------------------------------------------------------------------
# adaptive RTO (RFC 6298-style SRTT/RTTVAR)
# ---------------------------------------------------------------------------


def test_rto_converges_below_initial_on_uncontended_link():
    """A quiet link's RTT is a few steps; the estimator must settle the
    timer far below the initial 200-step RTO so tail loss recovers
    fast — the old fixed-doubling timer never got faster."""
    cl = SimCluster(2)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 300)
    qp = aa.channels[0].h.qp(aa.channels[0].qpn)
    assert qp.srtt is not None
    assert qp.rto < QueuePair.RETRANS_TIMEOUT / 2
    assert qp.rto >= QueuePair.MIN_RTO


def test_rto_tracks_contention_upward():
    """Queueing delay on a saturated port shows up in RTT samples: the
    adaptive timer rises above its uncontended level instead of firing
    spuriously and flooding the port with duplicate windows."""
    def settled_rto(bw):
        cl = SimCluster(2, link_bandwidth_Bps=bw)
        aa, ab = make_sendbw_pair(cl, msg_size=4096, window=16)
        _run(cl, 1500)
        return aa.channels[0].h.qp(aa.channels[0].qpn).rto

    assert settled_rto(2e8) > 2 * settled_rto(5e9)


def test_karn_no_sample_from_retransmits():
    """A retransmitted PSN must not feed the estimator (its ACK is
    ambiguous); losing a window leaves srtt untouched until fresh
    packets flow."""
    cl = SimCluster(2, loss_prob=1.0, seed=7)
    aa, ab = make_sendbw_pair(cl)
    for _ in range(600):
        cl.step_all()                        # everything lost: retx only
    qp = aa.channels[0].h.qp(aa.channels[0].qpn)
    assert qp.srtt is None                   # not one valid sample
    assert qp.rto > QueuePair.RETRANS_TIMEOUT   # backoff engaged
    cl.fabric.loss_prob = 0.0
    for _ in range(qp.MAX_RTO + 2000):
        cl.step_all()
    assert ab.received > 0                   # and the stream recovered
    assert qp.srtt is not None               # fresh packets resumed sampling


def test_migration_still_deterministic_with_qos():
    """Sim-clock figures stay bit-identical across runs with the
    scheduler enabled (the qos figure depends on this)."""
    def one():
        cl = SimCluster(3, qos=QoSConfig(enabled=True,
                                         migration_guarantee=0.5))
        aa, ab = make_sendbw_pair(cl)
        _run(cl, 50)
        rep = cl.migrate("recv", 2, strategy="pre_copy")
        return (rep.ok, rep.downtime_s, rep.transfer_s, rep.live_s)

    a, b = one(), one()
    assert a == b and a[0]
