"""Substrate tests: optimizer, data pipeline, checkpointing, serving,
elastic/FT policies, shadow interposition, fast-path overhead claim."""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim import adamw
from repro.runtime.ft import FailureDetector, MigrationPolicy
from repro.runtime.trainer import FabricTrainer


def test_adamw_optimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init_state(params)
    cfg = adamw.OptConfig(lr=0.3, warmup_steps=0, total_steps=200,
                          weight_decay=0.0)
    for _ in range(150):
        grads = {"w": 2 * state["params"]["w"]}
        state, _ = adamw.apply_updates(cfg, state, grads)
    assert float(jnp.abs(state["params"]["w"]).max()) < 0.05


def test_grad_clipping():
    params = {"w": jnp.zeros(4)}
    state = adamw.init_state(params)
    cfg = adamw.OptConfig(clip_norm=1.0)
    _, m = adamw.apply_updates(cfg, state, {"w": jnp.full(4, 100.0)})
    assert float(m["grad_norm"]) == pytest.approx(200.0, rel=1e-3)


def test_grad_compression_roundtrip_is_unbiasedish():
    cfg = adamw.OptConfig(compress_grads=True)
    g = jax.random.normal(jax.random.PRNGKey(0), (4096,))
    outs = []
    for s in range(8):
        q = adamw._compress(g, jax.random.PRNGKey(s))
        outs.append(np.asarray(q))
    err = np.abs(np.mean(outs, 0) - np.asarray(g)).max()
    scale = float(jnp.abs(g).max()) / 127
    assert err < 2.5 * scale / np.sqrt(8)   # averages toward the truth


def test_pipeline_determinism_and_restore():
    cfg = DataConfig(1000, 32, 4, seed=9)
    p1 = TokenPipeline(cfg)
    seq = [p1.next()["tokens"] for _ in range(5)]
    p2 = TokenPipeline(cfg)
    p2.load_state_dict({"step": 3, "seed": 9})
    np.testing.assert_array_equal(p2.next()["tokens"], seq[3])
    np.testing.assert_array_equal(p2.next()["tokens"], seq[4])


def test_checkpoint_roundtrip_and_latest():
    tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, tree, step=3, extra={"x": 1})
        ckpt.save(d, tree, step=7, extra={"x": 2})
        latest = ckpt.latest(d)
        assert latest.endswith("step_00000007")
        out = ckpt.restore(latest, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert ckpt.manifest_extra(latest)["x"] == 2


def test_checkpoint_async_writer():
    tree = {"w": jnp.ones((256, 256))}
    with tempfile.TemporaryDirectory() as d:
        _, t = ckpt.save(d, tree, step=1, async_write=True)
        t.join(10)
        out = ckpt.restore(ckpt.latest(d), tree)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.ones((256, 256)))


def test_serving_engine_decodes_and_migrates():
    from repro.configs.base import get_smoke_config
    from repro.models.model import LM
    from repro.serving.engine import Request, ServingEngine
    cfg = get_smoke_config("deepseek-7b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    eng = ServingEngine(lm, params, slots=2, capacity=64)
    rng = np.random.RandomState(0)
    reqs = [Request(i, rng.randint(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new=4) for i in range(2)]
    for r in reqs:
        assert eng.submit(r)
    eng.step()
    # migrate the engine state mid-decode
    blob = eng.state_dict()
    eng2 = ServingEngine(lm, params, slots=2, capacity=64)
    eng2.load_state_dict(blob)
    eng2.active = eng.active
    eng2.run_until_done()
    assert all(len(r.out) >= 4 for r in reqs)


def test_failure_detector_and_straggler_policy():
    det = FailureDetector(timeout_s=1.0)
    det.heartbeat(0, step_time=1.0, now=0.0)
    det.heartbeat(1, step_time=1.0, now=0.0)
    assert det.failed(now=0.5) == []
    assert det.failed(now=2.0) == [0, 1]

    det2 = FailureDetector()
    pol = MigrationPolicy(det2, factor=1.5, patience=2)
    flagged = set()
    for s in range(3):
        for r in range(4):
            det2.heartbeat(r, step_time=3.0 if r == 2 else 1.0,
                           now=float(s))
        flagged.update(pol.stragglers())
    assert flagged == {2}


def test_elastic_remesh_roundtrip():
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 devices")
    from repro.launch.mesh import make_mesh
    from repro.runtime.elastic import remesh_state
    m4 = make_mesh((4,), ("data",))
    m2 = make_mesh((2,), ("data",))
    state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    logical = {"w": ("embed", None)}
    s4 = remesh_state(state, logical, None, m4)
    s2 = remesh_state(s4, logical, m4, m2)
    np.testing.assert_array_equal(np.asarray(s2["w"]),
                                  np.asarray(state["w"]))


def test_checkpoint_restart_manager():
    from repro.runtime.ft import CheckpointRestartManager
    saved = {}

    def save_fn(step):
        saved[step] = f"ck{step}"
        return f"ck{step}"

    def restore_fn(cid, world):
        return (cid, world)

    mgr = CheckpointRestartManager(save_fn, restore_fn, interval_steps=5)
    for s in range(12):
        mgr.maybe_checkpoint(s)
    assert mgr.last_ckpt == "ck10"
    assert mgr.restart(6) == ("ck10", 6)
    assert mgr.restarts == 1


def test_shadow_interposition_does_extra_copies():
    """Fig. 8 mechanism: every send is bounced through a shadow MR and
    every recv completion is copied back (DMTCP architecture)."""
    from repro.core.shadow import ShadowVerbs, _ShadowMR
    from repro.runtime.cluster import SimCluster
    from repro.runtime.collectives import Channel, connect_pair
    from repro.core.verbs import SGE, SendWR
    from repro.core.packets import Op

    cl = SimCluster(2)
    ca, cb = cl.launch("a", 0), cl.launch("b", 1)
    c1, c2 = Channel(ca.ctx, 8192), Channel(cb.ctx, 8192)
    connect_pair(c1, c2)
    sh = ShadowVerbs(ca.ctx)
    pd = ca.ctx.pds[0]
    user = c1.h.mr(c1.mrn_send)
    sh._mrs[user.mrn] = _ShadowMR(user, pd.reg_mr(user.size))
    qp1 = c1.h.qp(c1.qpn)
    c2.post_recv(64)
    user.write(0, b"A" * 64)
    sh.post_send(qp1, SendWR(1, Op.SEND, SGE(user, 0, 64)))
    shadow_mr = sh._mrs[user.mrn].shadow
    assert shadow_mr.read(0, 64) == b"A" * 64     # bounce copy happened
    cl.run_until_idle()
    sh.poll(c1.h.cq(c1.cqn), 8)
    assert c2.recv_bytes(0, 64) == b"A" * 64      # delivery correct
    assert sh._qp_log[qp1.qpn]                    # bookkeeping maintained
