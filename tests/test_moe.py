"""MoE dispatch: local path vs dense reference, EP shard_map path vs
local, gradients, capacity dropping semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.launch.mesh import make_mesh
from repro.models import moe as MOE
from repro.models.layers import init_params
from repro.sharding import partition as part


def _ep_mesh():
    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs >1 device (run with "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    return make_mesh((1, n), ("data", "model"))


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("deepseek-moe-16b")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                              capacity_factor=8.0))
    key = jax.random.PRNGKey(0)
    p = init_params(MOE.moe_def(cfg), key, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, cfg.d_model))
    return cfg, p, x


def _dense_ref(cfg, p, x):
    m = cfg.moe
    xf = x.reshape(-1, cfg.d_model)
    probs = jax.nn.softmax(xf @ p["router"], -1)
    g, idx = jax.lax.top_k(probs, m.top_k)
    g = g / g.sum(-1, keepdims=True)
    y = jnp.zeros_like(xf)
    for e in range(m.num_experts):
        h = jax.nn.silu(xf @ p["wi_gate"][e]) * (xf @ p["wi_up"][e])
        y += (h @ p["wo"][e]) * ((idx == e) * g).sum(-1)[:, None]
    sp = p["shared"]
    y += (jax.nn.silu(xf @ sp["wi_gate"]) * (xf @ sp["wi_up"])) @ sp["wo"]
    return y.reshape(x.shape)


def test_local_path_matches_dense_reference(setup):
    cfg, p, x = setup
    y, aux = MOE.moe_apply(cfg, p, x)
    np.testing.assert_allclose(np.array(y), np.array(_dense_ref(cfg, p, x)),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) > 0


def test_ep_path_matches_local(setup):
    cfg, p, x = setup
    y_local, _ = MOE.moe_apply(cfg, p, x)
    mesh = _ep_mesh()
    with part.activate(mesh):
        y_ep, _ = jax.jit(lambda p, x: MOE.moe_apply(cfg, p, x))(p, x)
    np.testing.assert_allclose(np.array(y_ep), np.array(y_local),
                               rtol=1e-5, atol=1e-5)


def test_ep_path_nondivisible_tokens(setup):
    cfg, p, _ = setup
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 3, cfg.d_model))
    y_local, _ = MOE.moe_apply(cfg, p, x)
    mesh = _ep_mesh()
    with part.activate(mesh):
        y_ep, _ = jax.jit(lambda p, x: MOE.moe_apply(cfg, p, x))(p, x)
    np.testing.assert_allclose(np.array(y_ep), np.array(y_local),
                               rtol=1e-5, atol=1e-5)


def test_ep_gradients_match_local(setup):
    cfg, p, x = setup
    mesh = _ep_mesh()

    def loss_local(p):
        return (MOE.moe_apply(cfg, p, x)[0] ** 2).sum()

    def loss_ep(p):
        with part.activate(mesh):
            return (MOE.moe_apply(cfg, p, x)[0] ** 2).sum()

    g1 = jax.grad(loss_local)(p)
    with part.activate(mesh):
        g2 = jax.jit(jax.grad(loss_ep))(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        a, b = np.array(a), np.array(b)
        denom = max(float(np.abs(a).max()), 1e-6)
        assert float(np.abs(a - b).max()) / denom < 1e-5


def test_capacity_dropping_actually_drops():
    cfg = get_smoke_config("deepseek-moe-16b")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                              capacity_factor=0.25))
    key = jax.random.PRNGKey(3)
    p = init_params(MOE.moe_def(cfg), key, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 32, cfg.d_model))
    y_tight, _ = MOE.moe_apply(cfg, p, x)
    cfg2 = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                               capacity_factor=16.0))
    y_loose, _ = MOE.moe_apply(cfg2, p, x)
    assert float(np.abs(np.array(y_tight) - np.array(y_loose)).max()) > 1e-3
