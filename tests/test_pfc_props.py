"""Property-based PFC lossless-fabric harness.

Seeded-random schedules (``numpy.random.RandomState``, the repo's
stand-in for hypothesis — same pattern as ``test_preempt_props.py``)
draw XOFF/XON watermark pairs per traffic class, incast fan-in, queue
sizes, QoS/ECN toggles, and a mid-run migration *into* the congested
node — optionally pausing and resuming that migration while its own
traffic class may be PFC-paused — then assert the invariants a lossless
fabric must hold on EVERY trajectory:

* zero drops of reliable requests: no ingress overflow drops and no
  wire drops anywhere, for any watermark draw (headroom admission plus
  pause latches must absorb whatever the schedule throws at the queue);
* progress guarantee: every run drains — the incast receivers all make
  forward progress despite pause/resume duty cycles (no pause-latch
  deadlock, no XON lost forever), the migration lands, and once the
  senders stop offering load the fabric reaches quiescence with every
  egress/ingress backlog empty and every pause latch released;
* the metrics counter grammar holds for the new PFC counters:
  ``sum(name@gid) == name`` (``node_twin_sums``) — pause/resume frames
  and paused-step spans attribute to exactly one node each.

On any assertion failure the generating schedule is dumped as JSON to
``pfc_failures/`` (CI archives the directory) so the exact
counterexample replays with ``_run_schedule(json.load(...))``.

Seed matrix: ``PFC_SEEDS`` env var (comma-separated ints), default
``0,1,2,3`` — CI's extended step widens this to 20+ seeds and runs the
matrix under BOTH fabric pumps (the legacy exhaustive scan and the
event-driven active-set pump), since the pause latches feed the pump's
wake-time computation.
"""
import json
import os
from pathlib import Path

import numpy as np
import pytest

from repro.core.qos import QoSConfig
from repro.runtime.apps import SendBwApp
from repro.runtime.cluster import SimCluster
from repro.runtime.collectives import connect_pair

ARTIFACT_DIR = Path(__file__).resolve().parent.parent / "pfc_failures"
STRATEGIES = ("stop_and_copy", "pre_copy", "post_copy")


def _seeds():
    env = os.environ.get("PFC_SEEDS", "").strip()
    if env:
        return tuple(int(s) for s in env.split(",") if s.strip())
    return (0, 1, 2, 3)


def _draw_watermarks(rng: np.random.RandomState, qos: bool):
    """One (xon, xoff) pair with 0 < xon < xoff <= 1 per class. With
    QoS class queues each class polices its own backlog, so the pairs
    draw independently; single-FIFO mode reads the one shared counter
    (global-pause semantics), where per-class pairs would let one
    class's standing queue hold another's latch closed forever — so
    both classes share a single draw there."""
    xoff, xon = {}, {}
    for cls in ("app", "mig"):
        if not qos and cls == "mig":
            xon[cls], xoff[cls] = xon["app"], xoff["app"]
            continue
        lo = float(0.05 + 0.45 * rng.rand())        # xon in [0.05, 0.5)
        hi = float(min(1.0, lo + 0.1 + 0.5 * rng.rand()))
        xon[cls], xoff[cls] = lo, hi
    return xoff, xon


def _draw_schedule(rng: np.random.RandomState) -> dict:
    """One random lossless-fabric schedule. Plain JSON-serialisable
    dict so failures replay from the artifact."""
    qos = bool(rng.rand() < 0.5)
    xoff, xon = _draw_watermarks(rng, qos)
    pause_steps = int(rng.randint(64, 1024))
    sched = {
        "cluster_seed": int(rng.randint(0, 1000)),
        "fan_in": int(rng.randint(2, 5)),
        "queue_bytes": int(rng.choice([16, 32, 64])) * 1024,
        "xoff": xoff,
        "xon": xon,
        "pause_steps": pause_steps,
        "refresh_steps": int(rng.randint(8, max(9, pause_steps // 2))),
        "qos": qos,
        "ecn": bool(rng.rand() < 0.3),
        "strategy": str(rng.choice(list(STRATEGIES))),
        "bulk_bytes": int(rng.randint(8, 64)) * 1024,
        "pre_steps": int(rng.randint(100, 400)),
        "run_steps": int(rng.randint(800, 2000)),
        "pause_mig": bool(rng.rand() < 0.6),
        "pause_after": int(rng.randint(1, 40)),
        "park_steps": int(rng.randint(10, 400)),
    }
    return sched


def _build(sched: dict):
    n = sched["fan_in"]
    cl = SimCluster(n + 2, seed=sched["cluster_seed"])
    cl.configure_pump(sched.get("event_driven", True))
    cl.configure_ingress(rx_bandwidth_Bps=2e8,
                         queue_bytes=sched["queue_bytes"], node=0)
    cl.configure_pfc(enabled=True, xoff=dict(sched["xoff"]),
                     xon=dict(sched["xon"]),
                     pause_steps=sched["pause_steps"],
                     refresh_steps=sched["refresh_steps"])
    if sched["qos"]:
        cl.configure_qos(QoSConfig(enabled=True))
    if sched["ecn"]:
        cl.configure_ecn(enabled=True)
    receivers = []
    for i in range(n):
        A = cl.launch(f"s{i}", i + 1)
        B = cl.launch(f"r{i}", 0)
        aa = SendBwApp(msg_size=4096, window=8)
        aa.attach(A, sender=True)
        A.app = aa
        ab = SendBwApp(msg_size=4096, window=8)
        ab.attach(B, sender=False)
        B.app = ab
        connect_pair(aa.channels[0], ab.channels[0])
        receivers.append(ab)
    # the migration victim: memory-backed, parked on the spare node,
    # pre-copied INTO the congested node so its MIG_PAGE stream shares
    # the bounded ingress (and its class's pause latches) with the incast
    bulk = cl.launch("bulk", n + 1)
    bulk.ctx.alloc_pd().reg_mr(sched["bulk_bytes"])
    return cl, receivers


def _migrate(cl, sched: dict):
    """Run the scheduled migration, optionally preempting it mid-flight
    — this is where a pause_migration deadline can land while the mig
    class is itself PFC-paused at the sender's egress."""
    if sched["pause_mig"]:
        cl.pause_migration("bulk",
                           at=cl.fabric.now + sched["pause_after"])
    rep = cl.migrate("bulk", 0, strategy=sched["strategy"])
    if not rep.ok:
        assert rep.attempt is not None, \
            f"migration not ok yet no attempt token: {rep.stage_failed}"
        for _ in range(sched["park_steps"]):
            cl.step_all()           # incast keeps hammering while parked
        rep = cl.resume_migration("bulk")
    assert rep.ok, f"migration failed: stage={rep.stage_failed}"
    if rep.pager is not None:
        while rep.pager.remaining_pages:
            rep.pager.prefetch(16)
            cl.fabric.pump()
    return rep


def _assert_lossless(cl):
    stats = cl.fabric.stats
    assert stats.get("rx_dropped", 0) == 0, \
        f"ingress overflow dropped {stats['rx_dropped']} reliable pkts"
    assert stats.get("dropped", 0) == 0, \
        f"wire dropped {stats['dropped']} pkts on a loss-free fabric"


def _assert_counter_grammar(cl):
    sums = cl.fabric.metrics.node_twin_sums()
    for name, (bare, twin) in sums.items():
        assert bare == twin, (
            f"counter '{name}': bare total {bare} != twin sum {twin}")
    # the PFC counters must be node-attributed (present in the grammar)
    if cl.fabric.stats.get("pfc_pause_frames", 0):
        for name in ("pfc_pause_frames", "pfc_paused_steps"):
            assert name in sums, f"'{name}' missing @gid twins"


def _drain(cl, receivers):
    """Progress guarantee, part 2: stop offering load (senders stop
    stepping, receivers keep reposting) — the fabric must reach
    quiescence with every backlog empty and every pause latch released
    (XON or latch-lifetime expiry, either way: no deadlock)."""
    rcv_containers = [cl.containers[f"r{i}"]
                      for i in range(len(receivers))]
    for _ in range(3000):
        for c in rcv_containers:
            c.step()
        cl.pump()
        if not cl.fabric.in_flight():
            break
    assert not cl.fabric.in_flight(), \
        "fabric never drained after load stopped (pause deadlock?)"
    for node in cl.nodes:
        gid = node.gid
        eport = cl.fabric.port(gid)
        assert eport.backlog_packets == 0, \
            f"node {gid}: egress backlog stuck at {eport.backlog_packets}"
        assert cl.fabric.ingress_port(gid).backlog_packets == 0, \
            f"node {gid}: ingress backlog never drained"
    # a live latch with no backlog is harmless but must self-expire;
    # prove it by advancing past every remaining lifetime
    horizon = max([u for p in cl.nodes
                   for u in cl.fabric.port(p.gid)._pfc_until.values()]
                  or [cl.fabric.now])
    while cl.fabric.now <= horizon:
        cl.pump()
    assert not cl.fabric.in_flight()


def _run_schedule(sched: dict):
    cl, receivers = _build(sched)
    for _ in range(sched["pre_steps"]):
        cl.step_all()
    rep = _migrate(cl, sched)
    before = [r.received for r in receivers]
    for _ in range(sched["run_steps"]):
        cl.step_all()
    # progress guarantee, part 1: every incast pair moved bytes through
    # the paused-and-resumed fabric while the migration ran
    after = [r.received for r in receivers]
    assert all(a > b for a, b in zip(after, before)), \
        f"a receiver starved under PFC: {before} -> {after}"
    assert cl.containers["bulk"].node.gid == cl.nodes[0].gid, \
        "migration did not land on the congested node"
    _assert_lossless(cl)
    _drain(cl, receivers)
    _assert_lossless(cl)            # draining must not drop either
    _assert_counter_grammar(cl)
    return rep


def _dump_artifact(sched: dict, err: AssertionError) -> Path:
    ARTIFACT_DIR.mkdir(exist_ok=True)
    name = (f"{sched['strategy']}_seed{sched['cluster_seed']}"
            f"_{abs(hash(json.dumps(sched, sort_keys=True))) % 10**8}"
            f".json")
    path = ARTIFACT_DIR / name
    path.write_text(json.dumps(
        {"schedule": sched, "error": str(err)}, indent=2))
    return path


@pytest.mark.parametrize("event_driven", [False, True],
                         ids=["legacy", "event"])
@pytest.mark.parametrize("seed", _seeds())
def test_pfc_schedule_invariants(seed, event_driven):
    rng = np.random.RandomState(seed * 6271 + 17)
    sched = _draw_schedule(rng)
    sched["event_driven"] = event_driven
    try:
        _run_schedule(sched)
    except AssertionError as err:
        path = _dump_artifact(sched, err)
        raise AssertionError(
            f"schedule failed (replay artifact: {path}): {err}") from err
