"""Ingress-port model + true RNR NAK semantics.

Pins the receiver side of the wire model: bounded receive-processing
capacity and ingress queue (`repro.core.qos.IngressPort`), NIC- and
responder-generated `NakCode.RNR` with IBA retry semantics (min_rnr_timer
backoff, rnr_retry budget, retry exhaustion -> QP ERROR + error CQE),
incast determinism, detach draining, destination-aware admission, and the
PR 3 figure baselines under the unlimited-ingress default."""
import pytest

from repro.core.packets import NakCode, Op, Packet
from repro.core.qos import CLASS_APP, CLASS_MIG, IngressConfig, QoSConfig
from repro.core.states import QPState
from repro.core.transport import Fabric
from repro.core.verbs import PAGE_SIZE, WCStatus
from repro.orchestrator.orchestrator import AdmissionError
from repro.runtime.apps import SendBwApp
from repro.runtime.cluster import SimCluster
from repro.runtime.collectives import Channel, connect_pair
from tests.helpers import make_channel_pair

BPS = 2e8        # 200 B/step ports


def _run(cl, n):
    for _ in range(n):
        cl.step_all()


def _naks(trace, code):
    return [p for p in trace if p.op == Op.NAK and p.nak_code == code]


def _pair_named(cl, name, src, dst, *, window=8, msg=4096):
    A = cl.launch(name, src)
    B = cl.launch(name + "-sink", dst)
    aa = SendBwApp(msg_size=msg, window=window)
    aa.attach(A, sender=True)
    A.app = aa
    ab = SendBwApp(msg_size=msg, window=window)
    ab.attach(B, sender=False)
    B.app = ab
    connect_pair(aa.channels[0], ab.channels[0])
    return aa, ab


# ---------------------------------------------------------------------------
# the RNR mislabeling fix: unposted receive draws NakCode.RNR
# ---------------------------------------------------------------------------


def test_unposted_receive_draws_rnr_not_seq_err():
    """The responder's no-receive-posted path must emit the true RNR NAK
    (it used to mislabel it PSN_SEQ_ERR) and must not consume the
    one-NAK-per-gap budget (last_nak_epsn untouched)."""
    cl = SimCluster(2)
    cl.fabric.trace = []
    c1, c2, _, _ = make_channel_pair(cl)
    c1.post_send_bytes(b"x" * 512)      # no receive posted at c2
    _run(cl, 30)
    rnr = _naks(cl.fabric.trace, NakCode.RNR)
    assert rnr, "unposted receive must draw an RNR NAK"
    assert not _naks(cl.fabric.trace, NakCode.PSN_SEQ_ERR), \
        "receiver-not-ready is not a sequence error"
    qp2 = c2.h.qp(c2.qpn)
    assert qp2.last_nak_epsn == -1, \
        "RNR must not consume the one-NAK-per-gap budget"
    assert qp2.rnr_nak_sent


def test_rnr_window_dropped_silently_not_seq_naked():
    """While the responder is in an RNR condition, the rest of the
    sender's in-flight window (psn > epsn) is dropped silently: a
    PSN_SEQ_ERR would trigger immediate go-back-N and defeat the
    min_rnr_timer backoff the RNR NAK just requested."""
    cl = SimCluster(2)
    cl.fabric.trace = []
    c1, c2, _, _ = make_channel_pair(cl)
    for _ in range(4):                  # 4 messages: a real window
        c1.post_send_bytes(b"x" * 2048)
    _run(cl, 200)
    assert _naks(cl.fabric.trace, NakCode.RNR)
    assert not _naks(cl.fabric.trace, NakCode.PSN_SEQ_ERR)


def test_sender_backs_off_instead_of_goback_flood():
    """An RNR NAK parks the requester for min_rnr_timer steps: between
    the NAK and the backoff expiry no data packet leaves the sender."""
    cl = SimCluster(2)
    cl.fabric.trace = []
    c1, c2, _, _ = make_channel_pair(cl)
    qp1 = c1.h.qp(c1.qpn)
    qp1.min_rnr_timer = 50
    c1.post_send_bytes(b"x" * 512)
    _run(cl, 10)                        # NAK received, backoff armed
    assert qp1.rnr_wait_until > cl.fabric.now
    sends_before = sum(1 for p in cl.fabric.trace if p.op == Op.SEND)
    wait = qp1.rnr_wait_until
    while cl.fabric.now < wait - 1:     # stop just inside the backoff
        cl.step_all()
    sends_parked = sum(1 for p in cl.fabric.trace if p.op == Op.SEND)
    assert sends_parked == sends_before, \
        "no data may leave while parked in RNR backoff"
    _run(cl, 30)                        # backoff over: retransmission
    assert sum(1 for p in cl.fabric.trace if p.op == Op.SEND) \
        > sends_parked


def test_rnr_recovers_when_receive_posted():
    cl = SimCluster(2)
    c1, c2, _, _ = make_channel_pair(cl)
    qp1 = c1.h.qp(c1.qpn)
    qp1.min_rnr_timer = 8
    c1.post_send_bytes(b"hello rnr")
    _run(cl, 40)                        # at least one RNR episode
    assert cl.fabric.stats["rnr_naks"] > 0
    c2.post_recv(64)
    _run(cl, 60)
    wcs = c2.poll(4)
    assert [w.opcode for w in wcs] == ["RECV"]
    assert c2.recv_bytes(0, 9) == b"hello rnr"
    assert qp1.rnr_tries == 0, "progress re-arms the retry budget"


def test_rnr_retry_exhaustion_errors_qp_with_error_cqe():
    """A finite rnr_retry budget exhausts exactly as IBA specifies: the
    QP transitions to ERROR, the stalled WQE completes with
    RNR_RETRY_EXC_ERR, queued WQEs flush, and the fabric quiesces."""
    cl = SimCluster(2)
    c1, c2, _, _ = make_channel_pair(cl)
    qp1 = c1.h.qp(c1.qpn)
    qp1.rnr_retry = 2
    qp1.min_rnr_timer = 6
    c1.post_send_bytes(b"a" * 512)
    c1.post_send_bytes(b"b" * 512)
    _run(cl, 400)
    assert qp1.state == QPState.ERROR
    wcs = c1.poll(8)
    assert [w.status for w in wcs] == \
        [WCStatus.RNR_RETRY_EXC_ERR, WCStatus.WR_FLUSH_ERR]
    assert not qp1.inflight
    cl.run_until_idle()                 # nothing left in flight anywhere
    assert cl.fabric.stats["rnr_retries_exhausted"] == 1
    assert cl.fabric.stats["rnr_retries_exhausted@0"] == 1


def test_rnr_retry_forever_is_default():
    """rnr_retry=7 (the IBA 'infinite' encoding, our default) never
    errors the QP no matter how long the receiver stays not-ready."""
    cl = SimCluster(2)
    c1, c2, _, _ = make_channel_pair(cl)
    qp1 = c1.h.qp(c1.qpn)
    assert qp1.rnr_retry == 7
    qp1.min_rnr_timer = 4
    c1.post_send_bytes(b"x" * 256)
    _run(cl, 600)
    assert qp1.state == QPState.RTS
    assert cl.fabric.stats["rnr_naks"] > 10     # many episodes, no error
    c2.post_recv(64)
    _run(cl, 40)
    assert [w.opcode for w in c2.poll(4)] == ["RECV"]


def test_rnr_attrs_survive_migration():
    """Operator-set rnr_retry/min_rnr_timer are part of the dumped QP
    image and follow the container to the destination."""
    cl = SimCluster(3)
    c1, c2, ca, cb = make_channel_pair(cl)
    qp = cb.ctx.qps[0]
    qp.rnr_retry = 3
    qp.min_rnr_timer = 17
    qpn = qp.qpn
    assert cl.migrate("b", 2).ok
    moved = next(q for q in cb.ctx.qps if q.qpn == qpn)
    assert moved.rnr_retry == 3
    assert moved.min_rnr_timer == 17


# ---------------------------------------------------------------------------
# ingress port: bounded receive processing, overflow -> RNR, incast
# ---------------------------------------------------------------------------


def _incast(n_senders, *, bounded, steps=2500, queue=48 * 1024):
    cl = SimCluster(n_senders + 1, link_bandwidth_Bps=BPS)
    if bounded:
        cl.configure_ingress(rx_bandwidth_Bps=BPS, queue_bytes=queue,
                             node=0)
    receivers = []
    for i in range(n_senders):
        _, ab = _pair_named(cl, f"s{i}", i + 1, 0)
        receivers.append(ab)
    _run(cl, steps)
    return cl, [r.received for r in receivers]


def test_incast_collapse_under_bounded_ingress():
    """4:1 incast: free receive processing hides the collapse entirely;
    a bounded ingress shares one node's processing across all senders
    (>=2x per-sender goodput loss) and exercises the overflow path."""
    cl_free, free = _incast(4, bounded=False)
    cl_bound, bound = _incast(4, bounded=True)
    assert cl_free.fabric.stats["rx_dropped"] == 0
    assert cl_free.fabric.stats["rnr_naks"] == 0
    assert all(g > 0 for g in bound), "shaped, not starved"
    assert max(bound) * 2 <= min(free), \
        f"expected >=2x collapse: {bound} vs {free}"
    assert cl_bound.fabric.stats["rx_dropped@0"] > 0
    assert cl_bound.fabric.stats["rnr_naks@0"] > 0


def test_incast_reproduces_deterministically():
    """Same seed -> bit-identical rx_dropped and per-sender goodput
    (the RNR/backoff/scheduler pipeline has no hidden nondeterminism)."""
    def one():
        cl, good = _incast(4, bounded=True, steps=2000)
        return (good, cl.fabric.stats["rx_dropped@0"],
                cl.fabric.stats["rnr_naks@0"], cl.fabric.now,
                dict(cl.fabric.stats))

    assert one() == one()


def test_ingress_stats_per_node_consistency():
    cl, _ = _incast(4, bounded=True, steps=1500)
    s = cl.fabric.stats
    for key in ("rx_dropped", "rx_queued", "rnr_naks"):
        per_node = sum(v for k, v in s.items()
                       if k.startswith(f"{key}@"))
        assert s[key] == per_node, f"{key} aggregate != per-node sum"
    assert s["rx_queued@0"] > 0


def test_unlimited_ingress_is_passthrough():
    """Default config: no ingress queueing, no drops, no NAKs, and the
    port model reports zero utilization — the PR 3 wire model."""
    cl = SimCluster(2, link_bandwidth_Bps=BPS)
    _pair_named(cl, "a", 0, 1)
    _run(cl, 400)
    assert cl.fabric.ingress_utilization(1) == 0.0
    assert cl.fabric.ingress_capacity_Bps(1) is None
    assert cl.fabric.stats["rx_queued"] == 0
    assert cl.fabric.stats["rx_dropped"] == 0
    assert cl.fabric.ingress_port(1).backlog_bytes == 0


def test_configure_ingress_validation_and_flush():
    with pytest.raises(ValueError, match="rx_bandwidth_Bps"):
        IngressConfig(rx_bandwidth_Bps=0.0).validate()
    with pytest.raises(ValueError, match="queue_bytes"):
        IngressConfig(queue_bytes=0).validate()
    # switching a loaded node back to unlimited flushes its backlog
    cl = SimCluster(3, link_bandwidth_Bps=BPS)
    cl.configure_ingress(rx_bandwidth_Bps=BPS / 10, queue_bytes=32 * 1024,
                         node=0)
    for i in range(2):
        _pair_named(cl, f"s{i}", i + 1, 0)
    _run(cl, 300)
    assert cl.fabric.ingress_port(0).backlog_bytes > 0
    cl.configure_ingress(rx_bandwidth_Bps=None, node=0)
    assert cl.fabric.ingress_port(0).backlog_bytes == 0
    _run(cl, 50)
    assert cl.fabric.ingress_utilization(0) == 0.0


def test_qos_classes_extend_to_ingress():
    """With QoS enabled the ingress queue is per-class like egress: the
    mig class drains under its configured weight even while app incast
    saturates the receiver."""
    cl = SimCluster(3, link_bandwidth_Bps=BPS,
                    qos=QoSConfig(enabled=True, migration_guarantee=0.5))
    cl.configure_ingress(rx_bandwidth_Bps=BPS, queue_bytes=48 * 1024,
                         node=2)
    _pair_named(cl, "noisy", 1, 2)
    _run(cl, 300)
    iport = cl.fabric.ingress_port(2)
    assert set(iport.classes) == {CLASS_APP, CLASS_MIG}
    svc = cl.nodes[0].device.service
    svc.post(2, Op.MIG_STATE, {"kind": "fill", "noack": True},
             b"m" * 20_000)
    _run(cl, 1500)
    assert iport.classes[CLASS_MIG].tx_bytes > 0, \
        "migration class must make progress through a loaded ingress"
    assert iport.classes[CLASS_APP].tx_bytes > 0


# ---------------------------------------------------------------------------
# detach with a non-empty ingress queue
# ---------------------------------------------------------------------------


def test_detach_drains_ingress_queue_to_unroutable():
    """Packets parked in a departing node's ingress queue could only
    ever hit the unroutable path: they are counted out at detach so
    in_flight() quiesces."""
    fab = Fabric(bandwidth_Bps=1e9)     # fast egress, slow receive
    fab.configure_ingress(IngressConfig(rx_bandwidth_Bps=1e7,
                                        queue_bytes=1 << 20), gid=1)

    class _Sink:
        def receive(self, pkt):
            pass

        def run_tasks(self):
            pass

        def idle(self):
            return True

    fab.attach(0, _Sink())
    fab.attach(1, _Sink())
    for i in range(20):
        fab.send(Packet(op=Op.SEND, src_gid=0, src_qpn=1, dest_gid=1,
                        dest_qpn=2, psn=i, payload=b"x" * 1024))
    fab.pump(40)                        # egress drains into ingress queue
    assert fab.ingress_port(1).backlog_packets > 0
    queued = fab.ingress_port(1).backlog_packets
    before = fab.stats["unroutable"]
    fab.detach(1)
    assert fab.stats["unroutable"] >= before + queued
    fab.run_until_idle()
    assert fab.in_flight() == 0


def test_detach_keeps_other_ingress_flowing():
    cl = SimCluster(3, link_bandwidth_Bps=BPS)
    cl.configure_ingress(rx_bandwidth_Bps=BPS, queue_bytes=48 * 1024)
    _, ab = _pair_named(cl, "keep", 0, 2)
    _run(cl, 300)
    got = ab.received
    cl.fabric.detach(1)                 # unrelated node departs
    _run(cl, 300)
    assert ab.received > got


# ---------------------------------------------------------------------------
# migration under receiver pressure
# ---------------------------------------------------------------------------


def test_migration_under_receiver_pressure_converges():
    """A pre-copy migration whose destination ingress is bounded and
    already loaded by app incast still converges: the MIG stream rides
    the same RNR/backoff machinery instead of timing out."""
    cl = SimCluster(4, link_bandwidth_Bps=BPS)
    cl.configure_ingress(rx_bandwidth_Bps=BPS, queue_bytes=48 * 1024,
                         node=3)
    for i in range(2):                  # app pressure into the dest node
        _pair_named(cl, f"noisy{i}", i + 1, 3)
    bulk = cl.launch("bulk", 0)
    mr = bulk.ctx.alloc_pd().reg_mr(32 * PAGE_SIZE)
    for pg in range(32):
        mr.write(pg * PAGE_SIZE, bytes([pg % 251]) * PAGE_SIZE)
    _run(cl, 500)
    assert cl.fabric.ingress_utilization(3) > 0.5   # genuinely loaded
    rep = cl.migrate("bulk", 3, strategy="pre_copy")
    assert rep.ok
    assert cl.fabric.stats["rx_dropped@3"] > 0      # pressure was real
    moved = cl.containers["bulk"]
    assert moved.node is cl.nodes[3]
    assert moved.ctx.mrs[0].read(5 * PAGE_SIZE, 8) == bytes([5]) * 8


def test_admission_prices_destination_ingress():
    """The orchestrator's transfer estimate must reflect the
    destination's receive path: an undersized/loaded ingress shrinks
    effective bandwidth, and a tight budget rejects the request."""
    def plan_for(rx_Bps):
        cl = SimCluster(2, link_bandwidth_Bps=BPS)
        if rx_Bps is not None:
            cl.configure_ingress(rx_bandwidth_Bps=rx_Bps,
                                 queue_bytes=64 * 1024, node=1)
        bulk = cl.launch("bulk", 0)
        bulk.ctx.alloc_pd().reg_mr(64 * PAGE_SIZE)
        return cl, cl.orchestrator.admit(bulk, cl.nodes[1])

    _, fast = plan_for(None)
    _, slow = plan_for(BPS / 20)
    assert "ingress" in fast.checks and "ingress" in slow.checks
    assert slow.est_transfer_s > 10 * fast.est_transfer_s

    cl = SimCluster(2, link_bandwidth_Bps=BPS)
    cl.configure_ingress(rx_bandwidth_Bps=BPS / 20,
                         queue_bytes=64 * 1024, node=1)
    cl.orchestrator.max_transfer_s = fast.est_transfer_s * 2
    bulk = cl.launch("bulk", 0)
    bulk.ctx.alloc_pd().reg_mr(64 * PAGE_SIZE)
    with pytest.raises(AdmissionError, match="ingress"):
        cl.orchestrator.admit(bulk, cl.nodes[1])


# ---------------------------------------------------------------------------
# PR 3 figure baselines: unlimited ingress + QoS off change nothing
# ---------------------------------------------------------------------------


def test_defaults_reproduce_pr3_downtime_figures():
    """The sim-clock figures of benchmarks/fig_downtime.py under the
    default (QoS off, unlimited ingress) are pinned byte-for-byte to
    their PR 3 values: the ingress refactor must be a pass-through."""
    from benchmarks import fig_downtime
    expected = {
        "stop_and_copy": (0.005677, 0.005677, 8),
        "pre_copy": (0.00011399999999999999, 0.00604, 86),
        "post_copy": (7e-05, 0.008688, 1),
    }
    for name, (down_exp, total_exp, received_exp) in expected.items():
        rep, down, total, ab = fig_downtime.run_strategy(name)
        assert rep.ok
        assert down == down_exp, f"{name} downtime drifted: {down!r}"
        assert total == total_exp, f"{name} total drifted: {total!r}"
        assert ab.received == received_exp


def test_defaults_reproduce_pr3_contention_figure(capsys):
    """fig_contention's dip/recovery assertions (the PR 3 acceptance
    bar) still hold under the defaults."""
    from benchmarks import fig_contention
    fig_contention.main()               # raises AssertionError on drift
    capsys.readouterr()
