"""Delta-aware migration page codec: unit roundtrips, end-to-end
pre-copy integration, the convergence controller, and the on-wire
transfer-budget accounting."""
import random

import pytest

from repro.core import pagecodec
from repro.core.pagecodec import (CodecConfig, CodecError, PageCodec,
                                  decode_batch, page_digest)
from repro.core.packets import Op
from repro.core.verbs import PAGE_SIZE
from repro.runtime.apps import SendBwApp
from repro.runtime.cluster import SimCluster
from repro.runtime.collectives import connect_pair

CFG = CodecConfig(enabled=True)


def _rand_page(seed, n=PAGE_SIZE):
    return random.Random(seed).randbytes(n)


def _roundtrip(codec, pages, stage, store):
    metas, payload, pending, stats = codec.encode_batch(pages)
    decode_batch(metas, payload, stage, store)
    codec.commit(pending)
    return metas, stats


# -- unit: the four record kinds --------------------------------------------

def test_record_kinds_roundtrip():
    codec = PageCodec(CFG)
    stage, store = {}, {}
    zero = bytes(PAGE_SIZE)
    pa, pb = _rand_page(1), _rand_page(2)
    metas, stats = _roundtrip(
        codec, [(1, 0, pa), (1, 1, zero), (1, 2, pa), (1, 3, pb)],
        stage, store)
    kinds = [m[3] for m in metas]
    assert kinds == [pagecodec.PAGE_FULL, pagecodec.PAGE_ZERO,
                     pagecodec.PAGE_DUP, pagecodec.PAGE_FULL]
    assert stats == {**stats, "full": 2, "zero": 1, "dup": 1, "delta": 0}
    assert stage[(1, 0)] == pa and stage[(1, 2)] == pa
    assert stage[(1, 1)] == zero and stage[(1, 3)] == pb

    # re-dirty page 0 with a tiny in-place change: ships as a delta
    pa2 = bytearray(pa)
    pa2[100:108] = b"\x00" * 8
    pa2 = bytes(pa2)
    metas, stats = _roundtrip(codec, [(1, 0, pa2)], stage, store)
    assert metas[0][3] == pagecodec.PAGE_DELTA
    assert metas[0][4] < PAGE_SIZE and stats["delta_saved"] > 0
    assert stage[(1, 0)] == pa2


def test_delta_against_zero_page_falls_back_to_full():
    """Zero pages never enter the receiver's content store, so a page
    that was all-zero last round must re-ship FULL, never DELTA."""
    codec = PageCodec(CFG)
    stage, store = {}, {}
    _roundtrip(codec, [(1, 0, bytes(PAGE_SIZE))], stage, store)
    metas, _ = _roundtrip(codec, [(1, 0, _rand_page(3))], stage, store)
    assert metas[0][3] == pagecodec.PAGE_FULL


def test_decode_is_idempotent_under_redelivery():
    """A delivered-but-unacked batch may be re-encoded after the page
    changed; decoding the OLD records again (delta base resolved through
    the content store, not the mutable staged value) must still
    reproduce exactly the old content."""
    codec = PageCodec(CFG)
    stage, store = {}, {}
    p0 = _rand_page(4)
    _roundtrip(codec, [(1, 0, p0)], stage, store)
    p1 = bytearray(p0)
    p1[0:8] = b"\xffper-rnd"
    metas1, payload1, pending1, _ = codec.encode_batch([(1, 0, bytes(p1))])
    assert metas1[0][3] == pagecodec.PAGE_DELTA
    decode_batch(metas1, payload1, stage, store)    # delivered...
    # ...but never acked: sender re-encodes from committed state with
    # NEWER content, and the receiver then sees the old batch again
    p2 = bytearray(p0)
    p2[0:8] = b"\xeenewer!!"
    metas2, payload2, pending2, _ = codec.encode_batch([(1, 0, bytes(p2))])
    decode_batch(metas2, payload2, stage, store)
    decode_batch(metas1, payload1, stage, store)    # re-delivery (stale)
    assert stage[(1, 0)] == bytes(p1)
    decode_batch(metas2, payload2, stage, store)
    assert stage[(1, 0)] == bytes(p2)


def test_unknown_digest_raises():
    """A DUP/DELTA record referencing content the receiver never staged
    is the invalidation bug the codec must refuse to hide."""
    codec = PageCodec(CFG)
    codec.staged[page_digest(_rand_page(5))] = True   # stale cache entry
    metas, payload, _, _ = codec.encode_batch([(1, 0, _rand_page(5))])
    assert metas[0][3] == pagecodec.PAGE_DUP
    with pytest.raises(CodecError):
        decode_batch(metas, payload, {}, {})


def test_dump_restore_roundtrip():
    codec = PageCodec(CFG)
    stage, store = {}, {}
    _roundtrip(codec, [(1, 0, _rand_page(6)), (2, 3, _rand_page(7))],
               stage, store)
    back = PageCodec.restore(CFG, codec.dump())
    assert back.staged == codec.staged
    assert back.snaps == codec.snaps
    assert PageCodec.restore(CFG, {}).dump() == {}


def test_image_encode_roundtrip():
    blob = b"\x00" * 4096 + _rand_page(8)
    enc = pagecodec.encode_image(blob, CFG)
    assert len(enc) < len(blob)
    assert pagecodec.decode_image(enc) == blob
    raw = _rand_page(9, 64)    # incompressible: ships raw + 1 tag byte
    assert pagecodec.decode_image(pagecodec.encode_image(raw, CFG)) == raw


# -- integration: pre-copy with the codec on --------------------------------

def _codec_cluster():
    cl = SimCluster(3, link_bandwidth_Bps=1e8)
    cl.configure_codec(enabled=True)
    A = cl.launch("send", 0)
    B = cl.launch("recv", 1)
    aa = SendBwApp(msg_size=4096, window=16, buf_size=64 * 1024)
    aa.attach(A, sender=True)
    A.app = aa
    ab = SendBwApp(msg_size=4096, window=16, buf_size=64 * 1024)
    ab.attach(B, sender=False)
    B.app = ab
    connect_pair(aa.channels[0], ab.channels[0])
    # a second MR with a zero region and duplicate content pages
    mr = B.ctx.pds[0].reg_mr(64 * PAGE_SIZE)
    blk = bytes(range(256)) * (PAGE_SIZE // 256)
    for pg in range(8, 24):
        mr.write(pg * PAGE_SIZE, blk)
    return cl, B, mr.mrn, blk


def test_pre_copy_codec_end_to_end():
    cl, B, mrn, blk = _codec_cluster()
    for _ in range(40):
        cl.step_all()
    w0 = cl.fabric.stats.get("mig_tx_bytes", 0)
    rep = cl.migrate("recv", 2, strategy="pre_copy")
    wire = cl.fabric.stats.get("mig_tx_bytes", 0) - w0
    assert rep.ok
    # every staged/installed byte equals the source pattern
    mr = next(m for m in B.ctx.mrs if m.mrn == mrn)
    for pg in range(8, 24):
        assert bytes(mr.buf[pg * PAGE_SIZE:(pg + 1) * PAGE_SIZE]) == blk
    assert bytes(mr.buf[24 * PAGE_SIZE:]) == bytes(40 * PAGE_SIZE)
    # the codec genuinely shrank the stream and accounted itself
    logical = sum(r["bytes"] for r in rep.rounds)
    encoded = sum(r["wire_bytes"] for r in rep.rounds)
    assert encoded < logical
    assert wire < logical
    stats = cl.fabric.stats
    assert stats.get("pages_zero_elided", 0) > 0
    assert stats.get("pages_dedup_hits", 0) > 0
    for name, (bare, twin) in cl.fabric.metrics.node_twin_sums().items():
        assert bare == twin, f"twin invariant broken for {name}"
    # the decode store is released with the staging
    for node in cl.nodes:
        assert not node.device.service.codec_rx


def test_convergence_cutover():
    """A workload whose dirty set never shrinks (full-page fresh random
    content each step) must trip the convergence controller instead of
    burning the whole round budget."""
    cl = SimCluster(3, link_bandwidth_Bps=1e8)
    cl.configure_codec(enabled=True)
    c = cl.launch("churn", 0)
    pd = c.ctx.alloc_pd()
    mr = pd.reg_mr(32 * PAGE_SIZE)

    class Churn:
        ticks = 0

        def step(self):
            Churn.ticks += 1
            for pg in range(16):
                mr.write(pg * PAGE_SIZE,
                         _rand_page((Churn.ticks << 8) | pg))

        def checkpoint(self):
            return b""

        def restore(self, blob):
            pass

        def rebind(self, container, session):
            pass

    c.app = Churn()
    for _ in range(10):
        cl.step_all()
    rep = cl.migrate("churn", 1, strategy="pre_copy")
    assert rep.ok
    assert len(rep.rounds) < 8, "cutover should beat the round cap"
    assert any(r.get("cutover") for r in rep.rounds)
    assert cl.fabric.stats.get("codec_cutovers", 0) == 1


def test_transfer_budget_uses_wire_size():
    """``transfer`` must budget its timeout from the packed on-wire blob
    (``last_post_nbytes``), which is what actually serialises — not the
    logical payload."""
    cl = SimCluster(2)
    svc = cl.nodes[0].device.service
    xid = svc.post(cl.nodes[1].device.gid, Op.MIG_STATE,
                   {"kind": "probe"}, b"z" * 4096)
    assert svc.last_post_nbytes > 4096    # meta + msgpack framing
    cl.fabric.pump_until(lambda: xid in svc.acked, 100_000)
