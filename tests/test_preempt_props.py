"""Property-based preemption protocol harness.

Seeded-random schedules (``numpy.random.RandomState`` — the repo's
stand-in for hypothesis, same pattern as ``tests/test_properties.py``)
interleave operator pause/resume/abort with loss, ECN, and bounded-
ingress fabric conditions across all three migration strategies, then
assert the protocol invariants that must hold on EVERY trajectory:

* a paused-and-resumed migration completes with the destination memory
  image equal to the source (pattern planted in a page the app never
  writes, read back through the restored handle table — post-copy
  drains its pager first);
* no service-channel state leaks: after the outcome settles, every
  device's service has an empty tx backlog, no staged pages, no frozen
  page store, no suspended-peer flags, and no QP anywhere is left
  ``STOPPED``;
* the metrics counter grammar holds: ``sum(name@gid) == name`` for
  every node-attributable counter (``node_twin_sums``);
* the attempt token survives serialisation: ``from_bytes(to_bytes())``
  is byte-stable;
* pause+resume is never worse than uninterrupted *in the accounting*:
  ``transfer_s``/``downtime_s`` are independent of how long the
  migration sat parked — the gap lands in ``paused_s`` and nowhere
  else (two runs differing only in park duration report identical
  active-time floats).

On any assertion failure the generating schedule is dumped as JSON to
``preempt_failures/`` (CI archives the directory) so the exact
counterexample replays with ``_run_schedule(json.load(...))``.

Seed matrix: ``PREEMPT_SEEDS`` env var (comma-separated ints), default
``0,1,2,3`` — the fixed set CI runs.
"""
import json
import os
from pathlib import Path

import numpy as np
import pytest

from repro.core.migration import MigrationAttempt
from repro.core.states import QPState
from repro.core.transport import STEP_S
from repro.runtime.cluster import SimCluster
from tests.helpers import make_channel_pair, make_sendbw_pair

ARTIFACT_DIR = Path(__file__).resolve().parent.parent / "preempt_failures"
STRATEGIES = ("stop_and_copy", "pre_copy", "post_copy")
_PATTERN = b"\xa5PREEMPT" * 16


def _seeds():
    env = os.environ.get("PREEMPT_SEEDS", "").strip()
    if env:
        return tuple(int(s) for s in env.split(",") if s.strip())
    return (0, 1, 2, 3)


def _draw_schedule(rng: np.random.RandomState, strategy: str) -> dict:
    """One random protocol schedule: fabric conditions + an interleaving
    of deadline pauses, park windows, and resume/abort verdicts. Plain
    JSON-serialisable dict so failures replay from the artifact."""
    cycles = []
    for i in range(int(rng.randint(1, 4))):
        # later cycles may abort; the first parks and resumes so every
        # schedule exercises at least one pause/resume round-trip
        action = "resume" if i == 0 else \
            str(rng.choice(["resume", "resume", "abort"]))
        cycles.append({
            "pause_after": int(rng.randint(1, 40)),
            "park_steps": int(rng.randint(10, 400)),
            "action": action,
        })
    return {
        "strategy": strategy,
        "cluster_seed": int(rng.randint(0, 1000)),
        "loss_prob": float(rng.choice([0.0, 0.0, 0.0, 0.01])),
        "ecn": bool(rng.rand() < 0.3),
        "ingress": bool(rng.rand() < 0.3),
        "codec": bool(rng.rand() < 0.5),
        "pre_steps": int(rng.randint(20, 80)),
        "cycles": cycles,
    }


def _drain_pager(cl, rep):
    if rep.pager is not None:
        while rep.pager.remaining_pages:
            rep.pager.prefetch(16)
            cl.fabric.pump()
        for _ in range(200):       # app steps too: recvs keep refilling
            cl.step_all()


def _assert_no_leaks(cl):
    """Terminal-state invariant: the preemption machinery left nothing
    behind on any device's service channel, and no QP is STOPPED."""
    for node in cl.nodes:
        dev = node.device
        svc = dev.service
        assert svc.tx_backlog == 0, f"node {dev.gid}: tx backlog leaked"
        assert not svc.staging, f"node {dev.gid}: staged pages leaked"
        assert not svc.page_store, f"node {dev.gid}: page store leaked"
        assert not svc._suspended, f"node {dev.gid}: suspend flag leaked"
        assert not svc.codec_rx, f"node {dev.gid}: codec store leaked"
        stopped = [q.qpn for q in dev.qps.values()
                   if q.state == QPState.STOPPED]
        assert not stopped, f"node {dev.gid}: STOPPED QPs {stopped}"


def _assert_counter_grammar(cl):
    for name, (bare, twin) in \
            cl.fabric.metrics.node_twin_sums().items():
        assert bare == twin, (
            f"counter '{name}': bare total {bare} != twin sum {twin}")


def _assert_token_roundtrip(attempt):
    blob = attempt.to_bytes()
    clone = MigrationAttempt.from_bytes(blob)
    assert clone.to_bytes() == blob
    assert (clone.phase, clone.pending, clone.rounds_done) == \
        (attempt.phase, [list(p) for p in attempt.pending]
         if attempt.pending and isinstance(clone.pending[0], list)
         else attempt.pending, attempt.rounds_done)


def _run_schedule(sched: dict):
    """Execute one schedule and check every invariant; returns the
    final report (or None when the schedule ended in an abort)."""
    cl = SimCluster(4, loss_prob=sched["loss_prob"],
                    seed=sched["cluster_seed"])
    if sched["ecn"]:
        cl.configure_ecn(enabled=True)
    if sched.get("codec"):
        cl.configure_codec(enabled=True)
    if sched["ingress"]:
        cl.configure_ingress(rx_bandwidth_Bps=2e8,
                             queue_bytes=32 * 1024, node=2)
    aa, ab = make_sendbw_pair(cl)
    for _ in range(sched["pre_steps"]):
        cl.step_all()
    # plant a pattern in a page the receiver app never writes: the only
    # way it shows up on the destination is a faithful memory transfer
    ch = ab.channels[0]
    ch.h.mr(ch.mrn_send).write(0, _PATTERN)

    rep, aborted = None, False
    for cyc in sched["cycles"]:
        cl.pause_migration("recv", at=cl.fabric.now + cyc["pause_after"])
        rep = cl.migrate("recv", 2, strategy=sched["strategy"]) \
            if rep is None else cl.resume_migration("recv")
        if rep.ok:
            break                       # finished before the deadline hit
        assert rep.attempt is not None, \
            f"not ok yet no attempt token: stage={rep.stage_failed}"
        assert cl.orchestrator.paused.get("recv") is not None
        _assert_token_roundtrip(rep.attempt)
        for _ in range(cyc["park_steps"]):
            cl.step_all()               # app traffic flows while parked
        if cyc["action"] == "abort":
            cl.abort_migration("recv")
            aborted = True
            break
    if not aborted and not rep.ok:
        rep = cl.resume_migration("recv")
        assert rep.ok, f"final resume failed: stage={rep.stage_failed}"

    if aborted:
        # rollback: source container survives in place, traffic recovers
        assert cl.containers["recv"].alive
        assert ch.h.ctx.device.gid == 1
        before = ab.received
        for _ in range(400):
            cl.step_all()
        assert ab.received > before, "traffic dead after abort rollback"
    else:
        _drain_pager(cl, rep)
        assert ch.h.ctx.device.gid == 2, "container not on destination"
        assert ch.h.mr(ch.mrn_send).read(0, len(_PATTERN)) == _PATTERN, \
            "destination memory image diverged from source"
        if rep.preemptions:
            assert rep.paused_s > 0.0
        before = ab.received
        for _ in range(400):
            cl.step_all()
        assert ab.received > before, "traffic dead after resume"

    for _ in range(600):                # let RTO/RNR tails settle
        cl.step_all()
    _assert_no_leaks(cl)
    _assert_counter_grammar(cl)
    return rep


def _dump_artifact(sched: dict, err: AssertionError) -> Path:
    ARTIFACT_DIR.mkdir(exist_ok=True)
    name = (f"{sched['strategy']}_seed{sched['cluster_seed']}"
            f"_{abs(hash(json.dumps(sched, sort_keys=True))) % 10**8}.json")
    path = ARTIFACT_DIR / name
    path.write_text(json.dumps(
        {"schedule": sched, "error": str(err)}, indent=2))
    return path


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("seed", _seeds())
def test_preemption_schedule_invariants(strategy, seed):
    rng = np.random.RandomState(seed * 7919 + hash(strategy) % 1000)
    sched = _draw_schedule(rng, strategy)
    try:
        _run_schedule(sched)
    except AssertionError as err:
        path = _dump_artifact(sched, err)
        raise AssertionError(
            f"schedule failed (replay artifact: {path}): {err}") from err


# -- codec invalidation: resume onto a NEW destination ---------------------


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_codec_resume_new_destination(strategy):
    """Pause a codec-enabled migration mid-flight, then resume it onto a
    DIFFERENT destination. The dedup/delta-base cache described content
    staged only at the old node; the protocol must invalidate it (a
    stale PAGE_DUP/PAGE_DELTA against the new node raises ``CodecError``
    receiver-side, failing the migration), and the installed image —
    zero band, duplicate band, planted pattern — must still equal the
    source exactly."""
    import random

    from repro.core.verbs import PAGE_SIZE

    cl = SimCluster(4, link_bandwidth_Bps=1e8)
    cl.configure_codec(enabled=True)
    aa, ab = make_sendbw_pair(cl)
    for _ in range(30):
        cl.step_all()
    ch = ab.channels[0]
    ch.h.mr(ch.mrn_send).write(0, _PATTERN)
    # a large extra MR: zero band + duplicate band (codec-friendly) +
    # an incompressible random band that keeps round 0 on the wire long
    # enough for the pause to land with a PARTIALLY-populated digest
    # cache — the case invalidation exists for
    blk = bytes(range(256)) * (PAGE_SIZE // 256)
    rnd = {pg: random.Random(pg).randbytes(PAGE_SIZE)
           for pg in range(48, 112)}
    mr = ab.container.ctx.pds[0].reg_mr(128 * PAGE_SIZE)
    for pg in range(16, 48):
        mr.write(pg * PAGE_SIZE, blk)
    for pg, blob in rnd.items():
        mr.write(pg * PAGE_SIZE, blob)
    mrn = mr.mrn

    # deadline tuned per strategy so the pause lands mid-stream with
    # real progress behind it: post-copy's stop window is only the tiny
    # verbs image, while pre-copy / stop-and-copy serialise the random
    # band for thousands of steps (batch 1 — the zero/dup band — acks
    # around step ~700, so 1200 lands inside batch 2 with the digest
    # cache partially populated)
    deadline = 60 if strategy == "post_copy" else 1200
    cl.pause_migration("recv", at=cl.fabric.now + deadline)
    rep = cl.migrate("recv", 2, strategy=strategy)
    assert not rep.ok and rep.attempt is not None
    _assert_token_roundtrip(rep.attempt)
    if strategy == "pre_copy":
        assert rep.attempt.phase == "live"
        assert rep.attempt.pages_sent > 0
        assert rep.attempt.codec, \
            "live pre-copy token must carry codec state"
    for _ in range(200):
        cl.step_all()

    rep = cl.resume_migration("recv", dest_idx=3)
    assert rep.ok, f"resume onto new dest failed: {rep.stage_failed}"
    assert ch.h.ctx.device.gid == 3
    assert ch.h.mr(ch.mrn_send).read(0, len(_PATTERN)) == _PATTERN
    _drain_pager(cl, rep)
    mr2 = next(m for m in ab.container.ctx.mrs if m.mrn == mrn)
    assert bytes(mr2.buf[:16 * PAGE_SIZE]) == bytes(16 * PAGE_SIZE)
    for pg in range(16, 48):
        assert bytes(mr2.buf[pg * PAGE_SIZE:(pg + 1) * PAGE_SIZE]) == blk
    for pg, blob in rnd.items():
        assert bytes(mr2.buf[pg * PAGE_SIZE:(pg + 1) * PAGE_SIZE]) \
            == blob, f"random page {pg} corrupted"
    assert bytes(mr2.buf[112 * PAGE_SIZE:]) == bytes(16 * PAGE_SIZE)
    # the post-copy pager's fire-and-forget wire charges for the random
    # band (~260 KiB at 100 B/step) take thousands of steps to serialise
    # after the fills have already applied — drain the link before the
    # leak check
    cl.fabric.pump_until(
        lambda: all(n.device.service.tx_backlog == 0 for n in cl.nodes),
        200_000)
    for _ in range(600):
        cl.step_all()
    _assert_no_leaks(cl)
    _assert_counter_grammar(cl)


# -- accounting property: paused time never inflates active time -----------


def _accounting_run(strategy: str, park_steps: int):
    """Pause at a fixed deadline, park for ``park_steps``, resume.
    The appless channel pair keeps the fabric deterministic and idle
    while parked, so two runs differ ONLY in park duration."""
    cl = SimCluster(3)
    c1, c2, ca, cb = make_channel_pair(cl)
    cl.run_until_idle()
    cl.pause_migration("b", at=cl.fabric.now + 3)
    rep = cl.migrate("b", 2, strategy=strategy)
    assert not rep.ok and rep.attempt is not None
    parked_from = cl.fabric.now
    for _ in range(park_steps):
        cl.step_all()
    rep = cl.resume_migration("b")
    assert rep.ok
    return rep, (cl.fabric.now - parked_from)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_paused_time_excluded_from_active_time(strategy):
    """transfer_s/downtime_s must be bit-identical whether the migration
    sat parked for 50 steps or 5000 — the entire extra gap lands in
    paused_s. This is 'pause+resume never worse than uninterrupted' in
    its strongest falsifiable form: the reported cost metrics do not
    grow with pause duration. Both parks are long enough for the
    preempted leg's in-flight packets to drain, so the resumed legs
    start from identical wire states and only the gap length differs."""
    short, _ = _accounting_run(strategy, 2000)
    long, _ = _accounting_run(strategy, 8000)
    assert long.transfer_s == short.transfer_s
    assert long.downtime_s == short.downtime_s
    assert long.paused_s > short.paused_s
    # the paused_s delta is exactly the extra park time
    assert long.paused_s - short.paused_s == \
        pytest.approx(6000 * STEP_S, rel=1e-9)
