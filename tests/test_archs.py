"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward/train step on CPU with finite loss and
correct shapes; decode agrees with full forward (capacity bumped for MoE
so dropping doesn't differ between batch sizes)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.models.model import LM
from repro.optim import adamw


def _batch(cfg, key, B=2, S=64):
    batch = {"tokens": jax.random.randint(key, (B, S), 0,
                                          cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["tokens"] = batch["tokens"][:, :S - cfg.frontend_tokens]
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    lm = LM(cfg)
    key = jax.random.PRNGKey(0)
    params = lm.init(key)
    state = adamw.init_state(params)
    step = jax.jit(adamw.make_train_step(lm, adamw.OptConfig(lr=1e-3)))
    batch = _batch(cfg, key)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state["step"]) == 1
    # params actually moved
    moved = any(not np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(state["params"])))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes(arch):
    cfg = get_smoke_config(arch)
    lm = LM(cfg)
    key = jax.random.PRNGKey(1)
    params = lm.init(key)
    batch = _batch(cfg, key)
    logits, aux, off = lm.forward(params, batch)
    B = batch["tokens"].shape[0]
    assert logits.shape[0] == B
    assert logits.shape[-1] == cfg.padded_vocab
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        # avoid capacity-drop differences between T=130 and T=2 dispatch
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=64.0))
    lm = LM(cfg)
    key = jax.random.PRNGKey(2)
    params = lm.init(key)
    B, S = 2, 64
    batch = _batch(cfg, key, B, S)
    cache, last_logits = lm.prefill(params, batch, S + 8)
    logits_full, _, off = lm.forward(params, batch)
    np.testing.assert_allclose(np.asarray(last_logits),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-3, atol=2e-3)
    nxt = jax.random.randint(jax.random.fold_in(key, 3), (B, 1), 0,
                             cfg.vocab_size)
    cache, dec_logits = lm.decode_step(params, cache, nxt)
    batch2 = dict(batch, tokens=jnp.concatenate([batch["tokens"], nxt], 1))
    lf2, _, _ = lm.forward(params, batch2)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(lf2[:, -1]), rtol=2e-2,
                               atol=2e-2)


def test_full_configs_have_spec_sizes():
    """Full configs match the assigned parameter table exactly."""
    from repro.configs.base import get_config
    spec = {
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "mamba2-2.7b": (64, 2560, 80, 80, 0, 50280),
    }
    for arch, (L, D, H, Kh, F, V) in spec.items():
        c = get_config(arch)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (L, D, H, Kh, F, V), arch


def test_moe_extras():
    from repro.configs.base import get_config
    v2 = get_config("deepseek-v2-236b")
    assert (v2.moe.num_experts, v2.moe.top_k, v2.moe.num_shared) == \
        (160, 6, 2)
    assert (v2.mla.kv_lora_rank, v2.mla.qk_rope_head_dim) == (512, 64)
    m16 = get_config("deepseek-moe-16b")
    assert (m16.moe.num_experts, m16.moe.top_k) == (64, 6)
    mam = get_config("mamba2-2.7b")
    assert mam.ssm.d_state == 128
