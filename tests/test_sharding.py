"""Logical-axis resolver + small-mesh end-to-end lowering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_mesh
from repro.sharding import partition as part


def _abstract_mesh(shape, axes):
    try:   # newer jax: AbstractMesh(axis_sizes, axis_names)
        return jax.sharding.AbstractMesh(shape, axes)
    except TypeError:   # older jax: AbstractMesh(((name, size), ...))
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def test_resolver_basic_rules():
    mesh = _abstract_mesh((2, 4), ("data", "model"))
    assert part.resolve(("embed", "ffn"), (64, 64), mesh) == \
        P("data", "model")
    assert part.resolve(("vocab", "embed"), (256, 64), mesh) == \
        P("model", "data")


def test_resolver_drops_nondivisible():
    mesh = _abstract_mesh((2, 4), ("data", "model"))
    # 6 % 4 != 0 -> model dropped on that dim
    assert part.resolve(("embed", "ffn"), (64, 6), mesh) == P("data")
    # MQA: single kv head can't shard
    assert part.resolve((None, None, "heads", None), (8, 128, 1, 64),
                        mesh) == P()


def test_resolver_uses_unused_subset():
    mesh = _abstract_mesh((2, 4), ("data", "model"))
    # batch takes data; seq_kv=("data","model") falls back to model only
    spec = part.resolve(("batch", "seq_kv", None), (8, 128, 16), mesh)
    assert spec == P("data", "model")
    # batch=1: batch dropped; seq_kv gets both axes
    spec = part.resolve(("batch", "seq_kv", None), (1, 128, 16), mesh)
    assert spec[0] is None and set(spec[1]) == {"data", "model"}


def test_resolver_missing_axes_single_pod():
    mesh = _abstract_mesh((4,), ("data",))
    # ("pod","data") with no pod axis -> data only
    assert part.resolve(("batch", None), (8, 16), mesh) == P("data")


def test_constrain_is_identity_without_mesh():
    x = jnp.ones((4, 4))
    assert part.constrain(x, ("batch", None)) is x


def test_small_mesh_train_step_runs():
    """Real (non-dry-run) sharded train step on host devices."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device")
    from repro.configs.base import get_smoke_config
    from repro.models.model import LM
    from repro.optim import adamw
    n = len(jax.devices())
    mesh = make_mesh((1, n), ("data", "model"))
    cfg = get_smoke_config("gemma3-1b")
    lm = LM(cfg)
    with part.activate(mesh):
        params = lm.init(jax.random.PRNGKey(0))
        state = adamw.init_state(params)
        step = jax.jit(adamw.make_train_step(lm, adamw.OptConfig()))
        batch = {"tokens": jnp.zeros((2, 64), jnp.int32)}
        state, m = step(state, batch)
        assert np.isfinite(float(m["loss"]))
