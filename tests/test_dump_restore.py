"""dump_context / restore_object round-trip tests (paper §3.2, Table 2)."""
import msgpack
import pytest

from repro.core import dump as dumplib
from repro.core.states import QPState
from repro.runtime.cluster import SimCluster
from tests.helpers import make_channel_pair


def _ctx_with_traffic():
    cl = SimCluster(2)
    c1, c2, ca, cb = make_channel_pair(cl)
    c2.post_recv(4096)
    c1.post_send_bytes(b"y" * 4096)
    cl.pump(3)    # leave packets in flight
    return cl, c1, c2, ca, cb


def test_dump_stops_all_qps():
    cl, c1, c2, ca, cb = _ctx_with_traffic()
    dumplib.dump_context(ca.ctx)
    for qp in ca.ctx.qps:
        assert qp.state == QPState.STOPPED


def test_dump_covers_all_object_types():
    cl, c1, c2, ca, cb = _ctx_with_traffic()
    srq = ca.ctx.create_srq()
    img = msgpack.unpackb(dumplib.dump_context(ca.ctx), raw=False)
    assert img["pds"] and img["mrs"] and img["cqs"] and img["qps"]
    assert img["srqs"][0]["type"] == "SRQ"
    qp = img["qps"][0]
    for f in ("sq_psn", "una", "epsn", "inflight", "sq", "rq",
              "pending_comp", "cur_wqe"):
        assert f in qp


def test_restore_roundtrip_preserves_everything():
    cl, c1, c2, ca, cb = _ctx_with_traffic()
    src = ca.ctx
    qp0 = src.qps[0]
    snap = (qp0.qpn, qp0.sq_psn, qp0.una, qp0.epsn, len(qp0.inflight),
            [(m.mrn, m.lkey, m.rkey) for m in src.mrs])
    blob = dumplib.dump_context(src)

    ctx2 = cl.nodes[1].device.open_context()
    # free the numbers on the source device first (container destroyed)
    for qp in list(src.qps):
        src.device.destroy_qp(qp.qpn)
    s = dumplib.restore_context(ctx2, blob)
    qp1 = ctx2.qps[0]
    assert (qp1.qpn, qp1.sq_psn, qp1.una, qp1.epsn,
            len(qp1.inflight)) == snap[:5]
    assert [(m.mrn, m.lkey, m.rkey) for m in ctx2.mrs] == snap[5]
    assert qp1.state == QPState.RTS
    assert qp1.resume_pending     # REFILL queued the resume message


def test_qpn_collision_detected():
    cl = SimCluster(2)
    dev = cl.nodes[0].device
    ctx = dev.open_context()
    pd = ctx.alloc_pd()
    cq = ctx.create_cq()
    qp = pd.create_qp(cq, cq)
    dev.last_qpn = qp.qpn - 1     # force reuse of an occupied QPN
    with pytest.raises(RuntimeError, match="collision"):
        pd.create_qp(cq, cq)


def test_mrn_collision_detected():
    cl = SimCluster(2)
    dev = cl.nodes[0].device
    ctx = dev.open_context()
    pd = ctx.alloc_pd()
    mr = pd.reg_mr(64)
    dev.last_mrn = mr.mrn - 1
    with pytest.raises(RuntimeError, match="collision"):
        pd.reg_mr(64)


def test_namespace_partitioning_gives_disjoint_ranges():
    from repro.core.namespace import GlobalNamespace, RANGE
    ns = GlobalNamespace()
    bases = [ns.range_for(g) for g in range(8)]
    assert len(set(bases)) == 8
    assert GlobalNamespace.owner_of(bases[3] + 17) == 3


def test_object_dump_sizes_are_small():
    """Paper Table 2: per-object dumps are tens to hundreds of bytes."""
    cl, c1, c2, ca, cb = _ctx_with_traffic()
    img = msgpack.unpackb(dumplib.dump_context(ca.ctx, stop=False),
                          raw=False)
    pd_size = len(msgpack.packb(img["pds"][0]))
    mr_size = len(msgpack.packb(img["mrs"][0]))
    cq_size = len(msgpack.packb(img["cqs"][0]))
    assert pd_size < 64
    assert mr_size < 128
    assert cq_size < 256          # empty ring
