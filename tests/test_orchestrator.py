"""Orchestrator + live-migration engine tests: pre-copy convergence and
round-cap fallback, post-copy demand faulting, admission, queueing,
retry-after-failed-transfer, and rollback (no leaked stopped QPs)."""
import pytest

from repro.core.migration import MigrationError
from repro.core.states import QPState
from repro.core.transport import STEP_S
from repro.core.verbs import (PAGE_SIZE, CompletionQueue, CQOverrunError,
                              WCStatus, WorkCompletion)
from repro.orchestrator import (AdmissionError, DemandPager, PreCopy,
                                choose_migration_strategy)
from repro.runtime.cluster import SimCluster
from tests.helpers import make_channel_pair, make_sendbw_pair


def _run(cl, n):
    for _ in range(n):
        cl.step_all()


def _qp(app):
    ch = app.channels[0]
    return ch.h.qp(ch.qpn)


# ---------------------------------------------------------------------------
# pre-copy
# ---------------------------------------------------------------------------


def test_precopy_converges_on_quiet_container():
    """No writes during the live phase -> the very first delta round sees
    zero dirty bytes and the residual is empty."""
    cl = SimCluster(3)
    c1, c2, ca, cb = make_channel_pair(cl)
    cl.run_until_idle()
    rep = cl.migrate("b", 2, strategy="pre_copy")
    assert rep.ok
    assert len(rep.rounds) == 1            # round 0 only: converged at once
    assert rep.pages_sent == rep.pages_total
    assert rep.simulated_downtime_s < rep.rounds[0]["sim_s"]
    c2.h.ctx = cb.ctx      # appless container: rebind handles by hand
    # the channel still works end to end after the move
    c2.post_recv(512)
    c1.post_send_bytes(b"q" * 512)
    cl.run_until_idle()
    assert c2.recv_bytes(0, 512) == b"q" * 512


def test_precopy_write_active_keeps_running_and_bounds_downtime():
    """A write-active receiver migrates with traffic flowing: rounds
    re-send only dirtied pages and the stop window moves far less than the
    full footprint."""
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    received_before = ab.received
    rep = cl.migrate("recv", 2, strategy="pre_copy")
    assert rep.ok
    # messages kept flowing during the live phase (the whole point)
    assert ab.received > received_before
    assert rep.pages_sent >= rep.pages_total
    # residual (stop-window) bytes are a strict subset of the footprint
    full_bytes = rep.pages_total * PAGE_SIZE
    assert rep.simulated_downtime_s * cl.migrator.bw < full_bytes
    _run(cl, 400)
    assert ab.channels[0].h.ctx.device.gid == 2
    assert ab.received > received_before + 100


def test_precopy_round_cap_falls_back_to_stop_and_copy():
    """threshold=-1 can never converge; the engine must cut over after
    exactly max_rounds and finish with a stop-and-copy of the residual."""
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    rep = cl.migrate("recv", 2, strategy="pre_copy",
                     strategy_params={"threshold_bytes": -1,
                                      "max_rounds": 4})
    assert rep.ok
    assert len(rep.rounds) == 4            # round 0 + 3 delta rounds
    before = ab.received
    _run(cl, 400)
    assert ab.received > before


def test_precopy_transparent_for_trainer():
    """Loss trajectory is bitwise identical under a pre-copy migration."""
    from repro.runtime.trainer import FabricTrainer
    ref = FabricTrainer(2, seed=3)
    l_ref = ref.train(6)
    mig = FabricTrainer(2, seed=3)
    l_mig = [mig.step() for _ in range(3)]
    rep = mig.cluster.migrate("rank1", len(mig.cluster.nodes) - 1,
                              strategy="pre_copy")
    assert rep.ok
    l_mig += [mig.step() for _ in range(3)]
    assert l_mig == l_ref


# ---------------------------------------------------------------------------
# post-copy
# ---------------------------------------------------------------------------


def test_postcopy_demand_faults_pages_on_access():
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    # plant a pattern the destination can only get by faulting it in
    mr = ab.channels[0].h.mr(ab.channels[0].mrn_send)
    mr.write(0, b"\xabPOSTCOPY" * 16)
    rep = cl.migrate("recv", 2, strategy="post_copy")
    assert rep.ok and rep.pager is not None
    assert rep.pager.remaining_pages > 0   # pages NOT moved in stop window
    faults0 = rep.pager.faults
    # demand fault via a read through the restored handle table
    got = ab.channels[0].h.mr(ab.channels[0].mrn_send).read(0, 144)
    assert got == b"\xabPOSTCOPY" * 16
    assert rep.pager.faults > faults0
    # resumed traffic faults the recv MR in as packets land
    before = ab.received
    _run(cl, 400)
    assert ab.received > before


def test_postcopy_stop_window_excludes_memory():
    """The post-copy image is verbs+user state only — orders of magnitude
    smaller than the stop-and-copy image for the same container."""
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    full = cl.migrate("recv", 2)           # seed stop-and-copy
    cl2 = SimCluster(3)
    aa2, ab2 = make_sendbw_pair(cl2)
    _run(cl2, 50)
    post = cl2.migrate("recv", 2, strategy="post_copy")
    assert post.image_bytes < full.image_bytes / 4


def test_postcopy_prefetch_drains_and_detaches_pager():
    cl = SimCluster(3)
    c1, c2, ca, cb = make_channel_pair(cl, size=8 * PAGE_SIZE)
    cl.run_until_idle()
    rep = cl.migrate("b", 2, strategy="post_copy")
    pager = rep.pager
    assert pager.remaining_pages > 0
    while pager.remaining_pages:
        assert pager.prefetch(4) > 0
    # fully resident: the fast-path hook is gone from every MR
    assert all(m.pager is None for m in cb.ctx.mrs)
    c2.h.ctx = cb.ctx      # appless container: rebind handles by hand
    c2.post_recv(256)
    c1.post_send_bytes(b"z" * 256)
    cl.run_until_idle()
    assert c2.recv_bytes(0, 256) == b"z" * 256


# ---------------------------------------------------------------------------
# orchestrator: admission, queueing, retry, rollback
# ---------------------------------------------------------------------------


def test_admission_rejects_full_node():
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    cl.nodes[2].capacity = 0
    _run(cl, 20)
    with pytest.raises(AdmissionError, match="capacity"):
        cl.migrate("recv", 2, strategy="stop_and_copy")
    # nothing was stopped: traffic unaffected
    before = ab.received
    _run(cl, 100)
    assert ab.received > before


def test_admission_rejects_qpn_collision():
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 20)
    qpn = ab.channels[0].qpn
    # occupy the migrating QPN on the destination device
    dev = cl.nodes[2].device
    ctx = dev.open_context()
    pd = ctx.alloc_pd()
    cq = ctx.create_cq()
    dev.last_qpn = qpn - 1
    pd.create_qp(cq, cq)
    with pytest.raises(AdmissionError, match="QPN"):
        cl.migrate("recv", 2, strategy="stop_and_copy")


def test_admission_rejects_over_bandwidth_budget():
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 20)
    cl.orchestrator.max_transfer_s = 1e-12
    with pytest.raises(AdmissionError, match="budget"):
        cl.migrate("recv", 2, strategy="stop_and_copy")


def test_queue_serialises_concurrent_requests():
    cl = SimCluster(4)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    orch = cl.orchestrator
    orch.submit(cl.containers["send"], cl.nodes[2], strategy="pre_copy")
    orch.submit(cl.containers["recv"], cl.nodes[3], strategy="pre_copy")
    reports = orch.drain()
    assert len(reports) == 2 and all(r.ok for r in reports)
    before = ab.received
    _run(cl, 1500)
    assert ab.received > before
    assert aa.channels[0].h.ctx.device.gid == 2
    assert ab.channels[0].h.ctx.device.gid == 3


def test_rejected_request_does_not_abort_queue():
    """An admission failure for one queued request yields a failed report
    and the remaining requests still execute."""
    cl = SimCluster(4)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    cl.nodes[3].capacity = 0
    orch = cl.orchestrator
    orch.submit(cl.containers["send"], cl.nodes[3])   # will be rejected
    orch.submit(cl.containers["recv"], cl.nodes[2], strategy="pre_copy")
    reports = orch.drain()
    assert len(reports) == 2
    assert not reports[0].ok and reports[0].stage_failed == "admission"
    assert reports[1].ok
    assert ab.channels[0].h.ctx.device.gid == 2
    assert aa.channels[0].h.ctx.device.gid == 0      # never moved


def test_launch_respects_node_capacity():
    cl = SimCluster(2, node_capacity=1)
    cl.launch("a", 0)
    with pytest.raises(ValueError, match="capacity"):
        cl.launch("b", 0)
    cl.launch("b", 1)      # other node still has room


def test_retry_after_transfer_failure_resumes_peers():
    """fail_at='transfer' under the orchestrator: the transfer is retried
    from the captured image, the container lands on the destination, and
    the paused peer resumes instead of hanging on NAK_STOPPED."""
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    rep = cl.migrate("recv", 2, strategy="stop_and_copy",
                     fail_at="transfer", retries=1)
    assert rep.ok and rep.retries == 1 and not rep.rolled_back
    assert cl.containers["recv"].alive
    _run(cl, 600)
    assert _qp(aa).state == QPState.RTS        # peer resumed
    assert _qp(aa).dest_gid == 2               # re-addressed
    before = ab.received
    _run(cl, 200)
    assert ab.received > before
    # no stopped QPs leaked on the source device
    src_dev = cl.nodes[1].device
    assert not [q for q in src_dev.qps.values()
                if q.state == QPState.STOPPED]


def test_rollback_after_checkpoint_failure():
    """fail_at='checkpoint' cannot be retried (no image): the orchestrator
    rolls back — source QPs leave STOPPED in place and peers resume."""
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    rep = cl.migrate("recv", 2, strategy="stop_and_copy",
                     fail_at="checkpoint")
    assert not rep.ok and rep.rolled_back
    assert cl.containers["recv"].alive
    _run(cl, 600)
    assert _qp(aa).state == QPState.RTS
    assert _qp(ab).state == QPState.RTS
    assert ab.channels[0].h.ctx.device.gid == 1   # never moved
    before = ab.received
    _run(cl, 200)
    assert ab.received > before                   # traffic recovered


def test_rollback_when_retries_exhausted():
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    rep = cl.migrate("recv", 2, strategy="pre_copy",
                     fail_at="transfer", retries=0)
    assert not rep.ok and rep.rolled_back
    _run(cl, 600)
    assert _qp(aa).state == QPState.RTS
    assert not [q for q in cl.containers["recv"].ctx.qps
                if q.state == QPState.STOPPED]
    before = ab.received
    _run(cl, 200)
    assert ab.received > before


def test_stop_and_copy_strategy_matches_seed_controller():
    """Byte-identical: same deterministic cluster, same image, same
    delivered message count afterwards."""
    def scenario(strategy):
        cl = SimCluster(3)
        aa, ab = make_sendbw_pair(cl)
        _run(cl, 50)
        kw = {} if strategy is None else {"strategy": strategy}
        rep = cl.migrate("recv", 2, **kw)
        _run(cl, 400)
        return rep.image_bytes, ab.received, ab.sent

    assert scenario(None) == scenario("stop_and_copy")


# ---------------------------------------------------------------------------
# preemption: pause/resume/abort lifecycle, rollback, destination drain
# ---------------------------------------------------------------------------


def test_precopy_pause_mid_round_resume_preserves_image_and_accounting():
    """Acceptance scenario: pre-copy paused mid-round with app traffic
    still bursting, parked, resumed — the destination ends up with the
    same memory image (planted pattern included) and the parked gap is
    attributed to paused_s, never transfer_s."""
    cl = SimCluster(3, link_bandwidth_Bps=1e8)    # slow wire: rounds span
    aa, ab = make_sendbw_pair(cl)                 # many steps, so the
    _run(cl, 50)                                  # deadline lands mid-round
    ch = ab.channels[0]
    pattern = b"\x5aPAUSE-RESUME" * 8
    ch.h.mr(ch.mrn_send).write(0, pattern)        # app never writes here
    cl.pause_migration("recv", at=cl.fabric.now + 5)
    rep = cl.migrate("recv", 2, strategy="pre_copy")
    assert not rep.ok and rep.attempt is not None
    assert rep.attempt.phase == "live"            # yielded mid-round
    assert cl.orchestrator.paused["recv"].req.state == "paused"
    paused_at = rep.attempt.paused_at
    _run(cl, 300)                                 # app burst while parked
    resumed_at = cl.fabric.now
    rep = cl.resume_migration("recv")
    assert rep.ok and rep.preemptions >= 1
    # the parked gap lands in paused_s — exactly, and nowhere else
    assert rep.paused_s == pytest.approx(
        (resumed_at - paused_at) * STEP_S, rel=1e-9)
    assert rep.paused_s >= 300 * STEP_S
    assert rep.transfer_s + rep.downtime_s < rep.paused_s
    assert ch.h.ctx.device.gid == 2
    assert ch.h.mr(ch.mrn_send).read(0, len(pattern)) == pattern
    before = ab.received
    _run(cl, 400)
    assert ab.received > before


def test_abort_while_paused_rolls_back_and_releases_budget():
    """Aborting a parked migration rolls the source back to RTS in
    place, settles the report into history, and releases the admission
    state — a fresh migration of the same container is admitted and
    completes."""
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    cl.pause_migration("recv", at=cl.fabric.now + 10)
    rep = cl.migrate("recv", 2, strategy="pre_copy")
    assert not rep.ok and "recv" in cl.orchestrator.paused
    _run(cl, 100)
    assert cl.abort_migration("recv")
    assert "recv" not in cl.orchestrator.paused
    settled = cl.orchestrator.history[-1]
    assert settled.stage_failed == "aborted" and settled.rolled_back
    assert settled.paused_s > 0.0
    assert cl.containers["recv"].alive
    _run(cl, 600)
    assert _qp(aa).state == QPState.RTS
    assert _qp(ab).state == QPState.RTS
    assert ch_gid(ab) == 1                        # never moved
    before = ab.received
    _run(cl, 200)
    assert ab.received > before                   # traffic recovered
    rep2 = cl.migrate("recv", 2, strategy="pre_copy")
    assert rep2.ok                                # budget released


def test_abort_mid_round_rolls_back_to_source():
    """An abort landing at an in-flight round boundary (not while
    parked) rolls back: source QPs leave STOPPED, no attempt token
    survives, and the container is re-migratable."""
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    orch = cl.orchestrator
    base = orch.background
    calls = {"n": 0}

    def bg():
        calls["n"] += 1
        if calls["n"] == 8:
            cl.abort_migration("recv")
        base()

    orch.background = bg
    try:
        rep = cl.migrate("recv", 2, strategy="pre_copy")
    finally:
        orch.background = base
    assert not rep.ok and rep.stage_failed == "aborted"
    assert rep.rolled_back and rep.attempt is None
    assert "recv" not in orch.paused
    _run(cl, 600)
    assert _qp(aa).state == QPState.RTS
    assert not [q for q in cl.containers["recv"].ctx.qps
                if q.state == QPState.STOPPED]
    assert ch_gid(ab) == 1
    before = ab.received
    _run(cl, 200)
    assert ab.received > before
    rep2 = cl.migrate("recv", 2, strategy="pre_copy")
    assert rep2.ok


def test_resume_after_destination_drain_needs_new_destination():
    """Regression for draining a node mid-migration: the in-flight
    transfer suspends with reason='detach' instead of tripping the
    timeout-abort path, a blind resume is refused (original destination
    gone), and a redirected resume lands the container on the new
    node."""
    cl = SimCluster(4)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    orch = cl.orchestrator
    base = orch.background
    calls = {"n": 0}

    def bg():
        calls["n"] += 1
        if calls["n"] == 10:
            cl.fabric.detach(2)               # drain the destination
        base()

    orch.background = bg
    try:
        rep = cl.migrate("recv", 2, strategy="pre_copy")
    finally:
        orch.background = base
    assert not rep.ok and rep.attempt is not None
    assert rep.attempt.reason == "detach"
    _run(cl, 100)
    with pytest.raises(MigrationError, match="left the fabric"):
        cl.resume_migration("recv")
    assert "recv" in orch.paused              # refusal left it parked
    rep = cl.resume_migration("recv", dest_idx=3)
    assert rep.ok
    assert ch_gid(ab) == 3
    before = ab.received
    _run(cl, 400)
    assert ab.received > before


def test_pause_holds_queued_request_until_resumed():
    """Pausing a still-queued request holds it across drain() without
    executing it; resume re-queues and the next drain runs it."""
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    orch = cl.orchestrator
    orch.submit(cl.containers["recv"], cl.nodes[2], strategy="pre_copy")
    assert cl.pause_migration("recv")
    assert orch.drain() == []                 # held, not executed
    assert orch.queue[0].state == "held"
    assert cl.resume_migration("recv") is None
    reports = orch.drain()
    assert len(reports) == 1 and reports[0].ok
    assert ch_gid(ab) == 2


def ch_gid(app):
    return app.channels[0].h.ctx.device.gid


# ---------------------------------------------------------------------------
# policy wiring + substrate fixes
# ---------------------------------------------------------------------------


def test_choose_migration_strategy_budgets():
    bw = 1e9
    # fits the downtime budget -> stop-and-copy
    assert choose_migration_strategy(1000, 0.0, bw, 1.0) == "stop_and_copy"
    # too big, low dirty rate -> pre-copy converges
    assert choose_migration_strategy(10 ** 10, 1e6, bw, 1e-3) == "pre_copy"
    # too big, dirty rate near link speed -> post-copy
    assert choose_migration_strategy(10 ** 10, 9e8, bw, 1e-3) == "post_copy"


def test_straggler_migrator_moves_slow_rank():
    from repro.runtime.ft import (FailureDetector, MigrationPolicy,
                                  StragglerMigrator)
    cl = SimCluster(4)
    aa, ab = make_sendbw_pair(cl)   # "send" on node 0, "recv" on node 1
    _run(cl, 50)
    det = FailureDetector()
    pol = MigrationPolicy(det, factor=1.5, patience=1)
    # worker 0 = "send", worker 1 = "recv"; make recv the straggler
    for w, t in ((0, 0.01), (1, 0.2), (2, 0.011)):
        for _ in range(4):
            det.heartbeat(w, step_time=t, now=0.0)
    names = {0: "send", 1: "recv", 2: "nope"}
    sm = StragglerMigrator(cl, pol, strategy="pre_copy",
                           name_of=lambda w: names[w])
    reports = sm.check()
    assert len(reports) == 1 and reports[0].ok
    assert sm.migrated and sm.migrated[0][0] == 1
    # moved off node 1 to the least-loaded node
    assert ab.channels[0].h.ctx.device.gid not in (1,)
    before = ab.received
    _run(cl, 600)
    assert ab.received > before


def test_cq_overrun_surfaces_instead_of_dropping():
    cq = CompletionQueue(cqn=1, depth=2)
    wc = lambda i: WorkCompletion(i, WCStatus.SUCCESS, "SEND", 0, 0)
    cq.push(wc(1))
    cq.push(wc(2))
    with pytest.raises(CQOverrunError):
        cq.push(wc(3))
    assert cq.overruns == 1
    # previously acknowledged completions are still intact, in order
    assert [w.wr_id for w in cq.poll(4)] == [1, 2]


def test_rkey_index_tracks_register_destroy_and_rekey():
    cl = SimCluster(2)
    dev = cl.nodes[0].device
    ctx = dev.open_context()
    pd = ctx.alloc_pd()
    mr = pd.reg_mr(PAGE_SIZE)
    assert dev.rkey_lookup(mr.rkey) is mr
    old_rkey = mr.rkey
    dev.set_mr_keys(mr, 111, 222)
    assert dev.rkey_lookup(old_rkey) is None
    assert dev.rkey_lookup(222) is mr
    dev.dereg_mr(mr)
    assert dev.rkey_lookup(222) is None
    assert mr not in ctx.mrs


def test_rkey_index_coherent_across_migration():
    """After a migration the stale source rkeys must miss and the restored
    (identical) rkeys must hit on the destination device."""
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    ch = ab.channels[0]
    rkey = ch.h.mr(ch.mrn_recv).rkey
    cl.migrate("recv", 2)
    _run(cl, 200)
    assert cl.nodes[1].device.rkey_lookup(rkey) is None     # source: gone
    dst_mr = cl.nodes[2].device.rkey_lookup(rkey)           # dest: present
    assert dst_mr is not None and dst_mr.mrn == ch.mrn_recv


def test_fig_downtime_precopy_beats_stop_and_copy_total():
    """Acceptance bar for the benchmark: under a write-active workload,
    pre-copy (and post-copy) downtime < stop-and-copy total."""
    from benchmarks.fig_downtime import run_strategy
    _, _, sc_total, _ = run_strategy("stop_and_copy")
    _, pre_down, _, _ = run_strategy("pre_copy")
    _, post_down, _, _ = run_strategy("post_copy")
    assert pre_down < sc_total
    assert post_down < sc_total


def test_dirty_tracking_is_page_granular_and_cheap_when_off():
    cl = SimCluster(2)
    dev = cl.nodes[0].device
    ctx = dev.open_context()
    mr = ctx.alloc_pd().reg_mr(4 * PAGE_SIZE)
    mr.write(0, b"x")                       # tracking off: nothing recorded
    assert mr.collect_dirty() == set()
    mr.start_dirty_tracking()
    mr.write(10, b"y" * 10)                 # page 0
    mr.write(PAGE_SIZE - 1, b"zz")          # straddles pages 0-1
    mr.write(3 * PAGE_SIZE, b"w")           # page 3
    assert mr.collect_dirty() == {0, 1, 3}
    assert mr.collect_dirty() == set()      # collect cleared the bitmap
    mr.stop_dirty_tracking()
    mr.write(2 * PAGE_SIZE, b"q")
    assert mr.collect_dirty() == set()
