"""Wire-protocol tests: reliable delivery, framing, loss recovery, rkeys."""
import numpy as np
import pytest

from repro.core.packets import Op
from repro.core.states import QPState
from repro.core.verbs import SGE, SendWR
from repro.runtime.cluster import SimCluster
from tests.helpers import make_channel_pair


def test_single_packet_send_recv():
    cl = SimCluster(2)
    c1, c2, *_ = make_channel_pair(cl)
    c2.post_recv(11)
    c1.post_send_bytes(b"hello world")
    cl.run_until_idle()
    wcs = c2.poll(4)
    assert [w.opcode for w in wcs] == ["RECV"]
    assert c2.recv_bytes(0, 11) == b"hello world"
    assert [w.opcode for w in c1.poll(4)] == ["SEND"]


def test_multi_packet_message_framing():
    cl = SimCluster(2)
    c1, c2, *_ = make_channel_pair(cl)
    data = bytes(range(256)) * 40     # ~10 KiB => 10+ MTU packets
    c2.post_recv(len(data))
    c1.post_send_bytes(data)
    cl.run_until_idle()
    wcs = c2.poll(4)
    assert len(wcs) == 1 and wcs[0].byte_len == len(data)
    assert c2.recv_bytes(0, len(data)) == data


@pytest.mark.parametrize("loss,seed", [(0.05, 1), (0.2, 42), (0.4, 7)])
def test_loss_recovery_exactly_once(loss, seed):
    cl = SimCluster(2, loss_prob=loss, seed=seed)
    c1, c2, *_ = make_channel_pair(cl, size=1 << 20)
    rng = np.random.RandomState(seed)
    blobs = [bytes(rng.randint(0, 256, 1 + rng.randint(5000), dtype=np.uint8))
             for _ in range(5)]
    off = 0
    for b in blobs:
        c2.post_recv(len(b), offset=off)
        off += len(b)
    off = 0
    for b in blobs:
        # zero-copy semantics: each WR owns its buffer region until its
        # completion, so distinct messages need distinct send offsets
        c1.post_send_bytes(b, offset=off)
        off += len(b)
    cl.run_until_idle(max_steps=500_000)
    wcs = c2.poll(16)
    assert len(wcs) == 5                       # exactly once, in order
    off = 0
    for b in blobs:
        assert c2.recv_bytes(off, len(b)) == b
        off += len(b)
    assert cl.fabric.stats["dropped"] > 0      # loss actually happened


def test_rdma_write_with_rkey():
    cl = SimCluster(2)
    c1, c2, ca, cb = make_channel_pair(cl)
    target = c2.h.mr(c2.mrn_recv)
    mr1 = c1.h.mr(c1.mrn_send)
    mr1.write(0, b"direct-write!")
    qp = c1.h.qp(c1.qpn)
    qp.post_send(SendWR(99, Op.WRITE, SGE(mr1, 0, 13), raddr=100,
                        rkey=target.rkey))
    cl.run_until_idle()
    assert target.read(100, 13) == b"direct-write!"
    assert [w.opcode for w in c1.poll(4)] == ["WRITE"]


def test_rdma_write_bad_rkey_rejected():
    cl = SimCluster(2)
    c1, c2, *_ = make_channel_pair(cl)
    mr1 = c1.h.mr(c1.mrn_send)
    qp = c1.h.qp(c1.qpn)
    qp.post_send(SendWR(1, Op.WRITE, SGE(mr1, 0, 4), raddr=0,
                        rkey=0xDEAD))
    with pytest.raises(TimeoutError):
        cl.run_until_idle(max_steps=2000)      # NAKed forever; never idle
    assert c2.h.mr(c2.mrn_recv).read(0, 4) == b"\x00" * 4


def test_rnr_retry_when_recv_posted_late():
    cl = SimCluster(2)
    c1, c2, *_ = make_channel_pair(cl)
    c1.post_send_bytes(b"early bird")
    cl.pump(300)                               # no RR posted yet
    assert c2.poll(1) == []
    c2.post_recv(10)
    cl.run_until_idle()
    assert c2.recv_bytes(0, 10) == b"early bird"


def test_unknown_qpn_packets_are_counted():
    """Packets addressed to a QPN the device doesn't know (stale address
    after a migration, or a plain bug) are dropped — but observably."""
    from repro.core.packets import Packet
    cl = SimCluster(2)
    c1, c2, *_ = make_channel_pair(cl)
    assert cl.fabric.stats["unknown_qpn"] == 0
    cl.fabric.send(Packet(op=Op.SEND, src_gid=0, src_qpn=c1.qpn,
                          dest_gid=1, dest_qpn=999_999_999,
                          payload=b"lost"))
    cl.pump(5)
    assert cl.fabric.stats["unknown_qpn"] == 1
    # well-addressed traffic is unaffected
    c2.post_recv(2)
    c1.post_send_bytes(b"ok")
    cl.run_until_idle()
    assert c2.recv_bytes(0, 2) == b"ok"
    assert cl.fabric.stats["unknown_qpn"] == 1


def test_protection_keys_are_random_per_mr():
    cl = SimCluster(2)
    c1, c2, *_ = make_channel_pair(cl)
    keys = {c1.h.mr(c1.mrn_send).rkey, c1.h.mr(c1.mrn_recv).rkey,
            c2.h.mr(c2.mrn_send).rkey, c2.h.mr(c2.mrn_recv).rkey}
    assert len(keys) == 4
