"""ECN/DCQCN congestion control + SRQ limit watermark.

Pins the congestion subsystem end to end: ECT/CE codepoints and the CNP
op (packets.py), RED marking at both port types with per-class stats
twins (qos.py), notification-point CNP generation/coalescing and the
reaction-point rate machinery (tasks.py/qos.py), rate enforcement at
send admission, the Karn/ECN interaction (a CNP is not a loss),
congestion-state migration (dump.py), admission pricing against
observed marking rates (orchestrator.py), and the ibv_modify_srq
SRQ_LIMIT one-shot async event (verbs.py)."""
import pytest

from repro.core.dump import dump_context, restore_context
from repro.core.packets import CTRL_OPS, Op
from repro.core.qos import (CLASS_APP, CLASS_MIG, CongestionControl,
                            ECNConfig)
from repro.core.states import QPState
from repro.core.verbs import QueuePair, RecvWR, SGE
from repro.orchestrator.orchestrator import AdmissionError
from repro.runtime.apps import SendBwApp
from repro.runtime.cluster import SimCluster
from repro.runtime.collectives import connect_pair
from tests.helpers import make_channel_pair, make_sendbw_pair

BPS = 2e8        # 200 B/step ports


def _run(cl, n):
    for _ in range(n):
        cl.step_all()


def _incast(n_senders, *, ecn, steps=2500, queue=48 * 1024, **ecn_kw):
    cl = SimCluster(n_senders + 1, link_bandwidth_Bps=BPS)
    cl.configure_ingress(rx_bandwidth_Bps=BPS, queue_bytes=queue, node=0)
    if ecn:
        cl.configure_ecn(enabled=True, **ecn_kw)
    receivers = []
    for i in range(n_senders):
        A = cl.launch(f"s{i}", i + 1)
        B = cl.launch(f"r{i}", 0)
        aa = SendBwApp(msg_size=4096, window=8)
        aa.attach(A, sender=True)
        A.app = aa
        ab = SendBwApp(msg_size=4096, window=8)
        ab.attach(B, sender=False)
        B.app = ab
        connect_pair(aa.channels[0], ab.channels[0])
        receivers.append(ab)
    _run(cl, steps)
    return cl, [r.received for r in receivers]


# ---------------------------------------------------------------------------
# config + RED curve
# ---------------------------------------------------------------------------


def test_ecn_config_validation():
    with pytest.raises(ValueError, match="kmin"):
        ECNConfig(kmin=0.9, kmax=0.5).validate()
    with pytest.raises(ValueError, match="pmax"):
        ECNConfig(pmax=0.0).validate()
    with pytest.raises(ValueError, match="timers"):
        ECNConfig(cnp_interval=0).validate()
    with pytest.raises(ValueError, match="rai_Bps"):
        ECNConfig(rai_Bps=-1.0).validate()
    with pytest.raises(ValueError):
        SimCluster(2).configure_ecn(enabled=True, g=2.0)


def test_red_marking_curve():
    cfg = ECNConfig(kmin=0.5, kmax=1.0, pmax=0.4)
    assert cfg.mark_probability(0.0) == 0.0
    assert cfg.mark_probability(0.49) == 0.0
    assert cfg.mark_probability(0.75) == pytest.approx(0.2)
    assert cfg.mark_probability(1.0) == 1.0
    assert cfg.mark_probability(2.0) == 1.0


# ---------------------------------------------------------------------------
# disabled by default: no codepoints, no marks, no CNPs, no rate state
# ---------------------------------------------------------------------------


def test_ecn_off_is_inert():
    cl, _ = _incast(4, ecn=False, steps=1500)
    s = cl.fabric.stats
    assert s.get("ecn_marked", 0) == 0
    assert s.get("cnps_sent", 0) == 0
    trace_cl = SimCluster(2, link_bandwidth_Bps=BPS)
    trace_cl.fabric.trace = []
    c1, c2, ca, cb = make_channel_pair(trace_cl)
    c2.post_recv(1024)
    c1.post_send_bytes(b"x" * 512)
    _run(trace_cl, 40)
    assert all(not p.ect and not p.ce for p in trace_cl.fabric.trace)
    assert all(qp.cc is None for qp in ca.ctx.qps + cb.ctx.qps)


def test_ect_stamped_on_data_not_control():
    cl = SimCluster(2, link_bandwidth_Bps=BPS)
    cl.configure_ecn(enabled=True)
    cl.fabric.trace = []
    c1, c2, _, _ = make_channel_pair(cl)
    c2.post_recv(1024)
    c1.post_send_bytes(b"x" * 512)
    _run(cl, 40)
    data = [p for p in cl.fabric.trace if p.op not in CTRL_OPS]
    ctrl = [p for p in cl.fabric.trace if p.op in CTRL_OPS]
    assert data and all(p.ect for p in data)
    assert ctrl and all(not p.ect for p in ctrl)


# ---------------------------------------------------------------------------
# marking: ingress queue, egress queue, stats twins
# ---------------------------------------------------------------------------


def test_ingress_marking_and_stats_twins():
    cl, _ = _incast(4, ecn=True, steps=2500)
    s = cl.fabric.stats
    assert s["ecn_marked"] > 0
    assert s["cnps_sent"] > 0 and s["cnps_handled"] > 0
    for key in ("ecn_marked", "cnps_sent", "cnps_handled"):
        per_node = sum(v for k, v in s.items()
                       if k.startswith(f"{key}@"))
        assert s[key] == per_node, f"{key} aggregate != per-node sum"
        per_class = (s.get(f"{CLASS_APP}_{key}", 0)
                     + s.get(f"{CLASS_MIG}_{key}", 0))
        assert s[key] == per_class, f"{key} aggregate != class sum"
    assert cl.fabric.ingress_marking_rate(0) > 0.0


def test_egress_marking_at_reference_backlog():
    """A deep egress backlog (reference sized down to a packet) marks at
    the sender's own port — congestion can live at either end."""
    cl = SimCluster(2, link_bandwidth_Bps=BPS)
    cl.configure_ecn(enabled=True, egress_queue_bytes=2048.0,
                     mark_ingress=False)
    make_sendbw_pair(cl, msg_size=4096, window=16)
    _run(cl, 400)
    s = cl.fabric.stats
    assert s["ecn_marked"] > 0
    assert s["ecn_marked@0"] == s["ecn_marked"]   # sender-side marks
    assert cl.fabric.marking_rate(0) > 0.0
    assert cl.fabric.ingress_marking_rate(1) == 0.0


def test_marking_disabled_flags():
    cl, _ = _incast(4, ecn=True, steps=1200, mark_ingress=False,
                    mark_egress=False)
    assert cl.fabric.stats.get("ecn_marked", 0) == 0


# ---------------------------------------------------------------------------
# notification point: CNP generation + coalescing
# ---------------------------------------------------------------------------


def test_cnp_coalesced_per_interval():
    """kmin=kmax=0 marks every ECT packet, so without coalescing the
    responder would answer every arrival; the NP mute bounds CNPs to
    one per cnp_interval per QP."""
    cl = SimCluster(2, link_bandwidth_Bps=BPS)
    cl.configure_ecn(enabled=True, kmin=0.0, kmax=0.0, cnp_interval=100)
    aa, ab = make_sendbw_pair(cl, msg_size=2048, window=4)
    steps = 600
    _run(cl, steps)
    s = cl.fabric.stats
    assert s["ecn_marked"] > s["cnps_sent"] > 0
    assert s["cnps_sent"] <= steps / 100 + 2
    assert ab.received > 0      # marked traffic still delivers


# ---------------------------------------------------------------------------
# reaction point: decrease / recovery / enforcement
# ---------------------------------------------------------------------------


def test_cnp_cuts_rate_multiplicatively():
    cc = CongestionControl(ECNConfig(enabled=True).validate(), 200.0, 0)
    assert cc.rc == 200.0 and cc.alpha == 1.0
    cc.on_cnp(10)
    assert cc.rc == pytest.approx(100.0)     # alpha=1 -> halve
    assert cc.rt == 200.0                    # target remembers
    assert cc.cnps_handled == 1
    cc.on_cnp(20)
    assert cc.rc == pytest.approx(50.0)


def test_timer_recovery_toward_line_rate():
    cfg = ECNConfig(enabled=True, increase_timer=100,
                    alpha_timer=50).validate()
    cc = CongestionControl(cfg, 200.0, 0)
    cc.on_cnp(0)
    assert cc.rc == pytest.approx(100.0)
    cc.advance(600, 200.0)      # 6 timer events: fast recovery first
    assert cc.rc > 190.0, "fast recovery must close most of the gap"
    cc.advance(5000, 200.0)     # additive + hyper push rt to line
    assert cc.rc == pytest.approx(200.0, rel=0.02)
    assert cc.alpha < 0.1       # decayed without further CNPs


def test_rate_enforcement_at_send_admission():
    """A cut reaction point bounds what the requester emits: the egress
    port transmits no faster than rc + the burst allowance."""
    cl = SimCluster(2, link_bandwidth_Bps=BPS)
    cl.configure_ecn(enabled=True)
    aa, _ = make_sendbw_pair(cl, msg_size=4096, window=16)
    _run(cl, 5)                 # first sends create the rate state
    qp = aa.channels[0].h.qp(aa.channels[0].qpn)
    assert qp.cc is not None
    qp.cc.rc = 20.0             # pace hard: 20 B/step
    qp.cc.rt = 20.0
    qp.cc.tokens = 0.0
    base = cl.fabric.port(0).tx_bytes
    steps = 1000
    _run(cl, steps)
    sent = cl.fabric.port(0).tx_bytes - base
    assert sent <= 20.0 * steps + qp.cc.cfg.burst_bytes + 4096, \
        f"emitted {sent}B, rate allows ~{20.0 * steps}B"
    assert sent > 0.25 * 20.0 * steps, "paced, not parked"


def test_rnr_nak_is_a_severe_congestion_cut():
    """An RNR NAK cuts the reaction point like a CNP: flows whose
    packets drop at admission never see CE marks, so the NAK is their
    only congestion feedback."""
    cl = SimCluster(2, link_bandwidth_Bps=BPS)
    cl.configure_ecn(enabled=True)
    c1, c2, _, _ = make_channel_pair(cl)
    c1.post_send_bytes(b"x" * 2048)     # no receive posted -> RNR
    _run(cl, 100)
    qp1 = c1.h.qp(c1.qpn)
    assert cl.fabric.stats["rnr_naks"] > 0
    assert qp1.cc is not None
    assert qp1.cc.rate_cuts > 0
    assert qp1.cc.rc < cl.fabric.bytes_per_step
    assert qp1.cc.cnps_handled == 0     # a cut, not a CNP


def test_oversized_read_overdraws_instead_of_wedging():
    """Regression: a READ whose response exceeds the pacing bucket's
    depth must overdraw (like retransmits do), not wait forever on a
    bucket that can never hold the charge."""
    cl = SimCluster(2, link_bandwidth_Bps=BPS)
    cl.configure_ecn(enabled=True)      # burst 8 KiB < 16 KiB response
    c1, c2, _, _ = make_channel_pair(cl)
    from repro.core.verbs import SendWR
    mr_local = c1.h.mr(c1.mrn_recv)
    mr_remote = c2.h.mr(c2.mrn_send)
    qp1 = c1.h.qp(c1.qpn)
    qp1.post_send(SendWR(1, Op.READ_REQ, SGE(mr_local, 0, 16384),
                         raddr=0, rkey=mr_remote.rkey))
    _run(cl, 3000)
    assert [w.opcode for w in c1.poll(4)] == ["READ"], \
        "oversized READ must complete under ECN pacing"
    assert qp1.cur_wqe is None and not qp1.sq


def test_runtime_disable_goes_dormant():
    """configure_ecn(enabled=False) mid-run stops marking/CNPs at once
    and makes stale rate state fully dormant: no pacing, no retransmit
    holds against a bucket still deep in overdraft."""
    cl, _ = _incast(4, ecn=True, steps=1500)
    qp = cl.containers["s0"].ctx.qps[0]
    assert qp.cc is not None and qp.cc.rate_cuts > 0
    qp.cc.tokens = -1e9         # pathological debt: must not matter
    marked = cl.fabric.stats["ecn_marked"]
    cnps = cl.fabric.stats["cnps_sent"]
    got = [cl.containers[f"r{i}"].app.received for i in range(4)]
    cl.configure_ecn(enabled=False)
    _run(cl, 1000)
    assert cl.fabric.stats["ecn_marked"] == marked
    assert cl.fabric.stats["cnps_sent"] == cnps
    after = [cl.containers[f"r{i}"].app.received for i in range(4)]
    assert all(a > g for a, g in zip(after, got)), \
        "dormant rate state must not hold anyone back"


def test_read_driven_congestion_paces_the_reader():
    """READ_RESPs congesting the *reader's* ingress cut the reader's
    own reaction point (its READ_REQ admission is charged at response
    size) — no CNP crosses the wire toward the responder, whose
    emission rate a CNP could never govern."""
    cl = SimCluster(2, link_bandwidth_Bps=BPS)
    # queue sized at ~8 response packets so occupancy can actually land
    # inside the [kmin, kmax) marking band (one response is ~4 KiB)
    cl.configure_ingress(rx_bandwidth_Bps=BPS / 8,
                         queue_bytes=32 * 1024, node=0)
    cl.configure_ecn(enabled=True)
    c1, c2, _, _ = make_channel_pair(cl)
    from repro.core.verbs import SendWR
    mr_local = c1.h.mr(c1.mrn_recv)
    mr_remote = c2.h.mr(c2.mrn_send)
    qp1 = c1.h.qp(c1.qpn)
    for i in range(40):
        qp1.post_send(SendWR(i, Op.READ_REQ, SGE(mr_local, 0, 4096),
                             raddr=0, rkey=mr_remote.rkey))
    _run(cl, 3000)
    assert cl.fabric.stats["ecn_marked"] > 0, \
        "responses must be marked at the reader's bounded ingress"
    assert qp1.cc is not None and qp1.cc.rate_cuts > 0
    assert qp1.cc.rc < cl.fabric.bytes_per_step
    assert cl.fabric.stats.get("cnps_sent", 0) == 0, \
        "marked READ_RESPs are handled locally, not by wire CNPs"


# ---------------------------------------------------------------------------
# Karn/ECN interaction: a CNP is not a loss
# ---------------------------------------------------------------------------


def test_marked_packets_still_yield_rtt_samples_and_no_backoff():
    """Regression: an ECN-marked (but delivered) packet must contribute
    an RTT sample and must not trigger RTO backoff. The failure mode
    this pins: handling a CNP like an RNR NAK (clearing _send_time /
    rewinding progress) would starve the RFC 6298 estimator exactly
    when queues are building — the RTO would sit at its initial 200
    steps forever and timeouts would fire into the congestion."""
    cl = SimCluster(2, link_bandwidth_Bps=BPS)
    # mark every data packet: CNPs fire throughout the run
    cl.configure_ecn(enabled=True, kmin=0.0, kmax=0.0, cnp_interval=20)
    aa, ab = make_sendbw_pair(cl, msg_size=2048, window=4)
    _run(cl, 800)
    qp = aa.channels[0].h.qp(aa.channels[0].qpn)
    assert cl.fabric.stats["cnps_handled"] > 5
    # RTT samples flowed despite every ACKed packet having been marked
    assert qp.srtt is not None, "CE-marked deliveries must sample RTT"
    assert qp.rto < QueuePair.RETRANS_TIMEOUT, \
        "the estimator must converge below the initial RTO"
    # and the congestion was handled by rate, not by loss recovery
    assert cl.fabric.stats.get("rnr_naks", 0) == 0
    assert ab.received > 0


# ---------------------------------------------------------------------------
# congestion-state migration: resume at the learned rate
# ---------------------------------------------------------------------------


def _congested_sender(cl):
    """Drive the 4:1 incast until sender s0's QP has a learned rate."""
    qp = cl.containers["s0"].ctx.qps[0]
    assert qp.cc is not None, "incast must have created rate state"
    assert qp.cc.rc < cl.fabric.bytes_per_step / 2, \
        "mid-episode rate must sit well below line rate"
    return qp


def test_dump_restore_preserves_congestion_state_exactly():
    """Property: dump a QP mid-congestion-episode, restore it into a
    fresh context, and the reaction point is byte-identical — alpha,
    rates, counters, timer phases (same fabric clock)."""
    cl, _ = _incast(4, ecn=True, steps=2500)
    qp = _congested_sender(cl)
    pre = qp.cc.dump(cl.fabric.now)
    ctx = cl.containers["s0"].ctx
    image = dump_context(ctx, stop=True)
    ctx2 = cl.nodes[4].device.open_context(tenant="s0")
    session = restore_context(ctx2, image)
    moved = session.qp_by_n[qp.qpn]
    assert moved.cc is not None
    post = moved.cc.dump(cl.fabric.now)
    assert post["rc"] == pre["rc"], "must resume at the learned rate"
    assert post["rt"] == pre["rt"]
    assert post["alpha"] == pre["alpha"]
    assert post["cnps_handled"] == pre["cnps_handled"]
    assert post["rate_cuts"] == pre["rate_cuts"]
    assert post["t_events"] == pre["t_events"]
    assert post["b_events"] == pre["b_events"]
    assert post["alpha_phase"] == pre["alpha_phase"]
    assert moved.cnps_sent == qp.cnps_sent


def test_migrated_sender_resumes_at_learned_rate():
    """End to end: live-migrate a sender mid-incast; the restored
    requester's rate is the learned one (not line rate) and the stats
    invariants hold across the move."""
    cl, _ = _incast(4, ecn=True, steps=2500)
    qp = _congested_sender(cl)
    rc_learned = qp.cc.rc
    qpn = qp.qpn
    rep = cl.migrate("s0", 4)
    assert rep.ok
    moved_ctx = cl.containers["s0"].ctx
    moved = next(q for q in moved_ctx.qps if q.qpn == qpn)
    assert moved.cc is not None, "rate state must survive migration"
    line = cl.fabric.bytes_per_step
    assert moved.cc.rc < line / 2, \
        f"resumed at {moved.cc.rc} B/step — line rate is {line}"
    # recovery timers may have nudged it during the move, but the
    # learned operating point carries over (not a fresh line-rate QP)
    assert moved.cc.rc <= max(2.0 * rc_learned, rc_learned + line / 10)
    assert moved.cc.cnps_handled >= 1 or moved.cc.rate_cuts >= 1
    _run(cl, 500)               # keeps streaming after the move
    s = cl.fabric.stats
    for key in ("ecn_marked", "cnps_sent", "cnps_handled"):
        per_class = (s.get(f"{CLASS_APP}_{key}", 0)
                     + s.get(f"{CLASS_MIG}_{key}", 0))
        assert s[key] == per_class, f"{key} class twin broke"


def test_ecn_incast_deterministic():
    def one():
        cl, good = _incast(4, ecn=True, steps=1800)
        rates = [cl.containers[f"s{i}"].ctx.qps[0].cc.rc
                 for i in range(4)]
        return good, rates, dict(cl.fabric.stats), cl.fabric.now

    assert one() == one()


# ---------------------------------------------------------------------------
# admission prices observed marking rates
# ---------------------------------------------------------------------------


def test_admission_prices_marking_rates():
    # min_rate_Bps=BPS floors the reaction point at line rate so the
    # workload (and thus port utilization) is identical with and
    # without ECN — the only difference the estimates can see is the
    # marking-rate discount itself
    def plan_for(ecn):
        cl = SimCluster(2, link_bandwidth_Bps=BPS)
        if ecn:
            # reference backlog of ~2 packets: sustained streaming marks
            # heavily at the source's egress port
            cl.configure_ecn(enabled=True, egress_queue_bytes=2048.0,
                             min_rate_Bps=BPS)
        make_sendbw_pair(cl, msg_size=4096, window=16)
        _run(cl, 400)
        bulk = cl.launch("bulk", 0)
        bulk.ctx.alloc_pd().reg_mr(64 * 4096)
        return cl, cl.orchestrator.admit(bulk, cl.nodes[1])

    _, quiet = plan_for(ecn=False)
    cl, marked = plan_for(ecn=True)
    assert "ecn" in marked.checks and "ecn" not in quiet.checks
    assert cl.fabric.marking_rate(0) > 0.0
    assert marked.est_transfer_s > quiet.est_transfer_s

    cl2 = SimCluster(2, link_bandwidth_Bps=BPS)
    cl2.configure_ecn(enabled=True, egress_queue_bytes=2048.0,
                      min_rate_Bps=BPS)
    make_sendbw_pair(cl2, msg_size=4096, window=16)
    _run(cl2, 400)
    cl2.orchestrator.max_transfer_s = marked.est_transfer_s * 0.9
    bulk = cl2.launch("bulk", 0)
    bulk.ctx.alloc_pd().reg_mr(64 * 4096)
    with pytest.raises(AdmissionError, match="marking"):
        cl2.orchestrator.admit(bulk, cl2.nodes[1])


# ---------------------------------------------------------------------------
# SRQ limit watermark (ibv_modify_srq SRQ_LIMIT)
# ---------------------------------------------------------------------------


def _srq_setup(cl):
    ctx = cl.launch("srq-owner", 0).ctx
    pd = ctx.alloc_pd()
    mr = pd.reg_mr(1 << 16)
    srq = ctx.create_srq()
    for i in range(6):
        srq.post(RecvWR(i, SGE(mr, i * 1024, 1024)))
    return ctx, srq


def test_srq_limit_fires_once_below_watermark():
    cl = SimCluster(1)
    ctx, srq = _srq_setup(cl)
    srq.modify(srq_limit=3)
    assert srq.armed and not ctx.poll_async()
    srq.pop(); srq.pop(); srq.pop()     # 6 -> 3: not yet below
    assert not ctx.poll_async()
    srq.pop()                           # 2 < 3: fire
    events = ctx.poll_async()
    assert [e.event_type for e in events] == ["SRQ_LIMIT_REACHED"]
    assert events[0].srqn == srq.srqn
    srq.pop()                           # still below: one-shot, silent
    assert not ctx.poll_async()
    srq.modify(srq_limit=3)             # re-arm while already below
    assert [e.event_type for e in ctx.poll_async()] == \
        ["SRQ_LIMIT_REACHED"], "arming below the limit fires immediately"
    assert not srq.armed


def test_srq_limit_validation():
    cl = SimCluster(1)
    _, srq = _srq_setup(cl)
    with pytest.raises(ValueError, match="srq_limit"):
        srq.modify(srq_limit=-1)
    srq.modify(srq_limit=0)             # 0 disarms
    assert not srq.armed
    srq.pop()
    assert True                         # no event machinery consulted


def test_srq_limit_fires_from_wire_consumption():
    """The watermark fires on the real consumption path: SENDs draining
    SRQ receives through QueuePair.next_rr."""
    cl = SimCluster(2, link_bandwidth_Bps=BPS)
    a = cl.launch("a", 0)
    b = cl.launch("b", 1)
    pd_a = a.ctx.alloc_pd()
    cq_a = a.ctx.create_cq()
    mr_a = pd_a.reg_mr(1 << 16)
    qp_a = pd_a.create_qp(cq_a, cq_a)
    pd_b = b.ctx.alloc_pd()
    cq_b = b.ctx.create_cq()
    mr_b = pd_b.reg_mr(1 << 16)
    srq = b.ctx.create_srq()
    qp_b = pd_b.create_qp(cq_b, cq_b, srq)
    for qp, dst in ((qp_a, qp_b), (qp_b, qp_a)):
        qp.modify(QPState.INIT)
        qp.modify(QPState.RTR, dest_gid=dst.device.gid, dest_qpn=dst.qpn,
                  rq_psn=0)
        qp.modify(QPState.RTS, sq_psn=0)
    for i in range(4):
        srq.post(RecvWR(100 + i, SGE(mr_b, i * 1024, 1024)))
    srq.modify(srq_limit=2)
    from repro.core.packets import Op as _Op
    from repro.core.verbs import SendWR
    for i in range(3):
        mr_a.write(0, b"y" * 512)
        qp_a.post_send(SendWR(i, _Op.SEND, SGE(mr_a, 0, 512)))
    _run(cl, 80)
    events = b.ctx.poll_async()
    assert [e.event_type for e in events] == ["SRQ_LIMIT_REACHED"]
    assert len(srq.queue) == 1


def test_srq_limit_attrs_survive_migration():
    cl = SimCluster(3)
    ctx, srq = _srq_setup(cl)
    srq.modify(srq_limit=2)
    srqn = srq.srqn
    assert cl.migrate("srq-owner", 2).ok
    moved = cl.containers["srq-owner"].ctx
    new_srq = next(s for s in moved.srqs if s.srqn == srqn)
    assert new_srq.limit == 2 and new_srq.armed
    assert len(new_srq.queue) == 6
    new_srq.pop(); new_srq.pop(); new_srq.pop(); new_srq.pop(); new_srq.pop()
    assert [e.event_type for e in moved.poll_async()] == \
        ["SRQ_LIMIT_REACHED"]
