"""Shared test fixtures for the fabric/migration tests."""
from __future__ import annotations

from repro.runtime.apps import SendBwApp
from repro.runtime.cluster import SimCluster
from repro.runtime.collectives import Channel, connect_pair


def make_sendbw_pair(cl: SimCluster, msg_size=2048, window=8):
    A = cl.launch("send", 0)
    B = cl.launch("recv", 1)
    aa = SendBwApp(msg_size=msg_size, window=window)
    aa.attach(A, sender=True)
    A.app = aa
    ab = SendBwApp(msg_size=msg_size, window=window)
    ab.attach(B, sender=False)
    B.app = ab
    connect_pair(aa.channels[0], ab.channels[0])
    return aa, ab


def make_channel_pair(cl: SimCluster, size=1 << 16):
    ca = cl.launch("a", 0)
    cb = cl.launch("b", 1)
    c1 = Channel(ca.ctx, size)
    c2 = Channel(cb.ctx, size)
    connect_pair(c1, c2)
    return c1, c2, ca, cb
