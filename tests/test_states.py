"""QP state machine unit tests (paper Fig. 4)."""
import pytest

from repro.core.states import (InvalidTransition, QPState, can_receive,
                               can_send, check_transition)


def test_user_happy_path():
    for cur, new in [(QPState.RESET, QPState.INIT),
                     (QPState.INIT, QPState.RTR),
                     (QPState.RTR, QPState.RTS),
                     (QPState.RTS, QPState.SQD),
                     (QPState.SQD, QPState.RTS)]:
        check_transition(cur, new)


def test_user_cannot_jump_to_rts():
    with pytest.raises(InvalidTransition):
        check_transition(QPState.RESET, QPState.RTS)
    with pytest.raises(InvalidTransition):
        check_transition(QPState.INIT, QPState.RTS)


def test_user_cannot_enter_migration_states():
    """Stopped/Paused are invisible to the application (paper §3.3)."""
    for tgt in (QPState.STOPPED, QPState.PAUSED):
        with pytest.raises(InvalidTransition):
            check_transition(QPState.RTS, tgt, system=False)


def test_system_migration_transitions():
    check_transition(QPState.RTS, QPState.STOPPED, system=True)
    check_transition(QPState.RTS, QPState.PAUSED, system=True)
    check_transition(QPState.PAUSED, QPState.RTS, system=True)
    check_transition(QPState.STOPPED, QPState.RESET, system=True)
    # orchestrator rollback: an aborted migration re-arms the
    # still-attached source QPs in place
    check_transition(QPState.STOPPED, QPState.RTS, system=True)


def test_stopped_exits_only_via_system():
    """Stopped can only be left by the OS (rollback or destroy), never by
    the user application (paper §3.3: invisible states)."""
    with pytest.raises(InvalidTransition):
        check_transition(QPState.STOPPED, QPState.RTS, system=False)
    with pytest.raises(InvalidTransition):
        check_transition(QPState.STOPPED, QPState.PAUSED, system=True)


def test_send_recv_gates():
    assert can_send(QPState.RTS)
    assert not can_send(QPState.PAUSED)
    assert not can_send(QPState.STOPPED)
    assert not can_send(QPState.SQD)      # drain: no NEW sends
    assert can_receive(QPState.RTR)
    assert can_receive(QPState.SQD)
    assert not can_receive(QPState.STOPPED)


def test_user_teardown_always_allowed():
    check_transition(QPState.RTS, QPState.ERROR)
    check_transition(QPState.SQE, QPState.RESET)
