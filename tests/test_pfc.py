"""PFC lossless fabric: pause frames, latches, headroom, lossless CC.

Pins the 802.1Qbb subsystem end to end: the PAUSE/UNPAUSE control ops
(packets.py), XOFF/XON watermark evaluation and broadcast at the
bounded ingress, per-(dest, class) pause latches at egress with
lifetime self-release, headroom admission instead of overflow drops,
the lossless gate on the RNR rate-cut path (tasks.py), latch survival
across migration (dump.py), and the config validation surface.
"""
import pytest

from repro.core.packets import CTRL_OPS, PFC_OPS, Op, Packet
from repro.core.qos import CLASS_APP, CLASS_MIG, ECNConfig, PFCConfig
from repro.runtime.apps import SendBwApp
from repro.runtime.cluster import SimCluster
from repro.runtime.collectives import connect_pair
from tests.helpers import make_channel_pair, make_sendbw_pair

BPS = 2e8        # 200 B/step ports


def _run(cl, n):
    for _ in range(n):
        cl.step_all()


def _incast(n_senders, *, queue=32 * 1024, pfc=True, **pfc_kw):
    cl = SimCluster(n_senders + 1, link_bandwidth_Bps=BPS)
    cl.configure_ingress(rx_bandwidth_Bps=BPS, queue_bytes=queue, node=0)
    if pfc:
        cl.configure_pfc(enabled=True, **pfc_kw)
    receivers = []
    for i in range(n_senders):
        A = cl.launch(f"s{i}", i + 1)
        B = cl.launch(f"r{i}", 0)
        aa = SendBwApp(msg_size=4096, window=8)
        aa.attach(A, sender=True)
        A.app = aa
        ab = SendBwApp(msg_size=4096, window=8)
        ab.attach(B, sender=False)
        B.app = ab
        connect_pair(aa.channels[0], ab.channels[0])
        receivers.append(ab)
    return cl, receivers


# -- config validation ------------------------------------------------------

def test_pfc_config_validation():
    PFCConfig(enabled=True).validate()          # defaults are sane
    with pytest.raises(ValueError):             # xon above xoff
        PFCConfig(xoff={"app": 0.4}, xon={"app": 0.6}).validate()
    with pytest.raises(ValueError):             # missing xon key
        PFCConfig(xoff={"app": 0.6, "mig": 0.7},
                  xon={"app": 0.3}).validate()
    with pytest.raises(ValueError):             # xoff above 1
        PFCConfig(xoff={"app": 1.2}, xon={"app": 0.3}).validate()
    with pytest.raises(ValueError):             # refresh >= lifetime
        PFCConfig(pause_steps=64, refresh_steps=64).validate()


def test_per_class_ecn_validation_and_resolution():
    with pytest.raises(ValueError):
        ECNConfig(per_class={"app": (0.9, 0.5, 0.1)}).validate()
    with pytest.raises(ValueError):
        ECNConfig(per_class={"app": (0.1, 0.5, 0.0)}).validate()
    ecn = ECNConfig(kmin=0.8, kmax=1.0, pmax=0.2,
                    per_class={"mig": (0.2, 0.6, 0.5)}).validate()
    flat = ECNConfig(kmin=0.8, kmax=1.0, pmax=0.2).validate()
    for occ in (0.0, 0.5, 0.85, 0.99, 1.2):
        # unlisted class and no-class fall back to the flat knobs,
        # float-identical to the pre-per-class arithmetic
        assert ecn.mark_probability(occ) == flat.mark_probability(occ)
        assert ecn.mark_probability(occ, CLASS_APP) == \
            flat.mark_probability(occ)
    assert ecn.mark_probability(0.4, CLASS_MIG) == \
        pytest.approx(0.5 * (0.4 - 0.2) / (0.6 - 0.2))
    assert ecn.mark_probability(0.7, CLASS_MIG) == 1.0   # >= kmax


def test_pause_ops_are_out_of_band_control():
    assert Op.PAUSE in CTRL_OPS and Op.UNPAUSE in CTRL_OPS
    assert PFC_OPS == {Op.PAUSE, Op.UNPAUSE}
    assert Op.PAUSE.is_pfc and Op.UNPAUSE.is_pfc
    # PFC frames terminate at the port, never at a QP completer
    assert not Op.PAUSE.is_completer and not Op.UNPAUSE.is_completer


# -- watermark machinery ----------------------------------------------------

def test_incast_pauses_and_resumes_losslessly():
    cl, receivers = _incast(4)
    _run(cl, 2500)
    stats = cl.fabric.stats
    assert stats.get("pfc_pause_frames", 0) > 0
    assert stats.get("pfc_resume_frames", 0) > 0
    assert stats.get("pfc_paused_steps", 0) > 0
    assert stats.get("rx_dropped", 0) == 0
    assert stats.get("dropped", 0) == 0
    assert stats.get("rnr_naks", 0) == 0
    assert all(r.received > 0 for r in receivers)
    # counter grammar: the PFC counters are node-attributed
    sums = cl.fabric.metrics.node_twin_sums()
    for name in ("pfc_pause_frames", "pfc_resume_frames",
                 "pfc_paused_steps"):
        bare, twin = sums[name]
        assert bare == twin > 0


def test_headroom_admission_replaces_overflow_drop():
    # a queue much smaller than one in-flight window: overflow is
    # guaranteed before the first PAUSE lands, so lossless mode must
    # admit into headroom rather than drop
    cl, _ = _incast(4, queue=4 * 1024)
    _run(cl, 1500)
    stats = cl.fabric.stats
    assert stats.get("pfc_headroom_admits", 0) > 0
    assert stats.get("rx_dropped", 0) == 0


def test_pause_latch_blocks_class_and_lifetime_releases_it():
    cl = SimCluster(2, link_bandwidth_Bps=BPS)
    cl.configure_pfc(enabled=True, pause_steps=100, refresh_steps=50)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 5)
    port = cl.fabric.port(0)
    now = cl.fabric.now
    # hand-deliver a PAUSE as if node 1's ingress emitted it
    port.pfc_frame(Packet(op=Op.PAUSE, src_gid=1, src_qpn=0,
                          dest_gid=0, dest_qpn=0,
                          payload=CLASS_APP.encode(), length=100), now)
    assert port._pfc_until[(1, CLASS_APP)] == now + 100
    base = ab.received
    _run(cl, 40)
    assert ab.received == base, "app class transmitted while latched"
    # ... but the latch self-releases after its lifetime (the progress
    # guarantee: a lost UNPAUSE or a departed issuer cannot pause a
    # class forever)
    _run(cl, 200)
    assert ab.received > base
    assert cl.fabric.stats.get("pfc_paused_steps", 0) >= 100


def test_unpause_releases_early_and_counts_span():
    cl = SimCluster(2, link_bandwidth_Bps=BPS)
    cl.configure_pfc(enabled=True, pause_steps=400)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 5)
    port = cl.fabric.port(0)
    now = cl.fabric.now
    pause = Packet(op=Op.PAUSE, src_gid=1, src_qpn=0, dest_gid=0,
                   dest_qpn=0, payload=CLASS_APP.encode(), length=400)
    port.pfc_frame(pause, now)
    _run(cl, 30)
    port.pfc_frame(Packet(op=Op.UNPAUSE, src_gid=1, src_qpn=0,
                          dest_gid=0, dest_qpn=0,
                          payload=CLASS_APP.encode(), length=0),
                   cl.fabric.now)
    assert (1, CLASS_APP) not in port._pfc_until
    span = cl.fabric.stats.get("pfc_paused_steps", 0)
    assert 0 < span <= 60, f"span {span} should be ~the parked window"
    base = ab.received
    _run(cl, 100)
    assert ab.received > base


def test_latch_state_rides_the_dump():
    port = SimCluster(2).fabric.port(0)     # throwaway for API shape
    cl = SimCluster(3, link_bandwidth_Bps=BPS)
    cl.configure_pfc(enabled=True)
    port = cl.fabric.port(0)
    port.pfc_frame(Packet(op=Op.PAUSE, src_gid=1, src_qpn=0,
                          dest_gid=0, dest_qpn=0,
                          payload=CLASS_MIG.encode(), length=300),
                   cl.fabric.now)
    rem = port.pfc_dump(1, cl.fabric.now)
    assert rem == {CLASS_MIG: 300}
    other = cl.fabric.port(2)
    other.pfc_restore(1, rem, cl.fabric.now)
    assert other._pfc_until[(1, CLASS_MIG)] == cl.fabric.now + 300


def test_paused_peer_view_survives_migration():
    """A QP migrated mid-pause restores its view of the paused peer:
    the destination node's egress re-arms the latch from the verbs
    dump, so the moved sender does not blast into a queue that XOFF'd
    it moments earlier."""
    cl = SimCluster(3, link_bandwidth_Bps=BPS)
    cl.configure_pfc(enabled=True, pause_steps=4000)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 5)
    # node 1 (the receiver's node) pauses the app class of sender node 0
    cl.fabric.port(0).pfc_frame(
        Packet(op=Op.PAUSE, src_gid=1, src_qpn=0, dest_gid=0,
               dest_qpn=0, payload=CLASS_APP.encode(), length=4000),
        cl.fabric.now)
    rep = cl.migrate("send", 2, strategy="stop_and_copy")
    assert rep.ok
    assert cl.fabric.port(2)._pfc_until.get((1, CLASS_APP), 0) \
        > cl.fabric.now, "migrated sender lost the pause latch"


def test_disable_clears_all_latches():
    cl, _ = _incast(4)
    _run(cl, 800)
    assert any(cl.fabric.port(g)._pfc_until for g in range(5)) or \
        cl.fabric.ingress_port(0)._pfc_latched
    cl.configure_pfc(enabled=False)
    for g in range(5):
        assert not cl.fabric.port(g)._pfc_until
    assert not cl.fabric.ingress_port(0)._pfc_latched


# -- lossless congestion control (satellite regression) ---------------------

def test_rnr_cut_inert_in_lossless_mode():
    """Regression: with PFC on, congestion feedback is CNP-only. A
    spurious RNR NAK (responder not ready — nothing to do with fabric
    congestion in lossless mode) must NOT double-cut the rate below
    what the CNP stream derived."""
    cl = SimCluster(2, link_bandwidth_Bps=BPS)
    cl.configure_ecn(enabled=True)
    cl.configure_pfc(enabled=True)
    c1, c2, _, _ = make_channel_pair(cl)
    c1.post_send_bytes(b"x" * 2048)     # no receive posted -> RNR NAK
    _run(cl, 100)
    qp1 = c1.h.qp(c1.qpn)
    assert cl.fabric.stats.get("rnr_naks", 0) > 0, \
        "responder RNR must still fire (it is not an overflow signal)"
    if qp1.cc is not None:
        assert qp1.cc.rate_cuts == 0, \
            "lossless mode must not rate-cut on RNR NAKs"
        assert qp1.cc.rc == cl.fabric.bytes_per_step
    # identical scenario without PFC: the cut path stays live
    cl2 = SimCluster(2, link_bandwidth_Bps=BPS)
    cl2.configure_ecn(enabled=True)
    c1b, _, _, _ = make_channel_pair(cl2)
    c1b.post_send_bytes(b"x" * 2048)
    _run(cl2, 100)
    qp1b = c1b.h.qp(c1b.qpn)
    assert qp1b.cc is not None and qp1b.cc.rate_cuts > 0
