"""Kernel tests: Pallas (interpret=True) and blocked-jnp vs ref oracles,
swept over shapes and dtypes as required for every kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru import rglru_scan
from repro.kernels.ssd import ssd_scan


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


def _mk_qkv(seed, B, S, H, Kh, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Kh, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Kh, hd), jnp.float32).astype(dtype)
    return q, k, v


ATTN_SHAPES = [(1, 128, 4, 4, 32), (2, 256, 8, 2, 64), (1, 192, 6, 1, 16)]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("variant", ["causal", "bidir", "window",
                                     "softcap"])
def test_flash_attention_pallas_vs_ref(shape, dtype, variant):
    B, S, H, Kh, hd = shape
    q, k, v = _mk_qkv(0, B, S, H, Kh, hd, dtype)
    kw = {"causal": dict(causal=True),
          "bidir": dict(causal=False),
          "window": dict(causal=True, window=S // 3),
          "softcap": dict(causal=True, softcap=20.0)}[variant]
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True,
                          **kw)
    want = ref.attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32), **kw)
    np.testing.assert_allclose(np.array(out, np.float32), np.array(want),
                               **_tol(dtype))


@pytest.mark.parametrize("sched", ["full", "triangular"])
def test_blocked_attention_schedules(sched):
    q, k, v = _mk_qkv(1, 2, 256, 8, 2, 64, jnp.float32)
    out = ops.attention(q, k, v, causal=True, impl="blocked",
                        schedule=sched, chunk_q=64, chunk_k=64)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.array(out), np.array(want), rtol=2e-5,
                               atol=2e-5)


def test_flash_vjp_grads_match_ref():
    q, k, v = _mk_qkv(2, 2, 128, 4, 2, 32, jnp.float32)
    do = jax.random.normal(jax.random.PRNGKey(9), q.shape)

    def f(impl):
        def loss(q, k, v):
            if impl == "ref":
                o = ref.attention_ref(q, k, v, causal=True, window=48)
            else:
                o = ops.attention(q, k, v, causal=True, window=48,
                                  impl="flash", chunk_q=32, chunk_k=32)
            return (o * do).sum()
        return jax.grad(loss, (0, 1, 2))(q, k, v)

    for a, b in zip(f("ref"), f("flash")):
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-4,
                                   atol=1e-4)


@pytest.mark.parametrize("B,S,D", [(1, 64, 16), (2, 128, 48)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_pallas_vs_ref(B, S, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (B, S, D), jnp.float32).astype(dtype)
    al = jax.random.normal(ks[1], (D,))
    ga = jax.random.normal(ks[2], (B, S, D), jnp.float32).astype(dtype)
    gx = jax.random.normal(ks[3], (B, S, D), jnp.float32).astype(dtype)
    y, h = rglru_scan(x, al, ga, gx, block_d=16, block_t=32,
                      interpret=True)
    yr, hr = ref.rglru_ref(x.astype(jnp.float32), al,
                           ga.astype(jnp.float32),
                           gx.astype(jnp.float32))
    np.testing.assert_allclose(np.array(y, np.float32), np.array(yr),
                               **_tol(dtype))
    np.testing.assert_allclose(np.array(h), np.array(hr), **_tol(dtype))


def test_rglru_associative_scan_matches_ref():
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    x = jax.random.normal(ks[0], (2, 96, 24))
    al = jax.random.normal(ks[1], (24,))
    ga = jax.random.normal(ks[2], (2, 96, 24))
    gx = jax.random.normal(ks[3], (2, 96, 24))
    y, h = ops.rglru(x, al, ga, gx, impl="blocked")
    yr, hr = ref.rglru_ref(x, al, ga, gx)
    np.testing.assert_allclose(np.array(y), np.array(yr), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("B,S,H,P,G,N", [(1, 64, 2, 8, 1, 8),
                                         (2, 128, 4, 16, 2, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_pallas_vs_ref(B, S, H, P, G, N, dtype):
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    Al = jax.random.normal(ks[2], (H,)) * 0.5
    Bm = (jax.random.normal(ks[3], (B, S, G, N)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, S, G, N)) * 0.3).astype(dtype)
    Dm = jax.random.normal(ks[5], (H,))
    y, h = ssd_scan(x, dt, Al, Bm, Cm, D=Dm, chunk=32, interpret=True)
    yr, hr = ref.ssd_ref(x.astype(jnp.float32), dt, Al,
                         Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                         D=Dm)
    np.testing.assert_allclose(np.array(y, np.float32), np.array(yr),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_ssd_chunked_jnp_matches_ref_with_state():
    """Chunked path with h0 carry == sequential oracle split in two."""
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    B, S, H, P, G, N = 2, 128, 4, 16, 2, 8
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    Al = jax.random.normal(ks[2], (H,)) * 0.5
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    y_full, h_full = ops.ssd(x, dt, Al, Bm, Cm, impl="blocked", chunk=32)
    h = None
    ys = []
    for lo in (0, S // 2):
        hi = lo + S // 2
        y, h = ops.ssd(x[:, lo:hi], dt[:, lo:hi], Al, Bm[:, lo:hi],
                       Cm[:, lo:hi], h0=h, impl="blocked", chunk=32)
        ys.append(y)
    np.testing.assert_allclose(np.array(jnp.concatenate(ys, 1)),
                               np.array(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.array(h), np.array(h_full), rtol=1e-4,
                               atol=1e-4)


def test_decode_kernels_match_full_scan():
    """Single-step decode == full-sequence scan at every position."""
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    B, S, D = 2, 16, 12
    x = jax.random.normal(ks[0], (B, S, D))
    al = jax.random.normal(ks[1], (D,))
    ga = jax.random.normal(ks[2], (B, S, D))
    gx = jax.random.normal(ks[3], (B, S, D))
    y_full, _ = ops.rglru(x, al, ga, gx, impl="blocked")
    h = jnp.zeros((B, D))
    for t in range(S):
        y_t, h = ops.rglru_decode(h, x[:, t], al, ga[:, t], gx[:, t])
        np.testing.assert_allclose(np.array(y_t), np.array(y_full[:, t]),
                                   rtol=1e-4, atol=1e-4)
