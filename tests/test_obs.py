"""Observability subsystem tests (repro.obs): registry grammar, the
zero-overhead-when-disabled contract, tracing determinism, and the
phase-span / migration-report exactness guarantee."""
import json
import math

import pytest

from benchmarks import fig_downtime
from repro.obs import (EventKind, MetricsRegistry, Tracer,
                       WindowedHistogram, build_migration_report,
                       chrome_trace, render_timeline)
from repro.runtime.cluster import SimCluster

# the PR 5 figure floats, pinned byte-for-byte: (downtime_s, total_s,
# receiver messages) per strategy under the default (untraced) run
PR5_FIGURES = {
    "stop_and_copy": (0.005677, 0.005677, 8),
    "pre_copy": (0.00011399999999999999, 0.00604, 86),
    "post_copy": (7e-05, 0.008688, 1),
}


@pytest.fixture(scope="module")
def traced_runs():
    """One traced run per strategy, shared across tests (each returns
    the 5-tuple: rep, downtime, total, app, cluster)."""
    return {name: fig_downtime.run_strategy(name, trace=True)
            for name in PR5_FIGURES}


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_twin_grammar():
    m = MetricsRegistry()
    m.inc("rnr_naks", gid=1)
    m.inc("rnr_naks", gid=2)
    m.inc("rnr_naks", 3, gid=2)
    m.inc("tx_bytes", 100, gid=0, cls="mig")
    m.inc("tx_bytes", 50, gid=1, cls="app")
    assert m.counters["rnr_naks"] == 5
    assert m.counters["rnr_naks@1"] == 1
    assert m.counters["rnr_naks@2"] == 4
    assert m.counters["tx_bytes"] == 150
    assert m.counters["mig_tx_bytes"] == 100
    assert m.counters["app_tx_bytes"] == 50
    sums = m.node_twin_sums()
    assert sums == {"rnr_naks": (5, 5), "tx_bytes": (150, 150)}


def test_registry_gauges_and_histograms():
    m = MetricsRegistry(window=100)
    m.set_gauge("rate", 3.5, gid=2)
    assert m.gauges["rate@2"] == 3.5
    for step in range(10):
        m.observe("depth", step, float(step), gid=0)
    h = m.histogram("depth", gid=0)
    assert len(h) == 10
    assert h.percentile(50) == 4.0
    s = h.summary()
    assert s["count"] == 10 and s["min"] == 0.0 and s["max"] == 9.0


def test_windowed_histogram_trims_old_samples():
    h = WindowedHistogram(window=10)
    h.observe(0, 100.0)
    h.observe(5, 1.0)
    h.observe(14, 2.0)          # step 0 and 5 samples age out (<= 14-10)
    assert [v for _, v in h.samples] == [100.0, 1.0, 2.0] or len(h) == 2
    h.trim(14)
    assert sorted(v for _, v in h.samples) == [1.0, 2.0]
    assert h.percentile(99, now=30) == 0.0   # everything aged out


def test_stats_is_registry_view():
    cl = SimCluster(2)
    assert cl.fabric.stats is cl.fabric.metrics.counters
    cl.fabric.metrics.inc("x", 7, gid=0)
    assert cl.fabric.stats["x"] == 7 and cl.fabric.stats["x@0"] == 7


def test_node_twin_invariant_on_workload(traced_runs):
    """Every counter ever incremented with a gid satisfies
    sum(name@gid) == name — uniformly, including the historically
    twin-less ones (dropped/unroutable/qos_bucket_deferrals)."""
    for name, (rep, _, _, _, cl) in traced_runs.items():
        sums = cl.fabric.metrics.node_twin_sums()
        assert sums, f"{name}: no node-attributed counters recorded"
        for cname, (bare, twin) in sums.items():
            assert bare == twin, \
                f"{name}: {cname} bare={bare} != twin sum {twin}"
        assert "tx_packets" in sums and "tx_bytes" in sums


# ---------------------------------------------------------------------------
# zero-overhead contract
# ---------------------------------------------------------------------------


def test_disabled_tracer_reproduces_pr5_figures():
    """Tracing off (the default): fig_downtime floats are byte-identical
    to their PR 5 values — the hook sites cost no behaviour."""
    for name, (down_exp, total_exp, received_exp) in PR5_FIGURES.items():
        rep, down, total, ab = fig_downtime.run_strategy(name)
        assert rep.ok
        assert down == down_exp, f"{name} downtime drifted: {down!r}"
        assert total == total_exp, f"{name} total drifted: {total!r}"
        assert ab.received == received_exp


def test_enabled_tracer_does_not_perturb_figures(traced_runs):
    """Tracing on: the same floats again — hooks observe, never act."""
    for name, (down_exp, total_exp, received_exp) in PR5_FIGURES.items():
        rep, down, total, ab, cl = traced_runs[name]
        assert rep.ok
        assert down == down_exp, f"{name} traced downtime: {down!r}"
        assert total == total_exp, f"{name} traced total: {total!r}"
        assert ab.received == received_exp
        assert cl.fabric.tracer is not None
        assert cl.fabric.tracer.events, f"{name}: tracer saw no events"


def test_tracing_is_deterministic():
    """Two seeded runs produce identical event streams, field for
    field — the tracer records sim state only (no ids, no wall clock)."""
    def stream():
        *_, cl = fig_downtime.run_strategy("stop_and_copy", trace=True)
        return [(e.kind, e.step, e.node, e.data)
                for e in cl.fabric.tracer.events]
    a, b = stream(), stream()
    assert len(a) == len(b)
    assert a == b


def test_configure_tracing_off_detaches():
    cl = SimCluster(2)
    trc = cl.configure_tracing(True)
    assert cl.fabric.tracer is trc
    assert cl.configure_tracing(False) is None
    assert cl.fabric.tracer is None


def test_tracer_max_events_bound():
    trc = Tracer(max_events=3)
    for i in range(10):
        trc.phase("p", i, i + 1)
    assert len(trc.events) == 3
    assert trc.dropped_events == 7


# ---------------------------------------------------------------------------
# migration report + exporters
# ---------------------------------------------------------------------------


def test_phase_spans_sum_to_report_fields(traced_runs):
    """The exactness contract: transfer spans sum to rep.transfer_s and
    checkpoint+transfer+restore spans to rep.downtime_s — the very same
    float operations, so equality is exact, not approximate."""
    for name, (rep, downtime, _, _, cl) in traced_runs.items():
        report = build_migration_report(cl.fabric.tracer,
                                        now=cl.fabric.now)
        assert report["transfer_s"] == rep.transfer_s, name
        assert report["downtime_s"] == rep.downtime_s, name
        assert math.isclose(report["downtime_s"], downtime,
                            rel_tol=1e-12)
        if name == "pre_copy":
            assert report["live_s"] == rep.live_s
            assert len(report["rounds"]) == len(rep.rounds)


def test_report_attributes_wire_traffic(traced_runs):
    rep, _, _, _, cl = traced_runs["pre_copy"]
    report = build_migration_report(cl.fabric.tracer, now=cl.fabric.now)
    assert report["ports"], "no per-port wire attribution"
    # tx_bytes counts at *enqueue*; egress_tx fires at transmit — bytes
    # still queued (or loss-injected) when the run ends never transmit,
    # so the report's wire total is bounded by, not equal to, the stat
    total = sum(p["tx_bytes"] for p in report["ports"].values())
    assert 0 < total <= cl.fabric.stats["tx_bytes"]
    assert set(report["classes"]) <= {"app", "mig"}
    assert 0 < report["classes"]["mig"]["tx_bytes"] \
        <= cl.fabric.stats["mig_tx_bytes"]
    text = render_timeline(report)
    assert "transfer" in text and "downtime_s=" in text


def test_chrome_trace_is_valid(traced_runs):
    rep, _, _, _, cl = traced_runs["pre_copy"]
    blob = json.dumps(chrome_trace(cl.fabric.tracer))
    doc = json.loads(blob)
    events = doc["traceEvents"]
    assert events
    xs = [e for e in events if e["ph"] == "X"]
    assert xs, "no phase spans exported"
    for e in xs:
        assert e["dur"] >= 0 and "name" in e
    assert any(e["ph"] == "M" for e in events), "no process metadata"
    assert doc["otherData"]["sim_step_s"] == cl.fabric.step_s()


def test_render_timeline_empty_tracer():
    report = build_migration_report(Tracer())
    assert "no phase spans" in render_timeline(report)


# ---------------------------------------------------------------------------
# tools wired into CI
# ---------------------------------------------------------------------------


def test_check_docs_passes():
    from tools import check_docs
    assert check_docs.main() == 0


def test_event_taxonomy_is_complete():
    """Every EventKind the AST gate sees is a real member, and every
    member's value appears in docs/observability.md."""
    from tools.check_docs import check_event_taxonomy, event_kinds
    kinds = event_kinds()
    assert sorted(kinds) == sorted(k.value for k in EventKind)
    assert check_event_taxonomy(kinds) == []


def test_bench_summary_writer(tmp_path):
    from benchmarks.run import run_modules, write_summary

    class Good:
        @staticmethod
        def main():
            return {"metric": 1}

    class Bad:
        @staticmethod
        def main():
            raise RuntimeError("boom")

    summary = run_modules([("good", Good), ("bad", Bad)])
    assert summary["good"]["ok"] and summary["good"]["metrics"] == \
        {"metric": 1}
    assert not summary["bad"]["ok"] and "boom" in summary["bad"]["error"]
    path = write_summary(summary, str(tmp_path / "BENCH_summary.json"))
    with open(path) as f:
        assert json.load(f)["good"]["wall_s"] is not None


def test_trace_report_cli(tmp_path, capsys):
    from tools import trace_report
    out = str(tmp_path / "trace.json")
    rc = trace_report.main(["--strategy", "stop_and_copy",
                            "--chrome", out])
    captured = capsys.readouterr()
    assert rc == 0, captured.out
    assert "[ok]" in captured.out and "MISMATCH" not in captured.out
    with open(out) as f:
        assert json.load(f)["traceEvents"]
