"""Live-migration scenario tests (paper §3.4, §5.3-5.4)."""
import pytest

from repro.core.states import QPState
from repro.runtime.cluster import SimCluster
from tests.helpers import make_sendbw_pair


def _run(cl, n):
    for _ in range(n):
        cl.step_all()


def test_migrate_receiver_mid_stream():
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    before = ab.received
    rep = cl.migrate("recv", 2)
    assert rep.ok and rep.image_bytes > 0
    _run(cl, 400)
    assert ab.received > before
    # receiver really lives on node 2 now
    assert ab.channels[0].h.ctx.device.gid == 2


def test_migrate_sender_mid_stream():
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    before = ab.received
    cl.migrate("send", 2)
    _run(cl, 400)
    assert ab.received > before


def test_peer_pauses_on_nak_stopped_and_resumes():
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    qa = aa.channels[0].h.qp(aa.channels[0].qpn)
    saw_paused = {"v": False}
    orig_pump = cl.fabric.pump

    rep = cl.migrate("recv", 2)
    # sender may pause transiently during the stop window
    _run(cl, 400)
    assert qa.state == QPState.RTS            # resumed after RESUME msg
    assert qa.dest_gid == 2                   # address rewritten


def test_failed_migration_leaves_peer_paused():
    """Paper §3.4: on failure, paused QPs remain stuck forever."""
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    rep = cl.migrate("recv", 2, fail_at="transfer")
    assert not rep.ok
    _run(cl, 600)
    qa = aa.channels[0].h.qp(aa.channels[0].qpn)
    assert qa.state == QPState.PAUSED
    _run(cl, 600)
    assert qa.state == QPState.PAUSED         # still stuck


def test_migration_under_packet_loss():
    cl = SimCluster(3, loss_prob=0.05, seed=7)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 100)
    before = ab.received
    cl.migrate("recv", 2)
    _run(cl, 3000)
    assert ab.received > before


def test_simultaneous_migration_of_both_endpoints():
    """Paper §3.4: simultaneous migrations must not confuse addressing."""
    cl = SimCluster(4)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    before = ab.received
    cl.migrate("send", 2)
    cl.migrate("recv", 3)
    _run(cl, 1500)
    assert ab.received > before


def test_docker_runtime_interoperability():
    """Paper §5.4/Fig.12: slower runtime, same end result."""
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    before = ab.received
    rep = cl.migrate("recv", 2, runtime="docker")
    assert rep.ok
    assert rep.simulated_transfer_s > 0
    _run(cl, 400)
    assert ab.received > before


def test_migrate_back_and_forth():
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    for dest in (2, 1, 2, 1):
        cl.migrate("recv", dest)
        _run(cl, 400)
    before = ab.received
    _run(cl, 200)
    assert ab.received > before


def test_migration_preserves_ids():
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    ch = ab.channels[0]
    qpn, mrn_s, mrn_r, cqn = ch.qpn, ch.mrn_send, ch.mrn_recv, ch.cqn
    cl.migrate("recv", 2)
    _run(cl, 200)
    # handles still resolve — numbers preserved across restore (§4.1)
    assert ch.h.qp(qpn).qpn == qpn
    assert ch.h.mr(mrn_s).mrn == mrn_s
    assert ch.h.mr(mrn_r).mrn == mrn_r
    assert ch.h.cq(cqn).cqn == cqn


def test_migrate_to_same_node_is_explicit_noop():
    """dest == src returns a clearly-marked noop report, not a default
    stop-and-copy report that looks like a successful (empty) move."""
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    before = ab.received
    for strategy in (None, "pre_copy", "post_copy"):
        kw = {} if strategy is None else {"strategy": strategy}
        if strategy is None:
            rep = cl.migrate("recv", 1, **kw)      # bare controller path
        else:
            from repro.orchestrator.strategies import make_strategy
            rep = make_strategy(strategy).run(
                cl.migrator, cl.containers["recv"], cl.nodes[1])
        assert rep.strategy == "noop"
        assert rep.ok and rep.pages_total == 0 and rep.image_bytes == 0
    # nothing was stopped: the stream never hiccupped
    _run(cl, 100)
    assert ab.received > before
    assert ab.channels[0].h.ctx.device.gid == 1


def test_mr_keys_survive_migration():
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    ch = ab.channels[0]
    keys = (ch.h.mr(ch.mrn_recv).lkey, ch.h.mr(ch.mrn_recv).rkey)
    cl.migrate("recv", 2)
    _run(cl, 100)
    assert (ch.h.mr(ch.mrn_recv).lkey, ch.h.mr(ch.mrn_recv).rkey) == keys
