"""In-fabric migration data plane tests: service-channel streaming,
bandwidth-aware links, loss recovery on MIG_PAGE streams, concurrent
migrations sharing a link, sim-clock determinism, measured-utilization
admission, and O(1) teardown back-pointers."""
import hashlib

import pytest

from repro.core.packets import Op, Packet
from repro.core.transport import STEP_S
from repro.core.verbs import PAGE_SIZE
from repro.runtime.cluster import SimCluster
from tests.helpers import make_channel_pair, make_sendbw_pair


def _run(cl, n):
    for _ in range(n):
        cl.step_all()


def _mr_container(cl, name, node_idx, n_pages):
    """Container holding one MR of n_pages with a recognisable pattern."""
    c = cl.launch(name, node_idx)
    pd = c.ctx.alloc_pd()
    mr = pd.reg_mr(n_pages * PAGE_SIZE)
    for pg in range(n_pages):
        mr.write(pg * PAGE_SIZE, bytes([pg % 251]) * PAGE_SIZE)
    return c, mr


# ---------------------------------------------------------------------------
# service channel basics
# ---------------------------------------------------------------------------


def test_service_transfer_delivers_exact_bytes():
    cl = SimCluster(3)
    data = bytes(range(256)) * 500            # ~125 KiB
    svc = cl.nodes[0].device.service
    xid = svc.transfer(1, Op.MIG_STATE, {"kind": "image"}, data)
    got = cl.nodes[1].device.service.take_image(xid)
    assert got == data
    assert cl.fabric.stats["mig_tx_bytes"] > len(data)
    cl.run_until_idle()


def test_service_stream_survives_loss_with_checksum_intact():
    """MIG_PAGE/MIG_STATE ride the go-back-N machinery: a lossy link
    retransmits until the image arrives bit-exact."""
    cl = SimCluster(3, loss_prob=0.25, seed=11)
    data = bytes((i * 37) % 256 for i in range(80_000))
    svc = cl.nodes[0].device.service
    xid = svc.transfer(2, Op.MIG_STATE, {"kind": "image"}, data)
    got = cl.nodes[2].device.service.take_image(xid)
    assert hashlib.sha256(got).hexdigest() == \
        hashlib.sha256(data).hexdigest()
    assert cl.fabric.stats["dropped"] > 0          # loss really happened
    cl.run_until_idle(max_steps=500_000)


def test_service_qps_are_invisible_to_containers():
    """Kernel QPs live outside every container context: dumps and
    admission scans never see them."""
    cl = SimCluster(2)
    c = cl.launch("a", 0)
    dev = cl.nodes[0].device
    dev.service.qp_for(1)
    assert dev.service.ctx not in dev.contexts
    assert all(qp.ctx is not c.ctx for qp in dev.service.ctx.qps)
    assert c.ctx.qps == []


# ---------------------------------------------------------------------------
# bandwidth-aware links
# ---------------------------------------------------------------------------


def test_link_serialization_bounds_throughput():
    """A link can carry at most bandwidth * time bytes; the sendbw app
    offered load is clipped by the wire, not by the app's window."""
    cl = SimCluster(2, link_bandwidth_Bps=1e8)     # 100 B/step
    aa, ab = make_sendbw_pair(cl, msg_size=2048, window=16)
    t0, ln = cl.fabric.now, cl.fabric.link(0, 1)
    b0 = ln.tx_bytes
    _run(cl, 2000)
    # bytes are recorded at enqueue; whatever is still serialising in the
    # link's standing queue has not been delivered yet
    backlog = max(0.0, ln.busy_until - cl.fabric.now) * \
        cl.fabric.bytes_per_step
    delivered = ln.tx_bytes - b0 - backlog
    capacity = (cl.fabric.now - t0) * cl.fabric.bytes_per_step
    assert delivered <= capacity * 1.01 + 2048     # one packet of slack
    assert delivered > 0.5 * capacity              # and the link is busy


def test_migration_bytes_show_up_in_fabric_stats():
    """Acceptance bar: tx_bytes during a migration > app-only baseline of
    the identical scenario, and the difference is attributed to MIG ops."""
    def scenario(migrate):
        cl = SimCluster(3)
        aa, ab = make_sendbw_pair(cl)
        _run(cl, 50)
        if migrate:
            assert cl.migrate("recv", 2).ok
        _run(cl, 200)
        return dict(cl.fabric.stats)

    base = scenario(migrate=False)
    mig = scenario(migrate=True)
    assert mig["tx_bytes"] > base["tx_bytes"]
    assert base.get("mig_tx_bytes", 0) == 0
    assert mig["mig_tx_bytes"] > 0


def test_migration_timing_is_simclock_deterministic():
    """downtime_s / transfer_s derive from fabric.now, so two identical
    runs produce bit-identical figures (no wall-clock anywhere)."""
    def one():
        cl = SimCluster(3)
        aa, ab = make_sendbw_pair(cl)
        _run(cl, 50)
        rep = cl.migrate("recv", 2, strategy="pre_copy")
        return (rep.downtime_s, rep.transfer_s, rep.checkpoint_s,
                rep.restore_s, rep.live_s,
                tuple(r["wire_s"] for r in rep.rounds))

    a, b = one(), one()
    assert a == b
    steps = a[0] / STEP_S                          # whole sim steps
    assert a[0] > 0 and abs(steps - round(steps)) < 1e-6


def test_admission_reads_measured_link_utilization():
    """A busy link shrinks the measured headroom, so a transfer budget
    that admits on an idle link rejects while traffic is flowing."""
    from repro.orchestrator import AdmissionError
    cl = SimCluster(3, link_bandwidth_Bps=1e8)
    aa, ab = make_sendbw_pair(cl, msg_size=4096, window=16)
    c, _ = _mr_container(cl, "bulk", 0, n_pages=16)
    # idle link: admission passes with a budget sized for the raw rate
    est = 16 * PAGE_SIZE + 4096
    cl.orchestrator.max_transfer_s = est / 1e8 * 2.0
    plan = cl.orchestrator.admit(c, cl.nodes[1])
    assert plan.est_transfer_s <= cl.orchestrator.max_transfer_s
    _run(cl, 2000)                                 # saturate link (0, 1)
    util = cl.fabric.link_utilization(0, 1)
    assert util > 0.5
    with pytest.raises(AdmissionError, match="util"):
        cl.orchestrator.admit(c, cl.nodes[1])


# ---------------------------------------------------------------------------
# adversity: loss on page streams, concurrent migrations on one link
# ---------------------------------------------------------------------------


def test_precopy_page_stream_recovers_from_loss():
    """Loss injection on MIG_PAGE streams: go-back-N recovers and the
    migrated MR contents are checksum-identical to the source."""
    cl = SimCluster(3, loss_prob=0.1, seed=3)
    c, mr = _mr_container(cl, "m", 0, n_pages=24)
    want = hashlib.sha256(bytes(mr.buf)).hexdigest()
    rep = cl.migrate("m", 2, strategy="pre_copy")
    assert rep.ok
    assert cl.fabric.stats["dropped"] > 0
    got_mr = c.ctx.mrs[0]
    assert got_mr is not mr                        # really restored
    assert hashlib.sha256(bytes(got_mr.buf)).hexdigest() == want
    assert c.node is cl.nodes[2]


def test_postcopy_pull_stream_recovers_from_loss():
    cl = SimCluster(3, loss_prob=0.1, seed=5)
    c, mr = _mr_container(cl, "m", 0, n_pages=8)
    want = hashlib.sha256(bytes(mr.buf)).hexdigest()
    rep = cl.migrate("m", 2, strategy="post_copy")
    assert rep.ok and rep.pager.remaining_pages > 0
    while rep.pager.remaining_pages:
        rep.pager.prefetch(4)
    cl.run_until_idle(max_steps=500_000)           # drain wire charges
    assert hashlib.sha256(bytes(c.ctx.mrs[0].buf)).hexdigest() == want
    assert cl.fabric.stats["mig_tx_bytes"] > 8 * PAGE_SIZE


def test_concurrent_migrations_share_one_link():
    """Two migrations whose streams cross the same (src, dest) link:
    both complete, and their combined throughput never exceeds the link
    bandwidth (the shared FIFO serialises them)."""
    cl = SimCluster(3, link_bandwidth_Bps=1e8)     # 100 B/step
    ca, _ = _mr_container(cl, "m1", 0, n_pages=32)
    cb, _ = _mr_container(cl, "m2", 0, n_pages=32)
    orch = cl.orchestrator
    orch.submit(ca, cl.nodes[2], strategy="pre_copy")
    orch.submit(cb, cl.nodes[2], strategy="pre_copy")
    t0 = cl.fabric.now
    ln = cl.fabric.link(0, 2)
    b0 = ln.tx_bytes
    reports = orch.drain()
    assert len(reports) == 2 and all(r.ok for r in reports)
    assert ca.node is cl.nodes[2] and cb.node is cl.nodes[2]
    backlog = max(0.0, ln.busy_until - cl.fabric.now) * \
        cl.fabric.bytes_per_step
    delivered = ln.tx_bytes - b0 - backlog
    capacity = (cl.fabric.now - t0) * cl.fabric.bytes_per_step
    assert delivered > 2 * 32 * PAGE_SIZE          # both streams went over
    assert delivered <= capacity * 1.01 + 2048     # <= link bandwidth


def test_transfer_timeout_aborts_stream_and_channel_recovers():
    """A hopeless stream (here: total loss) times out, the kernel QP pair
    is torn down (no eternal retransmission — the fabric still reaches
    idle), and a fresh rendezvous works once the link heals."""
    from repro.core.service import ServiceError
    cl = SimCluster(2, loss_prob=1.0, seed=1)
    svc = cl.nodes[0].device.service
    with pytest.raises(ServiceError, match="not acked"):
        svc.transfer(1, Op.MIG_STATE, {"kind": "x"}, b"d" * 20_000,
                     max_steps=500)
    cl.fabric.loss_prob = 0.0
    cl.run_until_idle()                    # no zombie WQE keeps it busy
    xid = svc.transfer(1, Op.MIG_STATE, {"kind": "x"}, b"d" * 20_000)
    assert cl.nodes[1].device.service.take_image(xid) == b"d" * 20_000
    assert not cl.nodes[1].device.service.images     # nothing orphaned


def test_bare_controller_wire_failure_reports_instead_of_raising():
    """A real stream failure on the bare controller path lands in the
    same observable state as fail_at='transfer': a failed report with a
    retry token — never an exception thrown mid-migration."""
    from repro.core.migration import MigrationError
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)

    def boom(*a, **k):
        raise MigrationError("link died")

    cl.migrator.stream_image = boom
    rep = cl.migrate("recv", 2)
    assert not rep.ok and rep.stage_failed == "transfer"
    assert rep.attempt is not None and rep.attempt["image"]
    assert isinstance(rep.transfer_error, MigrationError)


def test_failed_attempts_release_service_channel_state():
    """Rollback frees what a dead attempt parked in service channels —
    at every failure stage, including ones that never built a retry
    token (pre-copy checkpoint failure, post-copy transfer failure)."""
    cl = SimCluster(3)
    aa, ab = make_sendbw_pair(cl)
    _run(cl, 50)
    # pre-copy dies at checkpoint: round 0 already staged the whole
    # footprint at the destination's service channel
    rep = cl.migrate("recv", 2, strategy="pre_copy", fail_at="checkpoint")
    assert not rep.ok and rep.rolled_back
    assert not cl.nodes[2].device.service.staging
    _run(cl, 600)
    # post-copy dies at transfer with no retries: the frozen page store
    # parked at the source must not outlive the rollback
    rep = cl.migrate("recv", 2, strategy="post_copy", fail_at="transfer",
                     retries=0)
    assert not rep.ok and rep.rolled_back
    assert not cl.nodes[1].device.service.page_store
    assert not any(mr.pager for mr in cl.containers["recv"].ctx.mrs)
    _run(cl, 600)
    before = ab.received
    _run(cl, 200)
    assert ab.received > before                    # traffic recovered


# ---------------------------------------------------------------------------
# satellites: teardown back-pointers
# ---------------------------------------------------------------------------


def test_teardown_uses_owner_backpointers():
    """QP/MR carry their owning context: destroy/dereg stay coherent
    without scanning every context on the device."""
    cl = SimCluster(2)
    dev = cl.nodes[0].device
    ctx1, ctx2 = dev.open_context(), dev.open_context()
    pd1, pd2 = ctx1.alloc_pd(), ctx2.alloc_pd()
    cq = ctx1.create_cq()
    qp = pd1.create_qp(cq, cq)
    mr = pd2.reg_mr(PAGE_SIZE)
    assert qp.ctx is ctx1 and mr.ctx is ctx2
    dev.destroy_qp(qp.qpn)
    dev.dereg_mr(mr)
    assert qp not in ctx1.qps and mr not in ctx2.mrs
    assert dev.rkey_lookup(mr.rkey) is None
    # double-free is a no-op, not a crash
    dev.destroy_qp(qp.qpn)
    dev.dereg_mr(mr)
