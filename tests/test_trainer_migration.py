"""Transparent live migration of a distributed training job (paper §5.4):
the loss trajectory and final weights must be bitwise identical with and
without migration — transparency, quantified."""
import numpy as np

from repro.runtime.trainer import FabricTrainer


def test_training_loss_decreases():
    tr = FabricTrainer(2, seed=0)
    losses = tr.train(15)
    assert losses[-1] < losses[0]


def test_allreduce_matches_local_sum():
    tr = FabricTrainer(4, seed=1)
    vecs = [np.full(1000, float(r + 1), np.float32) for r in range(4)]
    out = tr.allreduce.run(vecs)
    for o in out:
        np.testing.assert_allclose(o, np.full(1000, 10.0), rtol=1e-6)


def test_migration_is_bitwise_transparent():
    ref = FabricTrainer(4, seed=3)
    l_ref = ref.train(10)
    mig = FabricTrainer(4, seed=3)
    l_mig = mig.train(10, migrate_at=5, migrate_rank=1)
    assert l_ref == l_mig
    for r in range(4):
        assert np.array_equal(ref.weights(r), mig.weights(r))


def test_mid_collective_migration_is_transparent():
    ref = FabricTrainer(4, seed=3)
    l_ref = ref.train(8)

    mig = FabricTrainer(4, seed=3)
    fired = {"done": False}

    def hook(now):
        if not fired["done"] and now > 40:
            fired["done"] = True
            mig.cluster.migrate("rank2", len(mig.cluster.nodes) - 1)

    l_mig = [mig.step(step_hook=hook if s == 4 else None) for s in range(8)]
    assert l_ref == l_mig
    for r in range(4):
        assert np.array_equal(ref.weights(r), mig.weights(r))


def test_multiple_sequential_migrations():
    ref = FabricTrainer(3, seed=9)
    l_ref = ref.train(9)
    mig = FabricTrainer(3, seed=9)
    out = []
    for s in range(9):
        if s == 3:
            mig.cluster.migrate("rank0", 3)
        if s == 6:
            mig.cluster.migrate("rank2", 3)   # same spare node, two ranks
        out.append(mig.step())
    assert out == l_ref
