"""Property-style randomized sweeps (hypothesis is unavailable offline;
seeded sweeps cover the same invariant space).

Invariants:
  P1  reliable channels deliver every message exactly once, in order,
      under any (loss_prob, msg sizes, migration time) combination;
  P2  dump->restore is the identity on all verbs object state;
  P3  training with k migrations at random steps == training with none.
"""
import msgpack
import numpy as np
import pytest

from repro.core import dump as dumplib
from repro.runtime.cluster import SimCluster
from repro.runtime.collectives import Channel, connect_pair
from repro.runtime.trainer import FabricTrainer


@pytest.mark.parametrize("seed", range(6))
def test_p1_exactly_once_under_chaos(seed):
    rng = np.random.RandomState(seed)
    loss = float(rng.choice([0.0, 0.02, 0.1]))
    cl = SimCluster(3, loss_prob=loss, seed=seed)
    ca = cl.launch("a", 0)
    cb = cl.launch("b", 1)
    c1 = Channel(ca.ctx, 1 << 18)
    c2 = Channel(cb.ctx, 1 << 18)
    connect_pair(c1, c2)
    n_msgs = int(rng.randint(3, 9))
    sizes = [int(rng.randint(1, 6000)) for _ in range(n_msgs)]
    off = 0
    for sz in sizes:
        c2.post_recv(sz, offset=off)
        off += sz
    off = 0
    payloads = []
    for i, sz in enumerate(sizes):
        p = bytes([i % 251] * sz)
        payloads.append(p)
        c1.post_send_bytes(p, offset=off)
        off += sz
    migrate_at = int(rng.randint(1, 60))
    wcs = []
    for step in range(60_000):
        cl.pump()
        if step == migrate_at:
            cl.migrate("b", 2)
            c2.h.ctx = cl.containers["b"].ctx   # rebind (apps do this)
        wcs.extend(w for w in c2.poll(8) if w.opcode == "RECV")
        if len(wcs) == n_msgs:
            break
    assert len(wcs) == n_msgs, (loss, sizes, len(wcs))
    off = 0
    for p in payloads:
        assert c2.recv_bytes(off, len(p)) == p
        off += len(p)


@pytest.mark.parametrize("seed", range(4))
def test_p2_dump_restore_identity(seed):
    rng = np.random.RandomState(seed)
    cl = SimCluster(2, loss_prob=float(rng.choice([0.0, 0.1])), seed=seed)
    ca = cl.launch("a", 0)
    cb = cl.launch("b", 1)
    c1 = Channel(ca.ctx, 1 << 16)
    c2 = Channel(cb.ctx, 1 << 16)
    connect_pair(c1, c2)
    for i in range(int(rng.randint(1, 4))):
        c2.post_recv(512, offset=i * 512)
        c1.post_send_bytes(bytes([i]) * 512, offset=i * 512)
    cl.pump(int(rng.randint(1, 10)))
    img1 = dumplib.dump_context(ca.ctx, stop=True)
    # dumping a stopped context twice is a fixed point
    img2 = dumplib.dump_context(ca.ctx, stop=False)
    assert msgpack.unpackb(img1, raw=False) == \
        msgpack.unpackb(img2, raw=False)


@pytest.mark.parametrize("seed", range(3))
def test_p3_random_migrations_are_transparent(seed):
    rng = np.random.RandomState(100 + seed)
    steps = 8
    ref = FabricTrainer(3, n_nodes=6, seed=seed)
    l_ref = ref.train(steps)
    mig = FabricTrainer(3, n_nodes=6, seed=seed)
    when = sorted(rng.choice(range(1, steps), size=2, replace=False))
    ranks = rng.randint(0, 3, size=2)
    out = []
    for s in range(steps):
        for w, r in zip(when, ranks):
            if s == w:
                mig.cluster.migrate(f"rank{r}",
                                    int(rng.randint(3, 6)))
        out.append(mig.step())
    assert out == l_ref
