"""Event-driven pump core vs the legacy per-step scan: bit-identical.

The fabric has two pump cores (``configure_pump``): the default
event/active-set scheduler — ports and devices are visited only when
they have work, idle stretches are skipped in one sim-clock jump — and
the legacy exhaustive per-step scan it replaced. The scheduler's whole
contract is that the shortcut is unobservable: same sim-clock
trajectory, same packets, same counters, same figures, bit for bit.

Each scenario here is a reduced-scale cut of a pinned benchmark figure
(fig_downtime, fig_incast, fig_ecn), run once per core from identical
initial conditions. The comparison is exact equality — no tolerances —
on three layers:

* the ``fabric.now`` trajectory sampled at every driver step (idle
  skipping must land on exactly the clock values the scan walks to),
* the full ``metrics.counters`` dict (every per-node / per-class twin
  included), and
* the scenario's own outputs (delivery counts, migration report floats).

Pump gauges (``pump_steps_skipped``, ``active_*``) are deliberately
outside the comparison: they describe *how* each core worked, and are
the one place the cores legitimately differ.
"""
from repro.core.transport import STEP_S
from repro.runtime.apps import SendBwApp
from repro.runtime.cluster import SimCluster
from repro.runtime.collectives import connect_pair


def _counters(cl):
    # plain dict: defaultdict identity must not leak into the equality
    return dict(cl.fabric.metrics.counters)


def _assert_identical(ref, fast, scenario):
    assert ref.keys() == fast.keys()
    for key in ref:
        assert ref[key] == fast[key], (
            f"{scenario}: '{key}' diverges between the legacy scan and "
            f"the event-driven core:\n  legacy: {ref[key]!r}\n"
            f"  event-driven: {fast[key]!r}")


def _run_both(scenario_fn):
    ref = scenario_fn(event_driven=False)
    fast = scenario_fn(event_driven=True)
    _assert_identical(ref, fast, scenario_fn.__name__)
    return ref


# -- fig_downtime cut: live migration mid-stream ---------------------------

def _migration_scenario(strategy):
    def scenario(event_driven):
        cl = SimCluster(3, link_bandwidth_Bps=1e8)
        cl.configure_pump(event_driven)
        A = cl.launch("send", 0)
        B = cl.launch("recv", 1)
        aa = SendBwApp(msg_size=4096, window=16, buf_size=64 * 1024)
        aa.attach(A, sender=True)
        A.app = aa
        ab = SendBwApp(msg_size=4096, window=16, buf_size=64 * 1024)
        ab.attach(B, sender=False)
        B.app = ab
        connect_pair(aa.channels[0], ab.channels[0])

        trajectory = []
        for _ in range(40):
            cl.step_all()
            trajectory.append(cl.fabric.now)
        rep = cl.migrate("recv", 2, strategy=strategy)
        trajectory.append(cl.fabric.now)
        for _ in range(150):
            cl.step_all()
            trajectory.append(cl.fabric.now)
        post_pull_s = 0.0
        if rep.pager is not None:          # post-copy: drain demand pulls
            t0 = cl.fabric.now
            while rep.pager.remaining_pages:
                rep.pager.prefetch(16)
                cl.fabric.pump()
            cl.run_until_idle(max_steps=500_000)
            post_pull_s = (cl.fabric.now - t0) * STEP_S
        return {
            "trajectory": trajectory,
            "counters": _counters(cl),
            "downtime_s": rep.downtime_s,
            "live_s": rep.live_s,
            "post_pull_s": post_pull_s,
            "image_bytes": rep.image_bytes,
            "pages_sent": rep.pages_sent,
            "rounds": len(rep.rounds),
            "sent": aa.sent,
            "received": ab.received,
        }
    scenario.__name__ = f"migration[{strategy}]"
    return scenario


def test_migration_pre_copy_identical():
    ref = _run_both(_migration_scenario("pre_copy"))
    assert ref["received"] > 0 and ref["downtime_s"] > 0.0


def test_migration_post_copy_identical():
    ref = _run_both(_migration_scenario("post_copy"))
    assert ref["pages_sent"] > 0 and ref["post_pull_s"] > 0.0


# -- fig_delta cut: pre-copy with the page codec on ------------------------

def _codec_migration_scenario(event_driven):
    """Reduced fig_delta: a codec-enabled pre-copy migration of a
    container whose MR mixes a zero band, a duplicate band, and live
    app pages. The codec's encode path (digest cache, delta snapshots,
    zlib) and the convergence controller's wire-byte accounting both
    feed the transfer's sim-clock cost, so a scan-vs-event divergence
    anywhere in the encoded stream shows up in the trajectory and the
    ``pages_*``/``delta_*`` counter twins."""
    import random

    from repro.core.verbs import PAGE_SIZE

    cl = SimCluster(3, link_bandwidth_Bps=1e8)
    cl.configure_pump(event_driven)
    cl.configure_codec(enabled=True)
    A = cl.launch("send", 0)
    B = cl.launch("recv", 1)
    aa = SendBwApp(msg_size=4096, window=16, buf_size=64 * 1024)
    aa.attach(A, sender=True)
    A.app = aa
    ab = SendBwApp(msg_size=4096, window=16, buf_size=64 * 1024)
    ab.attach(B, sender=False)
    B.app = ab
    connect_pair(aa.channels[0], ab.channels[0])
    mr = B.ctx.pds[0].reg_mr(64 * PAGE_SIZE)
    blk = bytes(range(256)) * (PAGE_SIZE // 256)
    for pg in range(8, 24):
        mr.write(pg * PAGE_SIZE, blk)
    for pg in range(24, 32):
        mr.write(pg * PAGE_SIZE,
                 random.Random(pg).randbytes(PAGE_SIZE))

    trajectory = []
    for _ in range(40):
        cl.step_all()
        trajectory.append(cl.fabric.now)
    rep = cl.migrate("recv", 2, strategy="pre_copy")
    trajectory.append(cl.fabric.now)
    for _ in range(150):
        cl.step_all()
        trajectory.append(cl.fabric.now)
    return {
        "trajectory": trajectory,
        "counters": _counters(cl),
        "transfer_s": rep.transfer_s,
        "downtime_s": rep.downtime_s,
        "pages_sent": rep.pages_sent,
        "round_wire": [r.get("wire_bytes") for r in rep.rounds],
        "ok": rep.ok,
        "received": ab.received,
    }


def test_migration_codec_identical():
    ref = _run_both(_codec_migration_scenario)
    assert ref["ok"] and ref["received"] > 0
    # the codec paths must actually fire, or the comparison is vacuous
    assert ref["counters"].get("pages_zero_elided", 0) > 0
    assert ref["counters"].get("pages_dedup_hits", 0) > 0
    assert all(w is not None for w in ref["round_wire"])


# -- fig_downtime cut, preempted: pause mid-flight, park, resume -----------

def _paused_migration_scenario(strategy):
    def scenario(event_driven):
        cl = SimCluster(3, link_bandwidth_Bps=1e8)
        cl.configure_pump(event_driven)
        A = cl.launch("send", 0)
        B = cl.launch("recv", 1)
        aa = SendBwApp(msg_size=4096, window=16, buf_size=64 * 1024)
        aa.attach(A, sender=True)
        A.app = aa
        ab = SendBwApp(msg_size=4096, window=16, buf_size=64 * 1024)
        ab.attach(B, sender=False)
        B.app = ab
        connect_pair(aa.channels[0], ab.channels[0])

        trajectory = []
        for _ in range(40):
            cl.step_all()
            trajectory.append(cl.fabric.now)
        # deadline pause early in the transfer, park with app traffic
        # still flowing, then resume to completion
        cl.pause_migration("recv", at=cl.fabric.now + 5)
        rep = cl.migrate("recv", 2, strategy=strategy)
        trajectory.append(cl.fabric.now)
        paused = rep.attempt is not None
        for _ in range(60):
            cl.step_all()
            trajectory.append(cl.fabric.now)
        if paused:
            rep = cl.resume_migration("recv")
            trajectory.append(cl.fabric.now)
        for _ in range(150):
            cl.step_all()
            trajectory.append(cl.fabric.now)
        return {
            "trajectory": trajectory,
            "counters": _counters(cl),
            "paused": paused,
            "preemptions": rep.preemptions,
            "paused_s": rep.paused_s,
            "downtime_s": rep.downtime_s,
            "transfer_s": rep.transfer_s,
            "live_s": rep.live_s,
            "image_bytes": rep.image_bytes,
            "pages_sent": rep.pages_sent,
            "ok": rep.ok,
            "sent": aa.sent,
            "received": ab.received,
        }
    scenario.__name__ = f"paused-migration[{strategy}]"
    return scenario


def test_paused_resumed_migration_identical():
    """A paused-and-resumed pre-copy run — the preemption machinery's
    suspend/park/re-admit path included — must be bit-identical between
    the legacy scan and the event-driven core: same per-step clock
    trajectory, same counters (migration_pauses/resumes twins too),
    same report floats."""
    ref = _run_both(_paused_migration_scenario("pre_copy"))
    # the comparison is vacuous unless the pause actually happened
    assert ref["paused"] and ref["ok"]
    assert ref["preemptions"] >= 1 and ref["paused_s"] > 0.0
    assert ref["counters"].get("migration_pauses", 0) >= 1
    assert ref["counters"].get("migration_resumes", 0) >= 1
    assert ref["received"] > 0


# -- fig_incast cut: bounded ingress, RNR backoff --------------------------

def _incast_scenario(ecn, steps):
    n_senders = 4

    def scenario(event_driven):
        cl = SimCluster(n_senders + 1, link_bandwidth_Bps=2e8)
        cl.configure_pump(event_driven)
        cl.configure_ingress(rx_bandwidth_Bps=2e8,
                             queue_bytes=32 * 1024, node=0)
        if ecn:
            cl.configure_ecn(enabled=True)
        receivers = []
        for i in range(n_senders):
            A = cl.launch(f"s{i}", i + 1)
            B = cl.launch(f"r{i}", 0)
            aa = SendBwApp(msg_size=4096, window=8)
            aa.attach(A, sender=True)
            A.app = aa
            ab = SendBwApp(msg_size=4096, window=8)
            ab.attach(B, sender=False)
            B.app = ab
            connect_pair(aa.channels[0], ab.channels[0])
            receivers.append(ab)
        cl.configure_rnr(rnr_retry=7, min_rnr_timer=64)

        trajectory = []
        for _ in range(steps):
            cl.step_all()
            trajectory.append(cl.fabric.now)
        return {
            "trajectory": trajectory,
            "counters": _counters(cl),
            "goodput": [r.received for r in receivers],
        }
    scenario.__name__ = f"incast[ecn={ecn}]"
    return scenario


def test_incast_rnr_identical():
    ref = _run_both(_incast_scenario(ecn=False, steps=1500))
    # the RNR/overflow machinery must actually fire, or the comparison
    # would be vacuous for the paths this scenario exists to pin
    assert ref["counters"].get("rnr_naks@0", 0) > 0
    assert ref["counters"].get("rx_dropped@0", 0) > 0
    assert all(g > 0 for g in ref["goodput"])


# -- fig_ecn cut: DCQCN marking, CNPs, rate control ------------------------

def test_ecn_dcqcn_identical():
    ref = _run_both(_incast_scenario(ecn=True, steps=2000))
    assert ref["counters"].get("ecn_marked@0", 0) > 0
    assert ref["counters"].get("cnps_sent", 0) > 0
    assert ref["counters"].get("cnps_handled", 0) > 0


# -- fig_pfc cut: lossless fabric, pause latches, pre-copy under incast ----

def _pfc_scenario(event_driven):
    """Reduced fig_pfc ``lossless_prio``: a 3:1 incast held lossless by
    PFC (QoS classes on, per-priority ECN) while a pre-copy migration
    streams a memory-backed container INTO the congested node. The
    pause latches feed the event scheduler's wake-time computation
    (``pfc_blocked_until``), so this cut pins exactly the paths where
    a skipped-vs-scanned step could diverge: latched egress heads,
    latch expiry wakes, and the XON release on the serviced ingress."""
    from repro.core.qos import QoSConfig

    n_senders = 3
    cl = SimCluster(n_senders + 3, link_bandwidth_Bps=2e8)
    cl.configure_pump(event_driven)
    cl.configure_ingress(rx_bandwidth_Bps=2e8,
                         queue_bytes=32 * 1024, node=0)
    cl.configure_pfc(enabled=True, xoff={"app": 0.30, "mig": 0.85},
                     xon={"app": 0.12, "mig": 0.55})
    cl.configure_qos(QoSConfig(enabled=True))
    cl.configure_ecn(enabled=True,
                     per_class={"app": (0.3, 0.9, 0.08),
                                "mig": (0.7, 1.0, 0.1)})
    receivers = []
    for i in range(n_senders):
        A = cl.launch(f"s{i}", i + 1)
        B = cl.launch(f"r{i}", 0)
        aa = SendBwApp(msg_size=4096, window=8)
        aa.attach(A, sender=True)
        A.app = aa
        ab = SendBwApp(msg_size=4096, window=8)
        ab.attach(B, sender=False)
        B.app = ab
        connect_pair(aa.channels[0], ab.channels[0])
        receivers.append(ab)
    bulk = cl.launch("bulk", n_senders + 1)
    bulk.ctx.alloc_pd().reg_mr(64 * 1024)

    trajectory = []
    for _ in range(600):
        cl.step_all()
        trajectory.append(cl.fabric.now)
    rep = cl.migrate("bulk", 0, strategy="pre_copy")
    for _ in range(1200):
        cl.step_all()
        trajectory.append(cl.fabric.now)
    return {
        "trajectory": trajectory,
        "counters": _counters(cl),
        "goodput": [r.received for r in receivers],
        "report": (rep.ok, rep.transfer_s, rep.downtime_s,
                   rep.image_bytes, rep.pages_sent),
    }


def test_pfc_lossless_identical():
    ref = _run_both(_pfc_scenario)
    # the pause machinery must actually fire, and stay lossless, or
    # the comparison is vacuous for the latch/wake paths it pins
    assert ref["counters"].get("pfc_pause_frames", 0) > 0
    assert ref["counters"].get("pfc_paused_steps", 0) > 0
    assert ref["counters"].get("rx_dropped", 0) == 0
    assert ref["counters"].get("dropped", 0) == 0
    assert ref["report"][0] is True
    assert all(g > 0 for g in ref["goodput"])
