"""Loop-aware HLO analyzer: validated against XLA cost_analysis on
loop-free modules; exact trip-count scaling on scanned modules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_mesh
from repro.roofline import analysis as roof
from repro.roofline import hlo as hlolib


def _cost(compiled):
    """cost_analysis() returns a dict in newer jax, [dict] in older."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 host device")
    return make_mesh((1, len(jax.devices())), ("data", "model"))


def test_loop_free_matches_cost_analysis():
    def f(a, b, c):
        return (jnp.tanh(a @ b) @ c).sum()

    co = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 512), jnp.float32),
        jax.ShapeDtypeStruct((512, 64), jnp.float32)).compile()
    ca = _cost(co)
    mine = hlolib.analyze_text(co.as_text())
    # dots dominate; XLA adds elementwise flops we deliberately skip
    assert abs(mine["flops"] - ca["flops"]) / ca["flops"] < 0.05
    assert abs(mine["bytes"] - ca["bytes accessed"]) / \
        ca["bytes accessed"] < 0.05


def test_scan_bodies_are_trip_scaled():
    N = 12

    def g(a, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, a, ws)
        return y.sum()

    co = jax.jit(g).lower(
        jax.ShapeDtypeStruct((128, 256), jnp.float32),
        jax.ShapeDtypeStruct((N, 256, 256), jnp.float32)).compile()
    mine = hlolib.analyze_text(co.as_text())
    expected = 2 * 128 * 256 * 256 * N
    assert abs(mine["flops"] - expected) / expected < 0.01
    # cost_analysis counts the body once: we must be ~N x larger
    ca = _cost(co)
    assert mine["flops"] > 0.9 * N * ca["flops"] / 2


def test_collectives_are_found_and_loop_scaled():
    import os
    if len(jax.devices()) < 2:
        pytest.skip("needs multiple devices")
    mesh = make_mesh((len(jax.devices()),), ("model",))
    sh = NamedSharding(mesh, P(None, "model"))

    def f(a, ws):
        def body(x, w):
            y = x @ w                    # contract sharded dim: all-reduce
            return y, None
        out, _ = jax.lax.scan(body, a, ws)
        return out.sum()

    N = 4
    co = jax.jit(f, in_shardings=(sh, None)).lower(
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((N, 128, 128), jnp.float32)).compile()
    total, by_op = hlolib.collective_bytes(co.as_text())
    assert total > 0


def test_roofline_terms_and_bottleneck():
    r = roof.analyze(flops_per_dev=197e12, bytes_per_dev=819e9 / 2,
                     coll_bytes_per_dev=0.0, model_flops_total=197e12 * 256,
                     n_devices=256)
    assert r.bottleneck == "compute"
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.useful_ratio - 1.0) < 1e-9
    r2 = roof.analyze(flops_per_dev=1e9, bytes_per_dev=819e9,
                      coll_bytes_per_dev=0.0, model_flops_total=1.0,
                      n_devices=2)
    assert r2.bottleneck == "memory"


def test_model_flops_formulas():
    from repro.configs.base import SHAPES, get_config
    from repro.models.model import LM
    lm = LM(get_config("deepseek-7b"))
    counts = roof.count_params(lm)
    assert 6.5e9 < counts["total"] < 8e9
    mf_train = roof.model_flops(lm, SHAPES["train_4k"], counts)
    assert abs(mf_train - 6 * counts["total"] * 256 * 4096) < 1e-6 * mf_train
    lm2 = LM(get_config("deepseek-v2-236b"))
    c2 = roof.count_params(lm2)
    assert c2["active"] < 0.15 * c2["total"]   # MoE discount applies
