#!/usr/bin/env python3
"""Docs health check, run by CI next to the tier-1 tests.

Three gates:

1. Markdown link check: every relative link in README.md, ROADMAP.md,
   and docs/**.md must resolve to a file in the repo (anchors are
   stripped; absolute http(s)/mailto links are not fetched).
2. Paper-section check: every module under src/repro/core/ must have a
   module docstring that names the paper section/figure/table it
   implements (the repo's fidelity-audit convention; docs/paper-map.md
   is the cross-reference table built on it).
3. Operator-knob check: every public ``configure_*`` method on
   ``SimCluster`` and ``Fabric`` must be mentioned somewhere under
   docs/ — an undocumented knob is an unusable knob.
4. Trace-taxonomy check: every ``EventKind`` member in
   ``repro.obs.trace`` must appear (by its value string) in
   docs/observability.md — an event type nobody can look up is noise
   in every exported trace.

Exit code 0 iff all gates pass; failures are listed one per line.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# [text](target) — target group; images (![...]) match the same shape
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# inline/fenced code spans are stripped before link extraction
_FENCE = re.compile(r"```.*?```", re.S)
_CODE = re.compile(r"`[^`]*`")
# a paper anchor: §N, Fig. N, Table N, or Listing N
_PAPER_REF = re.compile(r"§\s*\d|Fig\.\s*\d|Table\s*\d|Listing\s*\d")


def md_files():
    for p in (ROOT / "README.md", ROOT / "ROADMAP.md"):
        if p.exists():
            yield p
    yield from sorted((ROOT / "docs").glob("**/*.md"))


def check_links() -> list:
    errors = []
    for md in md_files():
        text = _CODE.sub("", _FENCE.sub("", md.read_text()))
        for target in _LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = (md.parent / rel).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
    return errors


def check_core_docstrings() -> list:
    errors = []
    for py in sorted((ROOT / "src/repro/core").glob("*.py")):
        if py.name == "__init__.py":
            continue
        doc = ast.get_docstring(ast.parse(py.read_text()))
        if not doc:
            errors.append(f"{py.relative_to(ROOT)}: missing module "
                          f"docstring")
        elif not _PAPER_REF.search(doc):
            errors.append(f"{py.relative_to(ROOT)}: module docstring "
                          f"names no paper section (§N / Fig. N / "
                          f"Table N / Listing N)")
    return errors


# the operator surfaces whose configure_* knobs must be documented
_KNOB_CLASSES = {
    "src/repro/runtime/cluster.py": "SimCluster",
    "src/repro/core/transport.py": "Fabric",
    "src/repro/orchestrator/orchestrator.py": "Orchestrator",
}


def configure_knobs():
    """(class_name, method_name) for every public configure_* method on
    the operator-surface classes."""
    out = []
    for rel, cls_name in _KNOB_CLASSES.items():
        tree = ast.parse((ROOT / rel).read_text())
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef) and node.name == cls_name:
                for item in node.body:
                    if isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)) \
                            and item.name.startswith("configure_"):
                        out.append((cls_name, item.name))
    return out


def check_configure_knobs(knobs) -> list:
    docs_text = "\n".join(p.read_text()
                          for p in sorted((ROOT / "docs").glob("**/*.md")))
    errors = []
    if not knobs:
        errors.append("knob check found no configure_* methods — "
                      "did SimCluster/Fabric move?")
    for cls_name, name in knobs:
        if name not in docs_text:
            errors.append(f"{cls_name}.{name}: operator knob not "
                          f"mentioned anywhere under docs/")
    return errors


def event_kinds():
    """Value strings of every EventKind member in repro.obs.trace,
    read via AST so the check needs no importable package."""
    tree = ast.parse((ROOT / "src/repro/obs/trace.py").read_text())
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "EventKind":
            for item in node.body:
                if isinstance(item, ast.Assign) \
                        and isinstance(item.value, ast.Constant) \
                        and isinstance(item.value.value, str):
                    out.append(item.value.value)
    return out


def check_event_taxonomy(kinds) -> list:
    doc = ROOT / "docs/observability.md"
    if not doc.exists():
        return ["docs/observability.md missing (the trace-event "
                "taxonomy reference)"]
    text = doc.read_text()
    errors = []
    if not kinds:
        errors.append("taxonomy check found no EventKind members — "
                      "did repro.obs.trace move?")
    for kind in kinds:
        if kind not in text:
            errors.append(f"EventKind {kind!r} not documented in "
                          f"docs/observability.md")
    return errors


def main() -> int:
    knobs = configure_knobs()
    kinds = event_kinds()
    errors = (check_links() + check_core_docstrings()
              + check_configure_knobs(knobs)
              + check_event_taxonomy(kinds))
    for e in errors:
        print(f"FAIL: {e}")
    n_md = len(list(md_files()))
    n_py = len(list((ROOT / "src/repro/core").glob("*.py"))) - 1
    if not errors:
        print(f"docs OK: {n_md} markdown files linked, "
              f"{n_py} core modules cite their paper section, "
              f"{len(knobs)} configure_* knobs documented, "
              f"{len(kinds)} trace-event kinds documented")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
