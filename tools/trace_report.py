"""Render a migration timeline report from a traced fig_downtime run.

Runs one ``benchmarks.fig_downtime`` scenario with the fabric tracer
enabled, builds the migration report (``repro.obs``), prints the text
timeline, and *validates* the observability contract: the transfer phase
spans in the trace must sum exactly to the ``MigrationReport``'s
``transfer_s``, and the checkpoint+transfer+restore spans to its
``downtime_s``. Exits non-zero on any mismatch, so CI running this
catches a hook site drifting away from the report-field arithmetic.

Usage:
    PYTHONPATH=src python tools/trace_report.py [--strategy pre_copy]
        [--chrome trace.json] [--events]
"""
import argparse
import json
import math
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, ROOT)

from benchmarks.fig_downtime import run_strategy                  # noqa
from repro.obs import (build_migration_report, render_timeline,   # noqa
                       write_chrome_trace)


def check(label: str, got: float, want: float) -> bool:
    ok = math.isclose(got, want, rel_tol=1e-12, abs_tol=0.0) \
        or got == want
    mark = "ok" if ok else "MISMATCH"
    print(f"# {label}: spans={got!r} report={want!r} [{mark}]")
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--strategy", default="pre_copy",
                    choices=("stop_and_copy", "pre_copy", "post_copy"))
    ap.add_argument("--chrome", metavar="PATH", default=None,
                    help="also export Chrome trace-event JSON to PATH")
    ap.add_argument("--events", action="store_true",
                    help="print per-kind event counts")
    args = ap.parse_args(argv)

    rep, downtime, total, ab, cl = run_strategy(args.strategy, trace=True)
    tracer = cl.fabric.tracer
    report = build_migration_report(tracer, now=cl.fabric.now)
    print(render_timeline(report))
    print()
    ok = check("transfer_s", report["transfer_s"], rep.transfer_s)
    ok &= check("downtime_s", report["downtime_s"], rep.downtime_s)
    if args.events:
        for kind, n in sorted(report["event_counts"].items()):
            print(f"#   {kind}: {n}")
    if args.chrome:
        path = write_chrome_trace(tracer, args.chrome)
        with open(path) as f:
            n = len(json.load(f)["traceEvents"])
        print(f"# chrome trace -> {path} ({n} events)")
    if not ok:
        print("# FAILED: phase spans disagree with the migration report",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
