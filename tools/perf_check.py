"""Benchmark wall-clock gate: BENCH_summary.json vs the committed baseline.

``benchmarks.run --json`` leaves a per-figure summary with each figure's
wall-clock ``wall_s``. This tool compares it against the committed
``BENCH_baseline.json`` and exits non-zero when the suite has regressed
past the tolerance — the CI backstop for the event-driven pump core: an
accidental fallback to per-step scanning (or any O(n)-per-step creep on
the hot paths) shows up as a multiple, not a few percent.

Two gates, both against ``ratio`` (default 1.5x):

* the **suite total** — the hard gate. Totals average out per-figure
  jitter, so 1.5x on the sum is a real regression, not noise.
* **per figure**, but only for figures whose baseline wall_s is at
  least ``--floor`` seconds (default 0.5). Sub-floor figures finish in
  milliseconds, where interpreter warmup noise swamps any signal; they
  are reported but never gate.

Any figure that failed (``ok: false``) or is missing from the summary
fails the check outright. Absolute seconds differ across machines, so
the baseline should be refreshed (``--update``) on the reference runner
whenever the suite's expected cost legitimately changes — the gate
catches multiples, and CI runners are within 1.5x of each other for
this pure-Python suite.

Usage:
    PYTHONPATH=src python -m benchmarks.run --json
    python tools/perf_check.py [--ratio 1.5] [--update]
"""
import argparse
import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
DEFAULT_SUMMARY = os.path.join(ROOT, "BENCH_summary.json")
DEFAULT_BASELINE = os.path.join(ROOT, "BENCH_baseline.json")


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def totals(summary: dict) -> float:
    return sum(e.get("wall_s") or 0.0 for e in summary.values())


def update_baseline(summary: dict, path: str) -> None:
    """Freeze the current summary's wall clocks as the new baseline.
    Only names and wall_s are kept — metrics pinning is the figures'
    own assertions' job, not this gate's."""
    base = {name: {"wall_s": entry.get("wall_s")}
            for name, entry in sorted(summary.items())}
    with open(path, "w") as f:
        json.dump(base, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# baseline updated -> {path} "
          f"(total {totals(base):.2f}s, {len(base)} figures)")


def check(summary: dict, baseline: dict, ratio: float,
          floor: float) -> int:
    failures = []
    for name, entry in sorted(summary.items()):
        if not entry.get("ok"):
            failures.append(f"{name}: figure FAILED "
                            f"({entry.get('error', 'no result')})")
    for name, base in sorted(baseline.items()):
        entry = summary.get(name)
        if entry is None:
            failures.append(f"{name}: missing from summary "
                            f"(figure dropped without a baseline update?)")
            continue
        got = entry.get("wall_s") or 0.0
        want = base.get("wall_s") or 0.0
        gates = want >= floor
        verdict = "ok"
        if want > 0 and got > want * ratio:
            verdict = "REGRESSED" if gates else "slow (sub-floor, no gate)"
            if gates:
                failures.append(
                    f"{name}: {got:.3f}s vs baseline {want:.3f}s "
                    f"(> {ratio:.2f}x)")
        print(f"# {name}: {got:.3f}s baseline={want:.3f}s [{verdict}]")

    got_total = totals(summary)
    want_total = totals(baseline)
    print(f"# total: {got_total:.2f}s baseline={want_total:.2f}s "
          f"(gate {want_total * ratio:.2f}s)")
    if got_total > want_total * ratio:
        failures.append(
            f"suite total {got_total:.2f}s vs baseline "
            f"{want_total:.2f}s (> {ratio:.2f}x)")

    if failures:
        print("# perf check FAILED:", file=sys.stderr)
        for f in failures:
            print(f"#   {f}", file=sys.stderr)
        return 1
    print("# perf check ok")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--summary", default=DEFAULT_SUMMARY,
                    help="benchmarks.run --json output "
                         "(default BENCH_summary.json)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed baseline (default BENCH_baseline.json)")
    ap.add_argument("--ratio", type=float, default=1.5,
                    help="fail when wall_s exceeds baseline*ratio "
                         "(default 1.5)")
    ap.add_argument("--floor", type=float, default=0.5,
                    help="per-figure gating floor in baseline seconds; "
                         "faster figures report but never gate "
                         "(default 0.5)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current summary "
                         "instead of checking")
    args = ap.parse_args(argv)

    summary = load(args.summary)
    if args.update:
        update_baseline(summary, args.baseline)
        return 0
    if not os.path.exists(args.baseline):
        print(f"# no baseline at {args.baseline}; run with --update "
              f"to create one", file=sys.stderr)
        return 1
    return check(summary, load(args.baseline), args.ratio, args.floor)


if __name__ == "__main__":
    raise SystemExit(main())
