"""Generate EXPERIMENTS.md from results/*.jsonl + benchmark output."""
import json
import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.configs.base import ARCH_IDS, SHAPES, shape_applicable  # noqa


def load(path):
    fn = os.path.join(ROOT, "results", path)
    if not os.path.exists(fn):
        return []
    return [json.loads(l) for l in open(fn)]


def fmt_s(x):
    if x >= 1:
        return f"{x:7.2f}s "
    return f"{x*1e3:7.1f}ms"


def cell_row(r):
    rl = r["roofline"]
    mem = r["memory"]["per_device_total"] / 1e9
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{mem:6.1f} | {fmt_s(rl['compute_s'])} | "
            f"{fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} | "
            f"{rl['bottleneck']} | {rl['useful_ratio']:.2f} | "
            f"{rl['model_flops_total']:.2e} |")


def main():
    base = [r for r in load("dryrun_baseline.jsonl") if "error" not in r]
    hill = [r for r in load("hillclimb.jsonl") if "error" not in r]
    out = []
    w = out.append

    w("# EXPERIMENTS\n")
    w("All numbers from the CPU-hosted dry-run methodology (DESIGN.md §6):"
      " 512 placeholder host devices, `.lower().compile()` per cell,"
      " loop-aware HLO analysis for per-device FLOPs/bytes/collective"
      " bytes, TPU v5e constants (197 TF/s bf16, 819 GB/s HBM,"
      " 50 GB/s/link ICI). `useful` = MODEL_FLOPS/chips ÷ HLO_FLOPs/dev.\n")

    # ---------------- Dry-run -------------------------------------------------
    w("## §Dry-run\n")
    sp = [r for r in base if r["mesh"] == "16x16"]
    mp = [r for r in base if r["mesh"] == "2x16x16"]
    w(f"Every (architecture × applicable shape × mesh) cell lowers and "
      f"compiles: **{len(sp)} single-pod (16×16 = 256 chips) + {len(mp)} "
      f"multi-pod (2×16×16 = 512 chips) = {len(base)} cells, 0 failures**. "
      f"`long_500k` runs for the sub-quadratic archs "
      f"(recurrentgemma-9b, gemma3-1b, mamba2-2.7b) and is skipped for the "
      f"7 pure-full-attention archs (DESIGN.md §4). Per-cell compile time "
      f"{min(r['compile_s'] for r in base):.0f}–"
      f"{max(r['compile_s'] for r in base):.0f}s; memory_analysis / "
      f"cost_analysis / post-SPMD HLO recorded in "
      f"results/dryrun_baseline.jsonl.\n")
    w("Multi-pod cells prove the `pod` axis shards: batch splits over "
      "(`pod`,`data`), gradient reduction crosses pods, and per-device "
      "memory drops accordingly (e.g. deepseek-v2-236b train_4k: "
      + ", ".join(
          f"{r['mesh']}: {r['memory']['per_device_total']/1e9:.0f} GB/dev"
          for r in base if r["arch"] == "deepseek-v2-236b"
          and r["shape"] == "train_4k") + ").\n")

    # ---------------- Roofline ------------------------------------------------
    w("## §Roofline (single-pod 16×16, baseline configuration)\n")
    w("| arch | shape | mesh | GB/dev | compute | memory | collective |"
      " bottleneck | useful | MODEL_FLOPS |")
    w("|---|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_IDS:
        for s in SHAPES:
            for r in sp:
                if r["arch"] == a and r["shape"] == s:
                    w(cell_row(r))
    w("")
    w("**Multi-pod (2×16×16) supplement** — same cells at 512 chips "
      "(collective terms include the cross-pod axis):\n")
    w("| arch | shape | mesh | GB/dev | compute | memory | collective |"
      " bottleneck | useful | MODEL_FLOPS |")
    w("|---|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_IDS:
        for s in ("train_4k",):
            for r in mp:
                if r["arch"] == a and r["shape"] == s:
                    w(cell_row(r))
    w("")
    w("### Reading the table\n")
    w("* **Training cells are memory-term dominated** in the XLA-level "
      "baseline: the blocked-attention scans keep score blocks in HBM "
      "(XLA:CPU's fusion choices; on TPU the Pallas kernels in "
      "`src/repro/kernels/` hold them in VMEM — that gap is exactly the "
      "kernels' reason to exist, and §Perf quantifies the XLA-level "
      "recovery).")
    w("* **Decode cells** have `useful ≈ 1.0`: decode is honestly "
      "HBM-bound (KV-cache reads); compute terms are µs-level.")
    w("* **gemma3-1b prefill_32k is the one collective-bound cell** "
      "(§Perf cell B tracks it down to partitioner-chosen seq-sharding "
      "of MQA K/V).")
    w("* `useful > 1` on some decode cells: MODEL_FLOPS includes the "
      "attention cache-read term while XLA counts only dots — bounded "
      "approximation, stated in DESIGN.md §6.\n")

    # ---------------- Perf ----------------------------------------------------
    w("## §Perf — hypothesis → change → measure → validate\n")
    w("Three cells hillclimbed per the assignment: worst useful-ratio "
      "large-train (deepseek-v2-236b train_4k — also the most "
      "paper-representative: the EP arch has O(experts) channels per "
      "container, stressing multi-QP migration), the most "
      "collective-bound (gemma3-1b prefill_32k), and a representative "
      "dense train (stablelm-1.6b train_4k). Full per-run records in "
      "results/hillclimb.jsonl.\n")

    def find(arch, shape, **kw):
        kw.setdefault("schedule", "full")
        for r in hill:
            if r["arch"] != arch or r["shape"] != shape:
                continue
            ok = True
            for k, v in kw.items():
                if r.get(k) != v:
                    ok = False
            if ok:
                return r
        return None

    def perf_rows(title, arch, shape, runs):
        w(f"### {title}\n")
        w("| change | compute | memory | collective | GB/dev | Δdominant |")
        w("|---|---|---|---|---|---|")
        prev = None
        for label, kw in runs:
            r = find(arch, shape, **kw)
            if r is None:
                w(f"| {label} | (missing) | | | | |")
                continue
            rl = r["roofline"]
            dom = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
            delta = "" if prev is None else f"{(dom-prev)/prev*100:+.0f}%"
            w(f"| {label} | {fmt_s(rl['compute_s'])} | "
              f"{fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} | "
              f"{r['memory']['per_device_total']/1e9:.1f} | {delta} |")
            prev = dom
        w("")

    perf_rows("Cell A — stablelm-1.6b × train_4k (dominant: memory)",
              "stablelm-1.6b", "train_4k",
              [("baseline (blocked attn, full remat)",
                dict(impl=None, remat="full")),
               ("+ flash custom-vjp attention", dict(impl="flash",
                                                     remat="full")),
               ("+ dots_saveable remat", dict(impl="flash",
                                              remat="dots_saveable")),
               ("+ batch-pinned qkv", dict(impl="flash",
                                           qkv_constraint="batch")),
               ("triangular causal schedule (blocked impl)",
                dict(impl=None, schedule="triangular")),
               ("triangular + flash (schedule ignored by flash fwd)",
                dict(impl="flash", schedule="triangular"))])
    perf_rows("Cell B — gemma3-1b × prefill_32k (dominant: collective)",
              "gemma3-1b", "prefill_32k",
              [("baseline", dict(impl=None)),
               ("+ batch-pinned qkv", dict(impl=None,
                                           qkv_constraint="batch")),
               ("+ replicated weights (no FSDP at inference)",
                dict(impl=None, qkv_constraint="batch",
                     rules="replicated_weights")),
               ("+ flash attention", dict(impl="flash",
                                          qkv_constraint="batch",
                                          rules="replicated_weights"))])
    perf_rows("Cell C — deepseek-v2-236b × train_4k (dominant: memory; "
              "collective 2nd)",
              "deepseek-v2-236b", "train_4k",
              [("baseline (EP shard_map dispatch)", dict(impl=None)),
               ("+ flash custom-vjp attention (MLA)", dict(impl="flash")),
               ("+ capacity factor 1.25→1.0",
                dict(impl="flash", capacity_factor=1.0)),
               ("+ batch-pinned qkv",
                dict(impl="flash", capacity_factor=1.0,
                     qkv_constraint="batch"))])

    w("""### Iteration log (hypothesis → change → before → after → verdict)

**Cell A (stablelm-1.6b train_4k; dominant = memory 7.81s):**
1. *Hypothesis*: autodiff through the chunked-attention scans saves
   O(S²) score blocks for backward; a flash custom-VJP (save only
   out+lse, recompute scores blockwise) should cut the memory term by
   the score-block traffic share (napkin: ~25-35%% of bytes).
   *Change*: `impl=flash` (kernels/ops.py `_flash`). *Result*: memory
   7.81s → 5.55s (−29%%), 22.5 → 19.8 GB/dev. **Confirmed.**
2. *Hypothesis*: `dots_saveable` remat avoids recompute, trading memory
   capacity for less recompute traffic — might reduce bytes another
   ~10%%. *Change*: `remat=dots_saveable`. *Result*: memory **rose** to
   7.15s and residency exploded to 96.5 GB/dev (every matmul output of
   24 layers saved). **Refuted** — full remat + flash is strictly
   better at this scale; kept `remat=full`.
3. *Hypothesis*: batch-pinning qkv helps MQA archs; stablelm is MHA so
   expect no change. *Result*: identical terms. **Confirmed (neutral
   control).**
4. *Hypothesis*: the triangular causal schedule (statically unrolled
   q-chunks, above-diagonal blocks never built) should cut attention
   flops ~2x AND remove those blocks' saved-buffer traffic. *Change*:
   `schedule=triangular` (blocked impl). *Result*: compute 0.286 →
   0.257s (−10%%) and memory 7.81 → **5.09s (−35%%)** — better than
   flash on this shape, because skipped blocks save both flops and
   bytes. **Confirmed**; flash+triangular is identical to flash (the
   custom-VJP forward ignores the schedule), so the best cell-A config
   is blocked+triangular; flash remains the default for shapes where
   static unrolling is impractical (32k+ sequences).
   Stopping: remaining candidates (<5%% napkin estimates) not pursued.

**Cell B (gemma3-1b prefill_32k; dominant = collective 1.29s —
the only collective-bound cell):**
1. *Hypothesis*: 35,897 collective-permutes + 17,897 all-reduces of
   tiny blocks can only come from a partitioner decision inside the
   attention chunk loops: gemma3 is MQA (1 KV head, unshardable), so
   GSPMD sequence-shards K/V over `model`, and every
   `dynamic_slice`/window step becomes a cross-shard exchange.
   Pinning q/k/v to batch-only sharding should eliminate them at the
   price of redundant (replicated) attention math on the model axis.
   *Change*: `qkv_constraint=batch`. *Result*: collective 1.29s →
   0.44s (−66%%); compute 0.04 → 0.06s (redundancy, as predicted);
   bound flips to memory (1.08s). **Confirmed.**
2. *Hypothesis*: remaining collectives are FSDP weight all-gathers —
   replicating weights at inference (`embed→None` rule) should remove
   them. *Change*: `--replicate-weights`. *Result*: collective 0.44 →
   0.43s. **Refuted** (weight AGs were negligible for a 1B model; the
   remaining bytes are the tied-embedding gather + logits paths).
3. flash impl: no change for forward-only prefill (no backward saves
   to eliminate). **Confirmed (neutral).**
   Net: dominant term −19%%; collective term −66%%.

**Cell C (deepseek-v2-236b train_4k; dominant = memory 96.4s,
collective 17.9s; worst useful=0.38 of the big train cells):**
0. *Pre-step (recorded during bring-up)*: GSPMD auto-sharding of the
   naive scatter-based MoE dispatch replicated the token buffer:
   374 GB/dev and a 122s collective term (multi-pod). Replacing it
   with the explicit shard_map all-to-all EP dispatch (now the
   default) brought the multi-pod cell to ~80 GB/dev — the single
   largest win in the project and the reason EP is hand-written.
1. *Hypothesis*: MLA expands to 128 full heads in training, so
   flash-VJP should cut saved-score traffic ~25%%. *Change*:
   `impl=flash`. *Result*: memory 96.4s → 71.1s (−26%%), 169 → 145
   GB/dev. **Confirmed.**
2. *Hypothesis*: EP a2a volume and expert matmul padding scale with
   capacity_factor; 1.25→1.0 should trim ~5%% of collective+compute.
   *Change*: `--capacity-factor 1.0`. *Result*: collective 17.9 →
   17.0s, memory 71.1 → 68.8s, compute 7.2 → 6.9s. **Confirmed**
   (small, as predicted; more aggressive dropping changes semantics).
3. qkv pinning: no effect — MLA does not route through the GQA qkv
   path. **Neutral control.**
   Stopping: change 2 was <5%% on the dominant term; remaining memory
   is attention/expert block traffic that the TPU Pallas kernels keep
   in VMEM (below).

### What the dominant memory term really is (TPU projection)

The XLA:CPU dry-run charges every attention score/expert block to HBM
because XLA:CPU fuses far less than the TPU backend and nothing keeps
blocks in VMEM. The Pallas kernels (`kernels/flash_attention.py`,
`kernels/ssd.py`, `kernels/rglru.py`) are written precisely so scores /
SSD decay matrices / RG-LRU states never leave VMEM. Napkin check for
stablelm train_4k: QKV+O+dO+dQKV traffic ≈ 3·4·(16·4096·2048·2 B)·24L ≈
77 GB/dev → memory term ≈ 0.09s, vs compute 0.29s → the cell flips to
compute-bound at ~3.3× under the XLA-level number. That headroom is
recorded here rather than claimed as measured, since this container
cannot execute TPU kernels (interpret-mode validation only).
""")

    # optimized full table
    optim = [r for r in load("dryrun_optimized.jsonl") if "error" not in r]
    if optim:
        w("## §Roofline — optimized configuration (beyond-paper default: "
          "flash custom-VJP attention), single-pod\n")
        tot_b = tot_o = 0.0
        basemap = {(r["arch"], r["shape"]): r for r in sp}
        w("| arch | shape | step bound (baseline) | step bound (optimized)"
          " | Δ | bottleneck |")
        w("|---|---|---|---|---|---|")
        for r in optim:
            b = basemap[(r["arch"], r["shape"])]
            sb = b["roofline"]["step_s"]
            so = r["roofline"]["step_s"]
            tot_b += sb
            tot_o += so
            w(f"| {r['arch']} | {r['shape']} | {fmt_s(sb)} | {fmt_s(so)} |"
              f" {(so-sb)/sb*100:+.0f}% | {r['roofline']['bottleneck']} |")
        w("")
        w(f"Aggregate no-overlap step bound across all 33 cells: "
          f"**{tot_b:.0f}s → {tot_o:.0f}s ({(tot_o-tot_b)/tot_b*100:+.1f}%)"
          f"**. Both tables kept separately per the assignment: the "
          f"paper-faithful baseline above, the beyond-paper optimized "
          f"version here. Cell-A's best single config is actually the "
          f"blocked+triangular schedule (memory 7.81→5.09s, −35%, AND "
          f"compute −10%) — static above-diagonal block skipping removes "
          f"their saved buffers too; flash wins where windows/long "
          f"sequences make unrolled schedules impractical.\n")

    # paper-reproduction results
    w("## §Paper reproduction (MigrOS claims)\n")
    w("From `PYTHONPATH=src python -m benchmarks.run` "
      "(full output: bench_output.txt):\n")
    w("| paper artifact | paper's claim | our reproduction |")
    w("|---|---|---|")
    w("| Table 1 (SLOC) | migration support is a small delta; QP-task "
      "changes ~6%% of total | migration-marked lines are a small "
      "fraction of each component; `table1_sloc` prints the split and "
      "the QP-task share |")
    w("| Table 2 (dump sizes) | per-object dumps are tens-to-hundreds "
      "of bytes | PD 14B, MR 49B, CQ 41B, SRQ 68B, idle QP 147B; a QP "
      "dumped mid-message additionally carries its in-flight packet "
      "payloads (4.7KB here) — the 'current WQE state' the paper's "
      "Table 2 notes for QP w/SRQ (`table2_dump_sizes`) |")
    w("| Fig. 7 (no fast-path overhead) | migratable == non-migratable "
      "perf | stripped-vs-migratable QP tasks within noise "
      "(`fig7_overhead`, also tests/test_migration.py) |")
    w("| Fig. 8 (DMTCP shadows cost) | up to 70%% bandwidth loss, "
      "+23%% latency | shadow interposition measurably slower at all "
      "sizes (`fig8_shadow`) + bounce-copy semantics verified in tests |")
    w("| Fig. 9 (object creation) | ms-range, NIC-dependent | µs-range "
      "in the software fabric (`fig9_creation`) — relative ordering "
      "(QP>MR>CQ>PD) preserved |")
    w("| Fig. 10 (MR registration vs size) | grows with region size | "
      "monotone growth reproduced (`fig10_mr_reg`) |")
    w("| Fig. 11 (migration vs #QPs) | time ∝ #QPs + MR bytes | 1→64 "
      "QPs: monotone total time and image size; traffic resumes in "
      "every case (`fig11_qps`) |")
    w("| Fig. 13 (MPI app migration) | latency ∝ checkpoint size; apps "
      "continue | checkpoint/transfer/restore breakdown ∝ model size; "
      "**loss trajectory bitwise identical with/without migration** "
      "(`fig13_training_migration`, tests/test_trainer_migration.py) |")
    w("| §3.4 failure semantics | failed migration leaves peers paused "
      "forever | `test_failed_migration_leaves_peer_paused` |")
    w("| §3.4 simultaneous migrations | no addressing confusion | "
      "`test_simultaneous_migration_of_both_endpoints` (QPN-keyed "
      "control-plane relocation registry) |")
    w("")

    txt = "\n".join(out)
    open(os.path.join(ROOT, "EXPERIMENTS.md"), "w").write(txt)
    print(f"wrote EXPERIMENTS.md ({len(txt)} bytes) "
          f"base={len(base)} hill={len(hill)}")


if __name__ == "__main__":
    main()
