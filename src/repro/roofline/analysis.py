"""Roofline terms from dry-run artifacts (TPU v5e targets).

    compute    = HLO_FLOPs/dev ÷ peak FLOP/s
    memory     = HLO_bytes/dev ÷ HBM bandwidth
    collective = collective_bytes/dev ÷ ICI link bandwidth

``cost_analysis()`` on a post-SPMD executable reports *per-device* flops and
bytes (verified empirically: reported = total/N). MODEL_FLOPS follows the
assignment: 6·N·D for dense training, 6·N_active·D for MoE; forward-only
shapes use the 2·N·D forward term; decode adds the attention cache-read
term (2·2·L·S·kv_dim per sequence) since that dominates real decode work.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_total: float
    useful_ratio: float           # MODEL_FLOPS/chips ÷ HLO_FLOPs/dev
    bottleneck: str
    step_s: float                 # max of the three (no-overlap bound)
    roofline_frac: float          # compute_s / step_s (how compute-bound)

    def as_dict(self):
        return dict(self.__dict__)


def analyze(*, flops_per_dev: float, bytes_per_dev: float,
            coll_bytes_per_dev: float, model_flops_total: float,
            n_devices: int) -> Roofline:
    c = flops_per_dev / PEAK_FLOPS
    m = bytes_per_dev / HBM_BW
    k = coll_bytes_per_dev / ICI_BW
    terms = {"compute": c, "memory": m, "collective": k}
    bn = max(terms, key=terms.get)
    step = max(c, m, k)
    useful = (model_flops_total / n_devices) / max(flops_per_dev, 1.0)
    return Roofline(compute_s=c, memory_s=m, collective_s=k,
                    model_flops_total=model_flops_total,
                    useful_ratio=useful, bottleneck=bn, step_s=step,
                    roofline_frac=c / step if step > 0 else 0.0)


# ---------------------------------------------------------------------------
# MODEL_FLOPS
# ---------------------------------------------------------------------------


def count_params(lm) -> Dict[str, float]:
    """Total and active (MoE-discounted) parameter counts."""
    import jax
    from repro.models.layers import ParamDef

    cfg = lm.cfg
    total = routed = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(
            lm.defs(), is_leaf=lambda x: isinstance(x, ParamDef)):
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        if "experts" in leaf.axes:
            routed += n
    active = total - routed
    if cfg.moe is not None and routed:
        active += routed * cfg.moe.top_k / cfg.moe.num_experts
    return {"total": float(total), "active": float(active)}


def model_flops(lm, shape, counts: Optional[Dict[str, float]] = None
                ) -> float:
    cfg = lm.cfg
    counts = counts or count_params(lm)
    n = counts["active"] if cfg.moe is not None else counts["total"]
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * B * S
    if shape.kind == "prefill":
        return 2.0 * n * B * S
    # decode: one token per sequence + attention reads over the cache
    flops = 2.0 * n * B
    has_attn = any(k in ("attn", "local", "mla", "xdec")
                   for k in cfg.layer_kinds)
    if has_attn:
        for k in cfg.layer_kinds:
            if k == "local":
                eff, per_head = min(cfg.local_window, S), cfg.head_dim
            elif k in ("attn", "xdec"):
                eff, per_head = S, cfg.head_dim
            elif k == "mla":
                eff = S
                per_head = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
            else:
                continue
            flops += 4.0 * B * eff * cfg.num_heads * per_head
    return flops
