"""Loop-aware post-SPMD HLO static analysis.

``compiled.cost_analysis()`` counts each ``while`` (scan) body exactly once,
which silently undercounts every layer-scanned model by ~num_layers×. This
module parses the post-optimisation HLO text into its computation graph,
extracts loop trip counts from the condition computations, and produces:

  * flops        — dot/convolution flops, loop bodies multiplied by trips
  * bytes        — HBM traffic estimate: operand+output bytes of top-level
                   instructions (fusion internals excluded, matching XLA's
                   fusion-aware accounting), loop-scaled
  * collectives  — per-op operand bytes and counts, loop-scaled

All shapes in post-SPMD HLO are per-shard, so results are per-device.
Validated against cost_analysis() on loop-free modules (see
tests/test_roofline.py).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_TOK = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# `%name = <result> opcode(...)` ; result may be a tuple
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\(")
_COMP_HDR = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")


def _shape_bits(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOK.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    line: str
    operands: List[str] = field(default_factory=list)
    is_root: bool = False


@dataclass
class Computation:
    name: str
    instrs: Dict[str, Instr] = field(default_factory=dict)
    order: List[Instr] = field(default_factory=list)


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for line in text.splitlines():
        if "= " not in line and "{" in line and "->" in line:
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, shape, opcode = m.groups()
        # operand names appear inside the first (...) after the opcode
        rest = line[m.end():]
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            depth += (ch == "(") - (ch == ")")
            if depth == 0:
                end = i
                break
        ops = _OPERANDS.findall(rest[:end])
        ins = Instr(name, shape, opcode, line, ops,
                    is_root="ROOT " in line)
        cur.instrs[name] = ins
        cur.order.append(ins)
    return comps, entry


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = 0
    m = _SHAPE_TOK.findall(ins.shape)
    n = 1
    for dt, dims in m[:1]:
        for d in dims.split(","):
            if d:
                n *= int(d)
        out_elems = n
    # contracting size from lhs operand shape and contracting dims
    cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    lhs = comp.instrs.get(ins.operands[0]) if ins.operands else None
    if not cd or lhs is None:
        return 2.0 * out_elems  # fallback
    lhs_dims = []
    mm = _SHAPE_TOK.findall(lhs.shape)
    if mm:
        lhs_dims = [int(d) for d in mm[0][1].split(",") if d]
    contract = 1
    for i in (int(x) for x in cd.group(1).split(",") if x):
        if i < len(lhs_dims):
            contract *= lhs_dims[i]
    return 2.0 * out_elems * contract


def _trip_count(cond: Computation) -> int:
    """Loop bound from the condition computation (jax scans: i < N)."""
    consts = [int(m.group(1)) for i in cond.order
              for m in [re.search(r"constant\((\d+)\)", i.line)] if m]
    for i in cond.order:
        if i.opcode == "compare":
            for opn in i.operands:
                src = cond.instrs.get(opn)
                if src is not None and src.opcode == "constant":
                    m = re.search(r"constant\((\d+)\)", src.line)
                    if m:
                        return max(int(m.group(1)), 1)
    return max(consts) if consts else 1


_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "copy", "after-all", "partition-id", "replica-id"}


def comp_or(comp: Computation, name: str) -> Optional[Instr]:
    return comp.instrs.get(name)


class Analyzer:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._memo: Dict[str, Tuple[float, float, dict]] = {}

    def _called(self, ins: Instr) -> List[str]:
        out = []
        for m in _CALLS.finditer(ins.line):
            if m.group(1) in self.comps:
                out.append(m.group(1))
        mb = _BRANCHES.search(ins.line)
        if mb:
            for nm in _OPERANDS.findall(mb.group(1)):
                if nm in self.comps:
                    out.append(nm)
        return out

    def analyze_comp(self, name: str) -> Tuple[float, float, dict]:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps[name]
        flops = 0.0
        bts = 0.0
        colls: Dict[str, dict] = defaultdict(lambda: {"bytes": 0.0,
                                                      "count": 0.0})
        self._memo[name] = (0.0, 0.0, {})  # cycle guard
        for ins in comp.order:
            op = ins.opcode
            if op == "dot":
                flops += _dot_flops(ins, comp)
            if op == "while":
                cond_m = _COND.search(ins.line)
                body_m = re.search(r"body=%?([\w.\-]+)", ins.line)
                trips = 1
                if cond_m and cond_m.group(1) in self.comps:
                    trips = _trip_count(self.comps[cond_m.group(1)])
                if body_m and body_m.group(1) in self.comps:
                    bf, bb, bc = self.analyze_comp(body_m.group(1))
                    flops += trips * bf
                    bts += trips * bb
                    for k, v in bc.items():
                        colls[k]["bytes"] += trips * v["bytes"]
                        colls[k]["count"] += trips * v["count"]
                continue
            if op in ("fusion", "call", "conditional", "custom-call",
                      "reduce", "sort", "scatter", "map", "reduce-window",
                      "select-and-scatter"):
                for sub in self._called(ins):
                    sf, sb, sc = self.analyze_comp(sub)
                    # reducers/comparators are elementwise-trivial; fusion
                    # and call bodies carry real dots.
                    if op in ("fusion", "call", "conditional"):
                        flops += sf
                        for k, v in sc.items():
                            colls[k]["bytes"] += v["bytes"]
                            colls[k]["count"] += v["count"]
                # bytes at the call site: operands + output
                bts += self._site_bytes(ins, comp)
            elif op in COLLECTIVE_OPS or any(
                    ins.opcode == c + "-start" for c in COLLECTIVE_OPS):
                base = op.replace("-start", "")
                b = self._operand_bytes(ins, comp)
                colls[base]["bytes"] += b
                colls[base]["count"] += 1
                bts += self._site_bytes(ins, comp)
            elif op not in _SKIP_BYTES and not op.endswith("-done"):
                bts += self._site_bytes(ins, comp)
        res = (flops, bts, {k: dict(v) for k, v in colls.items()})
        self._memo[name] = res
        return res

    def _operand_bytes(self, ins: Instr, comp: Computation) -> float:
        total = 0.0
        for opn in ins.operands:
            src = comp.instrs.get(opn)
            if src is not None:
                total += _shape_bits(src.shape)
        return total

    # Ops that touch only a slice of their big operand: charging the full
    # operand would overcount by the slice ratio (XLA uses utilization-based
    # accounting here). Approximate with bytes actually read/written.
    def _site_bytes(self, ins: Instr, comp: Computation) -> float:
        op = ins.opcode
        out = _shape_bits(ins.shape)
        if op in ("dynamic-slice", "slice", "gather"):
            return 2.0 * out
        if op in ("dynamic-update-slice", "scatter"):
            upd = 0.0
            for opn in ins.operands[1:]:
                src = comp.instrs.get(opn)
                if src is not None:
                    upd += _shape_bits(src.shape)
            return 2.0 * upd + out * 0.0
        if op == "fusion":
            # in-place fusion: DUS root writes only the update slice
            called = self._called(ins)
            fused = self.comps.get(called[0]) if called else None
            if fused is not None:
                roots = [fi for fi in fused.order if fi.is_root]
                root = roots[0] if roots else (
                    fused.order[-1] if fused.order else None)
                # follow unary wrappers (convert/bitcast/copy/reshape)
                seen = 0
                while (root is not None and seen < 8 and
                       root.opcode in ("convert", "bitcast", "copy",
                                       "reshape", "transpose")
                       and root.operands):
                    root = fused.instrs.get(root.operands[0])
                    seen += 1
                if root is not None and root.opcode == "dynamic-update-slice":
                    upd = fused.instrs.get(root.operands[1]) \
                        if len(root.operands) > 1 else None
                    out = 2.0 * _shape_bits(upd.shape) if upd else out * 0.1
            return out + self._fusion_operand_bytes(ins, comp)
        return out + self._operand_bytes(ins, comp)

    def _fusion_operand_bytes(self, ins: Instr, comp: Computation) -> float:
        """Operand bytes with slice-utilization awareness: a fusion param
        consumed only by (dynamic-)slice/gather ops contributes the slice
        bytes, not the full array."""
        called = self._called(ins)
        fused = self.comps.get(called[0]) if called else None
        if fused is None:
            return self._operand_bytes(ins, comp)
        # parameter index -> instruction name in fused computation
        params: Dict[int, str] = {}
        for fi in fused.order:
            if fi.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", fi.line)
                if m:
                    params[int(m.group(1))] = fi.name
        total = 0.0
        for idx, opn in enumerate(ins.operands):
            src = comp.instrs.get(opn)
            if src is None:
                continue
            full = _shape_bits(src.shape)
            pname = params.get(idx)
            if pname is None:
                total += full
                continue
            users = [fi for fi in fused.order if pname in fi.operands]
            if users and all(u.opcode in ("dynamic-slice", "slice", "gather")
                             and u.operands and u.operands[0] == pname
                             for u in users):
                total += sum(_shape_bits(u.shape) for u in users)
            elif users and all(u.opcode == "dynamic-update-slice"
                               for u in users):
                # in-place update fusion: charge the update size
                total += sum(
                    sum(_shape_bits(comp_or(fused, o).shape)
                        for o in u.operands[1:2] if comp_or(fused, o))
                    for u in users)
            else:
                total += full
        return total

    def totals(self) -> dict:
        assert self.entry, "no ENTRY computation found"
        f, b, c = self.analyze_comp(self.entry)
        return {"flops": f, "bytes": b,
                "collective_bytes": sum(v["bytes"] for v in c.values()),
                "by_op": c}


def analyze_text(text: str) -> dict:
    return Analyzer(text).totals()


def collective_bytes(text: str) -> Tuple[float, Dict[str, dict]]:
    t = analyze_text(text)
    return t["collective_bytes"], t["by_op"]


def op_histogram(hlo_text: str, top: int = 12) -> Dict[str, int]:
    """Opcode frequency (duplicate-op smell test for remat waste)."""
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR.match(line)
        if m:
            counts[m.group(3)] += 1
    return dict(sorted(counts.items(), key=lambda kv: -kv[1])[:top])
