"""Batched serving engine: continuous batching over a fixed-size slot pool.

Requests join free slots; every engine step decodes one token for all
active slots (single jitted ``decode_step``). Prefill runs per request
(right-sized, cache written into the slot). Slot state (KV caches +
lengths) is an explicit pytree → the whole engine is dumpable/migratable
with the same MigrOS machinery as training state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import LM


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [S] int32
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, lm: LM, params, *, slots: int = 4,
                 capacity: int = 512):
        self.lm = lm
        self.params = params
        self.slots = slots
        self.capacity = capacity
        self.cache = lm.materialize_cache(slots, capacity)
        self.active: List[Optional[Request]] = [None] * slots
        self._decode = jax.jit(lm.decode_step)
        self.steps = 0

    def _write_slot_cache(self, slot, req_cache, length):
        """Copy a single-sequence prefill cache into slot `slot`."""
        def cp(dst, src):
            if dst.ndim == 0 or dst.shape[0] != self.slots:
                # stacked-core leading dim: [n_periods, B, ...]
                return dst.at[:, slot].set(src[:, 0])
            return dst.at[slot].set(src[0])
        new_layers = jax.tree.map(cp, self.cache["layers"],
                                  req_cache["layers"])
        lengths = self.cache["lengths"].at[slot].set(length)
        self.cache = {"lengths": lengths, "layers": new_layers}

    def submit(self, req: Request) -> bool:
        for s in range(self.slots):
            if self.active[s] is None:
                prompt = jnp.asarray(req.prompt)[None]
                cache, logits = self.lm.prefill(self.params,
                                                {"tokens": prompt},
                                                self.capacity)
                self._write_slot_cache(s, cache, len(req.prompt))
                req.out.append(int(jnp.argmax(logits[0])))
                self.active[s] = req
                return True
        return False

    def step(self):
        """Decode one token for every active slot."""
        if not any(self.active):
            return
        toks = np.zeros((self.slots, 1), np.int32)
        for s, r in enumerate(self.active):
            if r is not None:
                toks[s, 0] = r.out[-1]
        self.cache, logits = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        nxt = np.asarray(jnp.argmax(logits, -1))
        for s, r in enumerate(self.active):
            if r is None:
                continue
            r.out.append(int(nxt[s]))
            if len(r.out) >= r.max_new:
                r.done = True
                self.active[s] = None
        self.steps += 1

    def run_until_done(self, max_steps: int = 1024):
        for _ in range(max_steps):
            if not any(self.active):
                break
            self.step()

    # -- migratability ------------------------------------------------------------
    def state_dict(self):
        return {"cache": self.cache, "steps": self.steps}

    def load_state_dict(self, d):
        self.cache = d["cache"]
        self.steps = d["steps"]
