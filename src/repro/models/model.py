"""Unified language model covering all assigned families.

Depth is organised as   head (unrolled) + core (period-scanned) + tail
(unrolled)   so heterogeneous layer patterns (gemma3 5:1 local:global,
recurrentgemma rec-rec-attn, deepseek first-k-dense) compile with O(period)
HLO. Parameters/caches for the core are stacked over periods and scanned.

Public API (all pure functions over explicit pytrees):
    LM(cfg).init(key) / .abstract() / .specs()
    .forward(params, batch)            -> (logits, aux)
    .loss(params, batch)               -> (loss, metrics)
    .prefill(params, batch, capacity)  -> (cache, last_logits)
    .decode_step(params, cache, tok)   -> (cache, logits)
    .init_cache(batch, capacity)       -> abstract cache tree
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import rglru as REC
from repro.models import ssm as SSM
from repro.models.layers import (ParamDef, abstract_params, apply_mlp,
                                 apply_norm, init_params, logical_specs,
                                 mlp_def, norm_def)
from repro.sharding.partition import constrain

# ---------------------------------------------------------------------------
# Layer definitions
# ---------------------------------------------------------------------------

_MIXER_DEF = {
    "attn": A.attn_def, "local": A.attn_def, "enc": A.attn_def,
    "mla": A.mla_def, "rec": REC.rec_def, "ssm": SSM.ssm_def,
}


def _mlp_width(cfg: ModelConfig, mlpk: str) -> int:
    if cfg.moe is not None and mlpk == "dense":
        return cfg.moe.d_ff_dense or cfg.d_ff
    return cfg.d_ff


def layer_def(cfg: ModelConfig, kind: Tuple[str, str]):
    mixer, mlpk = kind
    d: Dict[str, Any] = {"ln1": norm_def(cfg)}
    if mixer == "xdec":
        d["mixer"] = A.attn_def(cfg)
        d["ln_x"] = norm_def(cfg)
        d["cross"] = A.xattn_def(cfg)
    else:
        d["mixer"] = _MIXER_DEF[mixer](cfg)
    if mlpk == "moe":
        d["ln2"] = norm_def(cfg)
        d["mlp"] = MOE.moe_def(cfg)
    elif mlpk == "dense":
        d["ln2"] = norm_def(cfg)
        d["mlp"] = mlp_def(cfg, _mlp_width(cfg, mlpk))
    return d


def layer_apply(cfg, kind, p, x, ctx):
    """Full-sequence layer. Returns (x, aux)."""
    mixer, mlpk = kind
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg, p["ln1"], x)
    if mixer in ("attn", "local", "enc"):
        mx = A.attn_forward(cfg, p["mixer"], h, ctx["positions"],
                            kind=mixer, causal=(mixer != "enc"),
                            impl=ctx.get("impl"),
                            schedule=ctx.get("schedule", "full"))
    elif mixer == "mla":
        mx = A.mla_forward(cfg, p["mixer"], h, ctx["positions"],
                           impl=ctx.get("impl"),
                           schedule=ctx.get("schedule", "full"))
    elif mixer == "rec":
        mx = REC.rec_forward(cfg, p["mixer"], h, impl=ctx.get("impl"))
    elif mixer == "ssm":
        mx = SSM.ssm_forward(cfg, p["mixer"], h, impl=ctx.get("impl"))
    elif mixer == "xdec":
        mx = A.attn_forward(cfg, p["mixer"], h, ctx["positions"],
                            kind="attn", impl=ctx.get("impl"),
                            schedule=ctx.get("schedule", "full"))
    x = x + mx
    if mixer == "xdec":
        hx = apply_norm(cfg, p["ln_x"], x)
        k, v = A.xattn_kv(cfg, p["cross"], ctx["enc_out"])
        x = x + A.xattn_forward(cfg, p["cross"], hx, k, v,
                                impl=ctx.get("impl"))
    if mlpk == "moe":
        h = apply_norm(cfg, p["ln2"], x)
        mo, a = MOE.moe_apply(cfg, p["mlp"], h)
        x, aux = x + mo, aux + a
    elif mlpk == "dense":
        h = apply_norm(cfg, p["ln2"], x)
        x = x + apply_mlp(cfg.replace(d_ff=_mlp_width(cfg, mlpk)),
                          p["mlp"], h)
    x = constrain(x, ("batch", "seq", None))
    return x, aux


def layer_cache_def(cfg, kind, batch, capacity, dtype):
    mixer, _ = kind
    if mixer in ("attn", "local"):
        return A.attn_cache_def(cfg, mixer, batch, capacity, dtype)
    if mixer == "mla":
        return A.mla_cache_def(cfg, batch, capacity, dtype)
    if mixer == "rec":
        return REC.rec_cache_def(cfg, batch, dtype)
    if mixer == "ssm":
        return SSM.ssm_cache_def(cfg, batch, dtype)
    if mixer == "xdec":
        d = A.attn_cache_def(cfg, "attn", batch, capacity, dtype)
        Se = cfg.frontend_tokens if capacity is None else None
        return d  # cross K/V added by prefill (shape depends on enc len)
    raise ValueError(mixer)


def layer_cache_axes(cfg, kind):
    mixer, _ = kind
    if mixer in ("attn", "local"):
        return A.attn_cache_axes(cfg, mixer)
    if mixer == "mla":
        return A.mla_cache_axes(cfg)
    if mixer == "rec":
        return REC.rec_cache_axes(cfg)
    if mixer == "ssm":
        return SSM.ssm_cache_axes(cfg)
    if mixer == "xdec":
        d = A.attn_cache_axes(cfg, "attn")
        x = ("batch", "seq_data", "heads", None)
        return dict(d, xk=x, xv=x)
    raise ValueError(mixer)


def layer_decode(cfg, kind, p, x, cache, ctx):
    mixer, mlpk = kind
    h = apply_norm(cfg, p["ln1"], x)
    if mixer in ("attn", "local"):
        mx, cache = A.attn_decode(cfg, p["mixer"], h, cache,
                                  ctx["positions"], kind=mixer)
    elif mixer == "mla":
        mx, cache = A.mla_decode(cfg, p["mixer"], h, cache, ctx["positions"])
    elif mixer == "rec":
        mx, c2 = REC.rec_decode(cfg, p["mixer"], h,
                                {"conv": cache["conv"], "h": cache["h"]})
        cache = dict(cache, **c2)
    elif mixer == "ssm":
        mx, c2 = SSM.ssm_decode(cfg, p["mixer"], h,
                                {"conv": cache["conv"], "h": cache["h"]})
        cache = dict(cache, **c2)
    elif mixer == "xdec":
        sc = {k: cache[k] for k in ("k", "v")}
        mx, sc = A.attn_decode(cfg, p["mixer"], h, sc, ctx["positions"],
                               kind="attn")
        cache = dict(cache, **sc)
    x = x + mx
    if mixer == "xdec":
        hx = apply_norm(cfg, p["ln_x"], x)
        x = x + A.xattn_decode(cfg, p["cross"], hx,
                               {"xk": cache["xk"], "xv": cache["xv"]})
    if mlpk == "moe":
        h = apply_norm(cfg, p["ln2"], x)
        mo, _ = MOE.moe_apply(cfg, p["mlp"], h)
        x = x + mo
    elif mlpk == "dense":
        h = apply_norm(cfg, p["ln2"], x)
        x = x + apply_mlp(cfg.replace(d_ff=_mlp_width(cfg, mlpk)),
                          p["mlp"], h)
    return x, cache


def layer_prefill(cfg, kind, p, x, ctx, capacity):
    """Full-sequence apply that also emits this layer's decode cache."""
    mixer, _ = kind
    h = apply_norm(cfg, p["ln1"], x)
    if mixer in ("attn", "local"):
        cache = A.attn_prefill_cache(cfg, p["mixer"], h, ctx["positions"],
                                     kind=mixer, capacity=capacity)
    elif mixer == "mla":
        cache = A.mla_prefill_cache(cfg, p["mixer"], h, ctx["positions"],
                                    capacity=capacity)
    elif mixer == "rec":
        dt = x.dtype
        u = h @ p["mixer"]["wx"].astype(dt)
        uc = REC._conv_full(u, p["mixer"]["conv_w"].astype(dt))
        R, nh, bh = REC._dims(cfg)
        ga = REC._block_gate(uc, p["mixer"]["w_ga"], p["mixer"]["b_ga"],
                             nh, bh)
        gx = REC._block_gate(uc, p["mixer"]["w_gx"], p["mixer"]["b_gx"],
                             nh, bh)
        from repro.kernels import ops
        _, hT = ops.rglru(uc, p["mixer"]["a_log"], ga, gx, c=cfg.rglru_c,
                          impl=ctx.get("impl"))
        K = cfg.rnn_conv
        cache = {"conv": u[:, -(K - 1):], "h": hT}
    elif mixer == "ssm":
        dt_ = x.dtype
        z, xBC, dtp, (s, d_inner, H, gn) = SSM._split(
            cfg, h @ p["mixer"]["in_proj"].astype(dt_))
        xc = SSM._conv_full(xBC, p["mixer"]["conv_w"].astype(dt_))
        B_, S_ = x.shape[0], x.shape[1]
        xs = xc[..., :d_inner].reshape(B_, S_, H, s.head_dim)
        Bm = xc[..., d_inner:d_inner + gn].reshape(B_, S_, s.ngroups,
                                                   s.d_state)
        Cm = xc[..., d_inner + gn:].reshape(B_, S_, s.ngroups, s.d_state)
        dtv = jax.nn.softplus(dtp.astype(jnp.float32) +
                              p["mixer"]["dt_bias"].astype(jnp.float32))
        from repro.kernels import ops
        _, hT = ops.ssd(xs, dtv, p["mixer"]["A_log"], Bm, Cm,
                        D=p["mixer"]["D"], chunk=s.chunk_size,
                        impl=ctx.get("impl"))
        cache = {"conv": xBC[:, -(s.d_conv - 1):], "h": hT}
    elif mixer == "xdec":
        cache = A.attn_prefill_cache(cfg, p["mixer"], h, ctx["positions"],
                                     kind="attn", capacity=capacity)
        k, v = A.xattn_kv(cfg, p["cross"], ctx["enc_out"])
        cache = dict(cache, xk=k, xv=v)
    else:
        raise ValueError(mixer)
    x, aux = layer_apply(cfg, kind, p, x, ctx)
    return x, cache, aux


# ---------------------------------------------------------------------------
# Depth segmentation + stacks
# ---------------------------------------------------------------------------


class Stack:
    """head (unrolled) + core (period-scanned) + tail (unrolled)."""

    def __init__(self, cfg: ModelConfig, kinds: Sequence[Tuple[str, str]],
                 period: int, head_n: int = 0):
        self.cfg = cfg
        self.kinds = list(kinds)
        L = len(kinds)
        if not cfg.scan_layers:
            head_n, period = 0, max(L, 1)
        self.head = self.kinds[:head_n]
        rest = L - head_n
        self.n_periods = rest // period if cfg.scan_layers else 0
        if self.n_periods <= 1:   # scanning 1 period is pure overhead
            self.n_periods = 0
        core_n = self.n_periods * period
        self.period_kinds = self.kinds[head_n:head_n + period] \
            if self.n_periods else []
        for i in range(core_n):
            assert self.kinds[head_n + i] == self.period_kinds[i % period]
        self.tail = self.kinds[head_n + core_n:]

    # -- parameter trees ------------------------------------------------------
    def defs(self):
        cfg = self.cfg

        def stacked(d: ParamDef) -> ParamDef:
            return ParamDef((self.n_periods,) + d.shape,
                            ("layers",) + d.axes, d.init, d.scale)

        return {
            "head": [layer_def(cfg, k) for k in self.head],
            "core": [jax.tree.map(stacked, layer_def(cfg, k),
                                  is_leaf=lambda t: isinstance(t, ParamDef))
                     for k in self.period_kinds],
            "tail": [layer_def(cfg, k) for k in self.tail],
        }

    def cache_defs(self, batch, capacity, dtype):
        cfg = self.cfg

        def stacked(s: jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct((self.n_periods,) + s.shape, s.dtype)

        return {
            "head": [layer_cache_def(cfg, k, batch, capacity, dtype)
                     for k in self.head],
            "core": [jax.tree.map(stacked,
                                  layer_cache_def(cfg, k, batch, capacity,
                                                  dtype))
                     for k in self.period_kinds],
            "tail": [layer_cache_def(cfg, k, batch, capacity, dtype)
                     for k in self.tail],
        }

    def cache_axes(self):
        cfg = self.cfg
        is_tup = lambda t: isinstance(t, tuple)  # noqa: E731

        def stacked(axes):
            return ("layers",) + axes

        return {
            "head": [layer_cache_axes(cfg, k) for k in self.head],
            "core": [jax.tree.map(stacked, layer_cache_axes(cfg, k),
                                  is_leaf=is_tup)
                     for k in self.period_kinds],
            "tail": [layer_cache_axes(cfg, k) for k in self.tail],
        }

    # -- forward ---------------------------------------------------------------
    def _remat(self, fn):
        r = self.cfg.remat
        if r == "none":
            return fn
        if r == "dots_saveable":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.dots_saveable)
        return jax.checkpoint(fn)

    def apply(self, params, x, ctx):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        for k, p in zip(self.head, params["head"]):
            body = self._remat(
                lambda p, x, k=k: layer_apply(cfg, k, p, x, ctx))
            x, a = body(p, x)
            aux = aux + a
        if self.n_periods:
            def period_body(carry, pslices):
                x, aux = carry
                for i, k in enumerate(self.period_kinds):
                    x, a = layer_apply(cfg, k, pslices[i], x, ctx)
                    aux = aux + a
                return (x, aux), None
            (x, aux), _ = jax.lax.scan(self._remat(period_body), (x, aux),
                                       tuple(params["core"]))
        for k, p in zip(self.tail, params["tail"]):
            body = self._remat(
                lambda p, x, k=k: layer_apply(cfg, k, p, x, ctx))
            x, a = body(p, x)
            aux = aux + a
        return x, aux

    def decode(self, params, x, cache, ctx):
        cfg = self.cfg
        new_head = []
        for k, p, c in zip(self.head, params["head"], cache["head"]):
            x, c = layer_decode(cfg, k, p, x, c, ctx)
            new_head.append(c)
        new_core = cache["core"]
        if self.n_periods:
            def period_body(x, sl):
                ps, cs = sl
                ncs = []
                for i, k in enumerate(self.period_kinds):
                    x, nc = layer_decode(cfg, k, ps[i], x, cs[i], ctx)
                    ncs.append(nc)
                return x, tuple(ncs)
            x, new_core = jax.lax.scan(
                period_body, x, (tuple(params["core"]),
                                 tuple(cache["core"])))
            new_core = list(new_core)
        new_tail = []
        for k, p, c in zip(self.tail, params["tail"], cache["tail"]):
            x, c = layer_decode(cfg, k, p, x, c, ctx)
            new_tail.append(c)
        return x, {"head": new_head, "core": new_core, "tail": new_tail}

    def prefill(self, params, x, ctx, capacity):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        head_c, tail_c = [], []
        for k, p in zip(self.head, params["head"]):
            x, c, a = layer_prefill(cfg, k, p, x, ctx, capacity)
            head_c.append(c)
            aux = aux + a
        core_c = []
        if self.n_periods:
            def period_body(carry, ps):
                x, aux = carry
                cs = []
                for i, k in enumerate(self.period_kinds):
                    x, c, a = layer_prefill(cfg, k, ps[i], x, ctx, capacity)
                    cs.append(c)
                    aux = aux + a
                return (x, aux), tuple(cs)
            (x, aux), core_c = jax.lax.scan(period_body, (x, aux),
                                            tuple(params["core"]))
            core_c = list(core_c)
        for k, p in zip(self.tail, params["tail"]):
            x, c, a = layer_prefill(cfg, k, p, x, ctx, capacity)
            tail_c.append(c)
            aux = aux + a
        return x, {"head": head_c, "core": core_c, "tail": tail_c}, aux


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        mixers = cfg.layer_kinds
        kinds = [(mixers[i], "none" if (cfg.d_ff == 0 and cfg.moe is None)
                  else cfg.mlp_kind_at(i)) for i in range(cfg.num_layers)]
        head_n = cfg.moe.first_k_dense if cfg.moe is not None else 0
        if cfg.encoder_layers:
            kinds = [("xdec", k[1]) for k in kinds]
            self.encoder = Stack(cfg, [("enc", "dense")] * cfg.encoder_layers,
                                 period=1)
        else:
            self.encoder = None
        self.decoder = Stack(cfg, kinds, period=len(cfg.layer_pattern),
                             head_n=head_n)
        self.compute_dtype = jnp.dtype(cfg.dtype)
        self.param_dtype = jnp.dtype(cfg.param_dtype)

    # -- params -----------------------------------------------------------------
    def defs(self):
        cfg = self.cfg
        D, V = cfg.d_model, cfg.padded_vocab
        d: Dict[str, Any] = {
            "embed": ParamDef((V, D), ("vocab", "embed"), "fixed",
                              scale=0.02),
            "final_norm": norm_def(cfg),
            "decoder": self.decoder.defs(),
        }
        if not cfg.tie_embeddings:
            d["head"] = ParamDef((D, V), ("embed", "vocab"))
        if self.encoder is not None:
            d["encoder"] = self.encoder.defs()
            d["enc_norm"] = norm_def(cfg)
        return d

    def init(self, key):
        return init_params(self.defs(), key, self.param_dtype)

    def abstract(self):
        return abstract_params(self.defs(), self.param_dtype)

    def specs(self):
        return logical_specs(self.defs())

    # -- embedding / logits -------------------------------------------------------
    def _embed(self, params, tokens):
        cfg = self.cfg
        x = params["embed"][tokens].astype(self.compute_dtype)
        if cfg.scale_embeddings:
            x = x * jnp.asarray(cfg.d_model ** 0.5, self.compute_dtype)
        return x

    def _logits(self, params, x):
        cfg = self.cfg
        x = apply_norm(cfg, params["final_norm"], x)
        w = (params["embed"].T if cfg.tie_embeddings else params["head"])
        logits = x @ w.astype(self.compute_dtype)
        if cfg.logits_softcap > 0:
            logits = jnp.tanh(logits / cfg.logits_softcap) * \
                cfg.logits_softcap
        return constrain(logits, ("batch", "seq", "vocab"))

    def _inputs(self, params, batch):
        """Returns (x, positions, enc_out, loss_mask_offset)."""
        cfg = self.cfg
        if cfg.encoder_layers:
            enc = batch["frames"].astype(self.compute_dtype)
            B, Se, _ = enc.shape
            pos = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))
            enc, _ = self.encoder.apply(params["encoder"], enc,
                                        {"positions": pos})
            enc = apply_norm(cfg, params["enc_norm"], enc)
            tok = batch["tokens"]
            x = self._embed(params, tok)
            return x, None, enc, 0
        if cfg.frontend == "vision":
            ve = batch["vision_embeds"].astype(self.compute_dtype)
            x = jnp.concatenate([ve, self._embed(params, batch["tokens"])],
                                1)
            return x, None, None, ve.shape[1]
        return self._embed(params, batch["tokens"]), None, None, 0

    # -- full-sequence forward ------------------------------------------------------
    def forward(self, params, batch, *, impl=None, schedule="full"):
        x, _, enc_out, off = self._inputs(params, batch)
        B, S, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = constrain(x, ("batch", "seq", None))
        ctx = {"positions": pos, "enc_out": enc_out, "impl": impl,
               "schedule": schedule}
        x, aux = self.decoder.apply(params["decoder"], x, ctx)
        return self._logits(params, x), aux, off

    def loss(self, params, batch, *, impl=None, schedule="full"):
        cfg = self.cfg
        logits, aux, off = self.forward(params, batch, impl=impl,
                                        schedule=schedule)
        B, S, V = logits.shape
        # predict token t+1 from position t, text region only
        lg = logits[:, off:S - 1]
        labels = batch["tokens"][:, 1:]
        lf = lg.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
        ce = jnp.mean(lse - gold)
        return ce + aux, {"ce": ce, "aux": aux}

    # -- serving ---------------------------------------------------------------------
    def init_cache(self, batch, capacity):
        d = {
            "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32),
            "layers": self.decoder.cache_defs(batch, capacity,
                                              self.compute_dtype),
        }
        if self.cfg.encoder_layers:
            Kh, hd = self.cfg.num_kv_heads, self.cfg.head_dim
            Se = self.cfg.frontend_tokens
            x = jax.ShapeDtypeStruct((batch, Se, Kh, hd), self.compute_dtype)
            for part in ("head", "core", "tail"):
                lst = d["layers"][part]
                for i, c in enumerate(lst):
                    if part == "core":
                        n = self.decoder.n_periods
                        xs = jax.ShapeDtypeStruct((n,) + x.shape, x.dtype)
                        lst[i] = dict(c, xk=xs, xv=xs)
                    else:
                        lst[i] = dict(c, xk=x, xv=x)
        return d

    def cache_logical(self):
        """Logical-axis tree matching ``init_cache`` structure."""
        return {"lengths": ("batch",),
                "layers": self.decoder.cache_axes()}

    def materialize_cache(self, batch, capacity):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.init_cache(batch, capacity))

    def prefill(self, params, batch, capacity, *, impl=None):
        x, _, enc_out, off = self._inputs(params, batch)
        B, S, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        ctx = {"positions": pos, "enc_out": enc_out, "impl": impl,
               "schedule": "full"}
        x, layer_cache, _ = self.decoder.prefill(params["decoder"], x, ctx,
                                                 capacity)
        cache = {"lengths": jnp.full((B,), S, jnp.int32),
                 "layers": layer_cache}
        logits = self._logits(params, x[:, -1:])
        return cache, logits[:, 0]

    def decode_step(self, params, cache, tokens, *, impl=None):
        """tokens: [B,1] -> (cache, logits [B,V])."""
        x = self._embed(params, tokens)
        positions = cache["lengths"]
        ctx = {"positions": positions, "impl": impl}
        x, layers = self.decoder.decode(params["decoder"], x,
                                        cache["layers"], ctx)
        logits = self._logits(params, x)
        new = {"lengths": cache["lengths"] + 1, "layers": layers}
        return new, logits[:, 0]
