"""Mixture-of-Experts block (DeepSeek-style: shared + routed, top-k).

Two dispatch paths:

* **EP (shard_map)** — the production path whenever a mesh with a "model"
  axis is active: experts are sharded over "model"; each batch shard sorts
  its token copies by destination expert shard, packs fixed-capacity send
  buffers, exchanges them with ``jax.lax.all_to_all``, runs its local
  experts as one batched matmul, and returns results through the reverse
  all-to-all. Explicit collectives == the honest EP cost (GSPMD
  auto-sharding of a generic scatter would replicate the token buffer —
  measured 374 GB/device on deepseek-v2 — hence this path).
* **Local (sort-based)** — single-device fallback for smoke tests: the same
  sort→pack→batched-matmul→combine with no collectives.

Both drop overflow beyond ``capacity_factor`` (standard dropping semantics)
and add a Switch-style load-balance aux loss.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import ParamDef
from repro.sharding import partition as part


def moe_def(cfg: ModelConfig):
    m = cfg.moe
    D, E, F = cfg.d_model, m.num_experts, m.d_ff_expert
    d = {
        "router": ParamDef((D, E), ("embed", None), scale=0.1),
        "wi_gate": ParamDef((E, D, F), ("experts", "embed", "ffn")),
        "wi_up": ParamDef((E, D, F), ("experts", "embed", "ffn")),
        "wo": ParamDef((E, F, D), ("experts", "ffn", "embed")),
    }
    if m.num_shared > 0:
        Fs = m.num_shared * F
        d["shared"] = {
            "wi_gate": ParamDef((D, Fs), ("embed", "ffn")),
            "wi_up": ParamDef((D, Fs), ("embed", "ffn")),
            "wo": ParamDef((Fs, D), ("ffn", "embed")),
        }
    return d


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(tokens * m.top_k * m.capacity_factor / m.num_experts) + 1
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def moe_apply(cfg: ModelConfig, p, x):
    """x: [B,S,D] -> (y [B,S,D], aux_loss scalar f32). Picks EP shard_map
    when a mesh with an expert axis is active, else the local path."""
    mesh, rules = part._active()
    if mesh is not None:
        ax = rules.get("experts")
        if (ax in mesh.shape and cfg.moe.num_experts % mesh.shape[ax] == 0
                and mesh.shape[ax] > 1):
            return _moe_ep(cfg, p, x, mesh, rules, ax)
    return _moe_local(cfg, p, x)


def _shared(cfg, p, xf, dt):
    sp = p["shared"]
    h = jax.nn.silu(xf @ sp["wi_gate"].astype(dt)) * \
        (xf @ sp["wi_up"].astype(dt))
    return h @ sp["wo"].astype(dt)


def _moe_local(cfg: ModelConfig, p, x):
    m = cfg.moe
    B, S, D = x.shape
    dt = x.dtype
    T = B * S
    E, K = m.num_experts, m.top_k
    xf = x.reshape(T, D)

    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                  # [T,E]
    gates, eidx = jax.lax.top_k(probs, K)                    # [T,K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux loss (Switch-style) ------------------------------
    me = probs.mean(0)                                        # [E]
    ce = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(
        1.0 / (T * K))
    aux = m.router_aux_weight * E * jnp.sum(me * ce)

    # ---- sort-based dispatch ------------------------------------------------
    C = _capacity(T, cfg)
    e_flat = eidx.reshape(-1)                                 # [T*K]
    order = jnp.argsort(e_flat)                               # stable
    se = e_flat[order]
    tok = order // K
    counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
    starts = jnp.cumsum(counts) - counts                      # [E]
    pos_in_e = jnp.arange(T * K) - starts[se]
    keep = pos_in_e < C
    dest = jnp.where(keep, se * C + pos_in_e, E * C)          # drop slot

    buf = jnp.zeros((E * C + 1, D), dt).at[dest].set(xf[tok])
    eb = buf[:E * C].reshape(E, C, D)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, p["wi_gate"].astype(dt)))
    h = h * jnp.einsum("ecd,edf->ecf", eb, p["wi_up"].astype(dt))
    eo = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))    # [E,C,D]

    flat = jnp.concatenate([eo.reshape(E * C, D),
                            jnp.zeros((1, D), dt)], 0)
    ys = flat[dest]                                           # sorted order
    w = (gates.reshape(-1)[order] * keep).astype(dt)          # [T*K]
    y = jnp.zeros((T, D), dt).at[tok].add(ys * w[:, None])

    if m.num_shared > 0:
        y = y + _shared(cfg, p, xf, dt)
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# Expert-parallel dispatch (shard_map + all_to_all)
# ---------------------------------------------------------------------------


def _moe_ep(cfg: ModelConfig, p, x, mesh, rules, expert_axis):
    m = cfg.moe
    B, S, D = x.shape
    dt = x.dtype
    nsh = mesh.shape[expert_axis]
    E_loc = m.num_experts // nsh
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    in_specs = (P(batch_axes if B % max(
        part._axis_size(mesh, batch_axes), 1) == 0 else None, None, None),
        P(None, None),                       # router (replicated)
        P(expert_axis, None, None),          # wi_gate [E,D,F]
        P(expert_axis, None, None),          # wi_up
        P(expert_axis, None, None))          # wo
    out_specs = (in_specs[0], P())

    @partial(jax.shard_map, mesh=mesh, in_specs=in_specs,
             out_specs=out_specs, check_vma=False)
    def routed(x_loc, router, wi_g, wi_u, wo):
        b, s, _ = x_loc.shape
        T_all = b * s
        K = m.top_k
        # x is replicated across the expert axis: each shard owns a token
        # slice (SP over the expert axis) so routing work isn't duplicated.
        T = -(-T_all // nsh)                      # padded slice length
        idx = jax.lax.axis_index(expert_axis)
        xf_all = x_loc.reshape(T_all, D)
        if T * nsh != T_all:
            xf_all = jnp.pad(xf_all, ((0, T * nsh - T_all), (0, 0)))
        xf = jax.lax.dynamic_slice(xf_all, (idx * T, 0), (T, D))
        tok_valid = (idx * T + jnp.arange(T)) < T_all
        logits = xf.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, -1)
        gates, eidx = jax.lax.top_k(probs, K)                    # [T,K]
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        gates = gates * tok_valid[:, None]

        # aux loss from this shard's stats (averaged over shards by psum)
        me = probs.mean(0)
        ce = jnp.zeros((m.num_experts,), jnp.float32).at[
            eidx.reshape(-1)].add(1.0 / (T * K))
        aux = m.router_aux_weight * m.num_experts * jnp.sum(me * ce)
        for ax in batch_axes + (expert_axis,):
            aux = jax.lax.pmean(aux, ax)

        # ---- pack per destination expert-shard -----------------------------
        e_flat = eidx.reshape(-1)
        shard_of = e_flat // E_loc
        C_send = max(4, -(-int(T * K * m.capacity_factor / nsh) // 4) * 4)
        tok = jnp.arange(T * K) // K
        meta = {"local_e": (e_flat % E_loc).astype(jnp.int32),
                "gate": gates.reshape(-1).astype(jnp.float32)}
        order = jnp.argsort(shard_of)
        sg = shard_of[order]
        counts = jnp.zeros((nsh,), jnp.int32).at[shard_of].add(1)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(T * K) - starts[sg]
        keep = pos < C_send
        dest = jnp.where(keep, sg * C_send + pos, nsh * C_send)
        send_x = jnp.zeros((nsh * C_send + 1, D), dt).at[dest].set(
            xf[tok[order]])[:nsh * C_send]
        send_e = jnp.full((nsh * C_send + 1,), -1, jnp.int32).at[dest].set(
            meta["local_e"][order])[:nsh * C_send]

        # ---- all-to-all to expert shards ------------------------------------
        recv_x = jax.lax.all_to_all(
            send_x.reshape(nsh, C_send, D), expert_axis, 0, 0, tiled=False
        ).reshape(nsh * C_send, D)
        recv_e = jax.lax.all_to_all(
            send_e.reshape(nsh, C_send), expert_axis, 0, 0, tiled=False
        ).reshape(nsh * C_send)

        # ---- local expert compute (pack by local expert id) -----------------
        R = nsh * C_send
        C_loc = max(4, -(-R // E_loc // 4) * 4)
        rec_e = jnp.where(recv_e < 0, E_loc, recv_e)  # invalid -> drop row
        order2 = jnp.argsort(rec_e)
        se2 = rec_e[order2]
        counts2 = jnp.zeros((E_loc + 1,), jnp.int32).at[rec_e].add(1)
        starts2 = jnp.cumsum(counts2) - counts2
        pos2 = jnp.arange(R) - starts2[se2]
        keep2 = (pos2 < C_loc) & (se2 < E_loc)
        dest2 = jnp.where(keep2, se2 * C_loc + pos2, E_loc * C_loc)
        ebuf = jnp.zeros((E_loc * C_loc + 1, D), dt).at[dest2].set(
            recv_x[order2])
        eb = ebuf[:E_loc * C_loc].reshape(E_loc, C_loc, D)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, wi_g.astype(dt)))
        h = h * jnp.einsum("ecd,edf->ecf", eb, wi_u.astype(dt))
        eo = jnp.einsum("ecf,efd->ecd", h, wo.astype(dt))
        flat = jnp.concatenate(
            [eo.reshape(E_loc * C_loc, D), jnp.zeros((1, D), dt)], 0)
        back = jnp.zeros((R, D), dt).at[order2].set(flat[dest2])

        # ---- return through reverse all-to-all -------------------------------
        ret = jax.lax.all_to_all(
            back.reshape(nsh, C_send, D), expert_axis, 0, 0, tiled=False
        ).reshape(nsh * C_send, D)

        # ---- combine --------------------------------------------------------
        flat_ret = jnp.concatenate([ret, jnp.zeros((1, D), dt)], 0)
        ys = flat_ret[dest]                        # sorted order
        w = (meta["gate"][order] * keep).astype(dt)
        y = jnp.zeros((T, D), dt).at[tok[order]].add(ys * w[:, None])
        # gather token slices back from all expert-axis shards
        y_all = jax.lax.all_gather(y, expert_axis, axis=0,
                                   tiled=True)[:T_all]
        return y_all.reshape(b, s, D), aux

    y, aux = routed(x, p["router"], p["wi_gate"], p["wi_up"], p["wo"])
    if m.num_shared > 0:
        y = y + _shared(cfg, p, x.reshape(B * S, D), dt).reshape(B, S, D)
    return y, aux
