"""Attention mixers: GQA/MQA (global + sliding-window), MLA, cross-attention.

Full-sequence paths (train/prefill) route through ``repro.kernels.ops``;
decode paths update KV caches in place (functionally) and use the decode
kernels. All caches are explicit pytrees so they serialise through the
MigrOS dump/restore machinery like any other buffer.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.layers import ParamDef, apply_rope
from repro.sharding.partition import constrain

# ---------------------------------------------------------------------------
# Standard GQA/MQA attention
# ---------------------------------------------------------------------------


def attn_def(cfg: ModelConfig):
    D = cfg.d_model
    d = {
        "wq": ParamDef((D, cfg.q_dim), ("embed", "heads")),
        "wk": ParamDef((D, cfg.kv_dim), ("embed", "heads")),
        "wv": ParamDef((D, cfg.kv_dim), ("embed", "heads")),
        "wo": ParamDef((cfg.q_dim, D), ("heads", "embed")),
    }
    if cfg.qk_norm:
        d["q_norm"] = ParamDef((cfg.head_dim,), ("norm",), "zeros")
        d["k_norm"] = ParamDef((cfg.head_dim,), ("norm",), "zeros")
    return d


def _rms_head(x, scale, eps):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def _qkv(cfg: ModelConfig, p, x, positions, rope=True):
    dt = x.dtype
    B, S, _ = x.shape
    H, Kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(dt)).reshape(B, S, H, hd)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, Kh, hd)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, Kh, hd)
    if cfg.qk_norm:
        q = _rms_head(q, p["q_norm"], cfg.norm_eps)
        k = _rms_head(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_pct, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_pct, cfg.rope_theta)
    if cfg.qkv_constraint == "batch":
        # pin activations to batch-sharded/heads-on-TP: stops the
        # partitioner from sequence-sharding MQA K/V, which turns every
        # blocked-attention slice into a collective (§Perf, cell B)
        q = constrain(q, ("batch", None, "heads", None))
        k = constrain(k, ("batch", None, "heads", None))
        v = constrain(v, ("batch", None, "heads", None))
    return q, k, v


def attn_forward(cfg: ModelConfig, p, x, positions, *, kind="attn",
                 causal=True, impl=None, schedule="full"):
    """x: [B,S,D]; positions: [B,S] absolute. Returns [B,S,D]."""
    B, S, _ = x.shape
    q, k, v = _qkv(cfg, p, x, positions)
    window = cfg.local_window if kind == "local" else 0
    o = ops.attention(q, k, v, causal=causal, window=window,
                      softcap=cfg.attn_logit_softcap, impl=impl,
                      schedule=schedule)
    return o.reshape(B, S, cfg.q_dim) @ p["wo"].astype(x.dtype)


def attn_cache_def(cfg: ModelConfig, kind, batch, capacity, dtype):
    """ShapeDtypeStructs for one layer's cache (materialise via zeros_like)."""
    Kh, hd = cfg.num_kv_heads, cfg.head_dim
    if kind == "local":
        W = min(cfg.local_window, capacity)
        return {
            "k": jax.ShapeDtypeStruct((batch, W, Kh, hd), dtype),
            "v": jax.ShapeDtypeStruct((batch, W, Kh, hd), dtype),
            "slot_pos": jax.ShapeDtypeStruct((batch, W), jnp.int32),
        }
    return {
        "k": jax.ShapeDtypeStruct((batch, capacity, Kh, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, capacity, Kh, hd), dtype),
    }


def attn_cache_axes(cfg: ModelConfig, kind):
    """Logical axes for the cache entries (mirrors ``attn_cache_def``).

    KV-head-rich caches shard heads over TP; MQA caches shard the sequence
    dim over whatever mesh axes remain (see sharding.partition rules).
    """
    if cfg.num_kv_heads % 8 == 0:
        kv = ("batch", "seq_data", "heads", None)
    else:
        kv = ("batch", "seq_kv", None, None)
    d = {"k": kv, "v": kv}
    if kind == "local":
        d["slot_pos"] = (kv[0], kv[1])
    return d


def mla_cache_axes(cfg: ModelConfig):
    return {"ckv": ("batch", "seq_kv", None),
            "kpe": ("batch", "seq_kv", None)}


def _write_at(cache, new, idx):
    """cache: [B,S,...]; new: [B,1,...]; idx: [B] -> per-row dynamic update."""
    def row(c, n, i):
        start = (i,) + (0,) * (c.ndim - 1)
        return jax.lax.dynamic_update_slice(c, n, start)
    return jax.vmap(row)(cache, new, idx)


def attn_decode(cfg: ModelConfig, p, x, cache, positions, *, kind="attn"):
    """x: [B,1,D]; positions: [B] index of the new token. -> (y, cache)."""
    B = x.shape[0]
    q, k, v = _qkv(cfg, p, x, positions[:, None])
    lengths = positions + 1
    if kind == "local":
        W = cache["k"].shape[1]
        slot = positions % W
        cache = dict(cache,
                     k=_write_at(cache["k"], k, slot),
                     v=_write_at(cache["v"], v, slot),
                     slot_pos=_write_at(cache["slot_pos"],
                                        positions[:, None], slot))
        o = ops.attention_decode(q, cache["k"], cache["v"], lengths,
                                 window=cfg.local_window,
                                 softcap=cfg.attn_logit_softcap,
                                 slot_positions=cache["slot_pos"])
    else:
        cache = dict(cache,
                     k=_write_at(cache["k"], k, positions),
                     v=_write_at(cache["v"], v, positions))
        o = ops.attention_decode(q, cache["k"], cache["v"], lengths,
                                 softcap=cfg.attn_logit_softcap)
    y = o.reshape(B, 1, cfg.q_dim) @ p["wo"].astype(x.dtype)
    return y, cache


def attn_prefill_cache(cfg: ModelConfig, p, x, positions, *, kind, capacity):
    """Build a decode cache from a full prefix (used by ``LM.prefill``)."""
    B, S, _ = x.shape
    _, k, v = _qkv(cfg, p, x, positions)
    dtype = k.dtype
    Kh, hd = cfg.num_kv_heads, cfg.head_dim
    if kind == "local":
        W = min(cfg.local_window, capacity)
        # keep the last W positions, placed at their ring slots
        pos_last = positions[:, -1]                        # [B]
        take = jnp.arange(W)                               # ring slots
        # slot s holds absolute position p where p % W == s and p in (last-W, last]
        def gather_row(kr, vr, plast):
            pos_for_slot = plast - ((plast - take) % W)    # [W]
            ok = pos_for_slot >= jnp.maximum(0, plast - W + 1)
            src = jnp.clip(pos_for_slot - (positions[0, 0] * 0), 0, S - 1)
            kk = kr[src] * ok[:, None, None].astype(kr.dtype)
            vv = vr[src] * ok[:, None, None].astype(vr.dtype)
            return kk, vv, jnp.where(ok, pos_for_slot, -1)
        kk, vv, sp = jax.vmap(gather_row)(k, v, pos_last)
        return {"k": kk, "v": vv, "slot_pos": sp}
    padk = jnp.zeros((B, capacity - S, Kh, hd), dtype)
    return {"k": jnp.concatenate([k, padk], 1),
            "v": jnp.concatenate([v, padk], 1)}


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V2)
# ---------------------------------------------------------------------------


def mla_def(cfg: ModelConfig):
    m = cfg.mla
    D, H = cfg.d_model, cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    d = {}
    if m.q_lora_rank:
        d["wq_a"] = ParamDef((D, m.q_lora_rank), ("embed", "lora"))
        d["q_norm"] = ParamDef((m.q_lora_rank,), ("norm",), "zeros")
        d["wq_b"] = ParamDef((m.q_lora_rank, H * qk_head), ("lora", "heads"))
    else:
        d["wq"] = ParamDef((D, H * qk_head), ("embed", "heads"))
    d["wkv_a"] = ParamDef((D, m.kv_lora_rank + m.qk_rope_head_dim),
                          ("embed", "lora"))
    d["kv_norm"] = ParamDef((m.kv_lora_rank,), ("norm",), "zeros")
    d["wkv_b"] = ParamDef((m.kv_lora_rank,
                           H * (m.qk_nope_head_dim + m.v_head_dim)),
                          ("lora", "heads"))
    d["wo"] = ParamDef((H * m.v_head_dim, D), ("heads", "embed"))
    return d


def _rms_vec(x, scale, eps):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def _mla_q(cfg, p, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    dt = x.dtype
    if m.q_lora_rank:
        qa = _rms_vec(x @ p["wq_a"].astype(dt), p["q_norm"], cfg.norm_eps)
        q = (qa @ p["wq_b"].astype(dt)).reshape(B, S, H, qk_head)
    else:
        q = (x @ p["wq"].astype(dt)).reshape(B, S, H, qk_head)
    q_nope, q_pe = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_pe = apply_rope(q_pe, positions, 1.0, cfg.rope_theta)
    return q_nope, q_pe


def _mla_ckv(cfg, p, x, positions):
    m = cfg.mla
    dt = x.dtype
    ckv = x @ p["wkv_a"].astype(dt)
    c, kpe = ckv[..., :m.kv_lora_rank], ckv[..., m.kv_lora_rank:]
    c = _rms_vec(c, p["kv_norm"], cfg.norm_eps)
    kpe = apply_rope(kpe[..., None, :], positions, 1.0, cfg.rope_theta)[..., 0, :]
    return c, kpe


def mla_forward(cfg: ModelConfig, p, x, positions, *, impl=None,
                schedule="full"):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    dt = x.dtype
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    q_nope, q_pe = _mla_q(cfg, p, x, positions)
    c, kpe = _mla_ckv(cfg, p, x, positions)
    kv = (c @ p["wkv_b"].astype(dt)).reshape(
        B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = kv[..., :m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim:]
    q = jnp.concatenate([q_nope, q_pe], -1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kpe[:, :, None], q_pe.shape)], -1)
    # pad v to qk_head so the shared kernel applies; slice after
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_head - m.v_head_dim)))
    o = ops.attention(q, k, vp, causal=True, scale=qk_head ** -0.5,
                      impl=impl, schedule=schedule)[..., :m.v_head_dim]
    return o.reshape(B, S, H * m.v_head_dim) @ p["wo"].astype(dt)


def mla_cache_def(cfg: ModelConfig, batch, capacity, dtype):
    m = cfg.mla
    return {
        "ckv": jax.ShapeDtypeStruct((batch, capacity, m.kv_lora_rank), dtype),
        "kpe": jax.ShapeDtypeStruct((batch, capacity, m.qk_rope_head_dim),
                                    dtype),
    }


def mla_decode(cfg: ModelConfig, p, x, cache, positions):
    """Absorbed-matmul MLA decode over the compressed cache."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    dt = x.dtype
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    q_nope, q_pe = _mla_q(cfg, p, x, positions[:, None])    # [B,1,H,*]
    c, kpe = _mla_ckv(cfg, p, x, positions[:, None])
    cache = dict(cache,
                 ckv=_write_at(cache["ckv"], c, positions),
                 kpe=_write_at(cache["kpe"], kpe, positions))
    wkv_b = p["wkv_b"].astype(dt).reshape(
        m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim)
    w_k = wkv_b[..., :m.qk_nope_head_dim]                   # [L,H,nope]
    w_v = wkv_b[..., m.qk_nope_head_dim:]                   # [L,H,v]
    q_eff = jnp.einsum("bhn,lhn->bhl", q_nope[:, 0], w_k)   # [B,H,L]
    scale = qk_head ** -0.5
    lengths = positions + 1
    S = cache["ckv"].shape[1]
    sc = (jnp.einsum("bhl,bsl->bhs", q_eff.astype(jnp.float32),
                     cache["ckv"].astype(jnp.float32)) +
          jnp.einsum("bhr,bsr->bhs", q_pe[:, 0].astype(jnp.float32),
                     cache["kpe"].astype(jnp.float32))) * scale
    valid = jnp.arange(S)[None] < lengths[:, None]
    sc = jnp.where(valid[:, None], sc, -1e30)
    pr = jax.nn.softmax(sc, axis=-1)
    ctx = jnp.einsum("bhs,bsl->bhl", pr,
                     cache["ckv"].astype(jnp.float32))      # [B,H,L]
    o = jnp.einsum("bhl,lhv->bhv", ctx, w_v.astype(jnp.float32))
    y = o.reshape(B, 1, H * m.v_head_dim).astype(dt) @ p["wo"].astype(dt)
    return y, cache


def mla_prefill_cache(cfg: ModelConfig, p, x, positions, *, capacity):
    m = cfg.mla
    B, S, _ = x.shape
    c, kpe = _mla_ckv(cfg, p, x, positions)
    padc = jnp.zeros((B, capacity - S, m.kv_lora_rank), c.dtype)
    padp = jnp.zeros((B, capacity - S, m.qk_rope_head_dim), kpe.dtype)
    return {"ckv": jnp.concatenate([c, padc], 1),
            "kpe": jnp.concatenate([kpe, padp], 1)}


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder)
# ---------------------------------------------------------------------------


def xattn_def(cfg: ModelConfig):
    D = cfg.d_model
    return {
        "wq": ParamDef((D, cfg.q_dim), ("embed", "heads")),
        "wk": ParamDef((D, cfg.kv_dim), ("embed", "heads")),
        "wv": ParamDef((D, cfg.kv_dim), ("embed", "heads")),
        "wo": ParamDef((cfg.q_dim, D), ("heads", "embed")),
    }


def xattn_kv(cfg: ModelConfig, p, enc_out):
    B, Se, _ = enc_out.shape
    dt = enc_out.dtype
    k = (enc_out @ p["wk"].astype(dt)).reshape(B, Se, cfg.num_kv_heads,
                                               cfg.head_dim)
    v = (enc_out @ p["wv"].astype(dt)).reshape(B, Se, cfg.num_kv_heads,
                                               cfg.head_dim)
    return k, v


def xattn_forward(cfg: ModelConfig, p, x, k, v, *, impl=None):
    B, S, _ = x.shape
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, cfg.num_heads, cfg.head_dim)
    o = ops.attention(q, k, v, causal=False, impl=impl)
    return o.reshape(B, S, cfg.q_dim) @ p["wo"].astype(dt)


def xattn_decode(cfg: ModelConfig, p, x, cache):
    """Cross-attention decode over precomputed encoder K/V (no cache write)."""
    B = x.shape[0]
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, 1, cfg.num_heads, cfg.head_dim)
    Se = cache["xk"].shape[1]
    lengths = jnp.full((B,), Se, jnp.int32)
    o = ops.attention_decode(q, cache["xk"], cache["xv"], lengths)
    return o.reshape(B, 1, cfg.q_dim) @ p["wo"].astype(dt)
