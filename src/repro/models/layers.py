"""Shared building blocks: ParamDef trees, norms, rotary embeddings, MLPs.

Parameters are declared once as trees of :class:`ParamDef` (shape + logical
axes + initialiser). The same tree serves three purposes:

* ``init_params``      — materialise real arrays (smoke tests, examples),
* ``abstract_params``  — ``ShapeDtypeStruct`` stand-ins (dry-run, no alloc),
* ``logical_specs``    — logical-axis tree consumed by ``repro.sharding``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# ParamDef
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamDef:
    """A single parameter: shape, logical axis names, initialiser."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones
    scale: float = 1.0        # stddev multiplier for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_def(x):
    return isinstance(x, ParamDef)


def init_params(defs, key, dtype):
    """Materialise a ParamDef tree into real arrays (path-keyed folding)."""
    leaves = jax.tree_util.tree_leaves_with_path(defs, is_leaf=_is_def)

    out = {}
    for i, (path, d) in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        if d.init == "zeros":
            arr = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            arr = jnp.ones(d.shape, dtype)
        elif d.init == "fixed":   # std = scale, independent of fan-in
            arr = (jax.random.normal(k, d.shape, jnp.float32)
                   * d.scale).astype(dtype)
        else:
            fan_in = d.shape[0] if len(d.shape) > 1 else max(d.shape[-1], 1)
            std = d.scale / (fan_in ** 0.5)
            arr = (jax.random.normal(k, d.shape, jnp.float32) * std).astype(dtype)
        out[path] = arr
    return jax.tree_util.tree_map_with_path(
        lambda p, d: out[p], defs, is_leaf=_is_def)


def abstract_params(defs, dtype):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=_is_def)


def logical_specs(defs):
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=_is_def)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_def(cfg: ModelConfig, dim: Optional[int] = None):
    dim = dim or cfg.d_model
    if cfg.norm_kind == "layernorm":
        return {"scale": ParamDef((dim,), ("norm",), "ones"),
                "bias": ParamDef((dim,), ("norm",), "zeros")}
    return {"scale": ParamDef((dim,), ("norm",), "zeros")}  # gemma-style (1+w)


def apply_norm(cfg: ModelConfig, p, x, eps=None):
    eps = eps or cfg.norm_eps
    xf = x.astype(jnp.float32)
    if cfg.norm_kind == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = jnp.mean(jnp.square(xf), -1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        y = y * (1.0 + p["scale"].astype(jnp.float32))
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (with partial-rotary support)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, rope_pct: float, theta: float):
    rot = int(head_dim * rope_pct) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return rot, inv


def apply_rope(x, positions, rope_pct=1.0, theta=10_000.0):
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    rot, inv = rope_freqs(hd, rope_pct, theta)
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    ang = positions[..., None].astype(jnp.float32) * inv          # [..., S, rot/2]
    cos = jnp.cos(ang)[..., None, :]                              # [..., S, 1, rot/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if rot < hd else out


# ---------------------------------------------------------------------------
# MLPs (dense)
# ---------------------------------------------------------------------------


def mlp_def(cfg: ModelConfig, d_ff: Optional[int] = None):
    D, F = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {"wi_gate": ParamDef((D, F), ("embed", "ffn")),
                "wi_up": ParamDef((D, F), ("embed", "ffn")),
                "wo": ParamDef((F, D), ("ffn", "embed"))}
    return {"wi": ParamDef((D, F), ("embed", "ffn")),
            "wo": ParamDef((F, D), ("ffn", "embed"))}


def apply_mlp(cfg: ModelConfig, p, x):
    dt = x.dtype
    if cfg.mlp_kind in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_kind == "swiglu" else (
            lambda v: jax.nn.gelu(v, approximate=True))
        h = act(x @ p["wi_gate"].astype(dt)) * (x @ p["wi_up"].astype(dt))
        return h @ p["wo"].astype(dt)
    h = jax.nn.gelu(x @ p["wi"].astype(dt), approximate=True)
    return h @ p["wo"].astype(dt)


def softcap(x, cap: float):
    return jnp.tanh(x / cap) * cap if cap > 0 else x
