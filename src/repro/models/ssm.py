"""Mamba-2 block (SSD mixer): in_proj -> causal depthwise conv -> SSD ->
gated RMSNorm -> out_proj. Full-sequence path uses the chunked SSD kernel;
decode keeps (conv window, SSD state) as the cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.layers import ParamDef


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.ngroups * s.d_state
    return s, d_inner, H, conv_dim


def ssm_def(cfg: ModelConfig):
    s, d_inner, H, conv_dim = _dims(cfg)
    D = cfg.d_model
    d_in_proj = 2 * d_inner + 2 * s.ngroups * s.d_state + H
    return {
        "in_proj": ParamDef((D, d_in_proj), ("embed", "ffn")),
        "conv_w": ParamDef((s.d_conv, conv_dim), (None, "ffn"), scale=1.0),
        "dt_bias": ParamDef((H,), (None,), "zeros"),
        "A_log": ParamDef((H,), (None,), "zeros"),
        "D": ParamDef((H,), (None,), "ones"),
        "norm": ParamDef((d_inner,), ("norm",), "zeros"),
        "out_proj": ParamDef((d_inner, D), ("ffn", "embed")),
    }


def _split(cfg, zxbcdt):
    s, d_inner, H, conv_dim = _dims(cfg)
    gn = s.ngroups * s.d_state
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:d_inner + conv_dim]
    dt = zxbcdt[..., d_inner + conv_dim:]
    return z, xBC, dt, (s, d_inner, H, gn)


def _conv_full(xBC, w):
    """Causal depthwise conv over time. xBC: [B,S,C]; w: [K,C]."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(pad[:, j:j + xBC.shape[1]] * w[j][None, None] for j in range(K))
    return jax.nn.silu(y)


def _gated_norm(y, z, scale, eps):
    yf = (y * jax.nn.silu(z)).astype(jnp.float32)
    o = yf * jax.lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + eps)
    return (o * (1.0 + scale.astype(jnp.float32))).astype(y.dtype)


def ssm_forward(cfg: ModelConfig, p, x, *, impl=None):
    """x: [B,S,D] -> [B,S,D]."""
    B, S, D = x.shape
    dt_ = x.dtype
    z, xBC, dt, (s, d_inner, H, gn) = _split(cfg, x @ p["in_proj"].astype(dt_))
    xBC = _conv_full(xBC, p["conv_w"].astype(dt_))
    xs = xBC[..., :d_inner].reshape(B, S, H, s.head_dim)
    Bm = xBC[..., d_inner:d_inner + gn].reshape(B, S, s.ngroups, s.d_state)
    Cm = xBC[..., d_inner + gn:].reshape(B, S, s.ngroups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    y, _ = ops.ssd(xs, dt, p["A_log"], Bm, Cm, D=p["D"],
                   chunk=s.chunk_size, impl=impl)
    y = _gated_norm(y.reshape(B, S, d_inner), z, p["norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(dt_)


def ssm_cache_def(cfg: ModelConfig, batch, dtype):
    s, d_inner, H, conv_dim = _dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, s.d_conv - 1, conv_dim), dtype),
        "h": jax.ShapeDtypeStruct((batch, H, s.head_dim, s.d_state),
                                  jnp.float32),
    }


def ssm_cache_axes(cfg: ModelConfig):
    return {"conv": ("batch", None, "ffn"),
            "h": ("batch", "heads", None, None)}


def ssm_decode(cfg: ModelConfig, p, x, cache):
    """x: [B,1,D] -> (y [B,1,D], cache)."""
    B = x.shape[0]
    dt_ = x.dtype
    z, xBC, dt, (s, d_inner, H, gn) = _split(
        cfg, x[:, 0] @ p["in_proj"].astype(dt_))
    # conv over (stored window ++ new input)
    w = p["conv_w"].astype(dt_)
    hist = jnp.concatenate([cache["conv"], xBC[:, None]], 1)  # [B,K,C]
    conv = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, w))
    new_conv = hist[:, 1:]
    xs = conv[..., :d_inner].reshape(B, H, s.head_dim)
    Bm = conv[..., d_inner:d_inner + gn].reshape(B, s.ngroups, s.d_state)
    Cm = conv[..., d_inner + gn:].reshape(B, s.ngroups, s.d_state)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) +
                          p["dt_bias"].astype(jnp.float32))
    y, h = ops.ssd_decode(cache["h"], xs, dtv, p["A_log"], Bm, Cm, D=p["D"])
    y = _gated_norm(y.reshape(B, 1, d_inner), z[:, None], p["norm"],
                    cfg.norm_eps)
    return y @ p["out_proj"].astype(dt_), {"conv": new_conv, "h": h}
