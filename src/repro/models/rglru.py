"""Griffin recurrent block (RecurrentGemma): dual linear branches, causal
depthwise conv, RG-LRU recurrence with block-diagonal gates, GeLU gating.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.layers import ParamDef


def _dims(cfg: ModelConfig):
    R = cfg.rnn_width or cfg.d_model
    nh = cfg.rnn_heads
    assert R % nh == 0
    return R, nh, R // nh


def rec_def(cfg: ModelConfig):
    R, nh, bh = _dims(cfg)
    D = cfg.d_model
    return {
        "wx": ParamDef((D, R), ("embed", "ffn")),
        "wg": ParamDef((D, R), ("embed", "ffn")),
        "conv_w": ParamDef((cfg.rnn_conv, R), (None, "ffn")),
        "a_log": ParamDef((R,), (None,), "ones", scale=0.5),
        "w_ga": ParamDef((nh, bh, bh), ("heads", None, None)),
        "b_ga": ParamDef((R,), (None,), "zeros"),
        "w_gx": ParamDef((nh, bh, bh), ("heads", None, None)),
        "b_gx": ParamDef((R,), (None,), "zeros"),
        "wo": ParamDef((R, D), ("ffn", "embed")),
    }


def _conv_full(u, w):
    K = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(pad[:, j:j + u.shape[1]] * w[j][None, None] for j in range(K))


def _block_gate(u, w, b, nh, bh):
    """u: [..., R]; w: [nh, bh, bh] block-diagonal projection."""
    shp = u.shape
    ub = u.reshape(*shp[:-1], nh, bh)
    g = jnp.einsum("...hi,hij->...hj", ub, w.astype(u.dtype))
    return g.reshape(*shp) + b.astype(u.dtype)


def rec_forward(cfg: ModelConfig, p, x, *, impl=None):
    """x: [B,S,D] -> [B,S,D]."""
    R, nh, bh = _dims(cfg)
    dt = x.dtype
    u = x @ p["wx"].astype(dt)
    g = jax.nn.gelu(x @ p["wg"].astype(dt), approximate=True)
    u = _conv_full(u, p["conv_w"].astype(dt))
    ga = _block_gate(u, p["w_ga"], p["b_ga"], nh, bh)
    gx = _block_gate(u, p["w_gx"], p["b_gx"], nh, bh)
    y, _ = ops.rglru(u, p["a_log"], ga, gx, c=cfg.rglru_c, impl=impl)
    return (y * g) @ p["wo"].astype(dt)


def rec_cache_def(cfg: ModelConfig, batch, dtype):
    R, _, _ = _dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.rnn_conv - 1, R), dtype),
        "h": jax.ShapeDtypeStruct((batch, R), jnp.float32),
    }


def rec_cache_axes(cfg: ModelConfig):
    return {"conv": ("batch", None, "ffn"), "h": ("batch", "ffn")}


def rec_decode(cfg: ModelConfig, p, x, cache):
    """x: [B,1,D] -> (y, cache)."""
    R, nh, bh = _dims(cfg)
    dt = x.dtype
    u = (x[:, 0] @ p["wx"].astype(dt))
    g = jax.nn.gelu(x[:, 0] @ p["wg"].astype(dt), approximate=True)
    w = p["conv_w"].astype(dt)
    hist = jnp.concatenate([cache["conv"], u[:, None]], 1)
    conv = jnp.einsum("bkc,kc->bc", hist, w)
    ga = _block_gate(conv, p["w_ga"], p["b_ga"], nh, bh)
    gx = _block_gate(conv, p["w_gx"], p["b_gx"], nh, bh)
    y, h = ops.rglru_decode(cache["h"], conv, p["a_log"], ga, gx,
                            c=cfg.rglru_c)
    out = ((y * g) @ p["wo"].astype(dt))[:, None]
    return out, {"conv": hist[:, 1:], "h": h}
