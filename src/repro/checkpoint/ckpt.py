"""Sharded checkpointing: msgpack + zstd (zlib fallback), per-leaf
streaming, async writer.

Layout: <dir>/step_<N>/{manifest.msgpack, leaf_<i>.bin}. Each leaf is the
full (unsharded) array — on restore, ``jax.device_put`` with the target
shardings re-shards for whatever mesh the restart runs on (elastic
restart). The MigrOS container path reuses the same serialisation for user
state inside migration images.
"""
from __future__ import annotations

import os
import shutil
import threading
import zlib
from typing import Any, Dict, Optional

import jax
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:          # zlib fallback keeps checkpoints working
    zstandard = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(raw: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=1).compress(raw)
    return zlib.compress(raw, 1)


def _decompress(blob: bytes) -> bytes:
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError("checkpoint is zstd-compressed but the "
                               "zstandard module is not installed")
        return zstandard.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _pack_leaf(arr) -> bytes:
    a = np.asarray(arr)
    meta = {"dtype": str(a.dtype), "shape": list(a.shape)}
    raw = msgpack.packb(meta) + bytes(a.tobytes())
    return _compress(raw)


def _unpack_leaf(blob: bytes) -> np.ndarray:
    raw = _decompress(blob)
    up = msgpack.Unpacker()
    up.feed(raw)
    meta = up.unpack()
    off = up.tell()
    a = np.frombuffer(raw[off:], dtype=np.dtype(meta["dtype"]))
    return a.reshape(meta["shape"])


def save(path: str, tree: Any, *, step: int, extra: Optional[Dict] = None,
         async_write: bool = False):
    """Save a pytree of arrays. Returns the checkpoint directory."""
    d = os.path.join(path, f"step_{step:08d}")
    tmp = d + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    host = [np.asarray(x) for x in leaves]   # device->host before async

    def _write():
        for i, a in enumerate(host):
            with open(os.path.join(tmp, f"leaf_{i:05d}.bin"), "wb") as f:
                f.write(_pack_leaf(a))
        manifest = {"n_leaves": len(host), "step": step,
                    "treedef": str(treedef), "extra": extra or {}}
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        if os.path.isdir(d):                 # re-save after restart
            shutil.rmtree(d)
        os.replace(tmp, d)                   # atomic publish

    if async_write:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return d, t
    _write()
    return d


def restore(ckpt_dir: str, like: Any, *, shardings: Any = None) -> Any:
    """Restore into the structure of `like` (pytree of arrays/SDS)."""
    with open(os.path.join(ckpt_dir, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read(), raw=False)
    leaves, treedef = jax.tree.flatten(like)
    assert manifest["n_leaves"] == len(leaves), "structure mismatch"
    out = []
    for i in range(len(leaves)):
        with open(os.path.join(ckpt_dir, f"leaf_{i:05d}.bin"), "rb") as f:
            out.append(_unpack_leaf(f.read()))
    tree = jax.tree.unflatten(treedef, out)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


def latest(path: str) -> Optional[str]:
    if not os.path.isdir(path):
        return None
    steps = sorted(d for d in os.listdir(path) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    return os.path.join(path, steps[-1]) if steps else None


def manifest_extra(ckpt_dir: str) -> Dict:
    with open(os.path.join(ckpt_dir, "manifest.msgpack"), "rb") as f:
        return msgpack.unpackb(f.read(), raw=False)["extra"]
