import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell on
512 placeholder host devices, prove the distribution config is coherent,
and extract the §Roofline terms from the compiled artifact.

Usage:
  python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun
  python -m repro.launch.dryrun --arch X --shape Y --multi-pod \
         --schedule triangular --remat dots_saveable
"""
import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs.base import (ARCH_IDS, SHAPES, get_config,  # noqa: E402
                                shape_applicable)
from repro.launch import mesh as meshlib                       # noqa: E402
from repro.launch import specs as speclib                      # noqa: E402
from repro.roofline import analysis as roof                    # noqa: E402
from repro.roofline import hlo as hlolib                       # noqa: E402
from repro.sharding import partition as part                   # noqa: E402


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             schedule: str = "full", remat: str = "full", impl=None,
             rules=None, verbose: bool = True,
             cfg_overrides=None, capacity_factor=None) -> dict:
    shape = SHAPES[shape_name]
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    overrides = dict(cfg_overrides or {})
    overrides.setdefault("remat", remat)
    if capacity_factor is not None:
        import dataclasses as _dc
        cfg0 = get_config(arch)
        if cfg0.moe is not None:
            overrides["moe"] = _dc.replace(
                cfg0.moe, capacity_factor=capacity_factor)
    rec_extra = {"rules": "replicated_weights" if rules else "default",
                 "capacity_factor": capacity_factor,
                 "qkv_constraint": overrides.get("qkv_constraint")}
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "devices": n_dev, "schedule": schedule, "impl": impl,
           "remat": overrides["remat"], **rec_extra}
    t0 = time.time()
    with part.activate(mesh, rules):
        spec = speclib.input_specs(arch, shape, mesh, rules=rules,
                                   cfg_overrides=overrides)
        fn = speclib.build_fn(spec, schedule=schedule, impl=impl)
        jitted = jax.jit(fn, in_shardings=spec["in_shardings"],
                         out_shardings=spec["out_shardings"],
                         donate_argnums=spec["donate_argnums"])
        lowered = jitted.lower(*spec["args"])
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    }
    rec["memory"]["per_device_total"] = (
        rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"])
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jax wraps the dict in a list
        ca = ca[0] if ca else {}
    # cost_analysis counts while (scan) bodies once; the loop-aware HLO
    # analyzer is authoritative (see roofline/hlo.py). Raw kept for ref.
    rec["cost_analysis_raw"] = {
        "flops_per_dev": float(ca.get("flops", 0.0)),
        "bytes_per_dev": float(ca.get("bytes accessed", 0.0)),
    }
    txt = compiled.as_text()
    t2 = time.time()
    hl = hlolib.analyze_text(txt)
    rec["analyze_s"] = round(time.time() - t2, 2)
    flops = float(hl["flops"])
    bytes_acc = float(hl["bytes"])
    coll_total = float(hl["collective_bytes"])
    rec["cost"] = {"flops_per_dev": flops, "bytes_per_dev": bytes_acc}
    rec["collectives"] = {"bytes_per_dev": coll_total,
                          "by_op": hl["by_op"]}
    rec["op_histogram"] = hlolib.op_histogram(txt)

    counts = roof.count_params(spec["lm"])
    rec["params"] = counts
    mf = roof.model_flops(spec["lm"], shape, counts)
    rl = roof.analyze(flops_per_dev=flops, bytes_per_dev=bytes_acc,
                      coll_bytes_per_dev=coll_total, model_flops_total=mf,
                      n_devices=n_dev)
    rec["roofline"] = rl.as_dict()
    if verbose:
        print(f"[{arch} × {shape_name} × {rec['mesh']}] "
              f"compile={rec['compile_s']}s "
              f"mem/dev={rec['memory']['per_device_total']/1e9:.2f}GB "
              f"compute={rl.compute_s*1e3:.2f}ms "
              f"memory={rl.memory_s*1e3:.2f}ms "
              f"coll={rl.collective_s*1e3:.2f}ms "
              f"bottleneck={rl.bottleneck} useful={rl.useful_ratio:.2f}")
        print(compiled.memory_analysis())
        print({k: v for k, v in ca.items() if "{" not in k})
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--schedule", default="full",
                    choices=["full", "triangular"])
    ap.add_argument("--remat", default="full",
                    choices=["none", "full", "dots_saveable"])
    ap.add_argument("--impl", default=None,
                    choices=[None, "blocked", "flash", "ref"])
    ap.add_argument("--qkv-constraint", default=None,
                    choices=[None, "none", "batch"])
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--replicate-weights", action="store_true",
                    help="inference rule override: no FSDP on weights")
    ap.add_argument("--out", default=None, help="JSONL output path")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                if shape_applicable(a, s):
                    cells.append((a, s))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape or --all required")
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    out = open(args.out, "a") if args.out else None
    failures = 0
    for arch, shp in cells:
        for mp in meshes:
            try:
                overrides = {}
                if args.qkv_constraint:
                    overrides["qkv_constraint"] = args.qkv_constraint
                rules = ({"embed": None} if args.replicate_weights
                         else None)
                rec = run_cell(arch, shp, multi_pod=mp, impl=args.impl,
                               schedule=args.schedule, remat=args.remat,
                               rules=rules, cfg_overrides=overrides,
                               capacity_factor=args.capacity_factor)
            except Exception as e:  # noqa: BLE001
                failures += 1
                rec = {"arch": arch, "shape": shp, "multi_pod": mp,
                       "error": f"{type(e).__name__}: {e}"}
                print(f"[{arch} × {shp} × mp={mp}] FAILED: {e}",
                      file=sys.stderr)
                traceback.print_exc()
            if out:
                out.write(json.dumps(rec) + "\n")
                out.flush()
    if out:
        out.close()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
