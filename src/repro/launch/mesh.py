"""Production meshes. Functions (not module constants) so importing this
module never touches jax device state.

Single pod : (16, 16)    -> ("data", "model")      = 256 chips (v5e pod)
Multi-pod  : (2, 16, 16) -> ("pod", "data", "model") = 512 chips
"""
from __future__ import annotations

import jax


def _mesh_kwargs(axes):
    # jax.sharding.AxisType landed in newer jax; older versions only take
    # (shape, axes) — omit the kwarg there
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * len(axes)}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, small-scale runs, elastic re-meshing)."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_mesh_kwargs(axes))


def make_host_mesh():
    """Whatever devices exist, as a 1-D 'data' mesh (CPU smoke runs)."""
    n = len(jax.devices())
    return make_mesh((n,), ("data",))
