"""Input/state specs per (arch × shape): ShapeDtypeStruct stand-ins and
NamedShardings — shared by the dry-run, trainer, and server. No allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig, get_config
from repro.models.model import LM
from repro.optim import adamw
from repro.sharding import partition as part


def batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                compute_dtype=jnp.bfloat16) -> Tuple[Dict, Dict]:
    """(ShapeDtypeStructs, logical-axes) for one training/prefill batch."""
    B, S = shape.global_batch, shape.seq_len
    sds, axes = {}, {}
    if cfg.family == "vlm":
        Sv = cfg.frontend_tokens
        sds["vision_embeds"] = jax.ShapeDtypeStruct((B, Sv, cfg.d_model),
                                                    compute_dtype)
        axes["vision_embeds"] = ("batch", "seq", None)
        sds["tokens"] = jax.ShapeDtypeStruct((B, S - Sv), jnp.int32)
        axes["tokens"] = ("batch", "seq")
    elif cfg.family == "encdec":
        sds["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                             compute_dtype)
        axes["frames"] = ("batch", "seq", None)
        sds["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        axes["tokens"] = ("batch", "seq")
    else:
        sds["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        axes["tokens"] = ("batch", "seq")
    return sds, axes


def shardings_of(tree_sds, tree_axes, mesh, rules=None):
    return jax.tree.map(
        lambda s, a: NamedSharding(mesh, part.resolve(a, s.shape, mesh,
                                                      rules)),
        tree_sds, tree_axes,
        is_leaf=lambda t: isinstance(t, jax.ShapeDtypeStruct))


def input_specs(arch_or_cfg, shape: ShapeConfig, mesh, *, rules=None,
                cfg_overrides=None) -> Dict[str, Any]:
    """Everything needed to lower one cell.

    Returns dict with: cfg, lm, kind, args (ShapeDtypeStructs tuple),
    in_shardings, out_shardings, donate_argnums, fn-builder inputs.
    """
    cfg = (get_config(arch_or_cfg) if isinstance(arch_or_cfg, str)
           else arch_or_cfg)
    if shape.kind != "train":
        # decode/prefill shapes size the enc-dec frontend to the shape
        if cfg.family == "encdec":
            cfg = cfg.replace(frontend_tokens=shape.seq_len)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    lm = LM(cfg)
    cdt = jnp.dtype(cfg.dtype)
    p_abs = lm.abstract()
    p_axes = lm.specs()
    p_sh = shardings_of(p_abs, p_axes, mesh, rules)

    if shape.kind == "train":
        sds, axes = batch_specs(cfg, shape, cdt)
        st_abs = adamw.abstract_state(p_abs)
        st_axes = adamw.state_logical(p_axes)
        st_sh = shardings_of(st_abs, st_axes, mesh, rules)
        b_sh = shardings_of(sds, axes, mesh, rules)
        return dict(cfg=cfg, lm=lm, kind="train",
                    args=(st_abs, sds), in_shardings=(st_sh, b_sh),
                    out_shardings=(st_sh, None), donate_argnums=(0,))

    if shape.kind == "prefill":
        sds, axes = batch_specs(cfg, shape, cdt)
        b_sh = shardings_of(sds, axes, mesh, rules)
        return dict(cfg=cfg, lm=lm, kind="prefill", capacity=shape.seq_len,
                    args=(p_abs, sds), in_shardings=(p_sh, b_sh),
                    out_shardings=None, donate_argnums=())

    # decode: one new token with a cache of capacity seq_len
    B = shape.global_batch
    cache_abs = lm.init_cache(B, shape.seq_len)
    cache_axes = lm.cache_logical()
    c_sh = shardings_of(cache_abs, cache_axes, mesh, rules)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, part.resolve(("batch", None), (B, 1),
                                              mesh, rules))
    return dict(cfg=cfg, lm=lm, kind="decode",
                args=(p_abs, cache_abs, tok),
                in_shardings=(p_sh, c_sh, tok_sh),
                out_shardings=(c_sh, None), donate_argnums=(1,))


def build_fn(spec, *, opt_cfg=None, impl=None, schedule="full"):
    lm = spec["lm"]
    if spec["kind"] == "train":
        opt_cfg = opt_cfg or adamw.OptConfig()
        return adamw.make_train_step(lm, opt_cfg, impl=impl,
                                     schedule_kind=schedule)
    if spec["kind"] == "prefill":
        cap = spec["capacity"]

        def prefill(params, batch):
            return lm.prefill(params, batch, cap, impl=impl)
        return prefill

    def decode(params, cache, tokens):
        return lm.decode_step(params, cache, tokens, impl=impl)
    return decode
