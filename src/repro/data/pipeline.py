"""Deterministic, checkpointable synthetic token pipeline.

Sequences are generated from a counter-based PRNG (position-independent):
batch ``i`` of a given config is identical no matter which host asks, when,
or after how many restarts — the property checkpoint-restart correctness
tests rely on. The cursor is just an integer, so it rides along in the
MigrOS container dump like any other piece of user state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # markov-ish structure so the LM has something learnable
    structure: float = 0.7


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.step = 0

    def _batch_at(self, step: int) -> np.ndarray:
        c = self.cfg
        rng = np.random.RandomState((c.seed * 1_000_003 + step) % 2**31)
        B, S, V = c.global_batch, c.seq_len, c.vocab_size
        base = rng.randint(0, V, (B, S))
        # structured component: next token = f(prev) with prob `structure`
        nxt = (base[:, :-1] * 31 + 7) % V
        mask = rng.rand(B, S - 1) < c.structure
        out = base.copy()
        out[:, 1:][mask] = nxt[mask]
        return out.astype(np.int32)

    def next(self) -> Dict[str, np.ndarray]:
        b = {"tokens": self._batch_at(self.step)}
        self.step += 1
        return b

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()

    # -- checkpointing ---------------------------------------------------------
    def state_dict(self) -> Dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, d: Dict):
        assert d["seed"] == self.cfg.seed, "pipeline seed mismatch"
        self.step = int(d["step"])


def frontend_stub_batch(cfg, shape, rng_seed: int = 0):
    """Precomputed frame/patch embeddings for audio/vlm archs (the modality
    frontend is a stub per the assignment spec)."""
    rng = np.random.RandomState(rng_seed)
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        Sv = cfg.frontend_tokens
        return {
            "vision_embeds": rng.randn(B, Sv, cfg.d_model).astype(
                np.float32) * 0.02,
            "tokens": rng.randint(0, cfg.vocab_size,
                                  (B, S - Sv)).astype(np.int32),
        }
    if cfg.family == "encdec":
        return {
            "frames": rng.randn(B, S, cfg.d_model).astype(np.float32)
            * 0.02,
            "tokens": rng.randint(0, cfg.vocab_size, (B, S)).astype(
                np.int32),
        }
    return {"tokens": rng.randint(0, cfg.vocab_size, (B, S)).astype(
        np.int32)}
