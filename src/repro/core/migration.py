"""Live-migration controller: the CRIU + container-runtime flow (paper §4).

stop QPs → dump (verbs + MR memory + user state) → transfer → restore at
destination (CREATE / key restore / state walk / REFILL) → resume messages
re-address partners → communication continues via normal go-back-N.

The checkpoint image is real traffic: it streams over the device service
channel (kernel QPs) as ``MIG_STATE`` messages, crossing the same
bandwidth-limited links as application SEND/WRITE traffic — so transfer
and downtime figures are read off the fabric sim clock
(``fabric.now * STEP_S``), never estimated from ``len(image)/bw``
arithmetic or wall-clock timers.

Two runtime modes reproduce the paper's comparison:
  * "crx"    — image streamed to the destination during checkpoint, held in
               RAM (the paper's CR-X runtime; fast path).
  * "docker" — checkpoint staged to 'local storage' first, then moved,
               then restored (no overlap; reproduces Fig. 12's gap). The
               image crosses the wire twice: once into storage, once out.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import msgpack

from repro.core import dump as dumplib
from repro.core import pagecodec
from repro.core.packets import Op
from repro.core.service import ServiceError, StreamPreempted
from repro.core.states import QPState
from repro.core.transport import STEP_S
from repro.obs.trace import record_phase


@dataclass
class MigrationReport:
    checkpoint_s: float = 0.0
    transfer_s: float = 0.0
    restore_s: float = 0.0
    image_bytes: int = 0
    simulated_transfer_s: float = 0.0
    ok: bool = True
    # -- live-migration engine extensions ----------------------------- [MIGR]
    strategy: str = "stop_and_copy"
    downtime_s: float = 0.0            # sim time QPs were actually stopped
    simulated_downtime_s: float = 0.0  # analytic: stopped-bytes / link bw
    live_s: float = 0.0                # pre-copy sim time spent still running
    rounds: List[Dict] = field(default_factory=list)   # per pre-copy round
    pages_total: int = 0
    pages_sent: int = 0                # includes re-sent dirty pages
    #   "checkpoint" | "transfer" | "paused" | "aborted" | "admission"
    stage_failed: Optional[str] = None
    retries: int = 0
    rolled_back: bool = False
    # retry token: strategy-private state (captured image / staged pages)
    # the orchestrator hands back to resume a failed transfer. A *paused*
    # migration parks a serialisable MigrationAttempt here instead.
    attempt: Optional[object] = field(default=None, repr=False,
                                      compare=False)
    # post-copy demand pager, still serving faults after migrate() returns
    pager: Optional[object] = field(default=None, repr=False, compare=False)
    # -- preemption accounting ----------------------------------------- [PRE]
    # sim time spent parked between a pause yield and its resume/abort.
    # Deliberately OUTSIDE transfer_s/live_s/downtime_s: those fields sum
    # only spans the migration was actively working, so an operator pause
    # never inflates the wire-attribution figures.
    paused_s: float = 0.0
    preemptions: int = 0               # pause yields taken mid-flight
    container: Optional[str] = None    # set by the orchestrator

    @property
    def total_s(self):
        return self.checkpoint_s + self.transfer_s + self.restore_s


@dataclass
class MigrationAttempt:
    """Serialisable checkpoint of an *in-flight* migration, taken at a
    round/page boundary when the orchestrator pauses it (the preemption
    counterpart of the per-QP dump: strategy, rounds completed, pages
    sent, service-channel stream cursor, and the service QP's learned
    congestion/RTO state all ride the token). ``resume`` re-enters the
    strategy from it — on the original destination or, if that node was
    drained meanwhile, a new one. ``refs`` carries live in-process
    objects (the post-copy pager) and is excluded from the wire form;
    ``from_bytes`` rebuilds them from fabric state."""
    container: str = ""
    strategy: str = ""
    runtime: str = "crx"
    src_gid: int = 0
    dest_gid: int = 0
    phase: str = "live"               # "live" | "stopped"
    reason: str = "pause"             # "pause" | "auto" | "detach"
    rounds_done: int = 0
    pages_sent: int = 0
    stream: Optional[int] = None      # service-channel stream cursor
    pending: List = field(default_factory=list)  # [(mrn, pg)] round rest
    round_pages: int = 0              # progress inside the split round
    round_bytes: int = 0
    round_steps: int = 0
    round_wire: int = 0               # encoded bytes of the split round
    image: Optional[bytes] = None     # stopped-phase checkpoint image
    service_qp: Dict = field(default_factory=dict)  # RTO/RTT + DCQCN
    paused_at: int = 0                # fabric.now at the yield
    # page-codec sender state (acked digest cache + delta-base snapshots,
    # ``pagecodec.PageCodec.dump``). Valid only toward the destination it
    # was built against: a resume onto a NEW destination discards it.
    codec: Dict = field(default_factory=dict)
    refs: Dict = field(default_factory=dict, repr=False, compare=False)

    _WIRE = ("container", "strategy", "runtime", "src_gid", "dest_gid",
             "phase", "reason", "rounds_done", "pages_sent", "stream",
             "pending", "round_pages", "round_bytes", "round_steps",
             "image", "service_qp", "paused_at")
    # conditional keys: absent from the wire form when falsy, so tokens
    # from codec-less runs stay byte-identical to the pre-codec format
    _WIRE_OPT = ("round_wire", "codec")

    def to_bytes(self) -> bytes:
        d = {k: getattr(self, k) for k in self._WIRE}
        for k in self._WIRE_OPT:
            v = getattr(self, k)
            if v:
                d[k] = v
        return msgpack.packb(d, use_bin_type=True)

    @classmethod
    def from_bytes(cls, blob: bytes) -> "MigrationAttempt":
        d = msgpack.unpackb(blob, raw=False, strict_map_key=False)
        d["pending"] = [tuple(p) for p in d.get("pending", [])]
        return cls(**d)


class MigrationError(RuntimeError):
    pass


class MigrationController:
    """Migrates a container between nodes over the fabric."""

    def __init__(self, fabric, *, link_bandwidth_Bps: Optional[float] = None,
                 stop_pump_steps: int = 50):
        self.fabric = fabric
        if link_bandwidth_Bps is not None:
            # single source of truth: the fabric's link model
            fabric.set_bandwidth(link_bandwidth_Bps)
        self.stop_pump_steps = stop_pump_steps
        # control-plane registry: cluster-unique QPN -> current gid.
        # Lets simultaneous migrations re-address each other.     # [MIGR]
        self.relocated = {}
        # data-plane cleanup tokens, registered by strategies as soon as
        # they park state in a service channel (staged pre-copy pages at
        # the destination, the post-copy frozen store at the source).
        # A failed attempt — including one that died by exception before
        # it could build a retry token — releases them via run_cleanups;
        # a successful one discards them via clear_cleanups. Strategies
        # also drain stale tokens at run() entry, so a later successful
        # attempt never silently discards a dead attempt's pending
        # cleanup.
        self._cleanups: Dict[object, List] = {}

    def register_cleanup(self, container, fn):
        self._cleanups.setdefault(container, []).append(fn)

    def clear_cleanups(self, container):
        self._cleanups.pop(container, None)

    def run_cleanups(self, container):
        for fn in self._cleanups.pop(container, []):
            fn()

    @property
    def bw(self) -> float:
        return self.fabric.bandwidth

    # -- image ------------------------------------------------------------------
    def _checkpoint(self, container) -> bytes:
        ctx = container.ctx
        verbs_image = dumplib.dump_context(ctx, stop=True)       # [MIGR]
        memory = {m.mrn: bytes(m.buf) for m in ctx.mrs}
        user = container.checkpoint_user()
        return msgpack.packb({"verbs": verbs_image, "memory": memory,
                              "user": user}, use_bin_type=True)

    def _restore(self, container, image_bytes: bytes, dest_node):
        image = msgpack.unpackb(image_bytes, raw=False,
                                strict_map_key=False)
        # tenant tag BEFORE restore builds QPs: QoS attribution follows
        # the container to its new node                           # [QOS]
        ctx = dest_node.device.open_context(tenant=container.name)
        session = dumplib.restore_context(ctx, image["verbs"],
                                          relocated=self.relocated)  # [MIGR]
        for qp in ctx.qps:                                       # [MIGR]
            self.relocated[qp.qpn] = dest_node.device.gid        # [MIGR]
        for mrn, buf in image["memory"].items():
            session.mr_by_n[int(mrn)].buf[:] = buf
        container.adopt(dest_node, ctx, session)
        container.restore_user(image["user"])

    # -- data plane -------------------------------------------------------------
    def stream_image(self, src_dev, dest_gid: int, image: bytes, *,
                     runtime: str = "crx",
                     preempt: Optional[Callable] = None) -> bytes:
        """Move a checkpoint image over the service channel and return the
        bytes that actually arrived at the destination. The call pumps the
        bare fabric until delivery, so the elapsed sim steps ARE the
        transfer time, contention and retransmissions included; QPs of
        every node keep draining, but applications are not stepped (the
        stop window freezes app progress, as in the seed flow — external
        drivers see only the fabric advance). The docker runtime crosses
        the wire twice (into 'storage', then out)."""
        svc = src_dev.service
        dest_dev = self.fabric.device(dest_gid)
        if dest_dev is None:
            # the destination left the fabric between yield points (e.g.
            # drained during a pre-copy settle window): suspend, exactly
            # as if the detach had landed mid-stream
            raise StreamPreempted("detach", -1)
        dest_svc = dest_dev.service
        codec = self.fabric.codec
        encoded = codec.enabled and codec.compress_image
        wire = pagecodec.encode_image(image, codec) if encoded \
            else bytes(image)
        for _hop in range(2 if runtime == "docker" else 1):
            xid = svc.transfer(dest_gid, Op.MIG_STATE, {"kind": "image"},
                               wire, preempt=preempt)
            wire = dest_svc.take_image(xid)
        delivered = pagecodec.decode_image(wire) if encoded else wire
        if delivered != image:
            raise MigrationError("image corrupted in transit")
        return delivered

    # -- flow -------------------------------------------------------------------
    def migrate(self, container, dest_node, *, runtime: str = "crx",
                fail_at: Optional[str] = None,
                preempt: Optional[Callable] = None) -> MigrationReport:
        src_node = container.node
        if dest_node is src_node:
            # explicit no-op: nothing was dumped, moved, or restored
            return MigrationReport(strategy="noop")
        rep = MigrationReport()

        fab = self.fabric
        t0 = fab.now
        rep.pages_total = sum(m.n_pages for m in container.ctx.mrs)
        src_dev = container.ctx.device
        image = self._checkpoint(container)
        # QPs are now STOPPED but still attached: while the image is being
        # written/moved, partner packets hit them and draw NAK_STOPPED
        # (this is where peers transition to PAUSED).             # [MIGR]
        fab.pump(self.stop_pump_steps)
        if runtime == "docker":
            # stage to local storage: extra serialise+copy round trip
            staged = zlib.compress(image, level=1)
            image = zlib.decompress(staged)
        rep.image_bytes = len(image)
        rep.checkpoint_s = (fab.now - t0) * STEP_S
        record_phase(fab, "checkpoint", t0, node=src_dev.gid,
                     image_bytes=len(image))
        if fail_at == "checkpoint":
            rep.ok = False
            rep.stage_failed = "checkpoint"                      # [MIGR]
            return rep

        t1 = fab.now
        # analytic figure kept for comparisons; the *measured* cost is the
        # sim-clock delta around the stream below
        rep.simulated_transfer_s = len(image) / self.bw
        if runtime == "docker":
            rep.simulated_transfer_s *= 2  # via storage, no streaming
        if fail_at == "transfer":
            # Failed migration: the stopped source QPs are NOT destroyed —
            # they keep answering NAK_STOPPED, so peers pause and stay
            # paused; MigrOS is responsible for eventual cleanup
            # (paper §3.4). The container itself is gone.
            container.alive = False
            rep.ok = False
            rep.stage_failed = "transfer"                        # [MIGR]
            # the image is complete; an orchestrator may retry the move
            rep.attempt = {"image": bytes(image),                # [MIGR]
                           "runtime": runtime}
            return rep
        try:
            moved = self.stream_image(src_dev, dest_node.device.gid, image,
                                      runtime=runtime, preempt=preempt)
        except StreamPreempted as e:
            # operator/policy yield mid-transfer: the source QPs stay
            # STOPPED (peers paused — exactly the fail_at="transfer" wire
            # state) and the complete image rides the attempt token. The
            # parked gap itself is accounted by the orchestrator into
            # paused_s at resume time, never into transfer_s.
            container.alive = False
            rep.ok = False
            rep.transfer_s = (fab.now - t1) * STEP_S
            record_phase(fab, "transfer", t1, node=src_dev.gid,
                         suspended=True)
            if e.reason == "abort":
                rep.stage_failed = "aborted"
                return rep
            rep.stage_failed = "paused"
            rep.preemptions += 1
            rep.attempt = MigrationAttempt(
                container=container.name, strategy=rep.strategy,
                runtime=runtime, src_gid=src_dev.gid,
                dest_gid=dest_node.device.gid, phase="stopped",
                reason=e.reason, pages_sent=rep.pages_sent,
                image=bytes(image),
                service_qp=src_dev.service.take_suspend_state(
                    dest_node.device.gid),
                paused_at=fab.now)
            return rep
        except (MigrationError, ServiceError) as e:
            # a real wire failure (stream timeout, corruption) lands in
            # the same state as fail_at="transfer": source QPs STOPPED,
            # peers paused, the complete image held as a retry token —
            # reported, not raised, so callers aren't left mid-migration
            container.alive = False
            rep.ok = False
            rep.stage_failed = "transfer"
            rep.transfer_error = e
            rep.attempt = {"image": bytes(image), "runtime": runtime}
            rep.transfer_s = (fab.now - t1) * STEP_S
            record_phase(fab, "transfer", t1, node=src_dev.gid,
                         failed=True)
            return rep
        rep.transfer_s = (fab.now - t1) * STEP_S
        record_phase(fab, "transfer", t1, node=src_dev.gid,
                     bytes=len(image))
        rep.pages_sent = rep.pages_total   # every page moved while stopped

        t2 = fab.now
        self._teardown_source(container)
        self._restore(container, moved, dest_node)
        rep.restore_s = (fab.now - t2) * STEP_S
        record_phase(fab, "restore", t2, node=dest_node.device.gid)
        # stop-and-copy: the whole flow is one stop-the-world window
        rep.downtime_s = rep.total_s                             # [MIGR]
        rep.simulated_downtime_s = rep.simulated_transfer_s      # [MIGR]
        return rep

    def _teardown_source(self, container):
        """Destroy the stopped source QPs (paper: stopped QPs remain until
        destroyed together with the checkpointed process)."""
        ctx = container.ctx
        dev = ctx.device
        for qp in list(ctx.qps):
            if qp.state not in (QPState.RESET,):
                qp.state = QPState.RESET                          # [MIGR]
            dev.destroy_qp(qp.qpn)
        ctx.qps.clear()
        for mr in list(ctx.mrs):
            dev.dereg_mr(mr)   # keep the device rkey index coherent
        ctx.mrs.clear()
        if ctx in dev.contexts:
            dev.contexts.remove(ctx)
