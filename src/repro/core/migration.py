"""Live-migration controller: the CRIU + container-runtime flow (paper §4).

stop QPs → dump (verbs + MR memory + user state) → transfer → restore at
destination (CREATE / key restore / state walk / REFILL) → resume messages
re-address partners → communication continues via normal go-back-N.

Two runtime modes reproduce the paper's comparison:
  * "crx"    — image streamed to the destination during checkpoint, held in
               RAM (the paper's CR-X runtime; fast path).
  * "docker" — checkpoint staged to 'local storage' first, then moved,
               then restored (no overlap; reproduces Fig. 12's gap).
"""
from __future__ import annotations

import time
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import msgpack

from repro.core import dump as dumplib
from repro.core.states import QPState


@dataclass
class MigrationReport:
    checkpoint_s: float = 0.0
    transfer_s: float = 0.0
    restore_s: float = 0.0
    image_bytes: int = 0
    simulated_transfer_s: float = 0.0
    ok: bool = True
    # -- live-migration engine extensions ----------------------------- [MIGR]
    strategy: str = "stop_and_copy"
    downtime_s: float = 0.0            # wall time QPs were actually stopped
    simulated_downtime_s: float = 0.0  # bytes moved while stopped / link bw
    live_s: float = 0.0                # pre-copy wall time spent still running
    rounds: List[Dict] = field(default_factory=list)   # per pre-copy round
    pages_total: int = 0
    pages_sent: int = 0                # includes re-sent dirty pages
    stage_failed: Optional[str] = None   # "checkpoint" | "transfer"
    retries: int = 0
    rolled_back: bool = False
    # retry token: strategy-private state (captured image / staged pages)
    # the orchestrator hands back to resume a failed transfer.
    attempt: Optional[Dict] = field(default=None, repr=False, compare=False)
    # post-copy demand pager, still serving faults after migrate() returns
    pager: Optional[object] = field(default=None, repr=False, compare=False)

    @property
    def total_s(self):
        return self.checkpoint_s + self.transfer_s + self.restore_s


class MigrationError(RuntimeError):
    pass


class MigrationController:
    """Migrates a container between nodes over the fabric."""

    def __init__(self, fabric, *, link_bandwidth_Bps: float = 40e9 / 8,
                 stop_pump_steps: int = 50):
        self.fabric = fabric
        self.bw = link_bandwidth_Bps
        self.stop_pump_steps = stop_pump_steps
        # control-plane registry: cluster-unique QPN -> current gid.
        # Lets simultaneous migrations re-address each other.     # [MIGR]
        self.relocated = {}

    # -- image ------------------------------------------------------------------
    def _checkpoint(self, container) -> bytes:
        ctx = container.ctx
        verbs_image = dumplib.dump_context(ctx, stop=True)       # [MIGR]
        memory = {m.mrn: bytes(m.buf) for m in ctx.mrs}
        user = container.checkpoint_user()
        return msgpack.packb({"verbs": verbs_image, "memory": memory,
                              "user": user}, use_bin_type=True)

    def _restore(self, container, image_bytes: bytes, dest_node):
        image = msgpack.unpackb(image_bytes, raw=False,
                                strict_map_key=False)
        ctx = dest_node.device.open_context()
        session = dumplib.restore_context(ctx, image["verbs"],
                                          relocated=self.relocated)  # [MIGR]
        for qp in ctx.qps:                                       # [MIGR]
            self.relocated[qp.qpn] = dest_node.device.gid        # [MIGR]
        for mrn, buf in image["memory"].items():
            session.mr_by_n[int(mrn)].buf[:] = buf
        container.adopt(dest_node, ctx, session)
        container.restore_user(image["user"])

    # -- flow -------------------------------------------------------------------
    def migrate(self, container, dest_node, *, runtime: str = "crx",
                fail_at: Optional[str] = None) -> MigrationReport:
        rep = MigrationReport()
        src_node = container.node
        if dest_node is src_node:
            return rep

        t0 = time.perf_counter()
        rep.pages_total = sum(m.n_pages for m in container.ctx.mrs)
        rep.pages_sent = rep.pages_total   # every page moves while stopped
        image = self._checkpoint(container)
        # QPs are now STOPPED but still attached: while the image is being
        # written/moved, partner packets hit them and draw NAK_STOPPED
        # (this is where peers transition to PAUSED).             # [MIGR]
        self.fabric.pump(self.stop_pump_steps)
        if runtime == "docker":
            # stage to local storage: extra serialise+copy round trip
            staged = zlib.compress(image, level=1)
            image = zlib.decompress(staged)
        rep.image_bytes = len(image)
        rep.checkpoint_s = time.perf_counter() - t0
        if fail_at == "checkpoint":
            rep.ok = False
            rep.stage_failed = "checkpoint"                      # [MIGR]
            return rep

        t1 = time.perf_counter()
        # the image moves over the same links the benchmark traffic uses
        rep.simulated_transfer_s = len(image) / self.bw
        if runtime == "docker":
            rep.simulated_transfer_s *= 2  # via storage, no streaming
        moved = bytes(image)               # actual byte movement
        rep.transfer_s = time.perf_counter() - t1
        if fail_at == "transfer":
            # Failed migration: the stopped source QPs are NOT destroyed —
            # they keep answering NAK_STOPPED, so peers pause and stay
            # paused; MigrOS is responsible for eventual cleanup
            # (paper §3.4). The container itself is gone.
            container.alive = False
            rep.ok = False
            rep.stage_failed = "transfer"                        # [MIGR]
            # the image is complete; an orchestrator may retry the move
            rep.attempt = {"image": moved, "runtime": runtime}   # [MIGR]
            return rep

        t2 = time.perf_counter()
        self._teardown_source(container)
        self._restore(container, moved, dest_node)
        rep.restore_s = time.perf_counter() - t2
        # stop-and-copy: the whole flow is one stop-the-world window
        rep.downtime_s = rep.total_s                             # [MIGR]
        rep.simulated_downtime_s = rep.simulated_transfer_s      # [MIGR]
        return rep

    def _teardown_source(self, container):
        """Destroy the stopped source QPs (paper: stopped QPs remain until
        destroyed together with the checkpointed process)."""
        ctx = container.ctx
        dev = ctx.device
        for qp in list(ctx.qps):
            if qp.state not in (QPState.RESET,):
                qp.state = QPState.RESET                          # [MIGR]
            dev.destroy_qp(qp.qpn)
        ctx.qps.clear()
        for mr in list(ctx.mrs):
            dev.dereg_mr(mr)   # keep the device rkey index coherent
        ctx.mrs.clear()
        if ctx in dev.contexts:
            dev.contexts.remove(ctx)
