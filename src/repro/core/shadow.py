"""DMTCP-style interposition baseline (paper §5.2, Fig. 8).

DMTCP achieves migratability by *intercepting every IB verbs call* and
maintaining shadow objects between the application and the NIC: work
requests are rewritten to point at shadow bounce buffers, completions are
rewritten back. The interception runs always — even if the process never
migrates. This module reproduces that architecture so the benchmarks can
measure its standing cost against MigrOS' zero-interception fast path.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass
from typing import Dict

from repro.core.verbs import (Context, MemoryRegion, QueuePair, RecvWR,
                              SendWR, SGE)


@dataclass
class _ShadowMR:
    user: MemoryRegion
    shadow: MemoryRegion


class ShadowVerbs:
    """Wraps a verbs Context; every data-path call goes through shadows."""

    def __init__(self, ctx: Context):
        self.ctx = ctx
        self._mrs: Dict[int, _ShadowMR] = {}      # user mrn -> pair
        self._wr_map: Dict[int, int] = {}         # wr_id bookkeeping
        self._qp_log: Dict[int, list] = defaultdict(list)

    # -- object shadowing -------------------------------------------------------
    def reg_mr(self, pd, size: int) -> MemoryRegion:
        user = pd.reg_mr(size)
        shadow = pd.reg_mr(size)
        self._mrs[user.mrn] = _ShadowMR(user, shadow)
        return user

    def create_qp(self, pd, send_cq, recv_cq, srq=None) -> QueuePair:
        qp = pd.create_qp(send_cq, recv_cq, srq)
        self._qp_log[qp.qpn] = []
        return qp

    # -- data path (interception overhead lives here) -----------------------------
    def post_send(self, qp: QueuePair, wr: SendWR):
        pair = self._mrs[wr.sge.mr.mrn]
        # bounce copy user -> shadow, rewrite the WR to the shadow MR
        data = wr.sge.mr.read(wr.sge.offset, wr.sge.length)
        pair.shadow.write(wr.sge.offset, data)
        rewritten = SendWR(wr.wr_id, wr.opcode,
                           SGE(pair.shadow, wr.sge.offset, wr.sge.length),
                           wr.raddr, wr.rkey)
        self._wr_map[wr.wr_id] = wr.sge.mr.mrn
        self._qp_log[qp.qpn].append(("send", wr.wr_id, wr.sge.length))
        qp.post_send(rewritten)

    def post_recv(self, qp: QueuePair, wr: RecvWR):
        pair = self._mrs[wr.sge.mr.mrn]
        rewritten = RecvWR(wr.wr_id,
                           SGE(pair.shadow, wr.sge.offset, wr.sge.length))
        self._wr_map[wr.wr_id] = wr.sge.mr.mrn
        self._qp_log[qp.qpn].append(("recv", wr.wr_id, wr.sge.length))
        qp.post_recv(rewritten)

    def poll(self, cq, n: int = 1):
        wcs = cq.poll(n)
        for wc in wcs:
            mrn = self._wr_map.pop(wc.wr_id, None)
            if mrn is None:
                continue
            pair = self._mrs[mrn]
            if wc.opcode == "RECV":
                # bounce copy shadow -> user on completion
                pair.user.buf[:wc.byte_len] = pair.shadow.buf[:wc.byte_len]
        return wcs
