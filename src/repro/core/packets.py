"""RoCEv2-style packet formats (BTH-level, per paper §3.4/§4.2).

MigrOS protocol additions are three wire-level items:           # [MIGR]
  * NAK code ``NAK_STOPPED``                                    # [MIGR]
  * ``RESUME`` packet carrying the sender's new address and the PSN of its
    first unacknowledged packet                                 # [MIGR]
  * ``RESUME_ACK`` acknowledging the last successfully received packet
    (normal ACK semantics reused)                               # [MIGR]
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional, Tuple


class Op(enum.Enum):
    SEND = "SEND"                    # two-sided send (consumes an RR)
    WRITE = "WRITE"                  # one-sided RDMA write
    READ_REQ = "READ_REQ"            # one-sided RDMA read request
    READ_RESP = "READ_RESP"
    ACK = "ACK"
    NAK = "NAK"
    RESUME = "RESUME"                # [MIGR]
    RESUME_ACK = "RESUME_ACK"        # [MIGR]
    # DCQCN notification point -> reaction point: the responder answers a
    # CE-marked (congestion experienced) arrival with a CNP so the sender
    # cuts its rate before queues overflow into RNR NAKs / timeouts
    CNP = "CNP"                      # [ECN]
    # PFC link-level flow control (802.1Qbb-style): an ingress queue
    # crossing its per-class XOFF watermark answers with PAUSE frames
    # toward its senders; UNPAUSE is the XON frame (the wire name
    # ``RESUME`` is taken by the migration handshake above). The class
    # rides the payload and the pause lifetime (in steps — the quanta
    # field of a real PFC frame) rides ``length``. Link-level: these
    # terminate at the receiving node's *egress port* latches and never
    # reach a QP.
    PAUSE = "PAUSE"                  # [PFC]
    UNPAUSE = "UNPAUSE"              # [PFC]
    # service-channel (kernel QP) data plane: checkpoint images, pre-copy
    # page rounds, and post-copy demand pulls are streamed as ordinary
    # PSN-sequenced traffic and contend with app SEND/WRITE for links.
    MIG_PAGE = "MIG_PAGE"            # [MIGR] page batch (pre/post-copy)
    MIG_STATE = "MIG_STATE"          # [MIGR] checkpoint image chunk
    MIG_ACK = "MIG_ACK"              # [MIGR] stream-level receipt


# ops carried by the migration data plane (service channel); the fabric
# accounts these separately so migration bandwidth use is observable —
# and the NIC-port QoS scheduler keys its migration traffic class on
# exactly this set (repro.core.qos.classify)
MIG_OPS = frozenset({Op.MIG_PAGE, Op.MIG_STATE, Op.MIG_ACK})

# pure acknowledgement/control ops: they carry no payload to process, so
# the ingress (receive-side) port delivers them past the bounded request
# queue — dropping a peer's ACK to signal *our* receive pressure would
# only amplify the congestion it reports. CNPs are here for the same
# reason DCQCN gives them the highest priority class on real fabrics: a
# congestion notification queued behind the congestion it reports is
# useless.
CTRL_OPS = frozenset({Op.ACK, Op.NAK, Op.RESUME, Op.RESUME_ACK, Op.CNP,
                      Op.PAUSE, Op.UNPAUSE})

# PFC pause/resume frames: intercepted at the ingress boundary and
# applied to the node's egress-port pause latches — a flow-control
# signal queued behind the data it governs would be useless, so like
# CNPs they bypass the bounded queue; unlike CNPs they are never
# delivered to any QP.
PFC_OPS = frozenset({Op.PAUSE, Op.UNPAUSE})

# reliable *request* ops: an ingress-queue overflow on one of these draws
# a receiver-not-ready NAK so the sender backs off (IBA RNR semantics)
# instead of burning retransmission timeouts. READ_RESP is a response —
# it cannot be NAKed; an overflow there is recovered by the requester's
# go-back-N timer re-issuing the READ_REQ.
RNR_OPS = frozenset({Op.SEND, Op.WRITE, Op.READ_REQ,
                     Op.MIG_PAGE, Op.MIG_STATE, Op.MIG_ACK})

# Precomputed membership flags on the members themselves: ``op in
# FROZENSET`` routes through Enum's Python-level ``__hash__`` and was
# measurable on the per-packet paths. The frozensets above remain the
# canonical definitions; the hot paths read these attributes.
# ``is_completer`` = the completer's half of a QP's rx queue (pure
# acks/notifications plus READ_RESP).
for _op in Op:
    _op.is_mig = _op in MIG_OPS
    _op.is_ctrl = _op in CTRL_OPS
    _op.is_rnr = _op in RNR_OPS
    _op.is_pfc = _op in PFC_OPS
    _op.is_completer = (_op in CTRL_OPS or _op is Op.READ_RESP) \
        and _op not in PFC_OPS
del _op


class NakCode(enum.Enum):
    PSN_SEQ_ERR = "PSN_SEQ_ERR"
    INVALID_RKEY = "INVALID_RKEY"
    STOPPED = "NAK_STOPPED"          # [MIGR]
    # receiver not ready (IBA §9.7.5.2.8): the responder has no receive
    # posted, or the NIC's ingress queue overflowed. The requester backs
    # off min_rnr_timer and retries up to rnr_retry times; exhaustion
    # moves the QP to ERROR. Distinct from PSN_SEQ_ERR: an RNR NAK is
    # *not* a sequence gap and must not trigger immediate go-back-N.
    RNR = "RNR"


@dataclass(slots=True)
class Packet:
    op: Op
    src_gid: int
    src_qpn: int
    dest_gid: int
    dest_qpn: int
    psn: int = 0
    # payload for SEND/WRITE/READ_RESP; (addr, length) metadata for one-sided
    payload: bytes = b""
    raddr: int = 0
    rkey: int = 0
    length: int = 0
    first: bool = True               # message framing over MTU packets
    last: bool = True
    wr_id: int = 0
    nak_code: Optional[NakCode] = None
    read_psn: int = 0                # responder PSN for READ_RESP streams
    # QoS attribution: the container (tenant) whose QP emitted the packet,
    # stamped at send time. Out-of-band metadata — a real NIC reads the
    # owning QP's context the same way — so it never counts in nbytes().
    tenant: Optional[str] = None
    # ECN codepoints (RoCEv2 carries them in the IP header): ``ect`` is
    # ECN-Capable-Transport, stamped at send time on data ops when the
    # fabric's ECN config is enabled; ``ce`` is Congestion-Experienced,
    # set by a port whose queue occupancy crossed the RED thresholds.
    # Two header bits on the wire — they never count in nbytes().
    ect: bool = False                # [ECN]
    ce: bool = False                 # [ECN]
    # stats attribution on CNPs only: traffic class (app/mig) of the
    # CE-marked packet this CNP answers, so the reaction point's
    # cnps_handled counters keep the per-class == total invariant.
    # Out-of-band metadata, like ``tenant``.
    ecn_class: Optional[str] = None  # [ECN]

    @property
    def route(self) -> Tuple[int, int]:
        return (self.dest_gid, self.dest_qpn)

    def nbytes(self) -> int:
        return 64 + len(self.payload)    # ~BTH/GRH header + payload
