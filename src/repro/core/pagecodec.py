"""Delta-aware migration page codec: zero elision, dedup, XOR deltas.

The paper's headline metrics (§5: bounded downtime, bounded transfer
time) are ultimately byte counts divided by contended link bandwidth —
and the pre-copy data plane as seeded re-sends every dirtied page in
full, 4 KiB a pop, every round. This module is the classic
live-migration data-reduction layer on top of the ``MIG_PAGE`` stream:

* ``PAGE_ZERO`` — an all-zero page ships as a bare record (meta tuple
  only, empty payload) instead of 4 KiB of zeros;
* ``PAGE_DUP``  — a page whose content (blake2b-128 digest, any offset)
  is already staged at the destination ships as a 16-byte digest
  reference into the receiver's content-addressed store;
* ``PAGE_DELTA``— a re-dirtied page ships as the zlib-compressed XOR
  diff against the last *acknowledged* snapshot of that page, when the
  diff is smaller than the page (``PAGE_FULL`` otherwise).

Sender state (``PageCodec``) is per-migration: a digest cache of the
content known staged at the destination plus per-page delta-base
snapshots. Both ride the ``MigrationAttempt`` pause token
(``dump``/``restore``) and MUST be discarded when an attempt resumes
onto a new destination — the old staging died with the old node, and a
stale dedup hit would silently corrupt the restored image. The decoder
makes that failure loud instead of silent: a ``PAGE_DUP``/``PAGE_DELTA``
referencing a digest the receiver never registered raises
``CodecError``.

Idempotency under preemption: a batch cut off mid-transfer counts as
unsent (the sender commits codec state only on the ``MIG_ACK``
receipt), but the message may still have been *delivered*. Deltas are
therefore decoded against the receiver's content-addressed store via
the record's base digest — never against the mutable staged value — so
re-delivery, and even a resend carrying *newer* page content, decodes
to exactly the content the sender hashed into the record's result
digest.

Everything is stdlib (``hashlib.blake2b`` + ``zlib``) and gated behind
``Fabric.configure_codec`` — disabled (the default), no call site
touches this module and the wire format is byte-identical to the
codec-less build.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from hashlib import blake2b
from typing import Dict, List, Optional, Tuple

# record kinds (the 4th element of an encoded page meta tuple)
PAGE_FULL = 0
PAGE_ZERO = 1
PAGE_DUP = 2
PAGE_DELTA = 3

DIGEST_SIZE = 16

_ZEROS: Dict[int, bytes] = {}


def _zeros(n: int) -> bytes:
    z = _ZEROS.get(n)
    if z is None:
        z = _ZEROS[n] = bytes(n)
    return z


def page_digest(data: bytes) -> bytes:
    """blake2b-128 content digest — the dedup/delta-base identity."""
    return blake2b(data, digest_size=DIGEST_SIZE).digest()


def _xor(a: bytes, b: bytes) -> bytes:
    return (int.from_bytes(a, "little")
            ^ int.from_bytes(b, "little")).to_bytes(len(a), "little")


class CodecError(RuntimeError):
    """A record referenced content the receiver never registered, or a
    reconstructed page failed its digest check — always a protocol bug
    (e.g. codec state surviving a destination re-point), never a state
    to limp past."""


@dataclass
class CodecConfig:
    """Operator knobs for the migration page codec (``configure_codec``).
    Disabled by default: no encode/decode happens anywhere and every
    pinned figure is byte-identical to the codec-less fabric."""
    enabled: bool = False
    zero_elision: bool = True    # all-zero pages -> bare PAGE_ZERO record
    dedup: bool = True           # staged-content digest hits -> PAGE_DUP
    delta: bool = True           # re-dirtied pages -> XOR+zlib PAGE_DELTA
    compress_image: bool = True  # MIG_STATE checkpoint images -> zlib
    zlib_level: int = 6          # delta/image compression level (1..9)
    # pre-copy convergence controller: cut over to stop-and-copy when the
    # projected encoded bytes of the next round are >= this fraction of
    # the round just sent (rounds stopped shrinking — the non-converging
    # writable-working-set pathology)
    cutover_ratio: float = 0.9

    def validate(self) -> "CodecConfig":
        if not 1 <= int(self.zlib_level) <= 9:
            raise ValueError("zlib_level must be in [1, 9]")
        if not 0.0 < self.cutover_ratio <= 1.0:
            raise ValueError("cutover_ratio must be in (0, 1]")
        return self


class PageCodec:
    """Sender-side, per-migration codec state.

    ``staged`` maps content digests known staged at the destination
    (insertion-ordered dict, never a set: bytes hashing is randomised
    per process, and the dump order must be run-stable). ``snaps`` maps
    ``(mrn, page)`` to the last *acknowledged* page bytes — the XOR
    delta base. Both advance only via ``commit`` (called on the batch's
    MIG_ACK receipt), so a preempted batch re-encodes from exactly the
    state the receiver provably holds."""

    def __init__(self, cfg: CodecConfig):
        self.cfg = cfg
        self.staged: Dict[bytes, bool] = {}
        self.snaps: Dict[Tuple[int, int], bytes] = {}

    # -- encode --------------------------------------------------------------
    def encode_batch(self, pages: List[Tuple[int, int, bytes]]):
        """Encode one MIG_PAGE batch of ``(mrn, page, data)`` triples.

        Returns ``(metas, payload, pending, stats)``: wire-ready page
        meta tuples + concatenated encoded payload, the tentative state
        overlay to ``commit`` once the batch is acked, and the encode
        statistics (counter feed). Meta tuple shapes:

        * ``(mrn, pg, ln, PAGE_FULL,  clen)``           payload = page
        * ``(mrn, pg, ln, PAGE_ZERO,  0)``              payload = empty
        * ``(mrn, pg, ln, PAGE_DUP,   16)``             payload = digest
        * ``(mrn, pg, ln, PAGE_DELTA, clen, rd, bd)``   payload = zlib(xor)

        where ``rd``/``bd`` are the result/base content digests (the
        base digest is only ever one the receiver has registered)."""
        cfg = self.cfg
        metas, parts = [], []
        pend_staged: Dict[bytes, bool] = {}
        pend_snaps: Dict[Tuple[int, int], bytes] = {}
        stats = {"full": 0, "zero": 0, "dup": 0, "delta": 0,
                 "bytes_in": 0, "bytes_out": 0, "delta_saved": 0}
        for mrn, pg, data in pages:
            ln = len(data)
            stats["bytes_in"] += ln
            dg = page_digest(data)
            key = (mrn, pg)
            meta = None
            if cfg.zero_elision and data == _zeros(ln):
                meta = (mrn, pg, ln, PAGE_ZERO, 0)
                stats["zero"] += 1
            elif cfg.dedup and (dg in pend_staged or dg in self.staged):
                meta = (mrn, pg, ln, PAGE_DUP, DIGEST_SIZE)
                parts.append(dg)
                stats["dup"] += 1
            else:
                base = pend_snaps.get(key, self.snaps.get(key))
                if cfg.delta and base is not None and len(base) == ln:
                    bd = page_digest(base)
                    if bd in pend_staged or bd in self.staged:
                        comp = zlib.compress(_xor(data, base),
                                             cfg.zlib_level)
                        if len(comp) < ln:
                            meta = (mrn, pg, ln, PAGE_DELTA, len(comp),
                                    dg, bd)
                            parts.append(comp)
                            stats["delta"] += 1
                            stats["delta_saved"] += ln - len(comp)
                if meta is None:
                    meta = (mrn, pg, ln, PAGE_FULL, ln)
                    parts.append(data)
                    stats["full"] += 1
            metas.append(meta)
            pend_snaps[key] = data
            if meta[3] != PAGE_ZERO:
                # zero pages are elided receiver-side too (never enter
                # the content store), so their digest must not become a
                # dedup/delta-base candidate
                pend_staged[dg] = True
        payload = b"".join(parts)
        stats["bytes_out"] = len(payload)
        return metas, payload, (pend_staged, pend_snaps), stats

    def commit(self, pending):
        """Fold a batch's tentative overlay in — call ONLY once the
        batch's MIG_ACK receipt arrived. A preempted batch's overlay is
        simply dropped; the resend re-encodes from committed state."""
        pend_staged, pend_snaps = pending
        self.staged.update(pend_staged)
        self.snaps.update(pend_snaps)

    # -- pause-token (de)serialisation ---------------------------------------
    def dump(self) -> dict:
        """Wire form for the ``MigrationAttempt`` token (msgpack-ready;
        empty dict when there is nothing to carry)."""
        if not self.staged and not self.snaps:
            return {}
        return {"staged": list(self.staged),
                "snaps": [[k[0], k[1], v] for k, v in self.snaps.items()]}

    @classmethod
    def restore(cls, cfg: CodecConfig, d: Optional[dict]) -> "PageCodec":
        c = cls(cfg)
        if d:
            for dg in d.get("staged", []):
                c.staged[bytes(dg)] = True
            for mrn, pg, data in d.get("snaps", []):
                c.snaps[(int(mrn), int(pg))] = bytes(data)
        return c


# -- receive side ------------------------------------------------------------

def decode_batch(metas, data: bytes, stage: Dict[Tuple[int, int], bytes],
                 store: Dict[bytes, bytes]):
    """Apply one encoded MIG_PAGE batch to a destination staging dict.

    ``store`` is the stream's content-addressed store: every FULL/DELTA
    page registers its content under its digest, and DUP/DELTA records
    resolve through it — never through the mutable staged value — so
    decoding is idempotent under re-delivery (the store is append-only
    and content-addressed; re-applying any record reproduces the same
    bytes). An unknown digest raises ``CodecError``: it means sender
    codec state outlived the staging it described (the
    new-destination-invalidation bug this codec refuses to hide)."""
    off = 0
    for m in metas:
        mrn, pg, ln, kind, clen = int(m[0]), int(m[1]), int(m[2]), \
            int(m[3]), int(m[4])
        chunk = bytes(data[off:off + clen])
        off += clen
        if kind == PAGE_FULL:
            page = chunk
            store[page_digest(page)] = page
        elif kind == PAGE_ZERO:
            page = _zeros(ln)
        elif kind == PAGE_DUP:
            page = store.get(chunk)
            if page is None or len(page) != ln:
                raise CodecError(
                    f"PAGE_DUP ({mrn},{pg}) references unstaged content "
                    f"{chunk.hex()}")
        elif kind == PAGE_DELTA:
            rd, bd = bytes(m[5]), bytes(m[6])
            base = store.get(bd)
            if base is None or len(base) != ln:
                raise CodecError(
                    f"PAGE_DELTA ({mrn},{pg}) base {bd.hex()} not in "
                    f"the stream's content store")
            page = _xor(base, zlib.decompress(chunk))
            if page_digest(page) != rd:
                raise CodecError(
                    f"PAGE_DELTA ({mrn},{pg}) reconstruction failed "
                    f"its digest check")
            store[rd] = page
        else:
            raise CodecError(f"unknown page record kind {kind}")
        stage[(mrn, pg)] = page
    if off != len(data):
        raise CodecError(
            f"encoded payload length mismatch ({off} != {len(data)})")


# -- checkpoint images --------------------------------------------------------
# One tag byte so the receiver-side take_image path stays format-blind:
# the *sender* (stream_image) decodes what it reads back, and a blob
# that did not shrink ships raw rather than inflated.

_IMG_RAW = b"\x00"
_IMG_ZLIB = b"\x01"


def encode_image(image: bytes, cfg: CodecConfig) -> bytes:
    """Wire form of a MIG_STATE checkpoint image: zlib-compressed when
    that is actually smaller, raw (1-byte tag overhead) otherwise."""
    comp = zlib.compress(image, cfg.zlib_level)
    if len(comp) + 1 < len(image):
        return _IMG_ZLIB + comp
    return _IMG_RAW + image


def decode_image(blob: bytes) -> bytes:
    tag, body = blob[:1], blob[1:]
    if tag == _IMG_ZLIB:
        return zlib.decompress(body)
    if tag == _IMG_RAW:
        return bytes(body)
    raise CodecError(f"unknown image encoding tag {tag!r}")
