"""Cluster-wide QPN/MRN namespace partitioning (paper §4.1).

Two processes must never share a QPN/MRN on one node. CRIU solved the
analogous PID problem with PID namespaces; for verbs objects the paper
partitions the number space across nodes ahead of time so a restored
object's original ID is guaranteed free on any node. Each node's device
draws from its own disjoint range; the controller validates ranges.
"""
from __future__ import annotations

from typing import Dict

RANGE = 1_000_000


class GlobalNamespace:
    def __init__(self):
        self._owners: Dict[int, int] = {}      # base -> gid

    def range_for(self, gid: int) -> int:
        base = gid * RANGE
        prev = self._owners.get(base)
        if prev is not None and prev != gid:
            raise ValueError(f"range {base} already owned by {prev}")
        self._owners[base] = gid
        return base

    @staticmethod
    def owner_of(number: int) -> int:
        """Which node allocated this QPN/MRN originally."""
        return number // RANGE
