"""Device-owned migration service channel (kernel QPs, paper §4.2).

SoftRoCE keeps kernel-owned QPs alongside user QPs; MigrOS rides them for
its control messages. This module gives every ``RdmaDevice`` the same
thing for the migration *data* plane: one kernel QP per peer node,
invisible to container contexts (never dumped, never migrated), through
which checkpoint images (``MIG_STATE``), pre-copy page rounds and
post-copy pulls (``MIG_PAGE``) are streamed as ordinary PSN-sequenced
traffic. The packets reuse the requester/responder/completer go-back-N
machinery verbatim — loss on a migration stream is retransmitted exactly
like loss on application traffic, and both contend for the same
per-(src,dest) link bandwidth in the fabric.

Each logical message is one WQE (chunked over the MTU by the requester,
reassembled by first/last framing on the receive side); the receiver
answers with a stream-level ``MIG_ACK`` receipt carrying the message's
``xid`` so a sender can pump the fabric until the bytes have really
crossed the wire.
"""
from __future__ import annotations

import msgpack
from collections import deque
from typing import Callable, Dict, Optional, Tuple

from repro.core import pagecodec
from repro.core.packets import Op
from repro.core.qos import CongestionControl
from repro.core.states import QPState
from repro.core.verbs import Context, MemoryRegion, QueuePair, SGE, SendWR


class ServiceError(RuntimeError):
    pass


class StreamPreempted(Exception):
    """A service transfer was *suspended* mid-stream — operator
    pause/abort, an auto-preemption policy yield, or the peer leaving the
    fabric — rather than failing. Deliberately NOT a ``ServiceError``:
    failure handlers (retry loops, rollback-on-wire-error) must never
    mistake a suspension for a dead stream. Callers convert it into a
    paused ``MigrationAttempt`` token instead."""

    def __init__(self, reason: str, xid: int):
        super().__init__(f"service stream suspended ({reason}) xid={xid}")
        self.reason = reason
        self.xid = xid


class ServiceChannel:
    """Kernel-owned migration endpoint of one device."""

    def __init__(self, device):
        self.device = device
        # kernel context: holds the service PD/CQ/QPs/MRs but is NOT
        # registered in device.contexts, so dump_context never sees it and
        # admission's per-container scans skip it. Its tenant key exists
        # only for QoS observability — migration traffic is classed by op
        # (MIG_*), not by tenant, and operators would not bucket the
        # kernel (doing so throttles migration below its class share).
        self.ctx = Context(device, ctx_id=-1,
                           tenant=f"_kernel@{device.gid}")
        self.pd = self.ctx.alloc_pd()
        self.cq = self.ctx.create_cq(depth=1 << 16)
        self._peers: Dict[int, QueuePair] = {}     # peer gid -> kernel QP
        self._wr = 0
        self._xid = 0
        self._stream = 0
        self._tx_mrs: Dict[int, Tuple[int, MemoryRegion]] = {}
        #   ^ wr_id -> (peer_gid, scratch MR), held until send completes
        # receive side
        self.acked: set = set()                    # xids receipt-acked
        self.images: Dict[int, bytes] = {}         # xid -> MIG_STATE blob
        self.staging: Dict[int, Dict[Tuple[int, int], bytes]] = {}
        #   ^ stream -> {(mrn, page): bytes}: pre-copy pages that arrived
        self.page_store: Dict[int, Dict[int, bytes]] = {}
        #   ^ stream -> {mrn: frozen buf}: post-copy source-side store
        # preemption: peer gid -> reason while a stream toward that peer
        # is suspended (an in-flight transfer() exits via StreamPreempted
        # instead of its timeout-abort path), and the suspended kernel
        # QP's learned wire state (RTO estimator, DCQCN rate) so a
        # resumed attempt starts from it rather than from scratch
        self._suspended: Dict[int, str] = {}
        self.suspend_state: Dict[int, dict] = {}
        # per-stream content-addressed store for codec-encoded pre-copy
        # batches (digest -> page bytes); append-only for a stream's
        # lifetime so record decode is idempotent under re-delivery
        self.codec_rx: Dict[int, Dict[bytes, bytes]] = {}
        # on-wire size of the most recent post()'s packed blob — the
        # honest serialisation cost for transfer()'s timeout budget
        self.last_post_nbytes = 0

    # -- identifiers ---------------------------------------------------------
    def next_xid(self) -> int:
        self._xid += 1
        return self.device.gid * 1_000_000_000 + self._xid

    def next_stream(self) -> int:
        self._stream += 1
        return self.device.gid * 1_000_000_000 + self._stream

    # -- kernel QP rendezvous ------------------------------------------------
    def qp_for(self, peer_gid: int) -> QueuePair:
        """Kernel QP toward ``peer_gid``; first use performs the two-sided
        rendezvous (both devices create and connect their kernel QPs —
        the out-of-band exchange ordinary channels do 'over TCP')."""
        qp = self._peers.get(peer_gid)
        if qp is not None:
            return qp
        peer_dev = self.device.fabric.device(peer_gid)
        if peer_dev is None:
            raise ServiceError(f"no device at gid {peer_gid}")
        peer_svc = peer_dev.service
        mine = self.pd.create_qp(self.cq, self.cq)
        theirs = peer_svc.pd.create_qp(peer_svc.cq, peer_svc.cq)
        for qp_, dst_dev, dst_qp in ((mine, peer_dev, theirs),
                                     (theirs, self.device, mine)):
            qp_.modify(QPState.INIT)
            qp_.modify(QPState.RTR, dest_gid=dst_dev.gid,
                       dest_qpn=dst_qp.qpn, rq_psn=0)
            qp_.modify(QPState.RTS, sq_psn=0)
        self._peers[peer_gid] = mine
        peer_svc._peers[self.device.gid] = theirs
        return mine

    # -- transmit ------------------------------------------------------------
    def post(self, peer_gid: int, op: Op, meta: dict,
             data: bytes = b"") -> int:
        """Queue one service message (fire-and-forget); returns its xid."""
        xid = meta.setdefault("xid", self.next_xid())
        if self._suspended \
                and self.device.fabric.device(peer_gid) is not None:
            # a suspension nobody observed (pause verdict latched with no
            # transfer in flight) must not poison the next, unrelated
            # message — but a detach flag persists until the peer is
            # actually back on the fabric
            self._suspended.pop(peer_gid, None)
        blob = msgpack.packb({"meta": meta, "data": data},
                             use_bin_type=True)
        # kernel-private scratch MR: built directly (never registered with
        # the device) so per-message buffers don't consume the node's
        # finite MRN namespace range or pollute the rkey index — it is
        # only ever read as a local SGE source
        mr = MemoryRegion(self.pd, len(blob), mrn=-1, lkey=0, rkey=0)
        mr.buf[:] = blob
        self.last_post_nbytes = len(blob)
        self._wr += 1
        wr = SendWR(self._wr, op, SGE(mr, 0, len(blob)))
        self._tx_mrs[self._wr] = (peer_gid, mr)
        self.qp_for(peer_gid).post_send(wr)
        fab = self.device.fabric
        trc = fab.tracer
        if trc is not None:
            trc.svc_post(fab.now, self.device.gid, peer_gid, op.value,
                         xid, len(blob))
        return xid

    def transfer(self, peer_gid: int, op: Op, meta: dict, data: bytes,
                 *, tick: Optional[Callable] = None,
                 max_steps: Optional[int] = None,
                 preempt: Optional[Callable] = None) -> int:
        """Stream one message and pump the fabric until the receiver's
        MIG_ACK receipt arrives — i.e. until the bytes have actually been
        serialised over the shared links, retransmissions included. The
        elapsed pump steps ARE the transfer time (``fabric.now`` delta).

        ``preempt`` (optional) is polled between pump steps; a truthy
        return ("pause" / "auto" / "abort") suspends the stream — the
        partially-sent WQE is torn down, the kernel QP's learned wire
        state is snapshotted for the resume, and ``StreamPreempted``
        carries the reason out. A suspension set externally
        (``suspend_peer`` / ``peer_detached`` from a caller tick) exits
        the same way instead of tripping the timeout-abort path."""
        fabric = self.device.fabric
        xid = self.post(peer_gid, op, meta, data)
        if max_steps is None:
            # generous: 20x the no-contention serialisation time at the
            # slower end of the path — a bounded receiver ingress rate
            # (incast pressure, RNR backoff) caps the stream below the
            # egress port's rate, and the timeout must not fire on a
            # transfer that is making honest progress through it
            per_step = fabric.bytes_per_step
            rx_cap = fabric.ingress_capacity_Bps(peer_gid)
            if rx_cap is not None:
                per_step = min(per_step, rx_cap * fabric.step_s())
            # budget against the packed on-wire size, not the logical
            # payload: a codec-encoded round serialises far fewer bytes
            # than it carries, and the slack must not inflate with it
            ser = (self.last_post_nbytes + 4096) / max(per_step, 1e-9)
            max_steps = int(20 * ser) + 100_000
        if tick is None:
            if preempt is None:
                # fast path: the exact pre-preemption predicate — with
                # ``tick=None`` nothing external runs between steps, so
                # no suspension can appear mid-pump either
                # (let the event scheduler skip the dead air between
                # wire events — RTO waits, latency pipes)
                if fabric.pump_until(lambda: xid in self.acked,
                                     max_steps):
                    self.acked.discard(xid)
                    return xid
            else:
                def _done():
                    if xid in self.acked:
                        return True
                    return self._poll_suspend(peer_gid, preempt) \
                        is not None
                if fabric.pump_until(_done, max_steps):
                    if xid in self.acked:
                        self.acked.discard(xid)
                        return xid
                    self._suspend(peer_gid, xid)
        else:
            # caller-supplied tick (containers stepping alongside): the
            # per-step loop is the contract
            for _ in range(max_steps):
                if xid in self.acked:
                    self.acked.discard(xid)
                    return xid
                if preempt is not None or self._suspended:
                    # a caller tick can pause/detach externally, so the
                    # suspension flag is checked even without preempt
                    if self._poll_suspend(peer_gid, preempt) is not None:
                        self._suspend(peer_gid, xid)
                tick()
        # the stream is hopeless: abort it. Leaving the WQE in place would
        # retransmit the image forever (the device never goes idle) and a
        # late delivery would orphan the blob in the receiver's inbox.
        self.reset_peer(peer_gid)
        peer_dev = fabric.device(peer_gid)
        if peer_dev is not None and peer_dev._service is not None:
            peer_dev._service.images.pop(xid, None)
        self.acked.discard(xid)
        raise ServiceError(
            f"service transfer xid={xid} not acked in {max_steps} steps")

    # -- receive (called from the responder via the device) ------------------
    def on_message(self, op: Op, blob: bytes, src_gid: int):
        msg = msgpack.unpackb(blob, raw=False, strict_map_key=False)
        meta, data = msg["meta"], msg["data"]
        fab = self.device.fabric
        trc = fab.tracer
        if trc is not None:
            trc.svc_deliver(fab.now, self.device.gid, src_gid, op.value,
                            len(blob))
        if op == Op.MIG_ACK:
            if trc is not None:
                trc.svc_ack(fab.now, self.device.gid, meta["ack"])
            self.acked.add(meta["ack"])
            return
        if op == Op.MIG_STATE:
            self.images[meta["xid"]] = data
        elif op == Op.MIG_PAGE:
            if not meta.get("postcopy"):
                # pre-copy staging: pages accumulate at the destination
                # until install applies them
                stage = self.staging.setdefault(meta["stream"], {})
                pages = meta["pages"]
                if pages and len(pages[0]) > 3:
                    # codec-encoded batch: ≥5-tuple metas (legacy senders
                    # ship bare (mrn, pg, ln) triples, kept byte-identical)
                    pagecodec.decode_batch(
                        pages, data,
                        stage, self.codec_rx.setdefault(meta["stream"], {}))
                else:
                    off = 0
                    for mrn, pg, ln in pages:
                        stage[(mrn, pg)] = data[off:off + ln]
                        off += ln
            # post-copy pulls were already applied synchronously at the
            # destination MR; the stream only accounts for the wire cost
        if not meta.get("noack"):
            self.post(src_gid, Op.MIG_ACK, {"ack": meta["xid"]})

    def take_image(self, xid: int) -> bytes:
        try:
            return self.images.pop(xid)
        except KeyError:
            raise ServiceError(f"no delivered image for xid {xid}") from None

    def take_staging(self, stream: int) -> Dict[Tuple[int, int], bytes]:
        self.codec_rx.pop(stream, None)
        return self.staging.pop(stream, {})

    def discard_stream(self, stream: int):
        """Release any staged pages / frozen stores a dead migration
        attempt left behind (rollback path)."""
        self.staging.pop(stream, None)
        self.page_store.pop(stream, None)
        self.codec_rx.pop(stream, None)

    def reset_peer(self, peer_gid: int):
        """Tear down the kernel QP pair toward a peer (both ends) after a
        dead stream; the next message performs a fresh rendezvous. PSN
        state is abandoned with the QPs, so no go-back-N gap survives."""
        sides = [(self, peer_gid)]
        peer_dev = self.device.fabric.device(peer_gid)
        if peer_dev is not None and peer_dev._service is not None:
            sides.append((peer_dev._service, self.device.gid))
        for svc, gid in sides:
            qp = svc._peers.pop(gid, None)
            if qp is not None:
                qp.sq.clear()
                qp.inflight.clear()
                qp.pending_comp.clear()
                qp.rx.clear()
                qp.cur_wqe = None
                svc.device.destroy_qp(qp.qpn)
            svc._tx_mrs = {w: (g, mr) for w, (g, mr)
                           in svc._tx_mrs.items() if g != gid}

    # -- preemption ----------------------------------------------------------
    def _poll_suspend(self, peer_gid: int, preempt) -> Optional[str]:
        """Suspension reason for the stream toward ``peer_gid``, if any:
        an externally-set flag wins, else the caller's preempt callable
        is consulted (its verdict is latched into the flag so the reason
        survives until the transfer loop acts on it)."""
        r = self._suspended.get(peer_gid)
        if r is None and preempt is not None:
            r = preempt()
            if r:
                self._suspended[peer_gid] = r
        return r or None

    def _suspend(self, peer_gid: int, xid: int):
        """Common exit of a suspended transfer: tear the stream down
        (snapshotting the QP's wire state), scrub the half-delivered
        message from the receiver, and raise ``StreamPreempted``."""
        reason = self._suspended.pop(peer_gid, "pause")
        if peer_gid in self._peers:
            self.suspend_peer(peer_gid, reason)
            self._suspended.pop(peer_gid, None)
        peer_dev = self.device.fabric.device(peer_gid)
        if peer_dev is not None and peer_dev._service is not None:
            peer_dev._service.images.pop(xid, None)
        self.acked.discard(xid)
        raise StreamPreempted(reason, xid)

    def suspend_peer(self, peer_gid: int, reason: str = "pause"):
        """Suspend the stream toward a peer: ``reset_peer`` mechanics
        (tear down the kernel QP pair, abandon in-flight WQEs) but with
        pause semantics — the QP's learned wire state (RFC 6298 RTO
        estimator, DCQCN rate) is snapshotted into ``suspend_state``
        first so a resumed attempt re-applies it, and the suspension is
        flagged so an in-flight ``transfer`` exits via
        ``StreamPreempted`` instead of its timeout-abort path."""
        qp = self._peers.get(peer_gid)
        if qp is not None:
            self.suspend_state[peer_gid] = self._snapshot_wire_state(qp)
        self._suspended[peer_gid] = reason
        self.reset_peer(peer_gid)

    def peer_detached(self, gid: int):
        """Fabric hook: ``gid`` left the fabric. A stream toward it must
        suspend *now* — left armed, its WQEs would retransmit into the
        void until the transfer timeout fired and aborted the whole
        migration (the pre-preemption failure mode). The suspension is a
        pause, not an error: the attempt can resume toward a new
        destination."""
        if gid in self._peers \
                or any(g == gid for g, _ in self._tx_mrs.values()):
            self.suspend_peer(gid, reason="detach")

    def _snapshot_wire_state(self, qp: QueuePair) -> dict:
        d = {"rto": qp.rto, "srtt": qp.srtt, "rttvar": qp.rttvar}
        if qp.cc is not None:
            fab = self.device.fabric
            if fab.ecn.enabled:
                qp.cc.advance(fab.now, fab.bytes_per_step)
            d["cc"] = qp.cc.dump(fab.now)
        return d

    def take_suspend_state(self, peer_gid: int) -> dict:
        return self.suspend_state.pop(peer_gid, {})

    def apply_wire_state(self, peer_gid: int, d: dict):
        """Re-apply a suspended stream's learned wire state onto the
        fresh kernel QP the resume's rendezvous creates (only meaningful
        toward the *same* peer — RTO/rate are path-learned)."""
        if not d:
            return
        qp = self.qp_for(peer_gid)
        qp.rto = d["rto"]
        qp.srtt = d["srtt"]
        qp.rttvar = d["rttvar"]
        fab = self.device.fabric
        if "cc" in d and fab.ecn.enabled:
            qp.cc = CongestionControl.restore(
                fab.ecn, d["cc"], fab.now, fab.bytes_per_step,
                fab.step_s())

    # -- housekeeping --------------------------------------------------------
    def reap(self):
        """Drop scratch MRs whose send completed (runs every pump); the
        buffers were never device-registered, so releasing the reference
        is the whole teardown."""
        for wc in self.cq.poll(64):
            self._tx_mrs.pop(wc.wr_id, None)

    @property
    def tx_backlog(self) -> int:
        return len(self._tx_mrs)
