"""Device-owned migration service channel (kernel QPs, paper §4.2).

SoftRoCE keeps kernel-owned QPs alongside user QPs; MigrOS rides them for
its control messages. This module gives every ``RdmaDevice`` the same
thing for the migration *data* plane: one kernel QP per peer node,
invisible to container contexts (never dumped, never migrated), through
which checkpoint images (``MIG_STATE``), pre-copy page rounds and
post-copy pulls (``MIG_PAGE``) are streamed as ordinary PSN-sequenced
traffic. The packets reuse the requester/responder/completer go-back-N
machinery verbatim — loss on a migration stream is retransmitted exactly
like loss on application traffic, and both contend for the same
per-(src,dest) link bandwidth in the fabric.

Each logical message is one WQE (chunked over the MTU by the requester,
reassembled by first/last framing on the receive side); the receiver
answers with a stream-level ``MIG_ACK`` receipt carrying the message's
``xid`` so a sender can pump the fabric until the bytes have really
crossed the wire.
"""
from __future__ import annotations

import msgpack
from collections import deque
from typing import Callable, Dict, Optional, Tuple

from repro.core.packets import Op
from repro.core.states import QPState
from repro.core.verbs import Context, MemoryRegion, QueuePair, SGE, SendWR


class ServiceError(RuntimeError):
    pass


class ServiceChannel:
    """Kernel-owned migration endpoint of one device."""

    def __init__(self, device):
        self.device = device
        # kernel context: holds the service PD/CQ/QPs/MRs but is NOT
        # registered in device.contexts, so dump_context never sees it and
        # admission's per-container scans skip it. Its tenant key exists
        # only for QoS observability — migration traffic is classed by op
        # (MIG_*), not by tenant, and operators would not bucket the
        # kernel (doing so throttles migration below its class share).
        self.ctx = Context(device, ctx_id=-1,
                           tenant=f"_kernel@{device.gid}")
        self.pd = self.ctx.alloc_pd()
        self.cq = self.ctx.create_cq(depth=1 << 16)
        self._peers: Dict[int, QueuePair] = {}     # peer gid -> kernel QP
        self._wr = 0
        self._xid = 0
        self._stream = 0
        self._tx_mrs: Dict[int, Tuple[int, MemoryRegion]] = {}
        #   ^ wr_id -> (peer_gid, scratch MR), held until send completes
        # receive side
        self.acked: set = set()                    # xids receipt-acked
        self.images: Dict[int, bytes] = {}         # xid -> MIG_STATE blob
        self.staging: Dict[int, Dict[Tuple[int, int], bytes]] = {}
        #   ^ stream -> {(mrn, page): bytes}: pre-copy pages that arrived
        self.page_store: Dict[int, Dict[int, bytes]] = {}
        #   ^ stream -> {mrn: frozen buf}: post-copy source-side store

    # -- identifiers ---------------------------------------------------------
    def next_xid(self) -> int:
        self._xid += 1
        return self.device.gid * 1_000_000_000 + self._xid

    def next_stream(self) -> int:
        self._stream += 1
        return self.device.gid * 1_000_000_000 + self._stream

    # -- kernel QP rendezvous ------------------------------------------------
    def qp_for(self, peer_gid: int) -> QueuePair:
        """Kernel QP toward ``peer_gid``; first use performs the two-sided
        rendezvous (both devices create and connect their kernel QPs —
        the out-of-band exchange ordinary channels do 'over TCP')."""
        qp = self._peers.get(peer_gid)
        if qp is not None:
            return qp
        peer_dev = self.device.fabric.device(peer_gid)
        if peer_dev is None:
            raise ServiceError(f"no device at gid {peer_gid}")
        peer_svc = peer_dev.service
        mine = self.pd.create_qp(self.cq, self.cq)
        theirs = peer_svc.pd.create_qp(peer_svc.cq, peer_svc.cq)
        for qp_, dst_dev, dst_qp in ((mine, peer_dev, theirs),
                                     (theirs, self.device, mine)):
            qp_.modify(QPState.INIT)
            qp_.modify(QPState.RTR, dest_gid=dst_dev.gid,
                       dest_qpn=dst_qp.qpn, rq_psn=0)
            qp_.modify(QPState.RTS, sq_psn=0)
        self._peers[peer_gid] = mine
        peer_svc._peers[self.device.gid] = theirs
        return mine

    # -- transmit ------------------------------------------------------------
    def post(self, peer_gid: int, op: Op, meta: dict,
             data: bytes = b"") -> int:
        """Queue one service message (fire-and-forget); returns its xid."""
        xid = meta.setdefault("xid", self.next_xid())
        blob = msgpack.packb({"meta": meta, "data": data},
                             use_bin_type=True)
        # kernel-private scratch MR: built directly (never registered with
        # the device) so per-message buffers don't consume the node's
        # finite MRN namespace range or pollute the rkey index — it is
        # only ever read as a local SGE source
        mr = MemoryRegion(self.pd, len(blob), mrn=-1, lkey=0, rkey=0)
        mr.buf[:] = blob
        self._wr += 1
        wr = SendWR(self._wr, op, SGE(mr, 0, len(blob)))
        self._tx_mrs[self._wr] = (peer_gid, mr)
        self.qp_for(peer_gid).post_send(wr)
        fab = self.device.fabric
        trc = fab.tracer
        if trc is not None:
            trc.svc_post(fab.now, self.device.gid, peer_gid, op.value,
                         xid, len(blob))
        return xid

    def transfer(self, peer_gid: int, op: Op, meta: dict, data: bytes,
                 *, tick: Optional[Callable] = None,
                 max_steps: Optional[int] = None) -> int:
        """Stream one message and pump the fabric until the receiver's
        MIG_ACK receipt arrives — i.e. until the bytes have actually been
        serialised over the shared links, retransmissions included. The
        elapsed pump steps ARE the transfer time (``fabric.now`` delta)."""
        fabric = self.device.fabric
        xid = self.post(peer_gid, op, meta, data)
        if max_steps is None:
            # generous: 20x the no-contention serialisation time at the
            # slower end of the path — a bounded receiver ingress rate
            # (incast pressure, RNR backoff) caps the stream below the
            # egress port's rate, and the timeout must not fire on a
            # transfer that is making honest progress through it
            per_step = fabric.bytes_per_step
            rx_cap = fabric.ingress_capacity_Bps(peer_gid)
            if rx_cap is not None:
                per_step = min(per_step, rx_cap * fabric.step_s())
            ser = (len(data) + 4096) / max(per_step, 1e-9)
            max_steps = int(20 * ser) + 100_000
        if tick is None:
            # let the event scheduler skip the dead air between wire
            # events (RTO waits, latency pipes) instead of stepping it
            if fabric.pump_until(lambda: xid in self.acked, max_steps):
                self.acked.discard(xid)
                return xid
        else:
            # caller-supplied tick (containers stepping alongside): the
            # per-step loop is the contract
            for _ in range(max_steps):
                if xid in self.acked:
                    self.acked.discard(xid)
                    return xid
                tick()
        # the stream is hopeless: abort it. Leaving the WQE in place would
        # retransmit the image forever (the device never goes idle) and a
        # late delivery would orphan the blob in the receiver's inbox.
        self.reset_peer(peer_gid)
        peer_dev = fabric.device(peer_gid)
        if peer_dev is not None and peer_dev._service is not None:
            peer_dev._service.images.pop(xid, None)
        self.acked.discard(xid)
        raise ServiceError(
            f"service transfer xid={xid} not acked in {max_steps} steps")

    # -- receive (called from the responder via the device) ------------------
    def on_message(self, op: Op, blob: bytes, src_gid: int):
        msg = msgpack.unpackb(blob, raw=False, strict_map_key=False)
        meta, data = msg["meta"], msg["data"]
        fab = self.device.fabric
        trc = fab.tracer
        if trc is not None:
            trc.svc_deliver(fab.now, self.device.gid, src_gid, op.value,
                            len(blob))
        if op == Op.MIG_ACK:
            if trc is not None:
                trc.svc_ack(fab.now, self.device.gid, meta["ack"])
            self.acked.add(meta["ack"])
            return
        if op == Op.MIG_STATE:
            self.images[meta["xid"]] = data
        elif op == Op.MIG_PAGE:
            if not meta.get("postcopy"):
                # pre-copy staging: pages accumulate at the destination
                # until install applies them
                stage = self.staging.setdefault(meta["stream"], {})
                off = 0
                for mrn, pg, ln in meta["pages"]:
                    stage[(mrn, pg)] = data[off:off + ln]
                    off += ln
            # post-copy pulls were already applied synchronously at the
            # destination MR; the stream only accounts for the wire cost
        if not meta.get("noack"):
            self.post(src_gid, Op.MIG_ACK, {"ack": meta["xid"]})

    def take_image(self, xid: int) -> bytes:
        try:
            return self.images.pop(xid)
        except KeyError:
            raise ServiceError(f"no delivered image for xid {xid}") from None

    def take_staging(self, stream: int) -> Dict[Tuple[int, int], bytes]:
        return self.staging.pop(stream, {})

    def discard_stream(self, stream: int):
        """Release any staged pages / frozen stores a dead migration
        attempt left behind (rollback path)."""
        self.staging.pop(stream, None)
        self.page_store.pop(stream, None)

    def reset_peer(self, peer_gid: int):
        """Tear down the kernel QP pair toward a peer (both ends) after a
        dead stream; the next message performs a fresh rendezvous. PSN
        state is abandoned with the QPs, so no go-back-N gap survives."""
        sides = [(self, peer_gid)]
        peer_dev = self.device.fabric.device(peer_gid)
        if peer_dev is not None and peer_dev._service is not None:
            sides.append((peer_dev._service, self.device.gid))
        for svc, gid in sides:
            qp = svc._peers.pop(gid, None)
            if qp is not None:
                qp.sq.clear()
                qp.inflight.clear()
                qp.pending_comp.clear()
                qp.rx.clear()
                qp.cur_wqe = None
                svc.device.destroy_qp(qp.qpn)
            svc._tx_mrs = {w: (g, mr) for w, (g, mr)
                           in svc._tx_mrs.items() if g != gid}

    # -- housekeeping --------------------------------------------------------
    def reap(self):
        """Drop scratch MRs whose send completed (runs every pump); the
        buffers were never device-registered, so releasing the reference
        is the whole teardown."""
        for wc in self.cq.poll(64):
            self._tx_mrs.pop(wc.wr_id, None)

    @property
    def tx_backlog(self) -> int:
        return len(self._tx_mrs)
