"""QP state machine (paper Fig. 4).

Standard IB verbs states: Reset, Init, RTR, RTS, SQD, SQE, Error.
MigrOS adds two states invisible to the user application:        # [MIGR]
  * STOPPED — set by ``dump_context``; the QP neither sends nor receives;
    incoming packets are answered with NAK_STOPPED and dropped.   # [MIGR]
  * PAUSED  — entered when the partner QP reports STOPPED; sending is
    suspended until a RESUME message re-addresses the connection. # [MIGR]
"""
from __future__ import annotations

import enum


class QPState(enum.Enum):
    RESET = "RESET"
    INIT = "INIT"
    RTR = "RTR"          # ready to receive
    RTS = "RTS"          # ready to send
    SQD = "SQD"          # send queue drain
    SQE = "SQE"          # send queue error
    ERROR = "ERROR"
    STOPPED = "STOPPED"  # [MIGR] checkpoint side
    PAUSED = "PAUSED"    # [MIGR] partner side


# Transitions available to the *user application* via modify_qp
# (paper: normal states/transitions).
USER_TRANSITIONS = {
    (QPState.RESET, QPState.INIT),
    (QPState.INIT, QPState.RTR),
    (QPState.RTR, QPState.RTS),
    (QPState.RTS, QPState.SQD),
    (QPState.SQD, QPState.RTS),
    # any state can be torn down to RESET or ERROR by the user
}

# Transitions driven by the OS / NIC.
SYSTEM_TRANSITIONS = {
    (QPState.RTS, QPState.ERROR),
    (QPState.RTR, QPState.ERROR),
    (QPState.RTS, QPState.SQE),
    (QPState.RTS, QPState.STOPPED),    # [MIGR] dump_context
    (QPState.RTR, QPState.STOPPED),    # [MIGR]
    (QPState.SQD, QPState.STOPPED),    # [MIGR]
    (QPState.RTS, QPState.PAUSED),     # [MIGR] partner saw NAK_STOPPED
    (QPState.PAUSED, QPState.RTS),     # [MIGR] resume received
    (QPState.STOPPED, QPState.RESET),  # [MIGR] destroyed with checkpoint
    (QPState.STOPPED, QPState.RTS),    # [MIGR] orchestrator rollback of an
                                       #        aborted migration: the QP
                                       #        was never destroyed, so it
                                       #        re-arms in place and sends
                                       #        RESUME to un-pause peers
}


class InvalidTransition(Exception):
    pass


def check_transition(cur: QPState, new: QPState, *, system: bool = False):
    if new in (QPState.RESET, QPState.ERROR) and not system:
        return  # user may always tear down
    table = SYSTEM_TRANSITIONS if system else USER_TRANSITIONS
    if (cur, new) not in table:
        raise InvalidTransition(f"{cur.value} -> {new.value} "
                                f"({'system' if system else 'user'})")


def can_send(state: QPState) -> bool:
    return state == QPState.RTS


def can_receive(state: QPState) -> bool:
    return state in (QPState.RTR, QPState.RTS, QPState.SQD)
