"""Requester / responder / completer QP tasks (paper Fig. 6).

These three tasks are what a hardware RoCEv2 NIC implements in silicon, so
changes here "directly translate to hardware changes" (paper §5.1). The
migration additions on the *fast path* are single-branch checks marked
# [MIGR]; everything else migration-related runs only while a connection is
actually migrating — mirroring the paper's minimal-changes claim, which
``benchmarks/table1_sloc.py`` quantifies.
"""
from __future__ import annotations

from repro.core.packets import NakCode, Op, Packet
from repro.core.qos import (CLASS_APP, MIN_BUCKET_BYTES, CongestionControl,
                            classify)
from repro.core.states import QPState, can_receive, can_send

_FAR = float("inf")


def _wc(*args, **kw):
    from repro.core.verbs import WorkCompletion
    return WorkCompletion(*args, **kw)


def _success():
    from repro.core.verbs import WCStatus
    return WCStatus.SUCCESS


def _emit(qp, pkt: Packet):
    qp.device.fabric.send(pkt)


def _retx(qp, pkt: Packet, reason: str = "rto"):
    """Retransmit: headers are rebuilt from the *current* QP context —
    after a partner migration the stored packet's address is stale and the
    resume handshake has updated qp.dest_*."""                 # [MIGR]
    pkt.src_gid, pkt.src_qpn = qp.device.gid, qp.qpn             # [MIGR]
    pkt.dest_gid, pkt.dest_qpn = qp.dest_gid, qp.dest_qpn        # [MIGR]
    # ECN codepoints are per-transmission: a CE mark belongs to the
    # previous traversal's queues, and ECT tracks the current config
    pkt.ect = qp.device.fabric.ecn.enabled and not pkt.op.is_ctrl
    pkt.ce = False
    # DCQCN paces the QP's *entire* egress, retransmissions included —
    # but go-back-N must stay atomic (a partially retransmitted window
    # needs cursor state and re-ordering care), so retransmits overdraw
    # the pacing bucket instead of waiting on it: the window goes out
    # now, and fresh sends stall until the debt repays at rc. Long-run
    # rate honors the reaction point either way. The enabled gate makes
    # a runtime configure_ecn(disabled) take effect immediately: stale
    # rate state goes fully dormant, as the Fabric docstring promises.
    if qp.cc is not None and qp.device.fabric.ecn.enabled:
        qp.cc.tokens -= pkt.nbytes()
    # Karn's algorithm: a retransmitted PSN yields no RTT sample (the
    # eventual ACK is ambiguous between the two transmissions)
    qp._send_time.pop(pkt.psn, None)
    trc = qp.device.fabric.tracer
    if trc is not None:
        trc.retransmit(qp.device.fabric.now, pkt, qp.device.gid,
                       qp.qpn, reason)
    qp.device.fabric.send(pkt)


def _mk(qp, op, **kw) -> Packet:
    dev = qp.device
    return Packet(op=op, src_gid=dev.gid, src_qpn=qp.qpn,
                  dest_gid=qp.dest_gid, dest_qpn=qp.dest_qpn,
                  tenant=qp.tenant,
                  # ECT on data ops only: control must never be marked
                  # (a CE'd ACK could only ask the victim to slow down)
                  ect=(dev.fabric.ecn.enabled and not op.is_ctrl),
                  **kw)


def _ensure_cc(qp) -> "CongestionControl":
    """Reaction-point rate state, created lazily under an ECN-enabled
    fabric (None otherwise — the ECN-off fast path carries no state)."""
    fab = qp.device.fabric
    if not fab.ecn.enabled:
        return None
    if qp.cc is None:
        qp.cc = CongestionControl(fab.ecn, fab.bytes_per_step, fab.now,
                                  fab.step_s())
    return qp.cc


# ---------------------------------------------------------------------------
# Requester: turns send WQEs into packets (go-back-N window)
# ---------------------------------------------------------------------------


def requester(qp):
    """Send-side admission pipeline. Every fresh packet passes, in this
    order and in this one place: (1) the migration gates (PAUSED /
    resume handshake), (2) the recovery gates (RNR parking + whole-
    window resend, RTO go-back-N), (3) the go-back-N window budget,
    (4) DCQCN rate admission (``qp.cc``, ECN-enabled fabrics only) — all
    ahead of the egress port's per-tenant token bucket, which shapes
    whatever this pipeline admits. Retransmissions bypass (3)/(4): they
    re-offer bytes the window already admitted, and their pacing is the
    RTO/min_rnr_timer backoff itself."""
    fab = qp.device.fabric
    now = fab.now
    if qp.cc is not None and fab.ecn.enabled:
        # run the DCQCN timers even while parked or blocked: rate
        # recovery is wall-clock (step-clock) driven, not send-driven
        qp.cc.advance(now, fab.bytes_per_step)
    if not _migration_gate(qp, now):
        return
    if not _recovery_gate(qp, now):
        return
    _admit_fresh(qp, now)


def _migration_gate(qp, now) -> bool:
    """False while migration state machinery owns the send side."""
    if qp.state == QPState.PAUSED:                              # [MIGR]
        return False                                            # [MIGR]
    if qp.resume_pending and qp.state == QPState.RTS:           # [MIGR]
        # retried until the partner's RESUME_ACK arrives        # [MIGR]
        if now - qp.last_resume_tx >= qp.RETRANS_TIMEOUT:       # [MIGR]
            _emit(qp, _mk(qp, Op.RESUME, psn=qp.una))           # [MIGR]
            qp.last_resume_tx = now                             # [MIGR]
        return False                                            # [MIGR]
    return can_send(qp.state)


def _recovery_gate(qp, now) -> bool:
    """False while loss/not-ready recovery owns the send side: RNR
    parking, the post-backoff whole-window resend, and RTO go-back-N
    all suppress fresh sends for this step."""
    # receiver-not-ready backoff (IBA): an RNR NAK parks the whole send
    # side — no fresh packets, no timeout retransmission — until the
    # min_rnr_timer expires, then the *whole unacknowledged window*
    # (inflight starts at una) retransmits. Resuming at the NAK's PSN
    # instead would livelock: under incast the first-dropped PSN the NAK
    # reports can sit ahead of packets the receiver never got, and
    # go-back-N must never skip past una.
    if now < qp.rnr_wait_until:
        return False
    if qp.rnr_resend_pending:
        # NIC self-awareness: while the previous window is still
        # serialising on our own egress port, queueing another copy
        # would only grow a standing queue of duplicates (the RNR NAKs
        # arrive long before a 64-packet window clears a slow port) —
        # hold the retransmission until the port drains this flow. The
        # flow is shared with co-located QPs toward the same peer, so
        # the deferral is bounded by the RTO: a neighbor's standing
        # backlog must not park this QP forever.
        fl = qp.device.fabric.port(qp.device.gid).flows.get(qp.dest_gid)
        if (fl is not None and fl.queued_bytes > 0
                and now - qp.last_progress <= qp.rto):
            return False
        # DCQCN: hold the whole-window retransmit while the pacing
        # bucket is repaying overdraft — re-offering 30+ KiB into a
        # queue that just RNR'd us is exactly the storm rate control
        # exists to prevent. Bounded: the debt repays at rc, and rc is
        # floored at min_rate. Holding also protects the rnr_retry
        # budget (no retransmit -> no fresh NAK -> no charge).
        if qp.cc is not None and qp.cc.tokens < 0 \
                and qp.device.fabric.ecn.enabled:
            return False
        for p in qp.inflight:
            _retx(qp, p, "rnr")
        qp.rnr_resend_pending = False
        qp.last_progress = now
        return False
    # retransmit on timeout (go-back-N); back the timer off so a slow,
    # contended link is not flooded with duplicate windows
    if qp.inflight and now - qp.last_progress > qp.rto:
        if qp.cc is not None and qp.cc.tokens < 0 \
                and qp.device.fabric.ecn.enabled:
            return False        # paced: hold go-back-N, don't back off
        for pkt in qp.inflight:
            _retx(qp, pkt, "rto")
        qp.last_progress = now
        qp.rto = min(qp.rto * 2, qp.MAX_RTO)   # RFC 6298 §5.5 backoff
        return False
    return True


def _admit_fresh(qp, now):
    """Fresh-packet admission: window budget, then the DCQCN pacing
    bucket per packet. The rate check sits *before* the bytes reach the
    fabric so an over-limit QP leaves its WQE queued (no duplicate
    state to unwind), and the egress port's tenant bucket still applies
    downstream."""
    inflight = qp.inflight
    budget = qp.WINDOW - len(inflight)
    if budget > 0 and (qp.sq or qp.cur_wqe is not None):
        cc = _ensure_cc(qp)
    else:
        cc = None
    fab_send = qp.device.fabric.send
    send_time = qp._send_time
    while budget > 0:
        if qp.cur_wqe is None:
            if not qp.sq:
                return
            qp.cur_wqe = qp.sq.popleft()
            qp.cur_wqe.first_psn = qp.sq_psn
        wr = qp.cur_wqe
        if wr.opcode == Op.READ_REQ:
            # a READ's wire cost is dominated by the *response* the
            # request elicits — pace injection by it, or READ-driven
            # congestion would be invisible to the reaction point (the
            # responder emits READ_RESP unpaced; the reader is the
            # congestion source and the only paceable end)
            n = 64 + 64 + wr.sge.length
            if cc is not None and not cc.admit(n):
                return              # paced: request stays queued
            pkt = _mk(qp, Op.READ_REQ, psn=qp.sq_psn, raddr=wr.raddr,
                      rkey=wr.rkey, length=wr.sge.length, wr_id=wr.wr_id)
            wr.last_psn = qp.sq_psn
            qp.sq_psn += 1
            inflight.append(pkt)
            send_time[pkt.psn] = now    # RTT stamp (RFC 6298 §3)
            fab_send(pkt)               # _emit, inlined
            if cc is not None:
                cc.on_send(n)
            qp.pending_comp.append((wr.last_psn, wr.wr_id, "READ",
                                    wr.sge.length))
            qp.cur_wqe = None
            budget -= 1
            continue
        chunk = min(qp.MTU, wr.sge.length - wr.sent)
        if cc is not None and not cc.admit(64 + chunk):
            return                  # paced: resumes as tokens refill
        payload = wr.sge.mr.read(wr.sge.offset + wr.sent, chunk)
        first = wr.sent == 0
        last = wr.sent + chunk >= wr.sge.length
        pkt = _mk(qp, wr.opcode, psn=qp.sq_psn, payload=payload,
                  first=first, last=last, wr_id=wr.wr_id,
                  raddr=wr.raddr + wr.sent, rkey=wr.rkey,
                  length=wr.sge.length)
        wr.sent += chunk
        wr.last_psn = qp.sq_psn
        qp.sq_psn += 1
        inflight.append(pkt)
        send_time[pkt.psn] = now        # RTT stamp (RFC 6298 §3)
        fab_send(pkt)                   # _emit, inlined
        if cc is not None:
            cc.on_send(64 + chunk)
        budget -= 1
        if last:
            qp.pending_comp.append((wr.last_psn, wr.wr_id,
                                    wr.opcode.value, wr.sge.length))
            qp.cur_wqe = None


# ---------------------------------------------------------------------------
# Wake calculator: the step at which this QP's task triple must run again
# ---------------------------------------------------------------------------


def _head_need(qp):
    """Pacing-bucket charge of the next fresh packet ``_admit_fresh``
    will offer — mirrors its charging rules exactly (READ by elicited
    response size; else header + next chunk, honoring a restored WR's
    partial ``sent`` cursor)."""
    wr = qp.cur_wqe if qp.cur_wqe is not None else qp.sq[0]
    if wr.opcode == Op.READ_REQ:
        return 64 + 64 + wr.sge.length
    return 64 + min(qp.MTU, wr.sge.length - wr.sent)


def _pacing_wake(qp, cc, now):
    """Earliest step at which the DCQCN bucket could admit the head
    packet, from the refill arithmetic ``advance`` will replay (rate
    ``rc`` per step, capped). Deliberately rounds *down* (plus a one-
    step safety margin against float drift): a spurious early wake
    re-runs admission and re-parks; a late one would stall the flow."""
    cap = cc.cfg.burst_bytes
    if cap < MIN_BUCKET_BYTES:
        cap = MIN_BUCKET_BYTES
    need = _head_need(qp)
    if need > cap:
        need = cap
    # materialise the bucket as advance(now) would leave it: rc is
    # constant over the stale interval (any rate event would have run
    # the triple and re-stamped ``last``)
    tokens = cc.tokens + (now - cc.last) * cc.rc
    if tokens > cap:
        tokens = cap
    if tokens >= need or cc.rc <= 0:
        return now + 1
    k = int((need - tokens) / cc.rc) - 1
    if k < 1:
        k = 1
    return now + k


def next_wake(qp, now):
    """Earliest future step at which running this QP's triple could do
    anything — the event scheduler parks the QP until then. Mirrors the
    requester's gate order exactly; every estimate rounds down and the
    caller clamps to ``now + 1``, so errors are only ever *early*
    (trajectory-safe no-op runs), never late.

    DCQCN alpha/increase boundaries are folded in unconditionally
    (before any state gate): the per-step model ran ``cc.advance`` every
    step even while PAUSED/STOPPED, and end-of-run reads (``fig_ecn``'s
    ``cc.rc``, ``cc.dump``) must observe rate state materialised through
    every boundary, not just through the last packet event."""
    if qp.rx:
        return now + 1          # queued packets: responder/completer work
    fab = qp.device.fabric
    wake = _FAR
    cc = qp.cc
    if cc is not None and fab.ecn.enabled:
        b = cc.alpha_last + cc.cfg.alpha_timer
        if b < wake:
            wake = b
        b = cc.incr_last + cc.cfg.increase_timer
        if b < wake:
            wake = b
    st = qp.state
    if st == QPState.PAUSED or st == QPState.STOPPED:
        return wake             # unparked by packets/modify, not time
    if qp.resume_pending and st == QPState.RTS:
        b = qp.last_resume_tx + qp.RETRANS_TIMEOUT
        return b if b < wake else wake
    if not can_send(st):
        return wake
    if now < qp.rnr_wait_until:
        b = qp.rnr_wait_until
        return b if b < wake else wake
    if qp.rnr_resend_pending:
        return now + 1          # deferral re-evaluated every step
    if qp.inflight:
        # retransmit fires when now - last_progress > rto (rto is a
        # float); once due but held by pacing debt, the clamp downstream
        # yields every-step wakes until the debt repays
        b = int(qp.last_progress + qp.rto) + 1
        if b < wake:
            wake = b
    if (qp.sq or qp.cur_wqe is not None) and len(qp.inflight) < qp.WINDOW:
        if cc is None or not fab.ecn.enabled:
            return now + 1      # sendable head, no pacing: run now
        b = _pacing_wake(qp, cc, now)
        if b < wake:
            wake = b
    return wake


# ---------------------------------------------------------------------------
# Responder: consumes request packets, ACKs, fills RRs / MRs
# ---------------------------------------------------------------------------


def _note_congestion(qp, pkt: Packet):
    """DCQCN notification point: a Congestion-Experienced arrival draws
    a CNP back at the sender — coalesced to one per ``cnp_interval``
    steps per QP, the way real NPs rate-limit CNP generation so a
    marked burst does not become a CNP storm. Runs for duplicates too:
    a CE'd duplicate still crossed the congested queue."""
    fab = qp.device.fabric
    if not fab.ecn.enabled:
        return
    now = fab.now
    if now < qp.cnp_mute_until:
        return
    qp.cnp_mute_until = now + fab.ecn.cnp_interval
    qp.cnps_sent += 1
    cls = classify(pkt)
    fab.metrics.inc("cnps_sent", gid=qp.device.gid, cls=cls)
    trc = fab.tracer
    if trc is not None:
        trc.cnp_sent(now, qp.device.gid, qp.qpn, cls)
    _emit(qp, _mk(qp, Op.CNP, psn=pkt.psn, ecn_class=cls))


def responder(qp):
    rx = qp.rx
    n = len(rx)
    if not n:
        return
    stopped = QPState.STOPPED
    dev = qp.device
    fab_send = dev.fabric.send
    for _ in range(n):
        pkt = rx.popleft()
        op = pkt.op
        if op.is_completer:
            rx.append(pkt)            # completer-class packet; requeue
            continue
        # qp.state re-read per packet: a service message mid-loop can
        # transition the QP (migration stop/restore)
        if qp.state == stopped:                                  # [MIGR]
            _emit(qp, _mk(qp, Op.NAK, psn=qp.epsn,               # [MIGR]
                          nak_code=NakCode.STOPPED))             # [MIGR]
            continue                                             # [MIGR]
        if not can_receive(qp.state):
            continue
        if pkt.ce and pkt.ect:                                   # [ECN]
            _note_congestion(qp, pkt)                            # [ECN]
        if pkt.psn != qp.epsn:
            if pkt.psn < qp.epsn:   # duplicate: re-ack, drop
                _emit(qp, _mk(qp, Op.ACK, psn=qp.epsn - 1))
            elif qp.rnr_nak_sent:
                # receiver-not-ready window: the RNR NAK for epsn already
                # told the sender to back off and retransmit from there;
                # the rest of its in-flight window is dropped *silently*
                # — a PSN_SEQ_ERR here would trigger immediate go-back-N
                # and defeat the min_rnr_timer backoff. Deliberately does
                # not touch last_nak_epsn: a later genuine loss gap still
                # gets its one sequence NAK.
                pass
            elif qp.last_nak_epsn != qp.epsn:   # one NAK per gap (RoCE)
                qp.last_nak_epsn = qp.epsn
                fab = qp.device.fabric
                fab.metrics.inc("psn_naks", gid=qp.device.gid)
                trc = fab.tracer
                if trc is not None:
                    trc.psn_nak(fab.now, qp.device.gid, qp.qpn, qp.epsn)
                _emit(qp, _mk(qp, Op.NAK, psn=qp.epsn,
                              nak_code=NakCode.PSN_SEQ_ERR))
            continue
        if op.is_mig:
            # service-channel message (kernel QPs only): same PSN/ACK
            # discipline as SEND, but the payload reassembles into the
            # device's service inbox instead of consuming an RR.  # [MIGR]
            if pkt.first:
                qp.svc_assembly = bytearray()
            qp.svc_assembly += pkt.payload
            qp.epsn += 1
            qp.last_nak_epsn = -1
            # _mk(qp, Op.ACK, psn=pkt.psn), spelled out: one ACK per
            # delivered data packet, and ect is always False on control
            fab_send(Packet(Op.ACK, dev.gid, qp.qpn, qp.dest_gid,
                            qp.dest_qpn, pkt.psn, tenant=qp.tenant))
            if pkt.last:
                qp.device.on_service_message(pkt.op,
                                             bytes(qp.svc_assembly),
                                             pkt.src_gid)
                qp.svc_assembly = bytearray()
        elif op is Op.SEND:
            if pkt.first and qp.cur_rr is None:
                qp.cur_rr = qp.next_rr()
            rr = qp.cur_rr
            if rr is None:
                # RNR: no receive posted yet (IBA §9.7.5.2.8) — a *true*
                # receiver-not-ready NAK, not a sequence error: the
                # sender waits min_rnr_timer, charges its rnr_retry
                # budget, and retransmits from this PSN. Only the
                # expected-PSN packet reaches here, so each retry attempt
                # draws exactly one fresh NAK; the rest of the sender's
                # window is silently dropped above via rnr_nak_sent.
                qp.rnr_nak_sent = True
                fab = qp.device.fabric
                fab.metrics.inc("rnr_naks", gid=qp.device.gid)
                trc = fab.tracer
                if trc is not None:
                    trc.rnr_nak(fab.now, qp.device.gid, "responder",
                                qp.dest_gid, qp.dest_qpn, qp.epsn)
                _emit(qp, _mk(qp, Op.NAK, psn=qp.epsn,
                              nak_code=NakCode.RNR))
                continue
            qp.rnr_nak_sent = False
            rr.sge.mr.write(rr.sge.offset + rr.received, pkt.payload)
            rr.received += len(pkt.payload)
            qp.epsn += 1
            qp.last_nak_epsn = -1
            # _mk(qp, Op.ACK, psn=pkt.psn), spelled out: one ACK per
            # delivered data packet, and ect is always False on control
            fab_send(Packet(Op.ACK, dev.gid, qp.qpn, qp.dest_gid,
                            qp.dest_qpn, pkt.psn, tenant=qp.tenant))
            if pkt.last:
                qp.recv_cq.push(_wc(rr.wr_id, _success(), "RECV",
                                    rr.received, qp.qpn))
                qp.cur_rr = None
        elif op is Op.WRITE:
            mr = qp.device.rkey_lookup(pkt.rkey)
            if mr is None:
                _emit(qp, _mk(qp, Op.NAK, psn=qp.epsn,
                              nak_code=NakCode.INVALID_RKEY))
                continue
            # Responder-side delivery dirties the page bitmap (and faults
            # in post-copy pages) inside MemoryRegion.write — pre-copy sees
            # remote RDMA WRITEs exactly like local stores.        # [MIGR]
            mr.write(pkt.raddr, pkt.payload)
            qp.epsn += 1
            qp.last_nak_epsn = -1
            # _mk(qp, Op.ACK, psn=pkt.psn), spelled out: one ACK per
            # delivered data packet, and ect is always False on control
            fab_send(Packet(Op.ACK, dev.gid, qp.qpn, qp.dest_gid,
                            qp.dest_qpn, pkt.psn, tenant=qp.tenant))
        elif op is Op.READ_REQ:
            mr = qp.device.rkey_lookup(pkt.rkey)
            if mr is None:
                _emit(qp, _mk(qp, Op.NAK, psn=qp.epsn,
                              nak_code=NakCode.INVALID_RKEY))
                continue
            qp.epsn += 1
            data = mr.read(pkt.raddr, pkt.length)
            _emit(qp, _mk(qp, Op.READ_RESP, psn=pkt.psn, payload=data,
                          wr_id=pkt.wr_id))


# ---------------------------------------------------------------------------
# Completer: processes ACK/NAK (+ resume) and posts send completions
# ---------------------------------------------------------------------------


def _handle_rnr_nak(qp, pkt: Packet):
    """Receiver-not-ready NAK: charge the retry budget, arm the
    min_rnr_timer backoff, and mark where retransmission restarts. One
    charge per not-ready episode — NAKs landing while the backoff is
    already armed are the same episode (a burst of ingress-overflow NAKs
    from one congested receiver), not fresh attempts."""
    now = qp.device.fabric.now
    if now < qp.rnr_wait_until:
        return
    if qp.rnr_retry != 7:               # IBA: rnr_retry=7 -> retry forever
        qp.rnr_tries += 1
        if qp.rnr_tries > qp.rnr_retry:
            _rnr_retry_exhausted(qp)
            return
    qp.rnr_wait_until = now + qp.min_rnr_timer
    qp.rnr_resend_pending = True
    # DCQCN: receiver-not-ready IS a congestion event — the severe one.
    # A flow whose packets drop at the ingress queue never sees CE
    # marks (they ride *delivered* packets), so the RNR NAK is its only
    # feedback; cut the reaction point like a CNP would.        # [ECN]
    # On a lossless (PFC) fabric nothing overflows, so an RNR NAK here
    # is spurious — a straggler from before configure_pfc, or replayed
    # out of a pre-PFC dump. Every delivered packet still earns CE/CNP
    # feedback, and cutting on top of that would double-punish the flow
    # below its CNP-derived rate: the cut path is inert.        # [PFC]
    fab = qp.device.fabric
    cc = _ensure_cc(qp)
    if cc is not None and not fab.pfc.enabled:
        cc.advance(now, fab.bytes_per_step)
        cc.cut(now)
        trc = fab.tracer
        if trc is not None:
            trc.rate_change(now, qp.device.gid, qp.qpn, cc.rc, cc.rt,
                            cc.alpha, "rnr")
    # Karn across the pause: ACKs of anything outstanding are ambiguous
    # once the window will be retransmitted
    qp._send_time.clear()


def _rnr_retry_exhausted(qp):
    """IBA retry exhaustion: the QP transitions to ERROR, the WQE whose
    request kept drawing RNR completes with an RNR-retry-exceeded CQE,
    and everything behind it flushes — the application *sees* the error
    instead of hanging on a peer that will never post a receive."""
    from repro.core.verbs import WCStatus
    if qp.state == QPState.RTS:
        qp.modify(QPState.ERROR, system=True)
    else:                               # defensive: exhaustion mid-drain
        qp.state = QPState.ERROR
    qp.device.fabric.metrics.inc("rnr_retries_exhausted",
                                 gid=qp.device.gid)
    status = WCStatus.RNR_RETRY_EXC_ERR
    while qp.pending_comp:
        _, wr_id, opcode, blen = qp.pending_comp.popleft()
        qp.send_cq.push(_wc(wr_id, status, opcode, blen, qp.qpn))
        status = WCStatus.WR_FLUSH_ERR
    if qp.cur_wqe is not None:
        qp.send_cq.push(_wc(qp.cur_wqe.wr_id, status,
                            qp.cur_wqe.opcode.value,
                            qp.cur_wqe.sge.length, qp.qpn))
        status = WCStatus.WR_FLUSH_ERR
        qp.cur_wqe = None
    while qp.sq:
        wr = qp.sq.popleft()
        qp.send_cq.push(_wc(wr.wr_id, WCStatus.WR_FLUSH_ERR,
                            wr.opcode.value, wr.sge.length, qp.qpn))
    qp.inflight.clear()
    qp._send_time.clear()
    qp.rnr_resend_pending = False
    qp.rnr_wait_until = -1


def _handle_cnp(qp, pkt: Packet):
    """DCQCN reaction point: multiplicative decrease of the send rate,
    alpha update, and a reset of the increase machinery.

    A CNP reports a *delivered* (CE-marked) packet, not a loss, so the
    RTO machinery is deliberately untouched: no backoff, no
    ``last_progress`` rewind, and — the Karn interaction — no eviction
    of ``_send_time`` stamps. The marked packet's ACK still yields an
    RTT sample (tests/test_ecn.py pins this; clearing the stamps here
    would starve the RTO estimator exactly when queues are building and
    its samples matter most)."""
    fab = qp.device.fabric
    cc = _ensure_cc(qp)
    if cc is None:
        return                  # ECN disabled: stray CNP ignored
    cc.advance(fab.now, fab.bytes_per_step)
    cc.on_cnp(fab.now)
    cls = pkt.ecn_class if pkt.ecn_class is not None else CLASS_APP
    fab.metrics.inc("cnps_handled", gid=qp.device.gid, cls=cls)
    trc = fab.tracer
    if trc is not None:
        trc.cnp_handled(fab.now, qp.device.gid, qp.qpn, cls)
        trc.rate_change(fab.now, qp.device.gid, qp.qpn, cc.rc, cc.rt,
                        cc.alpha, "cnp")


def _rtt_sample(qp, sample: float):
    """RFC 6298 §2 update: first sample seeds SRTT/RTTVAR, later samples
    blend with alpha=1/8, beta=1/4; RTO = SRTT + max(G, 4*RTTVAR) with
    clock granularity G = 1 fabric step, clamped to [MIN_RTO, MAX_RTO]."""
    if qp.srtt is None:
        qp.srtt = sample
        qp.rttvar = sample / 2.0
    else:
        qp.rttvar = 0.75 * qp.rttvar + 0.25 * abs(qp.srtt - sample)
        qp.srtt = 0.875 * qp.srtt + 0.125 * sample
    qp.rto = min(max(qp.srtt + max(1.0, 4.0 * qp.rttvar), qp.MIN_RTO),
                 qp.MAX_RTO)


def _ack_up_to(qp, psn: int):
    now = qp.device.fabric.now
    # RTT sample from the cumulative-ACK edge (Karn: only if that PSN was
    # never retransmitted), BEFORE the per-PSN bookkeeping is released
    send_time = qp._send_time
    t_sent = send_time.get(psn)
    if t_sent is not None:
        _rtt_sample(qp, now - t_sent)
    inflight = qp.inflight
    while inflight and inflight[0].psn <= psn:
        p = inflight.popleft()
        send_time.pop(p.psn, None)
    if psn >= qp.una:
        qp.una = psn + 1
        qp.last_progress = now
        qp.rnr_tries = 0    # fresh progress re-arms the RNR retry budget
        # NOTE: a backed-off RTO is NOT reset on progress alone (RFC 6298
        # §5.7) — only a valid RTT sample re-prices it. Resetting here
        # re-armed a spurious-timeout limit cycle on deep-queue ports:
        # every fresh window queued behind the previous timeout's
        # duplicates, timed out again before its first ACK could cross,
        # and (Karn) no sample ever seeded the estimator.
    while qp.pending_comp and qp.pending_comp[0][0] <= psn:
        _, wr_id, opcode, blen = qp.pending_comp.popleft()
        qp.send_cq.push(_wc(wr_id, _success(), opcode, blen, qp.qpn))


def completer(qp):
    rx = qp.rx
    n = len(rx)
    if not n:
        return
    op_ack = Op.ACK
    for _ in range(n):
        pkt = rx.popleft()
        op = pkt.op
        if not op.is_completer:
            rx.append(pkt)
            continue
        if op is op_ack:
            _ack_up_to(qp, pkt.psn)
        elif op is Op.CNP:                                       # [ECN]
            _handle_cnp(qp, pkt)                                 # [ECN]
        elif op is Op.READ_RESP:
            if pkt.ce and pkt.ect:                               # [ECN]
                # a marked response: WE are the congestion source (our
                # READ_REQs elicit these bytes, and their admission is
                # charged at response size), so cut our own reaction
                # point directly — a CNP to the responder would throttle
                # a rate that never governs READ_RESP emission. Own mute
                # field: the NP's CNP coalescing must not suppress this
                # (or vice versa) on a bidirectional QP.
                cc = _ensure_cc(qp)
                if cc is not None and \
                        qp.device.fabric.now >= qp.rd_cut_mute_until:
                    qp.rd_cut_mute_until = (qp.device.fabric.now
                                            + qp.device.fabric.ecn
                                            .cnp_interval)
                    cc.advance(qp.device.fabric.now,
                               qp.device.fabric.bytes_per_step)
                    cc.cut(qp.device.fabric.now)
                    trc = qp.device.fabric.tracer
                    if trc is not None:
                        trc.rate_change(qp.device.fabric.now,
                                        qp.device.gid, qp.qpn, cc.rc,
                                        cc.rt, cc.alpha, "read")
            # single-MTU READ: find the pending read WR, deliver payload
            _ack_up_to(qp, pkt.psn)
        elif op is Op.NAK:
            if pkt.nak_code == NakCode.STOPPED:                  # [MIGR]
                if qp.state == QPState.RTS:                      # [MIGR]
                    qp.modify(QPState.PAUSED, system=True)       # [MIGR]
                # the pause is not a round trip: anything still
                # unsampled would otherwise yield an RTT sample the
                # size of the partner's downtime (Karn across pauses)
                qp._send_time.clear()
                # a pending RNR backoff dies with the pause: the resume
                # handshake retransmits the whole window anyway
                qp.rnr_wait_until = -1
                qp.rnr_resend_pending = False
                # drop everything in flight; resume retransmits   # [MIGR]
                continue                                         # [MIGR]
            if pkt.nak_code == NakCode.RNR:
                # receiver not ready: back off, do NOT go-back-N now —
                # an RNR NAK is not a sequence gap
                _handle_rnr_nak(qp, pkt)
                continue
            if qp.device.fabric.now < qp.rnr_wait_until:
                # sequence gaps reported while the receiver has us in
                # RNR backoff are fallout of the same overflow (packets
                # admitted behind the dropped one): the post-backoff
                # whole-window retransmission already covers the gap —
                # flooding the congested receiver now would only add
                # duplicates to its queue
                qp.rnr_resend_pending = True
                continue
            # go-back-N: retransmit from the requested psn
            for p in qp.inflight:
                if p.psn >= pkt.psn:
                    _retx(qp, p, "nak")
            qp.last_progress = qp.device.fabric.now
        elif op is Op.RESUME:                                    # [MIGR]
            # Partner migrated: learn its new address (the source of the
            # resume), leave PAUSED, ack the last packet we received.
            qp.dest_gid = pkt.src_gid                            # [MIGR]
            qp.dest_qpn = pkt.src_qpn                            # [MIGR]
            if qp.state == QPState.PAUSED:                       # [MIGR]
                qp.modify(QPState.RTS, system=True)              # [MIGR]
            _emit(qp, _mk(qp, Op.RESUME_ACK, psn=qp.epsn - 1))   # [MIGR]
        elif op is Op.RESUME_ACK:                                # [MIGR]
            qp.resume_pending = False                            # [MIGR]
            # pre-migration send stamps span the whole pause — not a
            # round trip; drop them so the cumulative ack below cannot
            # seed SRTT with the partner's downtime
            qp._send_time.clear()
            _ack_up_to(qp, pkt.psn)                              # [MIGR]
            for p in qp.inflight:                                # [MIGR]
                _retx(qp, p, "resume")                           # [MIGR]
            qp.last_progress = qp.device.fabric.now              # [MIGR]
