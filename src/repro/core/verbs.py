"""IB-verbs-style object model over the software fabric.

Objects mirror the paper's Fig. 2: Context > PD > {MR, QP(SQ,RQ), SRQ} with
CQs for completions. Numbers (QPN/MRN) are device-assigned sequentially;
``last_qpn``/``last_mrn`` expose the ns_last_pid-style restore mechanism
(paper §4.1).                                                   # [MIGR]
"""
from __future__ import annotations

import enum
import random
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

from repro.core import tasks as qptasks
from repro.core.packets import NakCode, Op, Packet
from repro.core.states import QPState, can_send, check_transition


PAGE_SIZE = 4096        # dirty-tracking / demand-paging granularity # [MIGR]

_WAKE_FAR = float("inf")    # parked: no armed deadline


class CQOverrunError(RuntimeError):
    """A completion was pushed into a full CQ. The wire already committed
    to this work (it was ACKed), so silently dropping it would lose
    acknowledged completions — surface the overrun instead."""


class WCStatus(enum.Enum):
    SUCCESS = "SUCCESS"
    LOC_LEN_ERR = "LOC_LEN_ERR"
    REM_ACCESS_ERR = "REM_ACCESS_ERR"
    WR_FLUSH_ERR = "WR_FLUSH_ERR"
    # the receiver kept answering RNR NAK past the QP's rnr_retry budget;
    # the QP is in ERROR and everything behind this WQE flushed
    RNR_RETRY_EXC_ERR = "RNR_RETRY_EXC_ERR"


@dataclass(slots=True)
class WorkCompletion:
    wr_id: int
    status: WCStatus
    opcode: str
    byte_len: int = 0
    qpn: int = 0


@dataclass(slots=True)
class AsyncEvent:
    """ibv_get_async_event-style affiliated event, delivered to the
    owning context's event queue (``Context.poll_async``)."""
    event_type: str                 # e.g. "SRQ_LIMIT_REACHED"
    srqn: Optional[int] = None


@dataclass(slots=True)
class SGE:
    mr: "MemoryRegion"
    offset: int
    length: int


@dataclass(slots=True)
class SendWR:
    wr_id: int
    opcode: Op                      # SEND / WRITE / READ_REQ
    sge: SGE
    raddr: int = 0
    rkey: int = 0
    # requester progress (dumped as part of "current WQE state")
    sent: int = 0
    first_psn: int = -1
    last_psn: int = -1


@dataclass(slots=True)
class RecvWR:
    wr_id: int
    sge: SGE
    received: int = 0


class MemoryRegion:
    def __init__(self, pd: "ProtectionDomain", size: int, mrn: int,
                 lkey: int, rkey: int):
        self.pd = pd
        self.ctx = pd.ctx           # owner back-pointer: O(1) teardown
        self.size = size
        self.mrn = mrn
        self.lkey = lkey
        self.rkey = rkey
        self.buf = bytearray(size)
        # Live-migration hooks. Both stay None outside an active migration
        # so the fast path pays one predictable branch per access. # [MIGR]
        self._dirty: Optional[set] = None   # page-granular dirty bitmap
        self.pager = None                   # post-copy demand pager

    @property
    def n_pages(self) -> int:
        return -(-self.size // PAGE_SIZE)

    # -- dirty tracking (pre-copy) ---------------------------------- # [MIGR]
    def start_dirty_tracking(self):
        self._dirty = set()

    def stop_dirty_tracking(self):
        self._dirty = None

    def collect_dirty(self, *, clear: bool = True) -> set:
        """Pages written since tracking started / was last cleared."""
        pages = set() if self._dirty is None else set(self._dirty)
        if clear and self._dirty is not None:
            self._dirty = set()
        return pages

    def write(self, off: int, data: bytes):
        if off + len(data) > self.size:
            raise IndexError("MR overflow")
        if self.pager is not None:                               # [MIGR]
            self.pager.ensure(self, off, len(data))
        self.buf[off:off + len(data)] = data
        if self._dirty is not None and data:                     # [MIGR]
            self._dirty.update(range(off // PAGE_SIZE,
                                     (off + len(data) - 1) // PAGE_SIZE + 1))

    def read(self, off: int, length: int) -> bytes:
        if self.pager is not None:                               # [MIGR]
            self.pager.ensure(self, off, length)
        return bytes(self.buf[off:off + length])


class CompletionQueue:
    def __init__(self, cqn: int, depth: int = 4096):
        self.cqn = cqn
        self.depth = depth
        self.ring: Deque[WorkCompletion] = deque()
        self.head = 0                      # ring-buffer metadata (dumped)
        self.tail = 0
        self.overruns = 0

    def push(self, wc: WorkCompletion):
        if len(self.ring) >= self.depth:
            self.overruns += 1
            raise CQOverrunError(
                f"CQ {self.cqn} overrun: depth {self.depth} exceeded")
        self.ring.append(wc)
        self.tail += 1

    def poll(self, n: int = 1) -> List[WorkCompletion]:
        ring = self.ring
        if not ring:
            return []               # the common idle-app poll
        out = []
        while ring and len(out) < n:
            out.append(ring.popleft())
            self.head += 1
        return out


class SharedReceiveQueue:
    """SRQ with the ibv_modify_srq SRQ_LIMIT watermark: arming a limit
    makes the SRQ fire a one-shot ``SRQ_LIMIT_REACHED`` async event when
    the number of posted receives falls below it — the refill signal
    verbs promises applications sharing one receive pool. Re-arm with
    another ``modify`` call after handling the event (IBA semantics:
    the limit disarms when it fires)."""

    def __init__(self, srqn: int, ctx: Optional["Context"] = None):
        self.srqn = srqn
        self.ctx = ctx
        self.queue: Deque[RecvWR] = deque()
        self.limit = 0                  # watermark (0 = disarmed)
        self.armed = False

    def post(self, wr: RecvWR):
        self.queue.append(wr)

    def modify(self, *, srq_limit: int):
        """ibv_modify_srq(IBV_SRQ_LIMIT): arm the low-watermark. If the
        queue is already below the new limit the event fires
        immediately — the application asked to know, and waiting for
        one more consume would race the refill it wants to trigger."""
        if srq_limit < 0:
            raise ValueError("srq_limit must be >= 0")
        self.limit = srq_limit
        self.armed = srq_limit > 0
        if self.armed and len(self.queue) < self.limit:
            self._fire()

    def pop(self) -> Optional[RecvWR]:
        """Consume one posted receive (QP next_rr path), firing the
        armed watermark when consumption crosses below it."""
        if not self.queue:
            return None
        wr = self.queue.popleft()
        if self.armed and len(self.queue) < self.limit:
            self._fire()
        return wr

    def _fire(self):
        self.armed = False              # one-shot until re-armed
        if self.ctx is not None:
            self.ctx.events.append(
                AsyncEvent("SRQ_LIMIT_REACHED", srqn=self.srqn))


class QueuePair:
    MTU = 1024
    WINDOW = 64
    RETRANS_TIMEOUT = 200       # fabric steps: initial RTO (RFC 6298 §2.1)
    MIN_RTO = 8                 # floor for the adaptive timer
    MAX_RTO = 200 * 64          # backoff ceiling (the old x64 cap)

    def __init__(self, pd: "ProtectionDomain", qpn: int,
                 send_cq: CompletionQueue, recv_cq: CompletionQueue,
                 srq: Optional[SharedReceiveQueue] = None):
        self.pd = pd
        self.ctx = pd.ctx           # owner back-pointer: O(1) teardown
        self.device: "RdmaDevice" = pd.ctx.device
        self.qpn = qpn
        # QoS attribution: packets this QP emits are charged to the
        # owning context's tenant (the container name)          # [QOS]
        self.tenant: Optional[str] = pd.ctx.tenant
        self.send_cq = send_cq
        self.recv_cq = recv_cq
        self.srq = srq
        self.state = QPState.RESET
        # addressing
        self.dest_gid = -1
        self.dest_qpn = -1
        # requester
        self.sq: Deque[SendWR] = deque()
        self.cur_wqe: Optional[SendWR] = None
        self.sq_psn = 0                 # next PSN to assign
        self.una = 0                    # oldest unacknowledged PSN
        self.inflight: Deque[Packet] = deque()
        self.last_progress = 0
        # Adaptive retransmission timeout, RFC 6298-style: every ACK of a
        # never-retransmitted packet yields an RTT sample (Karn's
        # algorithm excludes retransmits) feeding SRTT/RTTVAR, and
        # RTO = SRTT + max(G, 4*RTTVAR) clamped to [MIN_RTO, MAX_RTO].
        # Uncontended links converge to a small RTO (fast loss recovery);
        # contended links see queueing delay in their samples and back
        # off, so go-back-N does not flood a slow port with duplicate
        # windows (congestion collapse). Timeout still doubles the RTO
        # until the next valid sample.
        self.rto = self.RETRANS_TIMEOUT
        self.srtt: Optional[float] = None
        self.rttvar: Optional[float] = None
        # psn -> first-tx step; a retransmit (or a migration pause)
        # evicts the entry, which IS Karn's exclusion: no stamp, no sample
        self._send_time: Dict[int, int] = {}
        self.pending_comp: Deque = deque()   # (last_psn, wr_id, opcode, len)
        # Receiver-not-ready (RNR) handling, IBA §9.7.5.2.8: an RNR NAK
        # (unposted receive at the responder, or ingress-queue overflow
        # at the destination NIC) parks the requester for min_rnr_timer
        # steps and charges rnr_retry; exhaustion moves the QP to ERROR
        # with an RNR_RETRY_EXC_ERR completion. rnr_retry=7 is the IBA
        # encoding for "retry forever" (the default, so transient
        # receiver pressure never errors a QP unless an operator asks).
        self.rnr_retry = 7
        self.min_rnr_timer = 64         # backoff per RNR NAK, in steps
        self.rnr_tries = 0              # episodes since the last progress
        self.rnr_wait_until = -1        # requester parked until this step
        self.rnr_resend_pending = False # retx whole window after the wait
        # responder
        self.rq: Deque[RecvWR] = deque()
        self.epsn = 0                   # next expected PSN
        self.last_nak_epsn = -1         # NAK suppression (one per gap)
        self.rnr_nak_sent = False       # in-window RNR mute (responder)
        self.cur_rr: Optional[RecvWR] = None
        self.rx: Deque[Packet] = deque()
        # DCQCN congestion control (repro.core.qos). ``cc`` is the
        # reaction-point rate state, created lazily on first send under
        # an ECN-enabled fabric (None otherwise: the fast path pays one
        # branch, and the wire model is byte-identical with ECN off);
        # the notification-point side is the CNP coalescing mute plus a
        # counter that migrates with the QP.                      # [ECN]
        self.cc = None                  # CongestionControl | None
        self.cnp_mute_until = -1        # NP: one CNP per cnp_interval
        self.rd_cut_mute_until = -1     # reader self-cut coalescing —
        #   separate from the NP mute: on a bidirectional QP the two
        #   congestion paths must not suppress each other
        self.cnps_sent = 0              # NP counter (dumped/restored)
        # migration                                              # [MIGR]
        self.resume_pending = False     # REFILL queues a resume  # [MIGR]
        self.last_resume_tx = -10**9    # resume retry timer      # [MIGR]
        self.svc_assembly = bytearray() # service-msg reassembly  # [MIGR]
        # event scheduler: earliest step at which the task triple could
        # do work (repro.core.tasks.next_wake). 0 = run at next pump;
        # refreshed after every run and forced down by the wake hooks
        # (receive/post_send/modify) — never allowed to be late.
        self._wake = 0

    # -- user API --------------------------------------------------------------
    def modify(self, new_state: QPState, *, dest_gid: int = None,
               dest_qpn: int = None, rq_psn: int = None, sq_psn: int = None,
               system: bool = False):
        check_transition(self.state, new_state, system=system)
        if new_state == QPState.RTR:
            if dest_gid is not None:
                self.dest_gid = dest_gid
            if dest_qpn is not None:
                self.dest_qpn = dest_qpn
            if rq_psn is not None:
                self.epsn = rq_psn
        if new_state == QPState.RTS and sq_psn is not None:
            self.sq_psn = sq_psn
            self.una = sq_psn
        old_state = self.state
        self.state = new_state
        self.device.wake(self)      # gates changed: re-evaluate next run
        if old_state != new_state:
            trc = self.device.fabric.tracer
            if trc is not None:
                trc.qp_state(self.device.fabric.now, self.device.gid,
                             self.qpn, old_state.name, new_state.name)

    def post_send(self, wr: SendWR):
        if self.state not in (QPState.RTS, QPState.PAUSED):
            raise RuntimeError(f"post_send in {self.state}")
        self.sq.append(wr)
        self.device.wake(self)

    def post_recv(self, wr: RecvWR):
        self.rq.append(wr)

    # -- helpers ----------------------------------------------------------------
    def next_rr(self) -> Optional[RecvWR]:
        if self.srq is not None and self.srq.queue:
            return self.srq.pop()       # fires the SRQ_LIMIT watermark
        if self.rq:
            return self.rq.popleft()
        return None

    def idle(self) -> bool:
        if self.state in (QPState.PAUSED, QPState.STOPPED, QPState.ERROR,
                          QPState.RESET, QPState.INIT):
            return not self.rx
        return (not self.sq and self.cur_wqe is None and
                not self.inflight and not self.rx and
                not self.resume_pending)


class ProtectionDomain:
    def __init__(self, ctx: "Context", pdn: int):
        self.ctx = ctx
        self.pdn = pdn

    def reg_mr(self, size: int) -> MemoryRegion:
        return self.ctx.device.reg_mr(self, size)

    def create_qp(self, send_cq, recv_cq, srq=None) -> QueuePair:
        return self.ctx.device.create_qp(self, send_cq, recv_cq, srq)


class Context:
    """Per-container verbs context (the unit of dump_context)."""

    def __init__(self, device: "RdmaDevice", ctx_id: int,
                 tenant: Optional[str] = None):
        self.device = device
        self.ctx_id = ctx_id
        # tenant key for NIC-port QoS (the container name); QPs snapshot
        # it at create time, so tag the context before building QPs
        self.tenant = tenant
        self.pds: List[ProtectionDomain] = []
        self.mrs: List[MemoryRegion] = []
        self.cqs: List[CompletionQueue] = []
        self.srqs: List[SharedReceiveQueue] = []
        self.qps: List[QueuePair] = []
        # affiliated async events (SRQ_LIMIT_REACHED, ...) — the
        # ibv_get_async_event surface, polled not blocking
        self.events: Deque[AsyncEvent] = deque()

    def alloc_pd(self) -> ProtectionDomain:
        pd = ProtectionDomain(self, self.device.next_pdn())
        self.pds.append(pd)
        return pd

    def create_cq(self, depth: int = 4096) -> CompletionQueue:
        cq = CompletionQueue(self.device.next_cqn(), depth)
        self.cqs.append(cq)
        return cq

    def create_srq(self) -> SharedReceiveQueue:
        srq = SharedReceiveQueue(self.device.next_srqn(), ctx=self)
        self.srqs.append(srq)
        return srq

    def poll_async(self, n: int = 16) -> List[AsyncEvent]:
        out = []
        while self.events and len(out) < n:
            out.append(self.events.popleft())
        return out


class RdmaDevice:
    """The 'NIC': owns numbering, routes packets to QPs, runs QP tasks."""

    def __init__(self, fabric, gid: int, *, qpn_base: Optional[int] = None):
        self.fabric = fabric
        self.gid = gid
        fabric.attach(gid, self)
        self.rng = random.Random(gid * 7919 + 13)
        # Cluster-wide QPN/MRN partitioning (paper §4.1): each node owns a
        # disjoint range so restored IDs never collide.          # [MIGR]
        base = qpn_base if qpn_base is not None else gid * 1_000_000
        self.qpn_base = base
        self._qpn = base
        self._mrn = base
        self._pdn = base
        self._cqn = base
        self._srqn = base
        self.last_qpn: Optional[int] = None   # [MIGR] ns_last_pid analogue
        self.last_mrn: Optional[int] = None   # [MIGR]
        self.qps: Dict[int, QueuePair] = {}
        self.contexts: List[Context] = []
        self._service = None        # kernel migration channel     # [MIGR]
        # rkey -> MR index: every inbound RDMA WRITE/READ resolves its rkey
        # here, so lookup must be O(1), not a scan over contexts × MRs.
        self.mr_by_rkey: Dict[int, MemoryRegion] = {}
        # event scheduler: earliest wake over this device's QPs, the
        # cached QP iteration snapshot, and the memoised idle() answer
        self._wake = 0
        self._qp_list: List[QueuePair] = []
        self._qps_dirty = True
        self._idle_dirty = True
        self._idle_cache = True

    # -- numbering ---------------------------------------------------------------
    def next_pdn(self):
        self._pdn += 1
        return self._pdn

    def next_cqn(self):
        self._cqn += 1
        return self._cqn

    def next_srqn(self):
        self._srqn += 1
        return self._srqn

    # -- object creation -----------------------------------------------------------
    def open_context(self, tenant: Optional[str] = None) -> Context:
        ctx = Context(self, len(self.contexts), tenant=tenant)
        self.contexts.append(ctx)
        return ctx

    def reg_mr(self, pd: ProtectionDomain, size: int) -> MemoryRegion:
        if self.last_mrn is not None:                        # [MIGR]
            mrn, self.last_mrn = self.last_mrn + 1, None     # [MIGR]
            if any(m.mrn == mrn for m in pd.ctx.mrs):        # [MIGR]
                raise RuntimeError(f"MRN {mrn} collision")   # [MIGR]
            self._mrn = max(self._mrn, mrn)                  # [MIGR]
        else:
            self._mrn += 1
            mrn = self._mrn
        mr = MemoryRegion(pd, size, mrn,
                          lkey=self.rng.getrandbits(32),
                          rkey=self.rng.getrandbits(32))
        pd.ctx.mrs.append(mr)
        self.mr_by_rkey[mr.rkey] = mr
        return mr

    def dereg_mr(self, mr: MemoryRegion):
        if self.mr_by_rkey.get(mr.rkey) is mr:
            del self.mr_by_rkey[mr.rkey]
        # owner back-pointer instead of a contexts x objects scan:
        # teardown happens per-migration, so it must not be O(cluster)
        try:
            mr.ctx.mrs.remove(mr)
        except ValueError:
            pass

    def set_mr_keys(self, mr: MemoryRegion, lkey: int, rkey: int):
        """Rebind MR keys (restore path) keeping the rkey index coherent."""
        if self.mr_by_rkey.get(mr.rkey) is mr:
            del self.mr_by_rkey[mr.rkey]
        mr.lkey, mr.rkey = lkey, rkey
        self.mr_by_rkey[rkey] = mr

    def create_qp(self, pd, send_cq, recv_cq, srq=None) -> QueuePair:
        if self.last_qpn is not None:                        # [MIGR]
            qpn, self.last_qpn = self.last_qpn + 1, None     # [MIGR]
            if qpn in self.qps:                              # [MIGR]
                raise RuntimeError(f"QPN {qpn} collision")   # [MIGR]
            self._qpn = max(self._qpn, qpn)                  # [MIGR]
        else:
            self._qpn += 1
            qpn = self._qpn
        qp = QueuePair(pd, qpn, send_cq, recv_cq, srq)
        self.qps[qpn] = qp
        pd.ctx.qps.append(qp)
        self._qps_dirty = True
        self.wake(qp)
        return qp

    def destroy_qp(self, qpn: int):
        qp = self.qps.pop(qpn, None)
        if qp is not None:
            try:
                qp.ctx.qps.remove(qp)
            except ValueError:
                pass
            self._qps_dirty = True
            self._idle_dirty = True

    # -- service channel (kernel migration data plane) ----------------- # [MIGR]
    @property
    def service(self):
        """Kernel-owned migration channel, created on first use (the
        import is deferred: service.py builds on the verbs objects)."""
        if self._service is None:
            from repro.core.service import ServiceChannel
            self._service = ServiceChannel(self)
        return self._service

    def on_service_message(self, op, blob: bytes, src_gid: int):
        self.service.on_message(op, blob, src_gid)

    # -- fabric interface ------------------------------------------------------------
    def wake(self, qp: Optional[QueuePair] = None):
        """Wake hook: an external event (packet arrival, posted work,
        state change, QP creation) may have unparked a QP — pull its
        wake (and the device's) down to ``now`` so the next pump step
        runs the triple. Spurious wakes are trajectory-safe no-ops;
        the one invariant is that no unparking event skips this."""
        now = self.fabric.now
        if qp is not None and qp._wake > now:
            qp._wake = now
        if self._wake > now:
            self._wake = now
        self._idle_dirty = True

    def receive(self, pkt: Packet):
        qp = self.qps.get(pkt.dest_qpn)
        if qp is None:
            # dropped; sender's go-back-N recovers after migration — but
            # count it so migration bugs (stale QPNs) are observable
            self.fabric.metrics.inc("unknown_qpn", gid=self.gid)
            return
        qp.rx.append(pkt)
        now = self.fabric.now       # wake(), inlined: this path is hot
        if qp._wake > now:
            qp._wake = now
        if self._wake > now:
            self._wake = now
        self._idle_dirty = True

    def run_tasks(self):
        fab = self.fabric
        if not fab.event_driven:
            # legacy exhaustive scan (the determinism-suite reference)
            for qp in list(self.qps.values()):
                qptasks.responder(qp)
                qptasks.completer(qp)
                qptasks.requester(qp)
            if self._service is not None:
                self._service.reap()
            return
        now = fab.now
        if self._qps_dirty:
            self._qp_list = list(self.qps.values())
            self._qps_dirty = False
        ecn_on = fab.ecn.enabled
        bps = fab.bytes_per_step
        nxt = _WAKE_FAR
        ran = False
        # park tentatively at +inf; wake hooks firing mid-loop (service
        # rendezvous creating QPs, handlers posting sends) pull this
        # back to ``now`` and must survive the final min below
        self._wake = _WAKE_FAR
        try:
            for qp in self._qp_list:
                w = qp._wake
                if w > now:
                    if w < nxt:
                        nxt = w
                    continue
                ran = True
                cc = qp.cc
                if cc is not None and ecn_on and cc.last < now - 1:
                    # parked QP: replay the DCQCN per-step clock up to
                    # the boundary the exhaustive scan would have
                    # reached *entering* this step — the completer
                    # charges retransmit debt against pre-refill tokens,
                    # so the catch-up cannot wait for the requester
                    cc.advance(now - 1, bps)
                qptasks.responder(qp)
                qptasks.completer(qp)
                qptasks.requester(qp)
                w = qptasks.next_wake(qp, now)
                qp._wake = w
                if w < nxt:
                    nxt = w
        except BaseException:
            self._wake = now        # defensive: retry next step
            raise
        if nxt < self._wake:
            self._wake = nxt
        if ran:
            self._idle_dirty = True
        svc = self._service
        if svc is not None and svc.cq.ring:
            svc.reap()

    def idle(self) -> bool:
        if self._idle_dirty:
            self._idle_cache = all(qp.idle() for qp in self.qps.values())
            self._idle_dirty = False
        return self._idle_cache

    def rkey_lookup(self, rkey: int):
        return self.mr_by_rkey.get(rkey)
