"""NIC-port QoS: traffic classes, weighted-fair scheduling, token buckets.

The fabric's wire model (paper §4.2: the SoftRoCE role) used to give every
(src, dest) pair a private full-bandwidth FIFO. A real NIC has one egress
port per node whose capacity is *summed over all destinations*, and a
converged dataplane (migration traffic riding the application fabric, the
CoRD argument) makes that port a contended resource: one container's burst
can starve a co-located migration stream or another tenant (the noisy-
neighbor failure mode). This module is the scheduler that sits on that
port:

* two **traffic classes** — ``mig`` (service-channel ``MIG_*`` packets,
  the migration data plane of §3.2/§3.4) and ``app`` (everything else) —
  arbitrated by weighted deficit-round-robin; operators either *cap*
  migration bandwidth (hard ceiling, non-work-conserving) or *guarantee*
  it a minimum share (weight floor, work-conserving);
* **per-tenant token buckets** keyed by the container that owns the
  sending QP, so a tenant's sustained rate is bounded while short bursts
  ride the bucket depth;
* **work conservation** across everything that is not explicitly capped:
  bandwidth an idle or bucket-throttled sender cannot use is immediately
  available to everyone else.

With QoS disabled (the default) every port degenerates to a single
first-come-first-served queue and no bucket is consulted — scheduling
adds nothing when it is not asked for, restating the paper's
"no overhead when migration does not happen" claim for bandwidth
arbitration.

The receive side is modeled too: every node owns one **ingress port**
(``IngressPort``) with finite receive-processing capacity and a bounded
request queue shared across all senders — receive processing is where
kernel-path RDMA designs actually pay (the CoRD measurement), and incast
(N senders converging on one receiver) is invisible as long as receiving
is free. Queue overflow draws a *receiver-not-ready* NAK
(``NakCode.RNR``) so senders back off instead of timing out; with the
default unlimited capacity the ingress port is a pass-through and the
wire model is byte-identical to the egress-only one.

On top of both port models sits **ECN/DCQCN-style congestion control**
(``ECNConfig`` + ``CongestionControl``): ports RED-mark ECT packets when
queue occupancy crosses a threshold (default ~80%), the responder
answers Congestion-Experienced arrivals with CNPs (paper §3.4's point
exactly: this is NIC state — rate limiters, alpha estimators — that
MigrOS can checkpoint *because the OS owns the model*), and each QP's
reaction point does DCQCN multiplicative decrease / additive+hyper
increase on its send rate, enforced at send admission ahead of the
tenant token bucket. Disabled by default: no marking, no CNPs, no rate
state — the wire model is byte-identical to the ECN-less one.

The last layer is **PFC link-level flow control** (``PFCConfig``,
802.1Qbb-style, the lossless-RoCE substrate the paper's §5 zero-overhead
argument assumes): when a bounded ingress queue crosses a traffic
class's XOFF occupancy watermark, the port answers its senders with
per-class ``PAUSE`` frames; crossing back below XON sends ``UNPAUSE``.
Senders latch the pause per (destination, class) on their egress port
and hold that class's packets off the wire until the XON frame — or the
frame's own lifetime — releases them, so in lossless mode nothing
overflows and congestion feedback rides ECN/CNP alone (the DCQCN + PFC
deployment stack). Disabled by default: no watermarks are evaluated, no
latch ever exists, and the wire model is byte-identical to the PFC-less
fabric.
"""
from __future__ import annotations

import random
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.packets import (CTRL_OPS, MIG_OPS, NakCode, Op, Packet,
                                RNR_OPS)

# traffic-class names (per-class fabric.stats counters use these keys)
CLASS_APP = "app"
CLASS_MIG = "mig"

# tenant key for packets nobody claimed (kernel QPs before tagging, bare
# test fixtures): they ride the app class unbucketed unless an operator
# configures a rate for this exact key
UNATTRIBUTED = "_unattributed"

# floor on every token/pacing bucket depth: a bucket shallower than one
# max-size packet (4 KiB payload + headers) could never pass anything
# and would wedge its queue forever; configured depths below this are
# silently raised to it (documented in docs/fabric-qos.md)
MIN_BUCKET_BYTES = 4096.0


def classify(pkt: Packet) -> str:
    """Traffic class of one packet: the migration data plane is exactly
    the service-channel MIG_* ops; everything else is application."""
    return CLASS_MIG if pkt.op.is_mig else CLASS_APP


@dataclass
class QoSConfig:
    """Operator knobs for the per-port scheduler (docs/fabric-qos.md is
    the operator guide; every field is validated at attach time).

    ``enabled=False`` (default) bypasses classes and buckets entirely:
    one FIFO per port, byte-identical arbitration to a single queue.
    """
    enabled: bool = False
    # weighted-fair class arbitration (shares are weight / sum(weights)
    # over backlogged classes)
    app_weight: float = 1.0
    mig_weight: float = 1.0
    # hard ceiling on the migration class, as a fraction of port bandwidth
    # (non-work-conserving: held even when the app class is idle)
    migration_cap: Optional[float] = None
    # minimum share guaranteed to a backlogged migration class, as a
    # fraction of port bandwidth (implemented as a weight floor, so it is
    # work-conserving: an idle migration class cedes it back)
    migration_guarantee: Optional[float] = None
    # per-tenant sustained rate (bytes/s) and burst depth (bytes); tenants
    # not listed are unthrottled unless default_tenant_rate_Bps is set
    tenant_rate_Bps: Dict[str, float] = field(default_factory=dict)
    tenant_burst_bytes: Dict[str, float] = field(default_factory=dict)
    default_tenant_rate_Bps: Optional[float] = None
    default_burst_bytes: float = 64 * 1024

    def validate(self) -> "QoSConfig":
        if self.app_weight <= 0 or self.mig_weight <= 0:
            raise ValueError("class weights must be > 0")
        for name, frac in (("migration_cap", self.migration_cap),
                           ("migration_guarantee",
                            self.migration_guarantee)):
            if frac is not None and not (0.0 < frac <= 1.0):
                raise ValueError(f"{name} must be in (0, 1], got {frac}")
        if (self.migration_cap is not None
                and self.migration_guarantee is not None
                and self.migration_cap < self.migration_guarantee):
            raise ValueError("migration_cap below migration_guarantee")
        for t, r in self.tenant_rate_Bps.items():
            if r <= 0:
                raise ValueError(f"tenant {t!r} rate must be > 0")
        return self

    def effective_weights(self) -> Dict[str, float]:
        """Class weights with the migration guarantee folded in: a
        guarantee g needs mig/(mig+app) >= g, i.e. a weight floor of
        g/(1-g) * app_weight (g=1 degenerates to mig-only)."""
        w_mig = self.mig_weight
        g = self.migration_guarantee
        if g is not None:
            if g >= 1.0:
                w_mig = float("inf")
            else:
                w_mig = max(w_mig, g / (1.0 - g) * self.app_weight)
        return {CLASS_APP: self.app_weight, CLASS_MIG: w_mig}

    def bucket_for(self, tenant: str) -> Optional[Tuple[float, float]]:
        """(rate_Bps, burst_bytes) for a tenant, or None (unthrottled).

        The default rate applies to *containers* only: the kernel
        service tenants (``_kernel@gid``) and unattributed packets are
        exempt unless an operator names that exact key — a blanket
        default must not throttle the migration data plane below the
        class share the cap/guarantee knobs govern."""
        rate = self.tenant_rate_Bps.get(tenant)
        if rate is None:
            if tenant == UNATTRIBUTED or tenant.startswith("_kernel@"):
                return None
            rate = self.default_tenant_rate_Bps
        if rate is None:
            return None
        burst = self.tenant_burst_bytes.get(tenant,
                                            self.default_burst_bytes)
        return rate, max(burst, MIN_BUCKET_BYTES)


class TokenBucket:
    """Deterministic token bucket in fabric-step time: refill is a pure
    function of the step delta (rate_per_step * elapsed), so identical
    runs refill identically — no wall clock anywhere."""

    __slots__ = ("rate_per_step", "burst", "tokens", "last")

    def __init__(self, rate_per_step: float, burst: float,
                 now: int = 0):
        self.rate_per_step = rate_per_step
        self.burst = float(burst)
        self.tokens = float(burst)          # starts full: bursts ride it
        self.last = now

    def refill(self, now: int):
        if now > self.last:
            self.tokens = min(self.burst,
                              self.tokens
                              + (now - self.last) * self.rate_per_step)
            self.last = now

    def peek(self, n: int, now: int) -> bool:
        self.refill(now)
        return self.tokens >= n

    def take(self, n: int):
        self.tokens -= n


# ---------------------------------------------------------------------------
# ECN marking + DCQCN reaction point
# ---------------------------------------------------------------------------


@dataclass
class ECNConfig:
    """Operator knobs for ECN marking and the DCQCN rate machinery
    (docs/fabric-qos.md has the operator table; everything is in
    fabric-step time so enabled runs stay bit-reproducible).

    ``enabled=False`` (default) turns the whole subsystem off: packets
    are not ECT, ports never mark, responders never emit CNPs, and QPs
    carry no rate state — byte-identical to the pre-ECN wire model.
    """
    enabled: bool = False
    # -- RED-style marking (shared by egress and ingress ports) -----------
    # occupancy fraction where marking starts / saturates; between them
    # the marking probability ramps linearly from 0 to pmax (>=kmax
    # marks every ECT packet)
    kmin: float = 0.8
    kmax: float = 1.0
    pmax: float = 0.2
    # per-traffic-class (kmin, kmax, pmax) overrides — real DCQCN+PFC
    # deployments run *per-priority* ECN: shallow thresholds for
    # latency-sensitive app flows (mark early, keep queues short), deep
    # thresholds for migration bulk (tolerate standing queue, keep
    # throughput). Classes not listed fall back to the flat knobs above;
    # ``None`` (default) is the flat single-threshold model,
    # byte-identical to the pre-per-class fabric.
    per_class: Optional[Dict[str, Tuple[float, float, float]]] = None
    # egress ports have no hard queue bound, so occupancy is measured
    # against this reference backlog; ingress occupancy uses the port's
    # own queue_bytes bound
    egress_queue_bytes: float = 128 * 1024
    mark_egress: bool = True
    mark_ingress: bool = True
    # -- notification point (responder) -----------------------------------
    # per-QP CNP coalescing window, in steps (DCQCN NPs fire at most one
    # CNP per flow per 50us; one step ~ 1us)
    cnp_interval: int = 50
    # -- reaction point (per-QP DCQCN rate state) -------------------------
    g: float = 1.0 / 16.0           # alpha gain on CNP / decay
    alpha_timer: int = 55           # steps between alpha decays, no CNP
    increase_timer: int = 300       # steps between timer increase events
    byte_counter: float = 64 * 1024  # bytes per byte-counter event
    fast_recovery_events: int = 5   # F: events before additive increase
    rai_Bps: Optional[float] = None   # additive step (None: line/50)
    rhai_Bps: Optional[float] = None  # hyper step (None: line/10)
    min_rate_Bps: Optional[float] = None  # rate floor (None: line/500)
    burst_bytes: float = 8 * 1024   # reaction-point pacing bucket depth

    def validate(self) -> "ECNConfig":
        if not (0.0 <= self.kmin <= self.kmax):
            raise ValueError("need 0 <= kmin <= kmax")
        if not (0.0 < self.pmax <= 1.0):
            raise ValueError("pmax must be in (0, 1]")
        if self.egress_queue_bytes <= 0:
            raise ValueError("egress_queue_bytes must be > 0")
        if self.cnp_interval < 1 or self.alpha_timer < 1 \
                or self.increase_timer < 1:
            raise ValueError("ECN timers must be >= 1 step")
        if not (0.0 < self.g <= 1.0):
            raise ValueError("g must be in (0, 1]")
        if self.byte_counter <= 0 or self.burst_bytes <= 0:
            raise ValueError("byte_counter/burst_bytes must be > 0")
        for name, v in (("rai_Bps", self.rai_Bps),
                        ("rhai_Bps", self.rhai_Bps),
                        ("min_rate_Bps", self.min_rate_Bps)):
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be > 0 (or None)")
        if self.per_class is not None:
            for cname, t in self.per_class.items():
                if len(t) != 3:
                    raise ValueError(f"per_class[{cname!r}] must be "
                                     f"(kmin, kmax, pmax)")
                km, kx, pm = t
                if not (0.0 <= km <= kx):
                    raise ValueError(f"per_class[{cname!r}]: need "
                                     f"0 <= kmin <= kmax")
                if not (0.0 < pm <= 1.0):
                    raise ValueError(f"per_class[{cname!r}]: pmax must "
                                     f"be in (0, 1]")
        return self

    def mark_probability(self, occupancy: float,
                         cls: Optional[str] = None) -> float:
        """RED curve: 0 below kmin, linear ramp to pmax at kmax, 1 at or
        above kmax (the queue is effectively full — mark everything).
        With ``per_class`` thresholds configured, ``cls`` selects that
        class's (kmin, kmax, pmax) triple; unknown/None classes use the
        flat knobs — the exact pre-per-class arithmetic."""
        kmin, kmax, pmax = self.kmin, self.kmax, self.pmax
        if cls is not None and self.per_class is not None:
            t = self.per_class.get(cls)
            if t is not None:
                kmin, kmax, pmax = t
        if occupancy < kmin:
            return 0.0
        if occupancy >= kmax:
            return 1.0
        span = max(kmax - kmin, 1e-12)
        return pmax * (occupancy - kmin) / span


def maybe_mark(fabric, rng, pkt: Packet, occupancy: float,
               gid: int, where: str = "egress") -> bool:
    """CE-mark one ECT packet with the RED probability for this queue
    occupancy. The rng is per-port and seeded off the fabric seed, so
    marking is deterministic and does not perturb the fabric's loss
    stream; it is only consulted inside the ramp (0 < p < 1)."""
    if not pkt.ect or pkt.ce:
        return False
    cls = classify(pkt)
    p = fabric.ecn.mark_probability(occupancy, cls)
    if p <= 0.0:
        return False
    if p < 1.0 and rng.random() >= p:
        return False
    pkt.ce = True
    fabric.metrics.inc("ecn_marked", gid=gid, cls=cls)
    trc = fabric.tracer
    if trc is not None:
        trc.ecn_mark(fabric.now, pkt, gid, where, occupancy)
    return True


# ---------------------------------------------------------------------------
# PFC link-level flow control (802.1Qbb-style)
# ---------------------------------------------------------------------------


@dataclass
class PFCConfig:
    """Operator knobs for per-class link-level pause (the lossless-RoCE
    substrate: docs/fabric-qos.md has the operator table).

    ``enabled=False`` (default) turns the subsystem off completely: no
    watermark is ever evaluated, no PAUSE frame exists on the wire, no
    latch is allocated — byte-identical to the PFC-less fabric.

    Enabling PFC switches the fabric to **lossless mode**: a bounded
    ingress queue stops dropping reliable requests on overflow (real PFC
    reserves headroom for the packets already in flight when XOFF fires;
    we waive the hard bound the same way) and the RNR-NAK rate-cut path
    in ``CongestionControl`` goes inert — congestion feedback rides
    ECN/CNP alone, the DCQCN-over-PFC deployment stack.

    Watermarks are fractions of the ingress queue bound (``backlog /
    queue_bytes``): class ``c`` pauses its senders when its occupancy
    reaches ``xoff[c]`` and releases them when it falls to ``xon[c]``.
    With QoS class queues enabled each class is judged on its OWN
    backlog (802.1Qbb pauses on the priority's buffer usage — another
    priority's standing queue must never hold a latch closed); in
    single-FIFO mode there is only the shared counter, so every class
    reads total occupancy — global-pause semantics. Defaults pause the
    app class first (shallower XOFF) so migration bulk keeps flowing a
    little longer before the link quiets entirely.
    """
    enabled: bool = False
    # per-class XOFF/XON occupancy watermarks (fractions of queue_bytes);
    # classes not listed are never paused
    xoff: Dict[str, float] = field(default_factory=lambda: {
        CLASS_APP: 0.60, CLASS_MIG: 0.75})
    xon: Dict[str, float] = field(default_factory=lambda: {
        CLASS_APP: 0.35, CLASS_MIG: 0.45})
    # lifetime of one PAUSE frame, in steps (the quanta field of a real
    # 802.1Qbb frame): a latch whose XON frame is lost — or whose issuer
    # departed mid-pause — self-releases after this long, which is the
    # progress guarantee against permanent pause deadlock
    pause_steps: int = 512
    # while occupancy stays above XOFF, the ingress re-broadcasts PAUSE
    # this often so latches are refreshed before they expire
    refresh_steps: int = 256

    def validate(self) -> "PFCConfig":
        for cname, hi in self.xoff.items():
            lo = self.xon.get(cname)
            if lo is None:
                raise ValueError(f"xoff[{cname!r}] has no xon watermark")
            if not (0.0 < lo < hi <= 1.0):
                raise ValueError(f"class {cname!r}: need "
                                 f"0 < xon < xoff <= 1, got "
                                 f"xon={lo} xoff={hi}")
        for cname in self.xon:
            if cname not in self.xoff:
                raise ValueError(f"xon[{cname!r}] has no xoff watermark")
        if self.pause_steps < 2:
            raise ValueError("pause_steps must be >= 2")
        if not (0 < self.refresh_steps < self.pause_steps):
            raise ValueError("need 0 < refresh_steps < pause_steps "
                             "(a refresh after expiry is a gap, not a "
                             "refresh)")
        return self


class CongestionControl:
    """DCQCN reaction-point state of one QP: current/target rate, the
    alpha congestion estimate, and the increase timers. Everything runs
    in fabric-step time (rates are bytes/step), advanced lazily from the
    requester — no wall clock, so identical runs evolve identically.

    The paper tie-in: this is exactly the NIC-resident communication
    state (§3.4) that makes hardware RDMA migration hard — because the
    OS owns this model, ``dump()``/``restore()`` move it with the QP and
    a migrated sender resumes at its *learned* rate, not line rate."""

    __slots__ = ("cfg", "line", "rc", "rt", "alpha", "tokens", "last",
                 "alpha_last", "incr_last", "byte_count", "t_events",
                 "b_events", "cnps_handled", "rate_cuts", "step_s")

    def __init__(self, cfg: ECNConfig, line_rate: float, now: int,
                 step_s: float = 1e-6):
        self.cfg = cfg
        # seconds per fabric step (Fabric.step_s()), for Bps knob
        # conversion — passed in so a retuned transport.STEP_S cannot
        # silently disagree with the rates computed here
        self.step_s = step_s
        self.line = line_rate           # bytes/step ceiling (port rate)
        self.rc = line_rate             # current send rate
        self.rt = line_rate             # target rate
        self.alpha = 1.0                # congestion estimate
        self.tokens = float(cfg.burst_bytes)
        self.last = now                 # last token refill
        self.alpha_last = now           # last alpha-decay evaluation
        self.incr_last = now            # last timer-increase evaluation
        self.byte_count = 0.0           # bytes toward the next B event
        self.t_events = 0               # timer events since last cut
        self.b_events = 0               # byte-counter events since cut
        self.cnps_handled = 0
        self.rate_cuts = 0

    # -- derived knobs (priced off line rate when not set) -----------------
    def _rai(self) -> float:
        if self.cfg.rai_Bps is not None:
            return self.cfg.rai_Bps * self.step_s
        return self.line / 50.0

    def _rhai(self) -> float:
        if self.cfg.rhai_Bps is not None:
            return self.cfg.rhai_Bps * self.step_s
        return self.line / 10.0

    def _min_rate(self) -> float:
        if self.cfg.min_rate_Bps is not None:
            return self.cfg.min_rate_Bps * self.step_s
        return max(self.line / 500.0, 1e-9)

    # -- time advance ------------------------------------------------------
    def advance(self, now: int, line_rate: float):
        """Refill the pacing bucket at rc and run the elapsed DCQCN
        timers: alpha decays every alpha_timer steps without a CNP, and
        every increase_timer steps the rate steps toward (then past) the
        target. Catch-up over a gap is an *exact per-step replay* of the
        step-driven call pattern (the requester historically called this
        once per step): float accumulation is not associative, so a
        closed-form catch-up would drift from the per-step trajectory by
        ulps — replaying keeps a QP the event scheduler parked for N
        steps bit-identical to one advanced N times. Each replayed step
        is a handful of float ops, and the boundary wakes in
        ``tasks.next_wake`` bound parked gaps to one timer period."""
        if line_rate != self.line:      # operator re-priced the port
            self.line = line_rate
            self.rc = min(self.rc, line_rate)
            self.rt = min(self.rt, line_rate)
        if now <= self.last:
            return
        cfg = self.cfg
        cap = max(cfg.burst_bytes, MIN_BUCKET_BYTES)
        alpha_decay = 1.0 - cfg.g
        t = self.last
        while t < now:
            t += 1
            if t - self.alpha_last >= cfg.alpha_timer:
                self.alpha *= alpha_decay
                self.alpha_last += cfg.alpha_timer
            if t - self.incr_last >= cfg.increase_timer:
                if self.rc < self.line or self.rt < self.line:
                    self._increase_event(timer=True)
                else:               # saturated: events only count
                    self.t_events += 1
                self.incr_last += cfg.increase_timer
            # refill after the increases so a long-idle QP resumes at
            # the recovered rate, not the stale one
            self.tokens = min(cap, self.tokens + self.rc)
        self.last = now

    # -- send admission (ahead of the tenant token bucket) -----------------
    def admit(self, n: int) -> bool:
        """True iff the pacing bucket lets ``n`` more bytes onto the
        send path right now; charges the bucket on success. A charge
        larger than the bucket can ever hold (a READ whose response
        exceeds burst_bytes) waits for a full bucket and then
        overdraws — the same debt semantics retransmits use; requiring
        tokens >= n would wedge the QP forever."""
        cap = max(self.cfg.burst_bytes, MIN_BUCKET_BYTES)
        need = min(float(n), cap)
        if self.tokens < need:
            return False
        self.tokens -= n
        return True

    def on_send(self, n: int):
        """Byte-counter increase events (DCQCN's B counter)."""
        self.byte_count += n
        while self.byte_count >= self.cfg.byte_counter:
            self.byte_count -= self.cfg.byte_counter
            self._increase_event(timer=False)

    # -- congestion events (multiplicative decrease) -----------------------
    def on_cnp(self, now: int):
        self.cnps_handled += 1
        self.cut(now)

    def cut(self, now: int):
        """DCQCN decrease: also applied on an RNR NAK — receiver-not-
        ready is the *severe* congestion signal (the queue already
        overflowed; marking should have slowed us sooner), and a flow
        whose packets all drop at admission never gets CE feedback at
        all, so without this the incast losers would starve while the
        winners get politely rate-controlled. On a lossless (PFC)
        fabric the RNR caller gates this path off: nothing overflows
        there, every packet earns CE feedback, and a spurious RNR cut
        would double-punish below the CNP-derived rate."""
        self.rate_cuts += 1
        cfg = self.cfg
        self.alpha = (1.0 - cfg.g) * self.alpha + cfg.g
        self.rt = self.rc
        self.rc = max(self._min_rate(), self.rc * (1.0 - self.alpha / 2))
        self.t_events = 0
        self.b_events = 0
        self.byte_count = 0.0
        self.alpha_last = now
        self.incr_last = now

    # -- rate increase -----------------------------------------------------
    def _increase_event(self, *, timer: bool):
        if timer:
            self.t_events += 1
        else:
            self.b_events += 1
        f = self.cfg.fast_recovery_events
        if self.t_events > f and self.b_events > f:
            self.rt = min(self.line, self.rt + self._rhai())   # hyper
        elif self.t_events > f or self.b_events > f:
            self.rt = min(self.line, self.rt + self._rai())    # additive
        # fast recovery: rt untouched, rc halves the gap toward it
        self.rc = min(self.line, (self.rt + self.rc) / 2.0)

    # -- checkpoint / restore (travels in the QP dump) --------------------
    def dump(self, now: int) -> dict:
        """Timer phases are stored relative to ``now`` so the state is
        meaningful on a destination whose clock reads the same fabric
        (and harmless if it does not)."""
        return {"alpha": self.alpha, "rc": self.rc, "rt": self.rt,
                "line": self.line, "tokens": self.tokens,
                "byte_count": self.byte_count,
                "t_events": self.t_events, "b_events": self.b_events,
                "alpha_phase": now - self.alpha_last,
                "incr_phase": now - self.incr_last,
                "cnps_handled": self.cnps_handled,
                "rate_cuts": self.rate_cuts}

    @classmethod
    def restore(cls, cfg: ECNConfig, d: dict, now: int,
                line_rate: float,
                step_s: float = 1e-6) -> "CongestionControl":
        cc = cls(cfg, line_rate, now, step_s)
        cc.alpha = d["alpha"]
        # the learned rate is absolute: resume at it (clamped to the new
        # port's line rate), NOT at line rate — the headline behaviour
        cc.rc = min(d["rc"], line_rate)
        cc.rt = min(d["rt"], line_rate)
        cc.tokens = min(d["tokens"],
                        max(cfg.burst_bytes, MIN_BUCKET_BYTES))
        cc.byte_count = d["byte_count"]
        cc.t_events = d["t_events"]
        cc.b_events = d["b_events"]
        cc.alpha_last = now - d["alpha_phase"]
        cc.incr_last = now - d["incr_phase"]
        cc.cnps_handled = d["cnps_handled"]
        cc.rate_cuts = d["rate_cuts"]
        return cc


class _ClassQueue:
    """One traffic class on one port: per-tenant FIFOs served round-robin
    plus the class's DRR deficit counter."""

    __slots__ = ("name", "weight", "tenants", "order", "deficit",
                 "backlog_bytes", "backlog_packets", "bucket",
                 "tx_bytes", "tx_packets")

    def __init__(self, name: str, weight: float):
        self.name = name
        self.weight = weight
        self.tenants: Dict[str, Deque[Packet]] = {}
        self.order: Deque[str] = deque()      # round-robin tenant order
        self.deficit = 0.0
        self.backlog_bytes = 0
        self.backlog_packets = 0
        self.bucket: Optional[TokenBucket] = None   # class cap (mig)
        self.tx_bytes = 0
        self.tx_packets = 0

    def push(self, tenant: str, pkt: Packet):
        q = self.tenants.get(tenant)
        if q is None:
            q = self.tenants[tenant] = deque()
            self.order.append(tenant)
        q.append(pkt)
        self.backlog_bytes += 64 + len(pkt.payload)  # nbytes(), inlined
        self.backlog_packets += 1

    def drain_all(self) -> List[Packet]:
        """Remove and return every queued packet (tenant-RR order);
        used when a port is re-built under a new QoS config."""
        out: List[Packet] = []
        while self.backlog_packets:
            for t in list(self.order):
                q = self.tenants[t]
                if q:
                    out.append(q.popleft())
                    self.backlog_packets -= 1
                    self.backlog_bytes -= out[-1].nbytes()
        self.tenants.clear()
        self.order.clear()
        self.deficit = 0.0
        return out


def _drr_spend(classes, budget: float, eligible, drain):
    """One step's weighted-DRR budget spend, shared by the egress and
    ingress ports: hand each *eligible* class its weight-proportional
    slice (infinite weights split the whole budget among themselves),
    let it drain, then reclaim deficit stranded in classes with nothing
    eligible and redistribute — so the port is work-conserving across
    everything the eligibility rules (caps, buckets, backlog) allow."""
    for _ in range(4):              # redistribution rounds
        elig = [cq for cq in classes if eligible(cq)]
        if not elig or budget <= 1e-9:
            break
        if any(cq.weight == float("inf") for cq in elig):
            wsum = sum(1.0 for cq in elig if cq.weight == float("inf"))
            shares = [(cq, budget / wsum
                       if cq.weight == float("inf") else 0.0)
                      for cq in elig]
        else:
            wsum = sum(cq.weight for cq in elig)
            shares = [(cq, budget * cq.weight / wsum) for cq in elig]
        budget = 0.0
        sent_any = 0
        for cq, share in shares:
            cq.deficit += share
            sent_any += drain(cq)
        for cq in classes:
            if cq.deficit > 0 and not eligible(cq):
                budget += cq.deficit
                cq.deficit = 0.0
        if not sent_any and budget <= 1e-9:
            break       # every eligible class is saving for a big head


class _Flow:
    """Per-(src, dest) accounting view, kept for observability and test
    compatibility with the old per-pair Link objects: ``tx_*`` counts at
    enqueue, ``queued_bytes`` is the not-yet-transmitted backlog, and
    ``busy_until`` is the step the backlog would clear at port rate."""

    __slots__ = ("port", "tx_bytes", "tx_packets", "queued_bytes")

    def __init__(self, port: "EgressPort"):
        self.port = port
        self.tx_bytes = 0
        self.tx_packets = 0
        self.queued_bytes = 0

    @property
    def busy_until(self) -> float:
        bps = self.port.fabric.bytes_per_step
        if bps <= 0:
            return float(self.port.fabric.now)
        return self.port.fabric.now + self.queued_bytes / bps


class EgressPort:
    """One node's NIC egress port: finite bandwidth shared across every
    destination, arbitrated by the QoS scheduler above. The port is
    step-driven like the rest of the fabric: each ``service()`` call
    spends one step's byte budget (``fabric.bytes_per_step``) on queued
    packets; budget a class saves toward an oversized head-of-line packet
    persists in its DRR deficit, budget nobody can use is discarded (an
    idle wire transmits nothing retroactively)."""

    def __init__(self, fabric, gid: int, cfg: QoSConfig):
        self.fabric = fabric
        self.gid = gid
        self.cfg = cfg
        self.classes: Dict[str, _ClassQueue] = {}
        self._class_list: List[_ClassQueue] = []    # cached .values()
        self.buckets: Dict[str, TokenBucket] = {}   # tenant -> bucket
        self.delivery: Deque[Tuple[int, Packet]] = deque()
        self.flows: Dict[int, _Flow] = {}           # dest gid -> view
        # port-level backlog, maintained incrementally (summing the
        # class counters per access is the old hot-path cost)
        self.backlog_bytes = 0
        self.backlog_packets = 0
        self.tx_bytes = 0                           # transmitted (wire)
        self.tx_packets = 0
        self._window: Deque[Tuple[int, int]] = deque()  # (enq_at, nbytes)
        self._win_bytes = 0
        # ECN: per-port marking rng (decoupled from the fabric's loss
        # stream) + trailing window of CE-marked bytes, the signal the
        # orchestrator's admission prices transfers against
        self._ecn_rng = random.Random(fabric.seed * 1_000_003
                                      + gid * 7919 + 1)
        self._mark_window: Deque[Tuple[int, int]] = deque()
        self._mark_bytes = 0
        # migration-class slice of the utilization window (mig is the
        # rare class, so only it is tracked; app = total - mig). The
        # auto-preemption policy reads the app share: a port busy only
        # with the migration's own stream must never read as app
        # pressure and pause the migration against itself.
        self._mig_window: Deque[Tuple[int, int]] = deque()
        self._mig_bytes = 0
        # PFC pause latches: (dest gid, class) -> latch expiry step. The
        # dict is empty whenever PFC is off, so every hot-path
        # consultation is a single falsy-dict test.
        self._pfc_until: Dict[Tuple[int, str], int] = {}
        self._build_classes()

    # -- configuration -------------------------------------------------------
    def _build_classes(self):
        queued = []
        for cq in self.classes.values():
            queued.extend(cq.drain_all())
        if self.cfg.enabled:
            weights = self.cfg.effective_weights()
            self.classes = {n: _ClassQueue(n, w)
                            for n, w in weights.items()}
            cap = self.cfg.migration_cap
            if cap is not None:
                rate = cap * self.fabric.bytes_per_step
                # burst: a handful of steps' worth so the cap is a rate,
                # not a per-step quantisation artefact
                self.classes[CLASS_MIG].bucket = TokenBucket(
                    rate, max(8 * rate, 8192.0), self.fabric.now)
        else:
            self.classes = {CLASS_APP: _ClassQueue(CLASS_APP, 1.0)}
        for pkt in queued:              # re-queue under the new shape
            self._class_of(pkt).push(self._tenant_of(pkt), pkt)
        self._class_list = list(self.classes.values())
        self.backlog_bytes = sum(cq.backlog_bytes
                                 for cq in self._class_list)
        self.backlog_packets = sum(cq.backlog_packets
                                   for cq in self._class_list)

    def reconfigure(self, cfg: QoSConfig):
        self.cfg = cfg.validate()
        self.buckets.clear()            # rebuilt lazily per tenant
        self._build_classes()

    def on_bandwidth_change(self):
        """Port rate changed: the mig-cap bucket is priced off it."""
        self._build_classes()

    def _class_of(self, pkt: Packet) -> _ClassQueue:
        if not self.cfg.enabled:
            return self.classes[CLASS_APP]
        return self.classes[classify(pkt)]

    def _tenant_of(self, pkt: Packet) -> str:
        if not self.cfg.enabled:
            # one FIFO per port: strict arrival order, no arbitration —
            # byte-identical to the pre-QoS shared-queue wire model
            return UNATTRIBUTED
        return pkt.tenant if pkt.tenant is not None else UNATTRIBUTED

    def _bucket(self, tenant: str) -> Optional[TokenBucket]:
        if not self.cfg.enabled:
            return None
        b = self.buckets.get(tenant)
        if b is None and tenant not in self.buckets:
            spec = self.cfg.bucket_for(tenant)
            b = None if spec is None else TokenBucket(
                spec[0] * self.fabric.step_s(), spec[1], self.fabric.now)
            self.buckets[tenant] = b
        return b

    def flow(self, dest_gid: int) -> _Flow:
        fl = self.flows.get(dest_gid)
        if fl is None:
            fl = self.flows[dest_gid] = _Flow(self)
        return fl

    # -- enqueue (called from Fabric.send) -----------------------------------
    def enqueue(self, pkt: Packet, now: int):
        n = 64 + len(pkt.payload)       # pkt.nbytes(), inlined (hot)
        fl = self.flows.get(pkt.dest_gid)
        if fl is None:
            fl = self.flows[pkt.dest_gid] = _Flow(self)
        fl.tx_bytes += n
        fl.tx_packets += 1
        fl.queued_bytes += n
        # utilization-window upkeep with _trim(now) inlined (per packet)
        w = self._window
        w.append((now, n))
        self._win_bytes += n
        cut = now - self.fabric.utilization_window
        while w[0][0] <= cut:
            self._win_bytes -= w.popleft()[1]
        mw = self._mark_window
        while mw and mw[0][0] <= cut:
            self._mark_bytes -= mw.popleft()[1]
        if pkt.op.is_mig:
            gw = self._mig_window
            gw.append((now, n))
            self._mig_bytes += n
            while gw[0][0] <= cut:
                self._mig_bytes -= gw.popleft()[1]
        # _class_of/_tenant_of, inlined (one call per packet on the wire)
        if self.cfg.enabled:
            self.classes[classify(pkt)].push(
                pkt.tenant if pkt.tenant is not None else UNATTRIBUTED,
                pkt)
        else:
            self.classes[CLASS_APP].push(UNATTRIBUTED, pkt)
        self.backlog_bytes += n
        self.backlog_packets += 1
        fab = self.fabric
        fab._in_flight += 1
        ecn = fab.ecn
        if ecn.enabled and ecn.mark_egress:
            # RED at enqueue: occupancy against the reference backlog
            # (egress queues have no hard byte bound of their own)
            occ = self.backlog_bytes / ecn.egress_queue_bytes
            if maybe_mark(fab, self._ecn_rng, pkt, occ, self.gid,
                          where="egress"):
                self._mark_window.append((now, n))
                self._mark_bytes += n
        trc = fab.tracer
        if trc is not None:
            trc.egress_enqueue(now, pkt, self.gid, self.backlog_bytes)

    # -- utilization window --------------------------------------------------
    def _trim(self, now: int):
        horizon = self.fabric.utilization_window
        while self._window and self._window[0][0] <= now - horizon:
            self._win_bytes -= self._window.popleft()[1]
        while self._mark_window and \
                self._mark_window[0][0] <= now - horizon:
            self._mark_bytes -= self._mark_window.popleft()[1]
        while self._mig_window and \
                self._mig_window[0][0] <= now - horizon:
            self._mig_bytes -= self._mig_window.popleft()[1]

    def window_bytes(self, now: int) -> int:
        self._trim(now)
        return self._win_bytes

    def app_window_bytes(self, now: int) -> int:
        """App-class bytes offered over the trailing window (total minus
        the migration class) — the auto-preemption policy's signal."""
        self._trim(now)
        return self._win_bytes - self._mig_bytes

    def marking_rate(self, now: int) -> float:
        """Fraction of bytes offered to this port over the trailing
        window that left CE-marked — the congestion signal admission
        reads (0.0 with ECN off or a quiet port)."""
        self._trim(now)
        if self._win_bytes <= 0:
            return 0.0
        return min(1.0, self._mark_bytes / self._win_bytes)

    def in_flight(self) -> int:
        return self.backlog_packets + len(self.delivery)

    # -- the scheduler -------------------------------------------------------
    def _eligible_head(self, cq: _ClassQueue, now: int) -> bool:
        """True iff some tenant FIFO in the class has a head packet the
        buckets would let on the wire right now."""
        if not cq.backlog_packets:
            return False
        pfc = self._pfc_until
        for t in cq.order:
            q = cq.tenants.get(t)
            if not q:
                continue
            pkt = q[0]
            if pfc and not pkt.op.is_pfc and pfc.get(
                    (pkt.dest_gid,
                     CLASS_MIG if pkt.op.is_mig else CLASS_APP),
                    0) > now:
                continue            # PFC-paused toward this destination
            n = pkt.nbytes()
            if cq.bucket is not None and not cq.bucket.peek(n, now):
                return False        # class cap gates every tenant in it
            b = self._bucket(t)
            if b is None or b.peek(n, now):
                return True
        return False

    def _drain_class(self, cq: _ClassQueue, now: int) -> int:
        """Transmit eligible head packets round-robin across the class's
        tenants while the DRR deficit covers them; returns packets sent."""
        sent = 0
        progress = True
        pfc = self._pfc_until
        while progress and cq.backlog_packets:
            progress = False
            for _ in range(len(cq.order)):
                t = cq.order[0]
                cq.order.rotate(-1)
                q = cq.tenants.get(t)
                if not q:
                    continue
                pkt = q[0]
                if pfc and not pkt.op.is_pfc and pfc.get(
                        (pkt.dest_gid,
                         CLASS_MIG if pkt.op.is_mig else CLASS_APP),
                        0) > now:
                    continue        # PFC-paused toward this destination
                n = pkt.nbytes()
                if cq.deficit < n:
                    continue
                if cq.bucket is not None and not cq.bucket.peek(n, now):
                    continue
                b = self._bucket(t)
                if b is not None and not b.peek(n, now):
                    continue
                q.popleft()
                cq.backlog_packets -= 1
                cq.backlog_bytes -= n
                self.backlog_packets -= 1
                self.backlog_bytes -= n
                cq.deficit -= n
                if cq.bucket is not None:
                    cq.bucket.take(n)
                if b is not None:
                    b.take(n)
                self._transmit(cq, pkt, n, now)
                sent += 1
                progress = True
        return sent

    def _transmit(self, cq: _ClassQueue, pkt: Packet, n: int, now: int):
        self.tx_bytes += n
        self.tx_packets += 1
        cq.tx_bytes += n
        cq.tx_packets += 1
        fl = self.flows.get(pkt.dest_gid)
        if fl is not None:
            fl.queued_bytes -= n
        fab = self.fabric
        trc = fab.tracer
        # the loss check is the fabric rng's only consumer, so a
        # lossless port skips the draw without perturbing any stream
        if fab.loss_prob and fab.rng.random() < fab.loss_prob:
            # serialisation time was spent before the wire dropped it
            fab._in_flight -= 1
            fab.metrics.inc("dropped", gid=self.gid, cls=classify(pkt))
            if trc is not None:
                trc.egress_drop(now, pkt, self.gid)
            return
        if trc is not None:
            trc.egress_tx(now, pkt, self.gid)
        self.delivery.append((now + fab.latency, pkt))

    def service(self, now: int):
        """Spend one step's byte budget via the shared DRR loop;
        eligibility folds in the class cap and tenant buckets, so a
        throttled class returns its unusable share to the pool."""
        if not self.backlog_packets:
            return
        cfg = self.cfg
        if not cfg.enabled:
            # single-FIFO degenerate mode: one class, one tenant, no
            # buckets — the DRR loop reduces exactly to "grant the whole
            # budget, drain heads while the deficit covers them, discard
            # the leftover when the queue empties" (same float
            # arithmetic: share = budget * 1.0 / 1.0)
            budget = self.fabric.bytes_per_step
            if budget <= 1e-9:
                return
            cq = self._class_list[0]
            q = cq.tenants.get(UNATTRIBUTED)
            pfc = self._pfc_until
            if pfc and q:
                pkt = q[0]
                if not pkt.op.is_pfc and pfc.get(
                        (pkt.dest_gid,
                         CLASS_MIG if pkt.op.is_mig else CLASS_APP),
                        0) > now:
                    # PFC-paused head: the single FIFO has no
                    # per-priority lanes, so the pause head-of-line
                    # blocks the whole port (the classic PFC HoL
                    # failure mode, docs/fabric-qos.md). The event-
                    # driven pump skips these steps wholesale, so this
                    # call must stay a strict no-op: no budget granted,
                    # the stored deficit untouched.
                    return
            # deficit rides a local: most calls only accumulate (the
            # head packet outweighs one step's budget), and the float
            # op order is unchanged — one add, one subtract per packet
            d = cq.deficit + budget
            while q:
                pkt = q[0]
                if pfc and not pkt.op.is_pfc and pfc.get(
                        (pkt.dest_gid,
                         CLASS_MIG if pkt.op.is_mig else CLASS_APP),
                        0) > now:
                    break           # pause latched mid-drain: HoL stop
                n = 64 + len(pkt.payload)   # pkt.nbytes(), inlined
                if d < n:
                    break
                q.popleft()
                cq.backlog_packets -= 1
                cq.backlog_bytes -= n
                self.backlog_packets -= 1
                self.backlog_bytes -= n
                d -= n
                self._transmit(cq, pkt, n, now)
            if d > 0 and not cq.backlog_packets:
                d = 0.0             # reclaimed, then discarded unused
            cq.deficit = d
            return
        if cfg.enabled and (cfg.tenant_rate_Bps
                            or cfg.default_tenant_rate_Bps is not None):
            # throttling observability: one count per (tenant, step)
            # whose head packet is waiting on bucket tokens right now.
            # Guarded per call (not cached): set_tenant_rate mutates the
            # shared QoSConfig dicts in place. With no rates configured
            # no bucket can exist, so the class×tenant walk is skipped.
            for cq in self._class_list:
                for t in cq.order:
                    q = cq.tenants.get(t)
                    if not q:
                        continue
                    b = self._bucket(t)
                    if b is not None and not b.peek(q[0].nbytes(), now):
                        self.fabric.metrics.inc("qos_bucket_deferrals",
                                                gid=self.gid)
        _drr_spend(self._class_list,
                   self.fabric.bytes_per_step,
                   lambda cq: self._eligible_head(cq, now),
                   lambda cq: self._drain_class(cq, now))

    # -- PFC pause latches ---------------------------------------------------
    def pfc_frame(self, pkt: Packet, now: int):
        """Apply one PAUSE/UNPAUSE frame addressed to this node: the
        frame's ``src_gid`` is the congested ingress that emitted it, so
        the latch holds *our* traffic toward that node, for the class in
        the payload, until the frame's lifetime (``length`` — the quanta
        field) runs out or an UNPAUSE releases it. Link-level: frames
        terminate here and never reach a QP."""
        cls = pkt.payload.decode()
        key = (pkt.src_gid, cls)
        fab = self.fabric
        if pkt.op is Op.PAUSE:
            # commit/refund accounting: charge the frame's whole
            # lifetime now (a refresh charges only the extension), and
            # refund the unused tail on early release. Totals come out
            # as latched-step spans, but every adjustment happens at a
            # frame event — delivered identically by both pump cores —
            # so an expired latch nobody touches again is already fully
            # accounted and needs no lazy close.
            new_until = now + pkt.length
            until = self._pfc_until.get(key)
            charge = pkt.length if until is None or until <= now \
                else new_until - until
            if charge > 0:
                fab.metrics.inc("pfc_paused_steps", charge,
                                gid=self.gid)
            if until is None or new_until > until:
                self._pfc_until[key] = new_until
        elif key in self._pfc_until:
            self._pfc_release(key, now)

    def _pfc_release(self, key: Tuple[int, str], now: int):
        """Drop one latch, refunding the committed-but-unused tail of
        its lifetime (time past expiry was never charged)."""
        until = self._pfc_until.pop(key)
        refund = until - now
        if refund > 0:
            self.fabric.metrics.inc("pfc_paused_steps", -refund,
                                    gid=self.gid)

    def pfc_clear(self, now: int):
        """Release every latch (PFC disabled mid-run)."""
        for key in list(self._pfc_until):
            self._pfc_release(key, now)

    def pfc_blocked_until(self, now: int) -> int:
        """Earliest step this port's backlog could move again, or
        ``now`` when it is not *provably* pause-blocked. The event-
        driven pump may only skip a service call that is a strict
        no-op, so any unpaused head packet, any queued PFC frame, or
        any configuration whose service call advances bucket or counter
        state forces ``now``."""
        pfc = self._pfc_until
        if not pfc or not self.backlog_packets:
            return now
        cfg = self.cfg
        if cfg.enabled and (cfg.migration_cap is not None
                            or cfg.tenant_rate_Bps
                            or cfg.default_tenant_rate_Bps is not None):
            # service() consults token buckets (whose refill float-op
            # order is per-call) and counts per-step deferrals — a
            # blocked call is not a no-op under those knobs
            return now
        blocked: Optional[int] = None
        for cq in self._class_list:
            if not cq.backlog_packets:
                continue
            for t in cq.order:
                q = cq.tenants.get(t)
                if not q:
                    continue
                pkt = q[0]
                if pkt.op.is_pfc:
                    return now      # PFC frames are never paused
                until = pfc.get(
                    (pkt.dest_gid,
                     CLASS_MIG if pkt.op.is_mig else CLASS_APP), 0)
                if until <= now:
                    return now
                if blocked is None or until < blocked:
                    blocked = until
        return now if blocked is None else blocked

    def pfc_dump(self, dest_gid: int, now: int) -> Dict[str, int]:
        """Remaining pause steps per class toward one destination —
        the slice of latch state that travels in a QP dump (§3.4: the
        sender's view of a paused peer must survive migration)."""
        out: Dict[str, int] = {}
        for (dgid, cls), until in self._pfc_until.items():
            if dgid == dest_gid and until > now:
                out[cls] = until - now
        return out

    def pfc_restore(self, dest_gid: int, spans: Dict[str, int],
                    now: int):
        """Re-arm latches from a dump on the destination node's port: a
        migrated QP resumes *respecting* the pause its old node had
        latched, instead of blasting into the still-congested peer."""
        for cls, rem in spans.items():
            key = (dest_gid, cls)
            until = now + int(rem)
            old = self._pfc_until.get(key)
            if old is None or old < until:
                charge = int(rem) if old is None or old <= now \
                    else until - old
                if charge > 0:
                    self.fabric.metrics.inc("pfc_paused_steps", charge,
                                            gid=self.gid)
                self._pfc_until[key] = until

    # -- delivery ------------------------------------------------------------
    def pop_due(self, now: int):
        dq = self.delivery
        fab = self.fabric
        while dq and dq[0][0] <= now:
            fab._in_flight -= 1
            yield dq.popleft()[1]

    def drop_to(self, gid: int) -> int:
        """Drain every undelivered packet destined to ``gid`` (the node
        departed): scheduler queues and the latency pipe both."""
        dropped = 0
        for cq in self.classes.values():
            for t, q in cq.tenants.items():
                keep = deque()
                for pkt in q:
                    if pkt.dest_gid == gid:
                        dropped += 1
                        n = pkt.nbytes()
                        cq.backlog_packets -= 1
                        cq.backlog_bytes -= n
                        self.backlog_packets -= 1
                        self.backlog_bytes -= n
                    else:
                        keep.append(pkt)
                cq.tenants[t] = keep
        keep = deque()
        for at, pkt in self.delivery:
            if pkt.dest_gid == gid:
                dropped += 1
            else:
                keep.append((at, pkt))
        self.delivery = keep
        self.fabric._in_flight -= dropped
        fl = self.flows.pop(gid, None)
        if fl is not None:
            fl.queued_bytes = 0
        if self._pfc_until:
            # the departed node's pauses die with it (a real peer that
            # vanished can never send the XON frame; its latches would
            # only ride out their lifetime anyway)
            for key in [k for k in self._pfc_until if k[0] == gid]:
                self._pfc_release(key, self.fabric.now)
        return dropped


# ---------------------------------------------------------------------------
# Ingress: receive-side processing capacity + bounded queue + RNR NAKs
# ---------------------------------------------------------------------------


@dataclass
class IngressConfig:
    """Operator knobs for one node's receive path.

    ``rx_bandwidth_Bps=None`` (default) models free receive processing —
    packets pass straight from the wire to the device, byte-identical to
    the egress-only fabric. A finite rate bounds how many bytes the node
    can *process* per step, and ``queue_bytes`` bounds how much can wait
    for processing; overflow of a reliable request draws an RNR NAK back
    at the sender (``rnr_nak=True``) or is silently dropped and left to
    the sender's retransmission timer (``rnr_nak=False``).
    """
    # receive-processing capacity (bytes/s); None = unlimited pass-through
    rx_bandwidth_Bps: Optional[float] = None
    # bound on bytes queued awaiting receive processing (all senders)
    queue_bytes: float = 256 * 1024
    # overflow of a reliable request draws NakCode.RNR at the sender
    rnr_nak: bool = True
    # per-(sender QP) mute window, in fabric steps: one RNR NAK per
    # not-ready episode, not one per dropped packet of the same window
    rnr_nak_interval: int = 32

    def validate(self) -> "IngressConfig":
        if self.rx_bandwidth_Bps is not None and self.rx_bandwidth_Bps <= 0:
            raise ValueError("rx_bandwidth_Bps must be > 0 (or None)")
        if self.queue_bytes <= 0:
            raise ValueError("queue_bytes must be > 0")
        if self.rnr_nak_interval < 1:
            raise ValueError("rnr_nak_interval must be >= 1")
        return self

    @property
    def unlimited(self) -> bool:
        return self.rx_bandwidth_Bps is None


class IngressPort:
    """One node's receive path: finite processing capacity shared across
    every *sender*, mirroring ``EgressPort`` on the other side of the
    wire. Packets whose propagation latency expired land here; the port
    spends one step's receive budget per ``service()`` call handing them
    to the device. Per-class (mig vs app) accounting reuses the same
    ``_ClassQueue``/DRR machinery as egress — with QoS enabled, the
    migration class's configured weights govern whose backlog gets
    processed first; disabled, the queue is a single FIFO.

    Pure control ops (ACK/NAK/RESUME/RESUME_ACK) bypass the bounded
    queue: dropping a peer's ACK to signal local receive pressure would
    amplify the congestion it reports. Overflow of a reliable request
    (SEND/WRITE/READ_REQ/MIG_*) synthesises a ``NakCode.RNR`` NAK toward
    the sending QP — the NIC-level receiver-not-ready signal the IBA
    retry machinery (rnr_retry / min_rnr_timer) is built around."""

    def __init__(self, fabric, gid: int, cfg: IngressConfig,
                 qos: QoSConfig):
        self.fabric = fabric
        self.gid = gid
        self.cfg = cfg.validate()
        self.qos = qos
        self.rx_bytes = 0               # processed (handed to the device)
        self.rx_packets = 0
        # queue backlog, maintained incrementally (mirrors EgressPort)
        self.backlog_bytes = 0
        self.backlog_packets = 0
        self._class_list: List[_ClassQueue] = []
        self._window: Deque[Tuple[int, int]] = deque()  # (step, nbytes)
        self._win_bytes = 0
        # ECN: marking rng distinct from the egress port's stream
        self._ecn_rng = random.Random(fabric.seed * 1_000_003
                                      + gid * 7919 + 2)
        self._mark_window: Deque[Tuple[int, int]] = deque()
        self._mark_bytes = 0
        self._rnr_mute: Dict[Tuple[int, int], int] = {}
        #   ^ (src_gid, src_qpn) -> step until which further RNR NAKs
        #     for that sender are suppressed
        # PFC: classes this queue has XOFF'd, mapped to the step at
        # which the PAUSE broadcast is refreshed (empty when PFC is off)
        self._pfc_latched: Dict[str, int] = {}
        # Order-aware admission state (the NIC owns both this port and
        # the destination QP contexts, so reading the responder's epsn
        # at line rate is exactly what real RNICs do):
        self._inq: Dict[Tuple[int, int], int] = {}
        #   ^ flow -> packets of that flow currently in the queue
        self._run: Dict[Tuple[int, int], int] = {}
        #   ^ flow -> next in-order PSN given what is already queued;
        #     dropped when the flow's last queued packet leaves (then
        #     the responder's epsn is the only truth again)
        self._build_classes()

    def _build_classes(self):
        queued: List[Packet] = []
        for cq in getattr(self, "classes", {}).values():
            queued.extend(cq.drain_all())
        if self.qos.enabled:
            weights = self.qos.effective_weights()
            self.classes = {n: _ClassQueue(n, w)
                            for n, w in weights.items()}
        else:
            self.classes = {CLASS_APP: _ClassQueue(CLASS_APP, 1.0)}
        self._class_list = list(self.classes.values())
        for pkt in queued:
            self._push(pkt)
        self.backlog_bytes = sum(cq.backlog_bytes
                                 for cq in self._class_list)
        self.backlog_packets = sum(cq.backlog_packets
                                   for cq in self._class_list)

    def reconfigure(self, cfg: Optional[IngressConfig] = None,
                    qos: Optional[QoSConfig] = None):
        if cfg is not None:
            self.cfg = cfg.validate()
        if qos is not None:
            self.qos = qos
        self._build_classes()
        if self.cfg.unlimited:          # pass-through: flush the backlog
            for cq in self.classes.values():
                for pkt in cq.drain_all():
                    self.fabric._in_flight -= 1
                    self._deliver(pkt)
            self.backlog_bytes = 0
            self.backlog_packets = 0
            self._inq.clear()
            self._run.clear()
            if self._pfc_latched:
                # an unlimited queue can never sit above XON again:
                # release the senders now instead of making them ride
                # out the latch lifetime
                self._pfc_check_xon(self.fabric.now)

    def _push(self, pkt: Packet):
        cls = classify(pkt) if self.qos.enabled else CLASS_APP
        tenant = (pkt.tenant if self.qos.enabled and pkt.tenant is not None
                  else UNATTRIBUTED)
        self.classes[cls].push(tenant, pkt)
        self.backlog_bytes += pkt.nbytes()
        self.backlog_packets += 1

    # -- capacity ------------------------------------------------------------
    @property
    def rx_bytes_per_step(self) -> float:
        if self.cfg.unlimited:
            return float("inf")
        return self.cfg.rx_bandwidth_Bps * self.fabric.step_s()

    def in_flight(self) -> int:
        return self.backlog_packets

    def window_bytes(self, now: int) -> int:
        self._trim(now)
        return self._win_bytes

    def marking_rate(self, now: int) -> float:
        """Fraction of arriving bytes CE-marked at this queue over the
        trailing window (the destination-side congestion signal)."""
        self._trim(now)
        if self._win_bytes <= 0:
            return 0.0
        return min(1.0, self._mark_bytes / self._win_bytes)

    def _trim(self, now: int):
        horizon = self.fabric.utilization_window
        while self._window and self._window[0][0] <= now - horizon:
            self._win_bytes -= self._window.popleft()[1]
        while self._mark_window and \
                self._mark_window[0][0] <= now - horizon:
            self._mark_bytes -= self._mark_window.popleft()[1]

    # -- arrival (wire latency expired) --------------------------------------
    def enqueue(self, pkt: Packet, now: int):
        if pkt.op.is_pfc:
            # link-level flow control terminates at the port boundary:
            # the frame programs this node's *egress* pause latches and
            # is never delivered, queued, or counted in the rx window
            self.fabric.port(self.gid).pfc_frame(pkt, now)
            return
        n = 64 + len(pkt.payload)       # pkt.nbytes(), inlined (hot)
        # utilization-window upkeep with _trim(now) inlined (per packet)
        w = self._window
        w.append((now, n))
        self._win_bytes += n
        cut = now - self.fabric.utilization_window
        while w[0][0] <= cut:
            self._win_bytes -= w.popleft()[1]
        mw = self._mark_window
        while mw and mw[0][0] <= cut:
            self._mark_bytes -= mw.popleft()[1]
        if self.cfg.unlimited:
            self._deliver(pkt)          # free receive processing (PR 3)
            return
        if pkt.op.is_ctrl:
            self._deliver(pkt)          # control never queues behind data
            return
        fab = self.fabric
        key = (pkt.src_gid, pkt.src_qpn)
        # _qp_epsn, inlined (one lookup per admitted data packet)
        if pkt.op is Op.READ_RESP:
            epsn = None
        else:
            dev = fab._devices.get(self.gid)    # fab.device(), inlined
            qps = getattr(dev, "qps", None)     # bare test doubles
            qp = qps.get(pkt.dest_qpn) if qps is not None else None
            epsn = None if qp is None else qp.epsn
        if epsn is not None:            # order is knowable for this flow
            if pkt.psn < epsn and pkt.op.is_rnr:
                # stale duplicate: line-rate dup-detect in the BTH
                # pipeline answers the cumulative re-ACK itself — the
                # responder already has this payload, so spending queue
                # space and receive-processing on it buys nothing
                # (matches the responder's own psn<epsn re-ACK path)
                fab.metrics.inc("rx_dup_acked", gid=self.gid)
                trc = fab.tracer
                if trc is not None:
                    trc.ingress_drop(now, pkt, self.gid, "dup_acked")
                fab.send(Packet(op=Op.ACK, src_gid=pkt.dest_gid,
                                src_qpn=pkt.dest_qpn,
                                dest_gid=pkt.src_gid,
                                dest_qpn=pkt.src_qpn,
                                psn=epsn - 1))
                return
            run = self._run.get(key)
            exp = epsn if run is None else max(epsn, run)
            if pkt.psn > exp:
                # out-of-order: the go-back-N responder would discard it,
                # so spending bounded queue space and receive-processing
                # cycles on it is pure waste — shed it at admission and
                # (muted) remind the sender where to resume
                self._drop(pkt, now, nak_psn=exp)
                return
            if run is not None and epsn <= pkt.psn < run:
                # duplicate of a packet still sitting in this queue: it
                # will be processed from here, a second copy adds nothing
                fab.metrics.inc("rx_dup_dropped", gid=self.gid)
                trc = fab.tracer
                if trc is not None:
                    trc.ingress_drop(now, pkt, self.gid, "dup_queued")
                return
        if self.backlog_bytes + n > self.cfg.queue_bytes:
            if not fab.pfc.enabled:
                self._drop(pkt, now)
                return
            # lossless mode: real PFC reserves headroom above XOFF for
            # the packets already serialised when the pause fired; we
            # waive the hard bound the same way and admit the packet —
            # the XOFF broadcast below is what stops the influx
            fab.metrics.inc("pfc_headroom_admits", gid=self.gid)
        if epsn is not None and pkt.psn == exp:
            self._run[key] = exp + 1
        self._inq[key] = self._inq.get(key, 0) + 1
        fab.metrics.inc("rx_queued", gid=self.gid)
        fab._in_flight += 1
        self._push(pkt)
        trc = fab.tracer
        if trc is not None:
            trc.ingress_queue(now, pkt, self.gid, self.backlog_bytes)
        ecn = fab.ecn
        if ecn.enabled and ecn.mark_ingress:
            # RED against the bounded queue itself: marking starts at
            # ~kmin occupancy, well before overflow draws an RNR NAK —
            # the DCQCN ordering (slow down first, drop last)
            occ = self.backlog_bytes / self.cfg.queue_bytes
            if maybe_mark(fab, self._ecn_rng, pkt, occ, self.gid,
                          where="ingress"):
                self._mark_window.append((now, n))
                self._mark_bytes += n
        if fab.pfc.enabled:
            self._pfc_check_xoff(now)

    # -- PFC watermark machinery ---------------------------------------------
    def _pfc_occupancy(self, cls: str) -> float:
        """Occupancy a class's watermarks are judged against. With QoS
        class queues this is the class's OWN backlog (802.1Qbb pauses on
        the priority's buffer usage — another priority's standing queue
        must not hold this one's latch closed, or a sustained app incast
        would starve the migration class forever). In single-FIFO mode
        there is only the shared counter, so every class reads total
        occupancy — global-pause semantics, with the HoL caveat the
        docs spell out."""
        if self.qos.enabled:
            cq = self.classes.get(cls)
            if cq is None:
                return 0.0
            return cq.backlog_bytes / self.cfg.queue_bytes
        return self.backlog_bytes / self.cfg.queue_bytes

    def _pfc_check_xoff(self, now: int):
        """Pause any class whose XOFF watermark its queue has crossed,
        and refresh latches still above XON before their lifetime runs
        out."""
        pfc = self.fabric.pfc
        latched = self._pfc_latched
        for cls, hi in pfc.xoff.items():
            occ = self._pfc_occupancy(cls)
            refresh_at = latched.get(cls)
            if refresh_at is None:
                if occ >= hi:
                    latched[cls] = now + pfc.refresh_steps
                    self._pfc_broadcast(Op.PAUSE, cls, now, occ)
            elif now >= refresh_at and occ > pfc.xon[cls]:
                # still above XON at refresh time: keep senders latched
                # through the hysteresis band (a lapsed latch here would
                # re-fill the queue and oscillate — the pause storm)
                latched[cls] = now + pfc.refresh_steps
                self._pfc_broadcast(Op.PAUSE, cls, now, occ)

    def _pfc_check_xon(self, now: int):
        """Release any latched class whose XON watermark its drained
        queue has fallen back to (called on every service exit path, so
        the call that empties the queue always releases)."""
        pfc = self.fabric.pfc
        if not pfc.enabled:
            self._pfc_latched.clear()   # disabled mid-run: forget
            return
        for cls in [c for c in sorted(self._pfc_latched)
                    if self._pfc_occupancy(c) <= pfc.xon.get(c, 1.0)]:
            del self._pfc_latched[cls]
            self._pfc_broadcast(Op.UNPAUSE, cls, now,
                                self._pfc_occupancy(cls))

    def _pfc_broadcast(self, op: Op, cls: str, now: int, occ: float):
        """Send one PAUSE/UNPAUSE frame to every node that has ever sent
        to us (sorted for determinism). The frames ride the ordinary
        egress + latency wire path — flow control is not magic; a pause
        can itself be delayed behind the congestion it answers."""
        fab = self.fabric
        targets = sorted(g for g, p in fab._ports.items()
                         if g != self.gid and self.gid in p.flows)
        pause = op is Op.PAUSE
        length = fab.pfc.pause_steps if pause else 0
        name = "pfc_pause_frames" if pause else "pfc_resume_frames"
        for g in targets:
            fab.metrics.inc(name, gid=self.gid)
            fab.send(Packet(op=op, src_gid=self.gid, src_qpn=0,
                            dest_gid=g, dest_qpn=0,
                            payload=cls.encode(), length=length))
        trc = fab.tracer
        if trc is not None:
            if pause:
                trc.pfc_pause(now, self.gid, cls, occ, len(targets))
            else:
                trc.pfc_resume(now, self.gid, cls, occ, len(targets))

    def _qp_epsn(self, pkt: Packet) -> Optional[int]:
        """Responder epsn of the destination QP, or None when order is
        unknowable (responses carry the request's PSN; an unknown QPN is
        the device's problem to count)."""
        if pkt.op == Op.READ_RESP:
            return None
        dev = self.fabric.device(self.gid)
        qps = getattr(dev, "qps", None)     # bare test doubles have none
        qp = qps.get(pkt.dest_qpn) if qps is not None else None
        return None if qp is None else qp.epsn

    def _drop(self, pkt: Packet, now: int, nak_psn: Optional[int] = None):
        self.fabric.metrics.inc("rx_dropped", gid=self.gid)
        trc = self.fabric.tracer
        if trc is not None:
            trc.ingress_drop(now, pkt, self.gid,
                             "out_of_order" if nak_psn is not None
                             else "overflow")
        if self.cfg.rnr_nak and pkt.op.is_rnr:
            self._emit_rnr_nak(pkt, now, psn=nak_psn)

    def _note_dequeue(self, pkt: Packet):
        key = (pkt.src_gid, pkt.src_qpn)
        left = self._inq.get(key)
        if left is None:
            return
        if left <= 1:
            self._inq.pop(key, None)
            self._run.pop(key, None)
        else:
            self._inq[key] = left - 1

    def _emit_rnr_nak(self, pkt: Packet, now: int,
                      psn: Optional[int] = None):
        """NIC-level receiver-not-ready: one NAK per not-ready episode
        (the requester retransmits its whole unacknowledged window after
        min_rnr_timer, so the NAK is a backoff signal, not a byte-exact
        retransmit pointer); further drops from the same QP are muted
        for rnr_nak_interval steps so one congested receiver does not
        answer an incast burst with a NAK storm."""
        key = (pkt.src_gid, pkt.src_qpn)
        if now < self._rnr_mute.get(key, -1):
            return
        self._rnr_mute[key] = now + self.cfg.rnr_nak_interval
        self.fabric.metrics.inc("rnr_naks", gid=self.gid)
        trc = self.fabric.tracer
        if trc is not None:
            trc.rnr_nak(now, self.gid, "ingress", pkt.src_gid,
                        pkt.src_qpn, psn if psn is not None else pkt.psn)
        self.fabric.send(Packet(op=Op.NAK, src_gid=pkt.dest_gid,
                                src_qpn=pkt.dest_qpn,
                                dest_gid=pkt.src_gid,
                                dest_qpn=pkt.src_qpn,
                                psn=psn if psn is not None else pkt.psn,
                                nak_code=NakCode.RNR))

    # -- processing ----------------------------------------------------------
    def _deliver(self, pkt: Packet):
        self.rx_bytes += 64 + len(pkt.payload)  # pkt.nbytes(), inlined
        self.rx_packets += 1
        fab = self.fabric
        dev = fab._devices.get(pkt.dest_gid)    # fab.device(), inlined
        if dev is None:
            # [MIGR] old address
            fab.metrics.inc("unroutable", gid=self.gid)
            return
        trc = fab.tracer
        if trc is not None:
            trc.ingress_deliver(fab.now, pkt, self.gid)
        dev.receive(pkt)

    def service(self, now: int):
        """Spend one step's receive-processing budget via the shared DRR
        loop (no tenant buckets on ingress: rate policy is an egress
        concern; here the weights only arbitrate whose backlog drains
        first)."""
        if not self.backlog_packets or self.cfg.unlimited:
            return
        if not self.qos.enabled:
            # single-FIFO degenerate mode, mirroring EgressPort.service:
            # one class, one tenant, eligibility is just backlog — the
            # DRR loop reduces to spend-then-drain with the same floats
            budget = self.rx_bytes_per_step
            if budget <= 1e-9:
                return
            cq = self._class_list[0]
            # local deficit accumulator, as in EgressPort.service: same
            # float op order, one attribute write instead of several
            d = cq.deficit + budget
            q = cq.tenants.get(UNATTRIBUTED)
            while q:
                n = 64 + len(q[0].payload)  # pkt.nbytes(), inlined
                if d < n:
                    break
                pkt = q.popleft()
                cq.backlog_packets -= 1
                cq.backlog_bytes -= n
                self.backlog_packets -= 1
                self.backlog_bytes -= n
                self.fabric._in_flight -= 1
                d -= n
                cq.tx_bytes += n
                cq.tx_packets += 1
                self._note_dequeue(pkt)
                self._deliver(pkt)
            if d > 0 and not cq.backlog_packets:
                d = 0.0             # reclaimed, then discarded unused
            cq.deficit = d
            if self._pfc_latched:
                self._pfc_check_xon(now)
            return
        _drr_spend(self._class_list, self.rx_bytes_per_step,
                   lambda cq: cq.backlog_packets > 0, self._drain)
        if self._pfc_latched:
            self._pfc_check_xon(now)

    def _drain(self, cq: _ClassQueue) -> int:
        sent = 0
        progress = True
        while progress and cq.backlog_packets:
            progress = False
            for _ in range(len(cq.order)):
                t = cq.order[0]
                q = cq.tenants.get(t)
                if not q:
                    cq.order.rotate(-1)
                    continue
                n = q[0].nbytes()
                if cq.deficit < n:
                    # out of budget at THIS tenant: stop with the
                    # round-robin pointer parked here, so the deficit
                    # that accumulates across service calls belongs to
                    # it. The old shape (rotate on every check, full
                    # net rotation per pass) restarted each call at the
                    # same head tenant — in the sub-packet-per-step
                    # budget regime that starved everyone else forever
                    # once losses stopped interfering (PFC lossless
                    # mode made it reproducible).
                    return sent
                cq.order.rotate(-1)
                pkt = q.popleft()
                cq.backlog_packets -= 1
                cq.backlog_bytes -= n
                self.backlog_packets -= 1
                self.backlog_bytes -= n
                self.fabric._in_flight -= 1
                cq.deficit -= n
                cq.tx_bytes += n
                cq.tx_packets += 1
                self._note_dequeue(pkt)
                self._deliver(pkt)
                sent += 1
                progress = True
        return sent

    def drop_all(self) -> int:
        """Drain the whole queue (the node departed): every packet here
        was addressed to this gid, so all of them are unroutable now."""
        dropped = 0
        for cq in self.classes.values():
            dropped += len(cq.drain_all())
        self.backlog_bytes = 0
        self.backlog_packets = 0
        self.fabric._in_flight -= dropped
        self._inq.clear()
        self._run.clear()
        # departed node: no UNPAUSE broadcast — its senders' latches
        # self-release when their lifetime runs out (the progress
        # guarantee against a vanished pause issuer)
        self._pfc_latched.clear()
        return dropped
