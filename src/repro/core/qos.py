"""NIC-port QoS: traffic classes, weighted-fair scheduling, token buckets.

The fabric's wire model (paper §4.2: the SoftRoCE role) used to give every
(src, dest) pair a private full-bandwidth FIFO. A real NIC has one egress
port per node whose capacity is *summed over all destinations*, and a
converged dataplane (migration traffic riding the application fabric, the
CoRD argument) makes that port a contended resource: one container's burst
can starve a co-located migration stream or another tenant (the noisy-
neighbor failure mode). This module is the scheduler that sits on that
port:

* two **traffic classes** — ``mig`` (service-channel ``MIG_*`` packets,
  the migration data plane of §3.2/§3.4) and ``app`` (everything else) —
  arbitrated by weighted deficit-round-robin; operators either *cap*
  migration bandwidth (hard ceiling, non-work-conserving) or *guarantee*
  it a minimum share (weight floor, work-conserving);
* **per-tenant token buckets** keyed by the container that owns the
  sending QP, so a tenant's sustained rate is bounded while short bursts
  ride the bucket depth;
* **work conservation** across everything that is not explicitly capped:
  bandwidth an idle or bucket-throttled sender cannot use is immediately
  available to everyone else.

With QoS disabled (the default) every port degenerates to a single
first-come-first-served queue and no bucket is consulted — scheduling
adds nothing when it is not asked for, restating the paper's
"no overhead when migration does not happen" claim for bandwidth
arbitration.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.packets import MIG_OPS, Packet

# traffic-class names (per-class fabric.stats counters use these keys)
CLASS_APP = "app"
CLASS_MIG = "mig"

# tenant key for packets nobody claimed (kernel QPs before tagging, bare
# test fixtures): they ride the app class unbucketed unless an operator
# configures a rate for this exact key
UNATTRIBUTED = "_unattributed"


def classify(pkt: Packet) -> str:
    """Traffic class of one packet: the migration data plane is exactly
    the service-channel MIG_* ops; everything else is application."""
    return CLASS_MIG if pkt.op in MIG_OPS else CLASS_APP


@dataclass
class QoSConfig:
    """Operator knobs for the per-port scheduler (docs/fabric-qos.md is
    the operator guide; every field is validated at attach time).

    ``enabled=False`` (default) bypasses classes and buckets entirely:
    one FIFO per port, byte-identical arbitration to a single queue.
    """
    enabled: bool = False
    # weighted-fair class arbitration (shares are weight / sum(weights)
    # over backlogged classes)
    app_weight: float = 1.0
    mig_weight: float = 1.0
    # hard ceiling on the migration class, as a fraction of port bandwidth
    # (non-work-conserving: held even when the app class is idle)
    migration_cap: Optional[float] = None
    # minimum share guaranteed to a backlogged migration class, as a
    # fraction of port bandwidth (implemented as a weight floor, so it is
    # work-conserving: an idle migration class cedes it back)
    migration_guarantee: Optional[float] = None
    # per-tenant sustained rate (bytes/s) and burst depth (bytes); tenants
    # not listed are unthrottled unless default_tenant_rate_Bps is set
    tenant_rate_Bps: Dict[str, float] = field(default_factory=dict)
    tenant_burst_bytes: Dict[str, float] = field(default_factory=dict)
    default_tenant_rate_Bps: Optional[float] = None
    default_burst_bytes: float = 64 * 1024

    def validate(self) -> "QoSConfig":
        if self.app_weight <= 0 or self.mig_weight <= 0:
            raise ValueError("class weights must be > 0")
        for name, frac in (("migration_cap", self.migration_cap),
                           ("migration_guarantee",
                            self.migration_guarantee)):
            if frac is not None and not (0.0 < frac <= 1.0):
                raise ValueError(f"{name} must be in (0, 1], got {frac}")
        if (self.migration_cap is not None
                and self.migration_guarantee is not None
                and self.migration_cap < self.migration_guarantee):
            raise ValueError("migration_cap below migration_guarantee")
        for t, r in self.tenant_rate_Bps.items():
            if r <= 0:
                raise ValueError(f"tenant {t!r} rate must be > 0")
        return self

    def effective_weights(self) -> Dict[str, float]:
        """Class weights with the migration guarantee folded in: a
        guarantee g needs mig/(mig+app) >= g, i.e. a weight floor of
        g/(1-g) * app_weight (g=1 degenerates to mig-only)."""
        w_mig = self.mig_weight
        g = self.migration_guarantee
        if g is not None:
            if g >= 1.0:
                w_mig = float("inf")
            else:
                w_mig = max(w_mig, g / (1.0 - g) * self.app_weight)
        return {CLASS_APP: self.app_weight, CLASS_MIG: w_mig}

    def bucket_for(self, tenant: str) -> Optional[Tuple[float, float]]:
        """(rate_Bps, burst_bytes) for a tenant, or None (unthrottled).

        The default rate applies to *containers* only: the kernel
        service tenants (``_kernel@gid``) and unattributed packets are
        exempt unless an operator names that exact key — a blanket
        default must not throttle the migration data plane below the
        class share the cap/guarantee knobs govern."""
        rate = self.tenant_rate_Bps.get(tenant)
        if rate is None:
            if tenant == UNATTRIBUTED or tenant.startswith("_kernel@"):
                return None
            rate = self.default_tenant_rate_Bps
        if rate is None:
            return None
        burst = self.tenant_burst_bytes.get(tenant,
                                            self.default_burst_bytes)
        # floor: a bucket shallower than one max-size packet could never
        # pass anything and would wedge the tenant's FIFO forever
        return rate, max(burst, 4096.0)


class TokenBucket:
    """Deterministic token bucket in fabric-step time: refill is a pure
    function of the step delta (rate_per_step * elapsed), so identical
    runs refill identically — no wall clock anywhere."""

    __slots__ = ("rate_per_step", "burst", "tokens", "last")

    def __init__(self, rate_per_step: float, burst: float,
                 now: int = 0):
        self.rate_per_step = rate_per_step
        self.burst = float(burst)
        self.tokens = float(burst)          # starts full: bursts ride it
        self.last = now

    def refill(self, now: int):
        if now > self.last:
            self.tokens = min(self.burst,
                              self.tokens
                              + (now - self.last) * self.rate_per_step)
            self.last = now

    def peek(self, n: int, now: int) -> bool:
        self.refill(now)
        return self.tokens >= n

    def take(self, n: int):
        self.tokens -= n


class _ClassQueue:
    """One traffic class on one port: per-tenant FIFOs served round-robin
    plus the class's DRR deficit counter."""

    __slots__ = ("name", "weight", "tenants", "order", "deficit",
                 "backlog_bytes", "backlog_packets", "bucket",
                 "tx_bytes", "tx_packets")

    def __init__(self, name: str, weight: float):
        self.name = name
        self.weight = weight
        self.tenants: Dict[str, Deque[Packet]] = {}
        self.order: Deque[str] = deque()      # round-robin tenant order
        self.deficit = 0.0
        self.backlog_bytes = 0
        self.backlog_packets = 0
        self.bucket: Optional[TokenBucket] = None   # class cap (mig)
        self.tx_bytes = 0
        self.tx_packets = 0

    def push(self, tenant: str, pkt: Packet):
        q = self.tenants.get(tenant)
        if q is None:
            q = self.tenants[tenant] = deque()
            self.order.append(tenant)
        q.append(pkt)
        self.backlog_bytes += pkt.nbytes()
        self.backlog_packets += 1

    def drain_all(self) -> List[Packet]:
        """Remove and return every queued packet (tenant-RR order);
        used when a port is re-built under a new QoS config."""
        out: List[Packet] = []
        while self.backlog_packets:
            for t in list(self.order):
                q = self.tenants[t]
                if q:
                    out.append(q.popleft())
                    self.backlog_packets -= 1
                    self.backlog_bytes -= out[-1].nbytes()
        self.tenants.clear()
        self.order.clear()
        self.deficit = 0.0
        return out


class _Flow:
    """Per-(src, dest) accounting view, kept for observability and test
    compatibility with the old per-pair Link objects: ``tx_*`` counts at
    enqueue, ``queued_bytes`` is the not-yet-transmitted backlog, and
    ``busy_until`` is the step the backlog would clear at port rate."""

    __slots__ = ("port", "tx_bytes", "tx_packets", "queued_bytes")

    def __init__(self, port: "EgressPort"):
        self.port = port
        self.tx_bytes = 0
        self.tx_packets = 0
        self.queued_bytes = 0

    @property
    def busy_until(self) -> float:
        bps = self.port.fabric.bytes_per_step
        if bps <= 0:
            return float(self.port.fabric.now)
        return self.port.fabric.now + self.queued_bytes / bps


class EgressPort:
    """One node's NIC egress port: finite bandwidth shared across every
    destination, arbitrated by the QoS scheduler above. The port is
    step-driven like the rest of the fabric: each ``service()`` call
    spends one step's byte budget (``fabric.bytes_per_step``) on queued
    packets; budget a class saves toward an oversized head-of-line packet
    persists in its DRR deficit, budget nobody can use is discarded (an
    idle wire transmits nothing retroactively)."""

    def __init__(self, fabric, gid: int, cfg: QoSConfig):
        self.fabric = fabric
        self.gid = gid
        self.cfg = cfg
        self.classes: Dict[str, _ClassQueue] = {}
        self.buckets: Dict[str, TokenBucket] = {}   # tenant -> bucket
        self.delivery: Deque[Tuple[int, Packet]] = deque()
        self.flows: Dict[int, _Flow] = {}           # dest gid -> view
        self.tx_bytes = 0                           # transmitted (wire)
        self.tx_packets = 0
        self._window: Deque[Tuple[int, int]] = deque()  # (enq_at, nbytes)
        self._win_bytes = 0
        self._build_classes()

    # -- configuration -------------------------------------------------------
    def _build_classes(self):
        queued = []
        for cq in self.classes.values():
            queued.extend(cq.drain_all())
        if self.cfg.enabled:
            weights = self.cfg.effective_weights()
            self.classes = {n: _ClassQueue(n, w)
                            for n, w in weights.items()}
            cap = self.cfg.migration_cap
            if cap is not None:
                rate = cap * self.fabric.bytes_per_step
                # burst: a handful of steps' worth so the cap is a rate,
                # not a per-step quantisation artefact
                self.classes[CLASS_MIG].bucket = TokenBucket(
                    rate, max(8 * rate, 8192.0), self.fabric.now)
        else:
            self.classes = {CLASS_APP: _ClassQueue(CLASS_APP, 1.0)}
        for pkt in queued:              # re-queue under the new shape
            self._class_of(pkt).push(self._tenant_of(pkt), pkt)

    def reconfigure(self, cfg: QoSConfig):
        self.cfg = cfg.validate()
        self.buckets.clear()            # rebuilt lazily per tenant
        self._build_classes()

    def on_bandwidth_change(self):
        """Port rate changed: the mig-cap bucket is priced off it."""
        self._build_classes()

    def _class_of(self, pkt: Packet) -> _ClassQueue:
        if not self.cfg.enabled:
            return self.classes[CLASS_APP]
        return self.classes[classify(pkt)]

    def _tenant_of(self, pkt: Packet) -> str:
        if not self.cfg.enabled:
            # one FIFO per port: strict arrival order, no arbitration —
            # byte-identical to the pre-QoS shared-queue wire model
            return UNATTRIBUTED
        return pkt.tenant if pkt.tenant is not None else UNATTRIBUTED

    def _bucket(self, tenant: str) -> Optional[TokenBucket]:
        if not self.cfg.enabled:
            return None
        b = self.buckets.get(tenant)
        if b is None and tenant not in self.buckets:
            spec = self.cfg.bucket_for(tenant)
            b = None if spec is None else TokenBucket(
                spec[0] * self.fabric.step_s(), spec[1], self.fabric.now)
            self.buckets[tenant] = b
        return b

    def flow(self, dest_gid: int) -> _Flow:
        fl = self.flows.get(dest_gid)
        if fl is None:
            fl = self.flows[dest_gid] = _Flow(self)
        return fl

    # -- enqueue (called from Fabric.send) -----------------------------------
    def enqueue(self, pkt: Packet, now: int):
        n = pkt.nbytes()
        fl = self.flow(pkt.dest_gid)
        fl.tx_bytes += n
        fl.tx_packets += 1
        fl.queued_bytes += n
        self._window.append((now, n))
        self._win_bytes += n
        self._trim(now)
        self._class_of(pkt).push(self._tenant_of(pkt), pkt)

    # -- utilization window --------------------------------------------------
    def _trim(self, now: int):
        horizon = self.fabric.utilization_window
        while self._window and self._window[0][0] <= now - horizon:
            self._win_bytes -= self._window.popleft()[1]

    def window_bytes(self, now: int) -> int:
        self._trim(now)
        return self._win_bytes

    @property
    def backlog_bytes(self) -> int:
        return sum(cq.backlog_bytes for cq in self.classes.values())

    @property
    def backlog_packets(self) -> int:
        return sum(cq.backlog_packets for cq in self.classes.values())

    def in_flight(self) -> int:
        return self.backlog_packets + len(self.delivery)

    # -- the scheduler -------------------------------------------------------
    def _eligible_head(self, cq: _ClassQueue, now: int) -> bool:
        """True iff some tenant FIFO in the class has a head packet the
        buckets would let on the wire right now."""
        if not cq.backlog_packets:
            return False
        for t in cq.order:
            q = cq.tenants.get(t)
            if not q:
                continue
            n = q[0].nbytes()
            if cq.bucket is not None and not cq.bucket.peek(n, now):
                return False        # class cap gates every tenant in it
            b = self._bucket(t)
            if b is None or b.peek(n, now):
                return True
        return False

    def _drain_class(self, cq: _ClassQueue, now: int) -> int:
        """Transmit eligible head packets round-robin across the class's
        tenants while the DRR deficit covers them; returns packets sent."""
        sent = 0
        progress = True
        while progress and cq.backlog_packets:
            progress = False
            for _ in range(len(cq.order)):
                t = cq.order[0]
                cq.order.rotate(-1)
                q = cq.tenants.get(t)
                if not q:
                    continue
                pkt = q[0]
                n = pkt.nbytes()
                if cq.deficit < n:
                    continue
                if cq.bucket is not None and not cq.bucket.peek(n, now):
                    continue
                b = self._bucket(t)
                if b is not None and not b.peek(n, now):
                    continue
                q.popleft()
                cq.backlog_packets -= 1
                cq.backlog_bytes -= n
                cq.deficit -= n
                if cq.bucket is not None:
                    cq.bucket.take(n)
                if b is not None:
                    b.take(n)
                self._transmit(cq, pkt, n, now)
                sent += 1
                progress = True
        return sent

    def _transmit(self, cq: _ClassQueue, pkt: Packet, n: int, now: int):
        self.tx_bytes += n
        self.tx_packets += 1
        cq.tx_bytes += n
        cq.tx_packets += 1
        fl = self.flows.get(pkt.dest_gid)
        if fl is not None:
            fl.queued_bytes -= n
        fab = self.fabric
        if fab.rng.random() < fab.loss_prob:
            # serialisation time was spent before the wire dropped it
            fab.stats["dropped"] += 1
            return
        self.delivery.append((now + fab.latency, pkt))

    def service(self, now: int):
        """Spend one step's byte budget. Weighted sharing happens by
        handing each *eligible* class its weight-proportional slice of
        the remaining budget; a class that empties (or throttles) returns
        its unusable deficit to the pool, so the port is work-conserving
        across everything the caps and buckets allow."""
        if not self.backlog_packets:
            return
        # throttling observability: one count per (tenant, step) whose
        # head packet is waiting on bucket tokens right now
        for cq in self.classes.values():
            for t in cq.order:
                q = cq.tenants.get(t)
                if not q:
                    continue
                b = self._bucket(t)
                if b is not None and not b.peek(q[0].nbytes(), now):
                    self.fabric.stats["qos_bucket_deferrals"] += 1
        budget = self.fabric.bytes_per_step
        for _ in range(4):              # redistribution rounds
            elig = [cq for cq in self.classes.values()
                    if self._eligible_head(cq, now)]
            if not elig or budget <= 1e-9:
                break
            if any(cq.weight == float("inf") for cq in elig):
                wsum = sum(1.0 for cq in elig
                           if cq.weight == float("inf"))
                shares = [(cq, budget / wsum
                           if cq.weight == float("inf") else 0.0)
                          for cq in elig]
            else:
                wsum = sum(cq.weight for cq in elig)
                shares = [(cq, budget * cq.weight / wsum) for cq in elig]
            budget = 0.0
            sent_any = 0
            for cq, share in shares:
                cq.deficit += share
                sent_any += self._drain_class(cq, now)
            # reclaim deficit stranded in classes with nothing eligible
            for cq in self.classes.values():
                if cq.deficit > 0 and not self._eligible_head(cq, now):
                    budget += cq.deficit
                    cq.deficit = 0.0
            if not sent_any and budget <= 1e-9:
                break       # every eligible class is saving for a big head

    # -- delivery ------------------------------------------------------------
    def pop_due(self, now: int):
        dq = self.delivery
        while dq and dq[0][0] <= now:
            yield dq.popleft()[1]

    def drop_to(self, gid: int) -> int:
        """Drain every undelivered packet destined to ``gid`` (the node
        departed): scheduler queues and the latency pipe both."""
        dropped = 0
        for cq in self.classes.values():
            for t, q in cq.tenants.items():
                keep = deque()
                for pkt in q:
                    if pkt.dest_gid == gid:
                        dropped += 1
                        cq.backlog_packets -= 1
                        cq.backlog_bytes -= pkt.nbytes()
                    else:
                        keep.append(pkt)
                cq.tenants[t] = keep
        keep = deque()
        for at, pkt in self.delivery:
            if pkt.dest_gid == gid:
                dropped += 1
            else:
                keep.append((at, pkt))
        self.delivery = keep
        fl = self.flows.pop(gid, None)
        if fl is not None:
            fl.queued_bytes = 0
        return dropped
