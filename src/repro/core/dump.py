"""Checkpoint/restore API for verbs objects (paper §3.2, Listing 1).

``dump_context`` is atomic: it first moves every QP of the context to
STOPPED (so the 'NIC' can no longer mutate state behind the OS's back),
then serialises all objects. ``restore_object`` applies per-object recovery
commands: CREATE (with QPN/MRN pinning via the last-id mechanism),
SET_MR_KEYS, and REFILL (rings, PSNs, in-flight task state + queueing the
resume message). MR *contents* are not part of the verbs dump — they travel
with the container memory image, exactly as in CRIU (paper §3.2).
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List

import msgpack

from repro.core.packets import NakCode, Op, Packet
from repro.core.qos import CongestionControl
from repro.core.states import QPState
from repro.core.verbs import (CompletionQueue, Context, MemoryRegion,
                              ProtectionDomain, QueuePair, RecvWR, SendWR,
                              SGE, SharedReceiveQueue, WCStatus,
                              WorkCompletion)

DUMP_VERSION = 1


# ---------------------------------------------------------------------------
# serialisation helpers
# ---------------------------------------------------------------------------


def _wc(wc: WorkCompletion) -> dict:
    return {"wr_id": wc.wr_id, "status": wc.status.value,
            "opcode": wc.opcode, "byte_len": wc.byte_len, "qpn": wc.qpn}


def _sge(s: SGE) -> dict:
    return {"mrn": s.mr.mrn, "offset": s.offset, "length": s.length}


def _send_wr(wr: SendWR) -> dict:
    return {"wr_id": wr.wr_id, "op": wr.opcode.value, "sge": _sge(wr.sge),
            "raddr": wr.raddr, "rkey": wr.rkey, "sent": wr.sent,
            "first_psn": wr.first_psn, "last_psn": wr.last_psn}


def _recv_wr(wr: RecvWR) -> dict:
    return {"wr_id": wr.wr_id, "sge": _sge(wr.sge),
            "received": wr.received}


def _packet(p: Packet) -> dict:
    d = {"op": p.op.value, "src_gid": p.src_gid, "src_qpn": p.src_qpn,
         "dest_gid": p.dest_gid, "dest_qpn": p.dest_qpn, "psn": p.psn,
         "payload": bytes(p.payload), "raddr": p.raddr, "rkey": p.rkey,
         "length": p.length, "first": p.first, "last": p.last,
         "wr_id": p.wr_id}
    # conditional keys: images from ECN-off runs stay byte-identical to
    # the pre-ECN format (their size is on the wire-timing fast path)
    if p.ect:
        d["ect"] = True
    return d


def dump_object(obj) -> dict:
    """Single-object dump (sizes of these are the paper's Table 2)."""
    if isinstance(obj, ProtectionDomain):
        return {"type": "PD", "pdn": obj.pdn}
    if isinstance(obj, MemoryRegion):
        return {"type": "MR", "mrn": obj.mrn, "size": obj.size,
                "lkey": obj.lkey, "rkey": obj.rkey, "pdn": obj.pd.pdn}
    if isinstance(obj, CompletionQueue):
        return {"type": "CQ", "cqn": obj.cqn, "depth": obj.depth,
                "head": obj.head, "tail": obj.tail,
                "ring": [_wc(w) for w in obj.ring]}
    if isinstance(obj, SharedReceiveQueue):
        d = {"type": "SRQ", "srqn": obj.srqn,
             "queue": [_recv_wr(r) for r in obj.queue]}
        if obj.limit or obj.armed:      # SRQ_LIMIT watermark attrs
            d["limit"] = obj.limit
            d["armed"] = obj.armed
        return d
    if isinstance(obj, QueuePair):
        d = {"type": "QP", "qpn": obj.qpn, "state": obj.state.value,
             "dest_gid": obj.dest_gid, "dest_qpn": obj.dest_qpn,
             "pdn": obj.pd.pdn, "send_cqn": obj.send_cq.cqn,
             "recv_cqn": obj.recv_cq.cqn,
             "srqn": obj.srq.srqn if obj.srq else None,
             # requester/responder/completer ("QP tasks") state:
             "sq_psn": obj.sq_psn, "una": obj.una, "epsn": obj.epsn,
             # operator-set RNR attributes follow the QP across a
             # migration (transient rnr_tries/backoff state does not:
             # the resume handshake restarts the window anyway)
             "rnr_retry": obj.rnr_retry,
             "min_rnr_timer": obj.min_rnr_timer,
             "sq": [_send_wr(w) for w in obj.sq],
             "rq": [_recv_wr(w) for w in obj.rq],
             "inflight": [_packet(p) for p in obj.inflight],
             "pending_comp": [list(t) for t in obj.pending_comp],
             "cur_wqe": _send_wr(obj.cur_wqe) if obj.cur_wqe else None,
             "cur_rr": _recv_wr(obj.cur_rr) if obj.cur_rr else None}
        # DCQCN congestion state travels with the QP — the headline
        # paper tie-in (§3.4): rate limiters / alpha estimators are NIC
        # state the OS can checkpoint because it owns the model, so a
        # migrated sender resumes at its *learned* rate, not line rate.
        # Conditional keys keep ECN-off images byte-identical.  # [ECN]
        if obj.cc is not None:
            fab = obj.device.fabric
            if fab.ecn.enabled:
                # event scheduler: a parked QP's per-step DCQCN clock is
                # replayed lazily — materialise it through ``now`` so
                # the image captures the same tokens/timer phases the
                # exhaustive scan maintained eagerly
                obj.cc.advance(fab.now, fab.bytes_per_step)
            d["cc"] = obj.cc.dump(fab.now)
        if obj.cnps_sent:
            d["cnps_sent"] = obj.cnps_sent
        # PFC: the sender's view of a paused peer (remaining pause
        # steps per class toward this QP's destination) travels with
        # the QP, so a migrated sender resumes *respecting* the pause
        # instead of blasting into the still-congested receiver.
        # Conditional key keeps PFC-off images byte-identical.  # [PFC]
        fab = obj.device.fabric
        if fab.pfc.enabled:
            rem = fab.port(obj.device.gid).pfc_dump(obj.dest_gid,
                                                    fab.now)
            if rem:
                d["pfc"] = rem
        return d
    raise TypeError(type(obj))


def dump_context(ctx: Context, *, stop: bool = True) -> bytes:
    """Atomic dump of every verbs object in the context.       # [MIGR]

    Stops all QPs first so no packet processing can race the dump
    (the paper runs this in the kernel for the same reason)."""
    if stop:
        for qp in ctx.qps:                                       # [MIGR]
            if qp.state in (QPState.RTS, QPState.RTR, QPState.SQD):
                qp.modify(QPState.STOPPED, system=True)          # [MIGR]
    image = {
        "version": DUMP_VERSION,
        "gid": ctx.device.gid,
        "pds": [dump_object(p) for p in ctx.pds],
        "mrs": [dump_object(m) for m in ctx.mrs],
        "cqs": [dump_object(c) for c in ctx.cqs],
        "srqs": [dump_object(s) for s in ctx.srqs],
        "qps": [dump_object(q) for q in ctx.qps],
    }
    return msgpack.packb(image, use_bin_type=True)


# ---------------------------------------------------------------------------
# restore
# ---------------------------------------------------------------------------


class RestoreSession:
    """Tracks id→object maps while a context image is restored."""

    def __init__(self, ctx: Context):
        self.ctx = ctx
        self.pd_by_n: Dict[int, ProtectionDomain] = {}
        self.mr_by_n: Dict[int, MemoryRegion] = {}
        self.cq_by_n: Dict[int, CompletionQueue] = {}
        self.srq_by_n: Dict[int, SharedReceiveQueue] = {}
        self.qp_by_n: Dict[int, QueuePair] = {}

    def _rsge(self, d) -> SGE:
        return SGE(self.mr_by_n[d["mrn"]], d["offset"], d["length"])

    def _rsend(self, d) -> SendWR:
        wr = SendWR(d["wr_id"], Op(d["op"]), self._rsge(d["sge"]),
                    d["raddr"], d["rkey"])
        wr.sent = d["sent"]
        wr.first_psn = d["first_psn"]
        wr.last_psn = d["last_psn"]
        return wr

    def _rrecv(self, d) -> RecvWR:
        wr = RecvWR(d["wr_id"], self._rsge(d["sge"]))
        wr.received = d["received"]
        return wr


def restore_object(session: RestoreSession, cmd: str, entry: dict,
                   **kw):
    """Fine-grained per-object restore (paper's ibv_restore_object)."""
    ctx = session.ctx
    dev = ctx.device
    t = entry["type"]
    if cmd == "CREATE":
        # All object numbers are preserved across restore — the namespace
        # partitioning (§4.1) guarantees the original IDs are free on any
        # node, so user-held handles stay valid.                 # [MIGR]
        if t == "PD":
            pd = ctx.alloc_pd()
            pd.pdn = entry["pdn"]                                # [MIGR]
            session.pd_by_n[entry["pdn"]] = pd
            return pd
        if t == "CQ":
            cq = ctx.create_cq(entry["depth"])
            cq.cqn = entry["cqn"]                                # [MIGR]
            session.cq_by_n[entry["cqn"]] = cq
            return cq
        if t == "SRQ":
            srq = ctx.create_srq()
            srq.srqn = entry["srqn"]                             # [MIGR]
            session.srq_by_n[entry["srqn"]] = srq
            return srq
        if t == "MR":
            dev.last_mrn = entry["mrn"] - 1                      # [MIGR]
            mr = session.pd_by_n[entry["pdn"]].reg_mr(entry["size"])
            assert mr.mrn == entry["mrn"]
            session.mr_by_n[mr.mrn] = mr
            return mr
        if t == "QP":
            dev.last_qpn = entry["qpn"] - 1                      # [MIGR]
            qp = session.pd_by_n[entry["pdn"]].create_qp(
                session.cq_by_n[entry["send_cqn"]],
                session.cq_by_n[entry["recv_cqn"]],
                session.srq_by_n.get(entry["srqn"]))
            assert qp.qpn == entry["qpn"]
            session.qp_by_n[qp.qpn] = qp
            return qp
        raise TypeError(t)

    if cmd == "SET_MR_KEYS":                                     # [MIGR]
        mr = session.mr_by_n[entry["mrn"]]
        dev.set_mr_keys(mr, entry["lkey"], entry["rkey"])
        return mr

    if cmd == "REFILL":                                          # [MIGR]
        if t == "CQ":
            cq = session.cq_by_n[entry["cqn"]]
            cq.head, cq.tail = entry["head"], entry["tail"]
            for w in entry["ring"]:
                cq.ring.append(WorkCompletion(
                    w["wr_id"], WCStatus(w["status"]), w["opcode"],
                    w["byte_len"], w["qpn"]))
            return cq
        if t == "SRQ":
            srq = session.srq_by_n[entry["srqn"]]
            for r in entry["queue"]:
                srq.queue.append(session._rrecv(r))
            # SRQ_LIMIT watermark attrs (.get: pre-watermark images)
            srq.limit = entry.get("limit", 0)
            srq.armed = entry.get("armed", False)
            return srq
        if t == "QP":
            qp = session.qp_by_n[entry["qpn"]]
            assert qp.state == QPState.RTS, "REFILL requires RTS"
            qp.sq_psn = entry["sq_psn"]
            qp.una = entry["una"]
            qp.epsn = entry["epsn"]
            # .get(): images dumped before the RNR attributes existed
            qp.rnr_retry = entry.get("rnr_retry", 7)
            qp.min_rnr_timer = entry.get("min_rnr_timer",
                                         qp.min_rnr_timer)
            # congestion state: resume at the learned rate       # [ECN]
            if "cc" in entry:
                qp.cc = CongestionControl.restore(
                    dev.fabric.ecn, entry["cc"], dev.fabric.now,
                    dev.fabric.bytes_per_step, dev.fabric.step_s())
            qp.cnps_sent = entry.get("cnps_sent", 0)
            # pause latch toward the peer, re-armed on the new node's
            # egress port (.get(): pre-PFC images)              # [PFC]
            pfc_rem = entry.get("pfc")
            if pfc_rem and dev.fabric.pfc.enabled:
                dev.fabric.port(dev.gid).pfc_restore(
                    qp.dest_gid, pfc_rem, dev.fabric.now)
            qp.sq = deque(session._rsend(w) for w in entry["sq"])
            qp.rq = deque(session._rrecv(w) for w in entry["rq"])
            qp.pending_comp = deque(tuple(t_) for t_ in
                                    entry["pending_comp"])
            qp.cur_wqe = (session._rsend(entry["cur_wqe"])
                          if entry["cur_wqe"] else None)
            qp.cur_rr = (session._rrecv(entry["cur_rr"])
                         if entry["cur_rr"] else None)
            # Re-emit in-flight packets with OUR (possibly new) source
            # address; the resume handshake tells us what to retransmit.
            qp.inflight = deque(
                Packet(op=Op(p["op"]), src_gid=dev.gid, src_qpn=qp.qpn,
                       dest_gid=qp.dest_gid, dest_qpn=qp.dest_qpn,
                       psn=p["psn"], payload=p["payload"],
                       raddr=p["raddr"], rkey=p["rkey"],
                       length=p["length"], first=p["first"],
                       last=p["last"], wr_id=p["wr_id"],
                       tenant=qp.tenant, ect=p.get("ect", False))
                for p in entry["inflight"])
            qp.last_progress = dev.fabric.now
            qp.resume_pending = True                             # [MIGR]
            return qp
        raise TypeError(t)
    raise ValueError(cmd)


def restore_context(ctx: Context, image_bytes: bytes,
                    relocated=None) -> RestoreSession:
    """Full recovery flow: CREATE all → keys → state walk → REFILL.

    ``relocated`` (control-plane): QPN -> current gid, so that QPs whose
    partner has ALSO migrated are restored with the partner's new address
    (paper §3.4: simultaneous migrations must not confuse addressing)."""
    image = msgpack.unpackb(image_bytes, raw=False)
    assert image["version"] == DUMP_VERSION
    if relocated:                                                # [MIGR]
        for e in image["qps"]:                                   # [MIGR]
            if e["dest_qpn"] in relocated:                       # [MIGR]
                e["dest_gid"] = relocated[e["dest_qpn"]]         # [MIGR]
    s = RestoreSession(ctx)
    for e in image["pds"]:
        restore_object(s, "CREATE", e)
    for e in image["cqs"]:
        restore_object(s, "CREATE", e)
    for e in image["srqs"]:
        restore_object(s, "CREATE", e)
    for e in image["mrs"]:
        restore_object(s, "CREATE", e)
        restore_object(s, "SET_MR_KEYS", e)
    for e in image["qps"]:
        qp = restore_object(s, "CREATE", e)
        # walk the state machine exactly as the paper prescribes:
        # Reset -> Init -> RTR -> RTS, then REFILL.
        if e["state"] in ("RTR", "RTS", "SQD", "STOPPED"):
            qp.modify(QPState.INIT)
            qp.modify(QPState.RTR, dest_gid=e["dest_gid"],
                      dest_qpn=e["dest_qpn"], rq_psn=e["epsn"])
        if e["state"] in ("RTS", "SQD", "STOPPED"):
            qp.modify(QPState.RTS, sq_psn=e["sq_psn"])
            restore_object(s, "REFILL", e)
    for e in image["cqs"]:
        restore_object(s, "REFILL", e)
    for e in image["srqs"]:
        restore_object(s, "REFILL", e)
    return s
