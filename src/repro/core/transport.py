"""Software fabric: deterministic packet router between nodes.

Plays the role SoftRoCE plays in the paper (§4.2) — a software
implementation of the wire protocol that lets the OS inspect and control
everything. The fabric is synchronous and step-driven (no threads):
``pump()`` delivers in-flight packets and runs QP
requester/responder/completer tasks; determinism makes protocol tests
exact. Loss injection exercises the go-back-N retransmission path that
migration (§3.4) relies on.

Time model: one pump step is ``STEP_S`` seconds of NIC time, and ``now``
is the single source of truth for every ``transfer_s``/``downtime_s``
figure. The pump core is an *event/active-set scheduler* that is
bit-identical to the naive exhaustive scan it replaced (the paper's §5
zero-overhead claim applied to the simulator itself: idle machinery must
cost nothing):

* **Active sets** — a step only touches egress ports with queued
  backlog, latency-pipe entries that are due, ingress ports with a
  bounded-queue backlog, and devices whose ``_wake`` deadline has
  arrived. Every QP carries a ``_wake`` step computed by
  ``repro.core.tasks.next_wake`` from its armed timers (RTO,
  ``min_rnr_timer``, resume retry, DCQCN alpha/increase boundaries,
  pacing-token refill estimates); everything else is skipped.
* **Idle-time skipping** — ``_next_event_time()`` is the earliest step
  at which *any* fabric state can change: ``now+1`` while any scheduler
  has backlog, else the earliest latency-pipe delivery deadline and the
  earliest device wake. ``pump(steps=N)``, ``run_until_idle`` and
  ``pump_until`` jump ``now`` across the dead air in between (counted
  in the ``pump_steps_skipped`` gauge). Skipped steps are provably
  inert: the loss rng only draws when an egress port transmits (backlog
  ⇒ no skip), the per-port ECN rngs only draw at enqueue, token buckets
  refill lazily on peek, and utilization windows trim lazily against
  absolute ``now`` — so no rng stream or float accumulation ever
  observes the skip.
* **Determinism argument** — a *spurious early* wake is always safe
  (the old loop ran every object every step, and running an idle object
  is a no-op), so every wake estimate rounds down and clamps to
  ``now+1``; a *late* wake is never allowed, so every state change that
  can unpark a QP routes through a wake hook on its device and parked
  DCQCN state is caught up by replaying the per-step arithmetic exactly
  (``CongestionControl.advance``). The legacy exhaustive scan is kept
  behind ``configure_pump(event_driven=False)`` and
  ``tests/test_determinism.py`` pins the two trajectories against each
  other — clock, figure floats, and counter dicts.

Every node has one **egress port** (``repro.core.qos.EgressPort``) whose
bandwidth is shared across *all* destinations — a real NIC port sums
over flows, so two streams leaving the same node contend even when they
target different peers. Within a port, a QoS scheduler arbitrates
migration (service-channel ``MIG_*``) against application traffic and
rate-limits tenants with token buckets; with QoS disabled the port is a
single FIFO. Packets occupy their port for ``nbytes()/bytes_per_step``
steps of budget before the propagation latency starts.

After the propagation latency, packets land in the destination node's
**ingress port** (``repro.core.qos.IngressPort``): finite
receive-processing capacity plus a bounded request queue shared across
all senders. With the default unlimited ingress the port is a
pass-through (byte-identical to the egress-only model); a finite rate
makes incast visible, and queue overflow draws receiver-not-ready NAKs
(``NakCode.RNR``) so senders back off instead of timing out.

With ECN enabled (``configure_ecn``), both port types RED-mark ECT
packets as their queues fill, responders answer Congestion-Experienced
arrivals with CNPs, and each QP's DCQCN reaction point paces its sends
— so congestion is resolved by rate adaptation *before* the
overflow/RNR/timeout machinery has to fire.

With PFC enabled (``configure_pfc``), the fabric is *lossless*: an
ingress queue crossing a class's XOFF watermark broadcasts per-class
PAUSE frames, senders latch the pause per (destination, class) on their
egress ports, and overflow stops dropping reliable requests (headroom
semantics) — congestion feedback rides ECN/CNP alone. A fully
pause-blocked egress port leaves the active set; ``_next_event_time``
covers the latch-expiry deadline so a lost XON can never park the pump
past the pause lifetime.
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional

from repro.core.packets import MIG_OPS, Packet
from repro.core.pagecodec import CodecConfig
from repro.core.qos import (CLASS_APP, CLASS_MIG, ECNConfig, EgressPort,
                            IngressConfig, IngressPort, PFCConfig,
                            QoSConfig)
from repro.obs.metrics import MetricsRegistry

# sim-time -> wall-time conversion: one fabric pump step models roughly a
# microsecond of NIC time. All MigrationReport second-figures derive from
# (fabric.now delta) * STEP_S, never from wall-clock timers.
STEP_S = 1e-6

# window (in steps) over which port_utilization() measures traffic
UTILIZATION_WINDOW = 1000

# "no armed deadline": parked until an external event re-arms the object
_FAR = float("inf")


class Fabric:
    def __init__(self, *, loss_prob: float = 0.0, seed: int = 0,
                 latency_steps: int = 1, bandwidth_Bps: float = 40e9 / 8,
                 qos: Optional[QoSConfig] = None,
                 ingress: Optional[IngressConfig] = None,
                 ecn: Optional[ECNConfig] = None,
                 pfc: Optional[PFCConfig] = None):
        self.loss_prob = loss_prob
        self.seed = seed            # ports derive their ECN-marking rngs
        self.rng = random.Random(seed)
        self.latency = max(1, latency_steps)
        self.now = 0
        self.qos = (qos or QoSConfig()).validate()
        self.ingress_default = (ingress or IngressConfig()).validate()
        self.ecn = (ecn or ECNConfig()).validate()
        self.pfc = (pfc or PFCConfig()).validate()
        self.codec = CodecConfig()
        self.utilization_window = UTILIZATION_WINDOW
        self._ports: Dict[int, EgressPort] = {}       # src gid -> port
        self._ingress: Dict[int, IngressPort] = {}    # dest gid -> port
        self._devices: Dict[int, "RdmaDevice"] = {}   # gid -> device
        # fabric-wide undelivered-packet count, maintained incrementally
        # by the ports (in_flight() used to sum every queue per call)
        self._in_flight = 0
        # event-scheduler state: iteration snapshots cached until the
        # underlying dict changes (the per-step list() allocations were
        # measurable), plus the skipped-step odometer
        self.event_driven = True
        self._steps_skipped = 0
        self._port_list: List[EgressPort] = []
        self._ports_dirty = True
        self._ingress_list: List[IngressPort] = []
        self._ingress_dirty = True
        self._device_list: List = []
        self._devices_dirty = True
        self._any_wakeless = False    # any device without wake state?
        # gid -> memoized stat keys + resolved egress port, one dict per
        # traffic class so the per-send memo probe is an int-keyed get
        self._send_keys_app: Dict = {}
        self._send_keys_mig: Dict = {}
        # every counter routes through the registry; ``stats`` IS the
        # registry's counter dict (same object), so the pre-registry
        # string-dict surface keeps working unchanged
        self.metrics = MetricsRegistry(window=UTILIZATION_WINDOW)
        self.stats = self.metrics.counters
        # typed event tracing (repro.obs.trace), off by default: every
        # hook site in the stack is one `tracer is None` check, and the
        # disabled path leaves all pinned figures byte-identical
        self.tracer = None
        self.trace: Optional[List[Packet]] = None
        self.set_bandwidth(bandwidth_Bps)

    # -- cached iteration snapshots ------------------------------------------
    # Dirty flags are set on topology mutation (port/device creation,
    # detach). A mid-phase rebuild leaves the running for-loop on the old
    # list object — exactly the semantics the old per-phase list() calls
    # had: objects created mid-loop are picked up at the next phase.
    def _plist(self) -> List[EgressPort]:
        if self._ports_dirty:
            self._port_list = list(self._ports.values())
            self._ports_dirty = False
        return self._port_list

    def _ilist(self) -> List[IngressPort]:
        if self._ingress_dirty:
            self._ingress_list = list(self._ingress.values())
            self._ingress_dirty = False
        return self._ingress_list

    def _dlist(self) -> List:
        if self._devices_dirty:
            self._device_list = list(self._devices.values())
            # duck-typed test devices carry no wake state; when none are
            # attached (every real topology) the hot loops use plain
            # attribute access instead of a per-device getattr
            self._any_wakeless = any(
                getattr(d, "_wake", None) is None
                for d in self._device_list)
            self._devices_dirty = False
        return self._device_list

    # -- bandwidth -----------------------------------------------------------
    def set_bandwidth(self, bandwidth_Bps: float):
        old = getattr(self, "bytes_per_step", None)
        if old is not None:
            # materialise every QP's DCQCN state at the *old* line rate
            # first: the per-step model re-clamps rates at the start of
            # the first advance() after the change, so steps up to and
            # including now must replay against the old rate
            self._advance_all_cc(old)
        self.bandwidth = bandwidth_Bps
        # bytes one egress port can serialise per pump step
        self.bytes_per_step = bandwidth_Bps * STEP_S
        for port in self._ports.values():
            port.on_bandwidth_change()
        self._wake_all()

    @staticmethod
    def step_s() -> float:
        return STEP_S

    @property
    def time_s(self) -> float:
        """Sim-clock seconds — the single source of truth for migration
        timing figures."""
        return self.now * STEP_S

    # -- event scheduler knob ------------------------------------------------
    def configure_pump(self, event_driven: bool = True):
        """Operator knob: select the pump core. ``True`` (default) is
        the event/active-set scheduler — steps touch only ports with
        work and devices whose wake deadline arrived, and idle gaps are
        skipped in one clock jump. ``False`` falls back to the legacy
        exhaustive per-step scan. The two produce bit-identical
        sim-clock trajectories, figures, and counters
        (``tests/test_determinism.py`` pins this), so the knob exists
        for that cross-check and for debugging, not for tuning."""
        self.event_driven = bool(event_driven)
        if self.event_driven:
            self._wake_all()    # deadlines went stale while in legacy

    def _wake_all(self):
        """Re-arm every device and QP after a fabric-wide
        reconfiguration (bandwidth, ECN, pump mode): cached wake
        deadlines may assume rates or configs that no longer hold, and
        a spurious early wake is always trajectory-safe."""
        for dev in self._devices.values():
            if getattr(dev, "_wake", None) is None:
                continue        # duck-typed test device: no wake state
            dev._wake = 0
            dev._idle_dirty = True
            for qp in dev.qps.values():
                qp._wake = 0

    def _advance_all_cc(self, line_rate: float):
        """Materialise every QP's congestion state through ``now``: the
        per-step model advanced each one every step, so a config swap
        must replay parked QPs up to the swap point under the outgoing
        config before anything changes."""
        if not self.ecn.enabled:
            return      # the per-step model never advanced while off
        for dev in self._devices.values():
            for qp in getattr(dev, "qps", {}).values():
                if qp.cc is not None:
                    qp.cc.advance(self.now, line_rate)

    # -- QoS -----------------------------------------------------------------
    def configure_qos(self, qos: QoSConfig):
        """Swap the scheduler config on every port. Queued packets are
        re-filed under the new class shape (tenant-RR order within each
        old class); intended at quiet points, tolerated mid-flight."""
        self.qos = qos.validate()
        for port in self._ports.values():
            port.reconfigure(qos)
        for iport in self._ingress.values():
            iport.reconfigure(qos=qos)

    # -- ECN / DCQCN ---------------------------------------------------------
    def configure_ecn(self, ecn: ECNConfig):
        """Operator knob: swap the fabric-wide ECN/DCQCN config (RED
        marking thresholds on every port, CNP coalescing, reaction-point
        rate parameters). QPs that already carry congestion state keep
        their learned rates; new rate state is created against the new
        config on first use. Disabling stops marking and CNP generation
        immediately — existing rate state goes dormant (no admission
        gate is consulted while disabled)."""
        # catch parked QPs up under the outgoing config before it goes
        # away (no-op when it was disabled: nothing ever advanced)
        self._advance_all_cc(self.bytes_per_step)
        self.ecn = ecn.validate()
        self._wake_all()

    # -- PFC (link-level flow control) ---------------------------------------
    def configure_pfc(self, pfc: PFCConfig):
        """Operator knob: swap the fabric-wide PFC config (per-class
        XOFF/XON watermarks, pause lifetime). Enabling makes the fabric
        lossless — ingress overflow admits instead of dropping, and the
        RNR rate-cut path in ``CongestionControl`` goes inert. Disabling
        releases every pause latch immediately (accounting their spans)
        and forgets ingress XOFF state; in-flight PAUSE frames still
        deliver but latch nothing new once applied latches are cleared —
        their lifetime bounds any straggler."""
        self.pfc = pfc.validate()
        if not self.pfc.enabled:
            for port in self._ports.values():
                port.pfc_clear(self.now)
            for iport in self._ingress.values():
                iport._pfc_latched.clear()
        self._wake_all()

    # -- migration page codec ------------------------------------------------
    def configure_codec(self, codec: CodecConfig):
        """Operator knob: swap the migration page-codec config (zero-page
        elision, content-addressed dedup, XOR+zlib delta rounds, image
        compression — ``repro.core.pagecodec``). Applies to migrations
        *started* after the call; an in-flight or paused attempt keeps
        the codec state it was encoding with, and a paused attempt whose
        token carries codec state resumes decoding-compatible. Disabled
        — the default — the MIG_PAGE wire format is byte-identical to
        the codec-less fabric (pinned by the benchmark figures)."""
        self.codec = codec.validate()

    # -- tracing -------------------------------------------------------------
    def configure_tracing(self, enabled: bool = True, *,
                          max_events: Optional[int] = None):
        """Operator knob: attach (or detach, ``enabled=False``) a typed
        event tracer to the fabric. Returns the ``repro.obs.trace
        .Tracer`` (or None). Disabled — the default — the hook sites are
        a single attribute check and the wire model is byte-identical to
        an untraced run; enabled, every packet/congestion/migration
        event is recorded against the sim clock, exportable via
        ``repro.obs.export`` and ``tools/trace_report.py``.
        ``max_events`` bounds trace memory (overflow is counted, not
        silent)."""
        if not enabled:
            self.tracer = None
            return None
        from repro.obs.trace import Tracer
        self.tracer = Tracer(self, max_events=max_events)
        return self.tracer

    def marking_rate(self, gid: int) -> float:
        """Fraction of bytes CE-marked at a node's *egress* port over
        the trailing utilization window (0.0 with ECN off)."""
        port = self._ports.get(gid)
        return 0.0 if port is None else port.marking_rate(self.now)

    def ingress_marking_rate(self, gid: int) -> float:
        """Destination-side twin: fraction of arriving bytes CE-marked
        at a node's ingress queue over the trailing window."""
        port = self._ingress.get(gid)
        return 0.0 if port is None else port.marking_rate(self.now)

    # -- ingress (receive-side) ----------------------------------------------
    def configure_ingress(self, cfg: IngressConfig,
                          gid: Optional[int] = None):
        """Operator knob: bound one node's (or, with ``gid=None``, every
        node's) receive-processing rate and ingress queue. Packets
        already queued survive a reconfigure; switching a node back to
        unlimited flushes its backlog to the device immediately."""
        cfg = cfg.validate()
        if gid is None:
            self.ingress_default = cfg
            for iport in self._ingress.values():
                iport.reconfigure(cfg=cfg)
        else:
            self.ingress_port(gid).reconfigure(cfg=cfg)

    def ingress_port(self, gid: int) -> IngressPort:
        p = self._ingress.get(gid)
        if p is None:
            p = self._ingress[gid] = IngressPort(
                self, gid, self.ingress_default, self.qos)
            self._ingress_dirty = True
        return p

    def ingress_capacity_Bps(self, gid: int) -> Optional[float]:
        """Receive-processing rate of a node, or None (unlimited)."""
        cfg = (self._ingress[gid].cfg if gid in self._ingress
               else self.ingress_default)
        return cfg.rx_bandwidth_Bps

    def ingress_utilization(self, gid: int) -> float:
        """Measured fraction of a node's receive-processing capacity
        committed over the UTILIZATION_WINDOW horizon — the destination-
        side twin of ``port_utilization`` (admission prices the target's
        receive path with this, not just the source's egress). Same two
        signals, whichever is worse: bytes arrived over the trailing
        window, and the standing backlog awaiting processing."""
        port = self._ingress.get(gid)
        if port is None or port.cfg.unlimited:
            return 0.0
        per_step = port.rx_bytes_per_step
        if per_step <= 0:
            return 0.0
        cap = self.utilization_window * per_step
        offered = port.window_bytes(self.now) / cap
        backlog = (port.backlog_bytes / per_step) / self.utilization_window
        return min(1.0, max(offered, backlog))

    def set_tenant_rate(self, tenant: str, rate_Bps: Optional[float],
                        burst_bytes: Optional[float] = None):
        """Operator knob: (re)price one tenant's token bucket on every
        port. ``rate_Bps=None`` removes the throttle."""
        if rate_Bps is None:
            self.qos.tenant_rate_Bps.pop(tenant, None)
            self.qos.tenant_burst_bytes.pop(tenant, None)
        else:
            if rate_Bps <= 0:
                raise ValueError("tenant rate must be > 0")
            self.qos.tenant_rate_Bps[tenant] = rate_Bps
            if burst_bytes is not None:
                self.qos.tenant_burst_bytes[tenant] = burst_bytes
        for port in self._ports.values():
            port.buckets.pop(tenant, None)      # re-built lazily

    # -- topology ------------------------------------------------------------
    def attach(self, gid: int, device):
        assert gid not in self._devices, f"gid {gid} in use"
        self._devices[gid] = device
        self._devices_dirty = True

    def detach(self, gid: int):
        """Remove a device. Undelivered packets addressed to the departed
        gid are drained into ``stats['unroutable']`` immediately — they
        could only ever hit the unroutable path at delivery time, and
        leaving them queued would keep ``in_flight()`` from quiescing.
        The departed node's own ingress queue drains the same way: every
        packet parked there was addressed to it. Service-channel streams
        toward the departed gid are *suspended*, not left armed: a
        mid-migration transfer exits via the preemption path (a paused
        attempt, resumable toward a new destination) instead of
        retransmitting into the void until its timeout aborted the
        migration."""
        self._devices.pop(gid, None)
        self._devices_dirty = True
        for dev in self._devices.values():
            svc = getattr(dev, "_service", None)
            if svc is not None:
                svc.peer_detached(gid)
        for port in self._ports.values():
            self.metrics.inc("unroutable", port.drop_to(gid), gid=gid)
        iport = self._ingress.pop(gid, None)
        if iport is not None:
            self._ingress_dirty = True
            self.metrics.inc("unroutable", iport.drop_all(), gid=gid)

    def device(self, gid: int):
        return self._devices.get(gid)

    def port(self, gid: int) -> EgressPort:
        p = self._ports.get(gid)
        if p is None:
            p = self._ports[gid] = EgressPort(self, gid, self.qos)
            self._ports_dirty = True
        return p

    def link(self, src_gid: int, dest_gid: int):
        """Per-(src, dest) accounting view (the old Link surface):
        ``tx_bytes``/``tx_packets`` count at enqueue, ``busy_until``
        reflects this flow's share of the port backlog."""
        return self.port(src_gid).flow(dest_gid)

    def port_utilization(self, gid: int) -> float:
        """Measured fraction of the node's egress-port capacity committed
        over the UTILIZATION_WINDOW horizon (admission reads this, not an
        analytic guess). Two signals, whichever is worse: bytes enqueued
        over the trailing window (offered load), and the standing backlog
        still awaiting the scheduler (a drained-but-booked port is not
        free capacity)."""
        port = self._ports.get(gid)
        if port is None or self.bytes_per_step <= 0:
            return 0.0
        cap = self.utilization_window * self.bytes_per_step
        offered = port.window_bytes(self.now) / cap
        backlog = (port.backlog_bytes / self.bytes_per_step) \
            / self.utilization_window
        return min(1.0, max(offered, backlog))

    def link_utilization(self, src_gid: int, dest_gid: int) -> float:
        """Back-compat alias: capacity is a property of the *source
        node's egress port* now, not of a (src, dest) pair."""
        return self.port_utilization(src_gid)

    def app_utilization(self, gid: int) -> float:
        """App-class share of the node's egress capacity over the
        trailing window — what the auto-preemption policy reads. The
        migration class is excluded, so a port busy only with the
        migration's own stream never reads as app pressure (a policy
        fed ``port_utilization`` would pause every migration against
        itself)."""
        port = self._ports.get(gid)
        if port is None or self.bytes_per_step <= 0:
            return 0.0
        cap = self.utilization_window * self.bytes_per_step
        return min(1.0, port.app_window_bytes(self.now) / cap)

    # -- wire ----------------------------------------------------------------
    def send(self, pkt: Packet):
        n = 64 + len(pkt.payload)       # pkt.nbytes(), inlined (hot)
        gid = pkt.src_gid
        # the two inc() calls this replaces were measurable across every
        # figure (one send per packet): same counters, memoized twin keys
        memo = self._send_keys_mig if pkt.op.is_mig else \
            self._send_keys_app
        keys = memo.get(gid)
        if keys is None:
            cls = CLASS_MIG if pkt.op.is_mig else CLASS_APP
            m = self.metrics
            m.node_counters.add("tx_packets")
            m.node_counters.add("tx_bytes")
            keys = memo[gid] = (
                f"tx_packets@{gid}", f"{cls}_tx_packets",
                f"tx_bytes@{gid}", f"{cls}_tx_bytes",
                # egress ports are created once and only ever mutated in
                # place (reconfigure/detach never replace the object),
                # so the resolved port rides the memo
                self.port(gid))
        c = self.stats
        c["tx_packets"] += 1
        c[keys[0]] += 1
        c[keys[1]] += 1
        c["tx_bytes"] += n
        c[keys[2]] += n
        c[keys[3]] += n
        if self.trace is not None:
            self.trace.append(pkt)
        keys[4].enqueue(pkt, self.now)

    def in_flight(self) -> int:
        return self._in_flight

    # -- pump core -----------------------------------------------------------
    def _step(self):
        """One active-set step: egress schedulers with backlog, due
        latency-pipe deliveries, bounded-ingress schedulers with
        backlog, then every device whose wake deadline arrived. The
        skipped objects are exactly those for which the exhaustive
        scan's calls were no-ops."""
        self.now += 1
        now = self.now
        ingress = self._ingress     # mutated in place, never reassigned
        for port in self._plist():
            if port.backlog_packets:
                port.service(now)
            dq = port.delivery
            if dq and dq[0][0] <= now:
                # an ingress-overflow RNR NAK sent mid-loop may create
                # the receiver's egress port on first use; the dirty
                # flag folds it in at the next phase, as list() did.
                # port.pop_due, inlined: the generator frame per port
                # and resume per packet were measurable
                while dq and dq[0][0] <= now:
                    self._in_flight -= 1
                    pkt = dq.popleft()[1]
                    ip = ingress.get(pkt.dest_gid)
                    if ip is None:      # first packet to this node
                        ip = self.ingress_port(pkt.dest_gid)
                    ip.enqueue(pkt, now)
        for iport in self._ilist():
            if iport.backlog_packets:
                iport.service(now)
        devs = self._dlist()        # refreshes _any_wakeless when dirty
        if self._any_wakeless:
            for dev in devs:
                # duck-typed test devices have no wake state: always run
                if getattr(dev, "_wake", 0) <= now:
                    dev.run_tasks()
        else:
            for dev in devs:
                if dev._wake <= now:
                    dev.run_tasks()

    def _step_legacy(self):
        """The original exhaustive scan, verbatim — the reference
        trajectory that ``configure_pump(event_driven=False)`` exposes
        for the determinism cross-check."""
        self.now += 1
        for port in list(self._ports.values()):
            port.service(self.now)
            for pkt in port.pop_due(self.now):
                self.ingress_port(pkt.dest_gid).enqueue(pkt, self.now)
        for iport in list(self._ingress.values()):
            iport.service(self.now)
        for dev in list(self._devices.values()):
            dev.run_tasks()

    def _next_event_time(self):
        """Earliest step at which any fabric state can change: ``now+1``
        while any scheduler has backlog (it spends budget every step),
        else the earliest latency-pipe deadline and the earliest device
        wake. Returns +inf when everything is parked on external
        events that will re-arm a wake when they fire."""
        now = self.now
        nxt = _FAR
        for port in self._plist():
            if port.backlog_packets:
                if not port._pfc_until:
                    return now + 1
                # backlogged but possibly PFC-blocked: a fully paused
                # port's service calls are strict no-ops, so the only
                # deadline it owns is the earliest latch expiry (an
                # in-flight UNPAUSE rides someone's delivery pipe and
                # is covered by that port's deadline below)
                b = port.pfc_blocked_until(now)
                if b <= now:
                    return now + 1
                if b < nxt:
                    nxt = b
            dq = port.delivery
            if dq:
                d = dq[0][0]        # deadlines are enqueue-ordered
                if d < nxt:
                    nxt = d
        for iport in self._ilist():
            if iport.backlog_packets:
                return now + 1
        devs = self._dlist()
        if self._any_wakeless:
            return now + 1          # wake-less test device: every step
        for dev in devs:
            w = dev._wake
            if w < nxt:
                nxt = w
        if nxt <= now:
            return now + 1
        return nxt

    def _quiescent(self) -> bool:
        return self._in_flight == 0 and all(d.idle()
                                            for d in self._dlist())

    def _update_gauges(self):
        now = self.now
        m = self.metrics
        m.set_gauge("pump_steps_skipped", self._steps_skipped)
        m.set_gauge("active_ports",
                    sum(1 for p in self._plist()
                        if p.backlog_packets or p.delivery)
                    + sum(1 for p in self._ilist() if p.backlog_packets))
        m.set_gauge("active_devices",
                    sum(1 for d in self._dlist()
                        if getattr(d, "_wake", 0) <= now))

    def pump(self, steps: int = 1):
        """Advance time by ``steps`` fabric steps. Steps on which no
        port, delivery, or woken device has any work are skipped in one
        ``now`` jump; the executed steps and the final clock are
        bit-identical to running the legacy scan ``steps`` times."""
        if not self.event_driven:
            for _ in range(steps):
                self._step_legacy()
            return
        if steps == 1:
            # the hot path for step_all-style driver loops: a single
            # step can never jump (target <= now+1), so the event-time
            # scan would be pure overhead — and gauges refresh on the
            # batch entry points, not per step
            self._step()
            return
        end = self.now + steps
        while self.now < end:
            nxt = self._next_event_time()
            target = nxt if nxt < end else end
            jump = target - (self.now + 1)
            if jump > 0:
                self.now += jump
                self._steps_skipped += jump
            self._step()
        self._update_gauges()

    def pump_until(self, predicate, max_steps: int) -> bool:
        """Pump until ``predicate()`` turns true, checking before each
        executed step exactly like a caller-side ``for _ in
        range(max_steps): if p(): return True; pump()`` loop — but with
        inert steps skipped (the predicate can only change on an
        executed step, so the skipped checks were all guaranteed to
        repeat the last answer). Returns False after ``max_steps``
        steps without the predicate turning true; no trailing re-check,
        matching the caller-side loop shape it replaces."""
        if not self.event_driven:
            for _ in range(max_steps):
                if predicate():
                    return True
                self._step_legacy()
            return False
        done = 0
        while done < max_steps:
            if predicate():
                return True
            nxt = self._next_event_time()
            skip = nxt - self.now - 1
            cap = max_steps - done - 1
            if skip > cap:
                skip = cap
            if skip > 0:
                self.now += skip
                self._steps_skipped += skip
                done += skip
            self._step()
            done += 1
        self._update_gauges()
        return False

    def run_until_idle(self, max_steps: int = 100_000) -> int:
        """Pump until no packets are in flight and all QPs are
        quiescent; returns the number of sim steps that elapsed
        (skipped ones included — the return value is a ``now`` delta,
        exactly as with the exhaustive scan)."""
        if not self.event_driven:
            for i in range(max_steps):
                self._step_legacy()
                if not self.in_flight() and all(d.idle() for d in
                                                self._devices.values()):
                    return i + 1
            raise TimeoutError("fabric did not quiesce")
        done = 0
        while done < max_steps:
            if not self._quiescent():
                # quiescence is constant across inert steps, so the
                # skipped per-step checks were all going to say "no" —
                # jump straight to the step that can change the answer.
                # (Already quiescent: no skip; the contract is one
                # pumped step then the check, like the old loop.)
                nxt = self._next_event_time()
                skip = nxt - self.now - 1
                cap = max_steps - done - 1
                if skip > cap:
                    skip = cap
                if skip > 0:
                    self.now += skip
                    self._steps_skipped += skip
                    done += skip
            self._step()
            done += 1
            if self._quiescent():
                self._update_gauges()
                return done
        self._update_gauges()
        raise TimeoutError("fabric did not quiesce")
