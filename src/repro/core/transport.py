"""Software fabric: deterministic packet router between nodes.

Plays the role SoftRoCE plays in the paper (§4.2) — a software
implementation of the wire protocol that lets the OS inspect and control
everything. The fabric is synchronous and step-driven (no threads):
``pump()`` delivers in-flight packets and runs every QP's
requester/responder/completer tasks once; determinism makes protocol
tests exact. Loss injection exercises the go-back-N retransmission path
that migration (§3.4) relies on.

Time model: one pump step is ``STEP_S`` seconds of NIC time. Every node
has one **egress port** (``repro.core.qos.EgressPort``) whose bandwidth
is shared across *all* destinations — a real NIC port sums over flows,
so two streams leaving the same node contend even when they target
different peers. Within a port, a QoS scheduler arbitrates migration
(service-channel ``MIG_*``) against application traffic and rate-limits
tenants with token buckets; with QoS disabled the port is a single FIFO.
Packets occupy their port for ``nbytes()/bytes_per_step`` steps of budget
before the propagation latency starts, and ``now`` is the single source
of truth for every ``transfer_s``/``downtime_s`` figure.

After the propagation latency, packets land in the destination node's
**ingress port** (``repro.core.qos.IngressPort``): finite
receive-processing capacity plus a bounded request queue shared across
all senders. With the default unlimited ingress the port is a
pass-through (byte-identical to the egress-only model); a finite rate
makes incast visible, and queue overflow draws receiver-not-ready NAKs
(``NakCode.RNR``) so senders back off instead of timing out.

With ECN enabled (``configure_ecn``), both port types RED-mark ECT
packets as their queues fill, responders answer Congestion-Experienced
arrivals with CNPs, and each QP's DCQCN reaction point paces its sends
— so congestion is resolved by rate adaptation *before* the
overflow/RNR/timeout machinery has to fire.
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional

from repro.core.packets import MIG_OPS, Packet
from repro.core.qos import (CLASS_APP, CLASS_MIG, ECNConfig, EgressPort,
                            IngressConfig, IngressPort, QoSConfig)
from repro.obs.metrics import MetricsRegistry

# sim-time -> wall-time conversion: one fabric pump step models roughly a
# microsecond of NIC time. All MigrationReport second-figures derive from
# (fabric.now delta) * STEP_S, never from wall-clock timers.
STEP_S = 1e-6

# window (in steps) over which port_utilization() measures traffic
UTILIZATION_WINDOW = 1000


class Fabric:
    def __init__(self, *, loss_prob: float = 0.0, seed: int = 0,
                 latency_steps: int = 1, bandwidth_Bps: float = 40e9 / 8,
                 qos: Optional[QoSConfig] = None,
                 ingress: Optional[IngressConfig] = None,
                 ecn: Optional[ECNConfig] = None):
        self.loss_prob = loss_prob
        self.seed = seed            # ports derive their ECN-marking rngs
        self.rng = random.Random(seed)
        self.latency = max(1, latency_steps)
        self.now = 0
        self.qos = (qos or QoSConfig()).validate()
        self.ingress_default = (ingress or IngressConfig()).validate()
        self.ecn = (ecn or ECNConfig()).validate()
        self.utilization_window = UTILIZATION_WINDOW
        self._ports: Dict[int, EgressPort] = {}       # src gid -> port
        self._ingress: Dict[int, IngressPort] = {}    # dest gid -> port
        self._devices: Dict[int, "RdmaDevice"] = {}   # gid -> device
        # every counter routes through the registry; ``stats`` IS the
        # registry's counter dict (same object), so the pre-registry
        # string-dict surface keeps working unchanged
        self.metrics = MetricsRegistry(window=UTILIZATION_WINDOW)
        self.stats = self.metrics.counters
        # typed event tracing (repro.obs.trace), off by default: every
        # hook site in the stack is one `tracer is None` check, and the
        # disabled path leaves all pinned figures byte-identical
        self.tracer = None
        self.trace: Optional[List[Packet]] = None
        self.set_bandwidth(bandwidth_Bps)

    # -- bandwidth -----------------------------------------------------------
    def set_bandwidth(self, bandwidth_Bps: float):
        self.bandwidth = bandwidth_Bps
        # bytes one egress port can serialise per pump step
        self.bytes_per_step = bandwidth_Bps * STEP_S
        for port in self._ports.values():
            port.on_bandwidth_change()

    @staticmethod
    def step_s() -> float:
        return STEP_S

    @property
    def time_s(self) -> float:
        """Sim-clock seconds — the single source of truth for migration
        timing figures."""
        return self.now * STEP_S

    # -- QoS -----------------------------------------------------------------
    def configure_qos(self, qos: QoSConfig):
        """Swap the scheduler config on every port. Queued packets are
        re-filed under the new class shape (tenant-RR order within each
        old class); intended at quiet points, tolerated mid-flight."""
        self.qos = qos.validate()
        for port in self._ports.values():
            port.reconfigure(qos)
        for iport in self._ingress.values():
            iport.reconfigure(qos=qos)

    # -- ECN / DCQCN ---------------------------------------------------------
    def configure_ecn(self, ecn: ECNConfig):
        """Operator knob: swap the fabric-wide ECN/DCQCN config (RED
        marking thresholds on every port, CNP coalescing, reaction-point
        rate parameters). QPs that already carry congestion state keep
        their learned rates; new rate state is created against the new
        config on first use. Disabling stops marking and CNP generation
        immediately — existing rate state goes dormant (no admission
        gate is consulted while disabled)."""
        self.ecn = ecn.validate()

    # -- tracing -------------------------------------------------------------
    def configure_tracing(self, enabled: bool = True, *,
                          max_events: Optional[int] = None):
        """Operator knob: attach (or detach, ``enabled=False``) a typed
        event tracer to the fabric. Returns the ``repro.obs.trace
        .Tracer`` (or None). Disabled — the default — the hook sites are
        a single attribute check and the wire model is byte-identical to
        an untraced run; enabled, every packet/congestion/migration
        event is recorded against the sim clock, exportable via
        ``repro.obs.export`` and ``tools/trace_report.py``.
        ``max_events`` bounds trace memory (overflow is counted, not
        silent)."""
        if not enabled:
            self.tracer = None
            return None
        from repro.obs.trace import Tracer
        self.tracer = Tracer(self, max_events=max_events)
        return self.tracer

    def marking_rate(self, gid: int) -> float:
        """Fraction of bytes CE-marked at a node's *egress* port over
        the trailing utilization window (0.0 with ECN off)."""
        port = self._ports.get(gid)
        return 0.0 if port is None else port.marking_rate(self.now)

    def ingress_marking_rate(self, gid: int) -> float:
        """Destination-side twin: fraction of arriving bytes CE-marked
        at a node's ingress queue over the trailing window."""
        port = self._ingress.get(gid)
        return 0.0 if port is None else port.marking_rate(self.now)

    # -- ingress (receive-side) ----------------------------------------------
    def configure_ingress(self, cfg: IngressConfig,
                          gid: Optional[int] = None):
        """Operator knob: bound one node's (or, with ``gid=None``, every
        node's) receive-processing rate and ingress queue. Packets
        already queued survive a reconfigure; switching a node back to
        unlimited flushes its backlog to the device immediately."""
        cfg = cfg.validate()
        if gid is None:
            self.ingress_default = cfg
            for iport in self._ingress.values():
                iport.reconfigure(cfg=cfg)
        else:
            self.ingress_port(gid).reconfigure(cfg=cfg)

    def ingress_port(self, gid: int) -> IngressPort:
        p = self._ingress.get(gid)
        if p is None:
            p = self._ingress[gid] = IngressPort(
                self, gid, self.ingress_default, self.qos)
        return p

    def ingress_capacity_Bps(self, gid: int) -> Optional[float]:
        """Receive-processing rate of a node, or None (unlimited)."""
        cfg = (self._ingress[gid].cfg if gid in self._ingress
               else self.ingress_default)
        return cfg.rx_bandwidth_Bps

    def ingress_utilization(self, gid: int) -> float:
        """Measured fraction of a node's receive-processing capacity
        committed over the UTILIZATION_WINDOW horizon — the destination-
        side twin of ``port_utilization`` (admission prices the target's
        receive path with this, not just the source's egress). Same two
        signals, whichever is worse: bytes arrived over the trailing
        window, and the standing backlog awaiting processing."""
        port = self._ingress.get(gid)
        if port is None or port.cfg.unlimited:
            return 0.0
        per_step = port.rx_bytes_per_step
        if per_step <= 0:
            return 0.0
        cap = self.utilization_window * per_step
        offered = port.window_bytes(self.now) / cap
        backlog = (port.backlog_bytes / per_step) / self.utilization_window
        return min(1.0, max(offered, backlog))

    def set_tenant_rate(self, tenant: str, rate_Bps: Optional[float],
                        burst_bytes: Optional[float] = None):
        """Operator knob: (re)price one tenant's token bucket on every
        port. ``rate_Bps=None`` removes the throttle."""
        if rate_Bps is None:
            self.qos.tenant_rate_Bps.pop(tenant, None)
            self.qos.tenant_burst_bytes.pop(tenant, None)
        else:
            if rate_Bps <= 0:
                raise ValueError("tenant rate must be > 0")
            self.qos.tenant_rate_Bps[tenant] = rate_Bps
            if burst_bytes is not None:
                self.qos.tenant_burst_bytes[tenant] = burst_bytes
        for port in self._ports.values():
            port.buckets.pop(tenant, None)      # re-built lazily

    # -- topology ------------------------------------------------------------
    def attach(self, gid: int, device):
        assert gid not in self._devices, f"gid {gid} in use"
        self._devices[gid] = device

    def detach(self, gid: int):
        """Remove a device. Undelivered packets addressed to the departed
        gid are drained into ``stats['unroutable']`` immediately — they
        could only ever hit the unroutable path at delivery time, and
        leaving them queued would keep ``in_flight()`` from quiescing.
        The departed node's own ingress queue drains the same way: every
        packet parked there was addressed to it."""
        self._devices.pop(gid, None)
        for port in self._ports.values():
            self.metrics.inc("unroutable", port.drop_to(gid), gid=gid)
        iport = self._ingress.pop(gid, None)
        if iport is not None:
            self.metrics.inc("unroutable", iport.drop_all(), gid=gid)

    def device(self, gid: int):
        return self._devices.get(gid)

    def port(self, gid: int) -> EgressPort:
        p = self._ports.get(gid)
        if p is None:
            p = self._ports[gid] = EgressPort(self, gid, self.qos)
        return p

    def link(self, src_gid: int, dest_gid: int):
        """Per-(src, dest) accounting view (the old Link surface):
        ``tx_bytes``/``tx_packets`` count at enqueue, ``busy_until``
        reflects this flow's share of the port backlog."""
        return self.port(src_gid).flow(dest_gid)

    def port_utilization(self, gid: int) -> float:
        """Measured fraction of the node's egress-port capacity committed
        over the UTILIZATION_WINDOW horizon (admission reads this, not an
        analytic guess). Two signals, whichever is worse: bytes enqueued
        over the trailing window (offered load), and the standing backlog
        still awaiting the scheduler (a drained-but-booked port is not
        free capacity)."""
        port = self._ports.get(gid)
        if port is None or self.bytes_per_step <= 0:
            return 0.0
        cap = self.utilization_window * self.bytes_per_step
        offered = port.window_bytes(self.now) / cap
        backlog = (port.backlog_bytes / self.bytes_per_step) \
            / self.utilization_window
        return min(1.0, max(offered, backlog))

    def link_utilization(self, src_gid: int, dest_gid: int) -> float:
        """Back-compat alias: capacity is a property of the *source
        node's egress port* now, not of a (src, dest) pair."""
        return self.port_utilization(src_gid)

    # -- wire ----------------------------------------------------------------
    def send(self, pkt: Packet):
        n = pkt.nbytes()
        cls = CLASS_MIG if pkt.op in MIG_OPS else CLASS_APP
        self.metrics.inc("tx_packets", gid=pkt.src_gid, cls=cls)
        self.metrics.inc("tx_bytes", n, gid=pkt.src_gid, cls=cls)
        if self.trace is not None:
            self.trace.append(pkt)
        self.port(pkt.src_gid).enqueue(pkt, self.now)

    def in_flight(self) -> int:
        return (sum(p.in_flight() for p in self._ports.values())
                + sum(p.in_flight() for p in self._ingress.values()))

    def pump(self, steps: int = 1):
        """Advance time: run every egress port's scheduler for one step's
        byte budget, land packets whose latency expired in their
        destination's ingress port (unlimited ingress delivers them to
        the device inline), spend each ingress port's receive-processing
        budget, then run all QP tasks."""
        for _ in range(steps):
            self.now += 1
            # list(): an ingress-overflow RNR NAK sent mid-loop may
            # create the receiver's egress port on first use
            for port in list(self._ports.values()):
                port.service(self.now)
                for pkt in port.pop_due(self.now):
                    self.ingress_port(pkt.dest_gid).enqueue(pkt, self.now)
            for iport in list(self._ingress.values()):
                iport.service(self.now)
            for dev in list(self._devices.values()):
                dev.run_tasks()

    def run_until_idle(self, max_steps: int = 100_000) -> int:
        """Pump until no packets are in flight and all QPs are quiescent."""
        for i in range(max_steps):
            self.pump()
            if not self.in_flight() and all(d.idle() for d in
                                            self._devices.values()):
                return i + 1
        raise TimeoutError("fabric did not quiesce")
