"""Software fabric: deterministic packet router between nodes.

Plays the role SoftRoCE plays in the paper — a software implementation of
the wire protocol that lets the OS inspect and control everything. The
fabric is synchronous and step-driven (no threads): ``pump()`` delivers
in-flight packets and runs every QP's requester/responder/completer tasks
once; determinism makes protocol tests exact. Loss injection exercises the
go-back-N retransmission path that migration relies on.

Time model: one pump step is ``STEP_S`` seconds of NIC time. Every
(src_gid, dest_gid) pair is a link with finite bandwidth — each packet
occupies the link for ``nbytes()/bytes_per_step`` steps before the
propagation latency starts, and packets on one link serialise FIFO behind
each other. Migration traffic (service-channel MIG_* packets) crosses the
same links as application traffic, so checkpoint streams and demand-paging
pulls contend for bandwidth instead of being free, and ``now`` is the
single source of truth for every ``transfer_s``/``downtime_s`` figure.
"""
from __future__ import annotations

import random
from collections import defaultdict, deque
from typing import Dict, List, Optional, Tuple

from repro.core.packets import MIG_OPS, Packet

# sim-time -> wall-time conversion: one fabric pump step models roughly a
# microsecond of NIC time. All MigrationReport second-figures derive from
# (fabric.now delta) * STEP_S, never from wall-clock timers.
STEP_S = 1e-6

# window (in steps) over which link_utilization() measures traffic
UTILIZATION_WINDOW = 1000


class Link:
    """One directed (src_gid, dest_gid) link: a shared FIFO with finite
    bandwidth. ``busy_until`` is the (fractional-step) time the last queued
    byte finishes serialising; the windowed byte counter feeds measured
    utilization for orchestrator admission."""

    __slots__ = ("busy_until", "queue", "tx_bytes", "tx_packets",
                 "_window", "_win_bytes")

    def __init__(self):
        self.busy_until = 0.0
        self.queue: deque = deque()            # (deliver_at, packet), FIFO
        self.tx_bytes = 0
        self.tx_packets = 0
        self._window: deque = deque()          # (sent_at, nbytes)
        self._win_bytes = 0

    def record(self, now: int, nbytes: int):
        self.tx_bytes += nbytes
        self.tx_packets += 1
        self._window.append((now, nbytes))
        self._win_bytes += nbytes
        self._trim(now)

    def _trim(self, now: int):
        # retention is capped at UTILIZATION_WINDOW so the deque stays
        # bounded on workloads that never query utilization
        while self._window and \
                self._window[0][0] <= now - UTILIZATION_WINDOW:
            self._win_bytes -= self._window.popleft()[1]

    def window_bytes(self, now: int) -> int:
        """Bytes enqueued over the last UTILIZATION_WINDOW steps."""
        self._trim(now)
        return self._win_bytes


class Fabric:
    def __init__(self, *, loss_prob: float = 0.0, seed: int = 0,
                 latency_steps: int = 1, bandwidth_Bps: float = 40e9 / 8):
        self.loss_prob = loss_prob
        self.rng = random.Random(seed)
        self.latency = max(1, latency_steps)
        self.now = 0
        self._links: Dict[Tuple[int, int], Link] = {}
        self._devices: Dict[int, "RdmaDevice"] = {}   # gid -> device
        self.stats = defaultdict(int)
        self.trace: Optional[List[Packet]] = None
        self.set_bandwidth(bandwidth_Bps)

    # -- bandwidth -----------------------------------------------------------
    def set_bandwidth(self, bandwidth_Bps: float):
        self.bandwidth = bandwidth_Bps
        # bytes one link can serialise per pump step
        self.bytes_per_step = bandwidth_Bps * STEP_S

    @property
    def time_s(self) -> float:
        """Sim-clock seconds — the single source of truth for migration
        timing figures."""
        return self.now * STEP_S

    # -- topology ------------------------------------------------------------
    def attach(self, gid: int, device):
        assert gid not in self._devices, f"gid {gid} in use"
        self._devices[gid] = device

    def detach(self, gid: int):
        self._devices.pop(gid, None)

    def device(self, gid: int):
        return self._devices.get(gid)

    def link(self, src_gid: int, dest_gid: int) -> Link:
        key = (src_gid, dest_gid)
        ln = self._links.get(key)
        if ln is None:
            ln = self._links[key] = Link()
        return ln

    def link_utilization(self, src_gid: int, dest_gid: int) -> float:
        """Measured fraction of the link's capacity committed over the
        UTILIZATION_WINDOW horizon (admission reads this, not an analytic
        guess). Two signals, whichever is worse: bytes enqueued over the
        trailing window (offered load), and the standing backlog still
        serialising (a drained-but-booked link is not free capacity)."""
        ln = self._links.get((src_gid, dest_gid))
        if ln is None or self.bytes_per_step <= 0:
            return 0.0
        cap = UTILIZATION_WINDOW * self.bytes_per_step
        offered = ln.window_bytes(self.now) / cap
        backlog = max(0.0, ln.busy_until - self.now) / UTILIZATION_WINDOW
        return min(1.0, max(offered, backlog))

    # -- wire ----------------------------------------------------------------
    def send(self, pkt: Packet):
        n = pkt.nbytes()
        self.stats["tx_packets"] += 1
        self.stats["tx_bytes"] += n
        if pkt.op in MIG_OPS:
            self.stats["mig_tx_packets"] += 1
            self.stats["mig_tx_bytes"] += n
        if self.trace is not None:
            self.trace.append(pkt)
        ln = self.link(pkt.src_gid, pkt.dest_gid)
        # the packet occupies the link whether or not it is then lost —
        # serialisation time is spent before the wire can drop anything
        start = max(float(self.now), ln.busy_until)
        ln.busy_until = start + n / self.bytes_per_step
        ln.record(self.now, n)
        if self.rng.random() < self.loss_prob:
            self.stats["dropped"] += 1
            return
        ln.queue.append((ln.busy_until + self.latency, pkt))

    def in_flight(self) -> int:
        return sum(len(ln.queue) for ln in self._links.values())

    def pump(self, steps: int = 1):
        """Advance time: deliver due packets, then run all QP tasks."""
        for _ in range(steps):
            self.now += 1
            for ln in self._links.values():
                q = ln.queue
                while q and q[0][0] <= self.now:
                    pkt = q.popleft()[1]
                    dev = self._devices.get(pkt.dest_gid)
                    if dev is None:
                        self.stats["unroutable"] += 1   # [MIGR] old address
                        continue
                    dev.receive(pkt)
            for dev in list(self._devices.values()):
                dev.run_tasks()

    def run_until_idle(self, max_steps: int = 100_000) -> int:
        """Pump until no packets are in flight and all QPs are quiescent."""
        for i in range(max_steps):
            self.pump()
            if not self.in_flight() and all(d.idle() for d in
                                            self._devices.values()):
                return i + 1
        raise TimeoutError("fabric did not quiesce")
