"""Software fabric: deterministic packet router between nodes.

Plays the role SoftRoCE plays in the paper — a software implementation of
the wire protocol that lets the OS inspect and control everything. The
fabric is synchronous and step-driven (no threads): ``pump()`` delivers
in-flight packets and runs every QP's requester/responder/completer tasks
once; determinism makes protocol tests exact. Loss injection exercises the
go-back-N retransmission path that migration relies on.
"""
from __future__ import annotations

import random
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.packets import Packet


class Fabric:
    def __init__(self, *, loss_prob: float = 0.0, seed: int = 0,
                 latency_steps: int = 1, bandwidth_Bps: float = 40e9 / 8):
        self.loss_prob = loss_prob
        self.rng = random.Random(seed)
        self.latency = max(1, latency_steps)
        self.bandwidth = bandwidth_Bps
        self.now = 0
        self._wire: deque = deque()           # (deliver_at, packet)
        self._devices: Dict[int, "RdmaDevice"] = {}   # gid -> device
        self.stats = defaultdict(int)
        self.trace: Optional[List[Packet]] = None

    # -- topology ------------------------------------------------------------
    def attach(self, gid: int, device):
        assert gid not in self._devices, f"gid {gid} in use"
        self._devices[gid] = device

    def detach(self, gid: int):
        self._devices.pop(gid, None)

    def device(self, gid: int):
        return self._devices.get(gid)

    # -- wire ----------------------------------------------------------------
    def send(self, pkt: Packet):
        self.stats["tx_packets"] += 1
        self.stats["tx_bytes"] += pkt.nbytes()
        if self.trace is not None:
            self.trace.append(pkt)
        if self.rng.random() < self.loss_prob:
            self.stats["dropped"] += 1
            return
        self._wire.append((self.now + self.latency, pkt))

    def pump(self, steps: int = 1):
        """Advance time: deliver due packets, then run all QP tasks."""
        for _ in range(steps):
            self.now += 1
            undelivered = deque()
            while self._wire:
                at, pkt = self._wire.popleft()
                if at > self.now:
                    undelivered.append((at, pkt))
                    continue
                dev = self._devices.get(pkt.dest_gid)
                if dev is None:
                    self.stats["unroutable"] += 1   # [MIGR] old address
                    continue
                dev.receive(pkt)
            self._wire = undelivered
            for dev in list(self._devices.values()):
                dev.run_tasks()

    def run_until_idle(self, max_steps: int = 100_000) -> int:
        """Pump until no packets are in flight and all QPs are quiescent."""
        for i in range(max_steps):
            self.pump()
            if not self._wire and all(d.idle() for d in
                                      self._devices.values()):
                return i + 1
        raise TimeoutError("fabric did not quiesce")
