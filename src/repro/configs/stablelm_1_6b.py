"""stablelm-1.6b [dense]: partial rotary (25%), LayerNorm.
[hf:stabilityai/stablelm-2-1_6b]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100_352,
    layer_pattern=("attn",),
    rope_pct=0.25,
    norm_kind="layernorm",
    mlp_kind="swiglu",
    tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=160, vocab_size=512, dtype="float32")
