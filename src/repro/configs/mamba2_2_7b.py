"""mamba2-2.7b [ssm]: attention-free, SSD (state-space duality) mixer.
[arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=80,              # d_inner / head_dim (informational)
    num_kv_heads=80,
    head_dim=64,
    d_ff=0,                    # no MLP sublayer
    vocab_size=50_280,
    layer_pattern=("ssm",),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  chunk_size=256, ngroups=1),
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        vocab_size=512, dtype="float32",
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      chunk_size=32, ngroups=1))
