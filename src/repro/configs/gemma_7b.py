"""gemma-7b [dense]: GeGLU, head_dim=256. [arXiv:2403.08295]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256_000,
    layer_pattern=("attn",),
    mlp_kind="geglu",
    tie_embeddings=True,
    scale_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=256, vocab_size=512, dtype="float32")
