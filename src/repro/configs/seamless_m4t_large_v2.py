"""seamless-m4t-large-v2 [audio]: encoder-decoder backbone; the speech
frontend is a stub emitting precomputed frame embeddings per the assignment
spec. [arXiv:2308.11596]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,             # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256_206,
    layer_pattern=("attn",),
    mlp_kind="gelu",
    norm_kind="layernorm",
    frontend="audio",
    frontend_tokens=4096,      # encoder frames per sample (overridden by shape)
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        frontend_tokens=32, dtype="float32")
