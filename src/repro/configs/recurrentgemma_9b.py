"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 2 recurrent : 1
local-attn pattern. [arXiv:2402.19427]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,            # MQA
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    layer_pattern=("rec", "rec", "local"),
    local_window=2048,
    rnn_width=4096,
    rnn_heads=16,
    mlp_kind="geglu",
    tie_embeddings=True,
    scale_embeddings=True,
    attn_logit_softcap=0.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=5, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512, rnn_width=64, rnn_heads=4,
        local_window=32, dtype="float32")
