"""internvl2-76b [vlm]: llama3-70b-class language backbone; InternViT
frontend is a stub emitting precomputed patch embeddings per the assignment
spec. [arXiv:2404.16821]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,            # GQA
    head_dim=128,
    d_ff=28672,
    vocab_size=128_256,
    layer_pattern=("attn",),
    mlp_kind="swiglu",
    frontend="vision",
    frontend_tokens=256,       # patch embeddings per image
    rope_theta=500_000.0,
    tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
        d_ff=160, vocab_size=512, frontend_tokens=16, dtype="float32")
