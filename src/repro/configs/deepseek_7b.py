"""deepseek-7b [dense]: llama-architecture. [arXiv:2401.02954]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,           # MHA
    head_dim=128,
    d_ff=11008,
    vocab_size=102_400,
    layer_pattern=("attn",),
    mlp_kind="swiglu",
    tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=160, vocab_size=512, dtype="float32")
