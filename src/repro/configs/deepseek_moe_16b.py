"""deepseek-moe-16b [moe]: fine-grained 2 shared + 64 routed top-6 experts,
first layer dense. [arXiv:2401.06066]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,                 # per-expert hidden
    vocab_size=102_400,
    layer_pattern=("attn",),
    moe=MoEConfig(num_experts=64, num_shared=2, top_k=6, d_ff_expert=1408,
                  first_k_dense=1, d_ff_dense=10944, capacity_factor=1.25),
    mlp_kind="swiglu",
    tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=32, vocab_size=512, dtype="float32",
        moe=MoEConfig(num_experts=8, num_shared=2, top_k=2, d_ff_expert=32,
                      first_k_dense=1, d_ff_dense=128))
