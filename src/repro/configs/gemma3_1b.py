"""gemma3-1b [dense]: 5 local : 1 global attention, MQA, 128k-class context.
[hf:google/gemma-3-1b-pt]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,            # MQA
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    layer_pattern=("local", "local", "local", "local", "local", "attn"),
    local_window=512,
    qk_norm=True,
    mlp_kind="geglu",
    tie_embeddings=True,
    scale_embeddings=True,
    rope_theta=1_000_000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512, local_window=32, dtype="float32")
