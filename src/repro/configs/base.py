"""Config system: frozen dataclasses + arch registry.

Every assigned architecture has a module ``repro.configs.<id>`` exposing
``CONFIG`` (full-size, exercised only via the dry-run) and ``smoke()``
(a reduced config of the same family for CPU tests).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int                 # routed experts
    num_shared: int                  # shared (always-on) experts
    top_k: int
    d_ff_expert: int                 # per-expert hidden size
    first_k_dense: int = 1           # leading layers use a dense MLP
    d_ff_dense: int = 0              # hidden size of those dense MLPs
    capacity_factor: float = 1.25    # dropping-dispatch capacity
    router_aux_weight: float = 1e-3  # load-balance aux loss weight


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536          # 0 = full-rank q projection
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128               # N
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64               # P
    chunk_size: int = 256
    ngroups: int = 8


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- layer pattern -----------------------------------------------------
    # One period of mixer kinds, cycled over depth. Kinds:
    #   "attn" (global), "local" (sliding window), "rec" (RG-LRU), "ssm".
    layer_pattern: Tuple[str, ...] = ("attn",)
    local_window: int = 0
    # --- attention ----------------------------------------------------------
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0            # partial rotary (stablelm: 0.25)
    qk_norm: bool = False
    attn_logit_softcap: float = 0.0
    # --- mlp ----------------------------------------------------------------
    mlp_kind: str = "swiglu"         # swiglu | geglu | gelu (non-gated)
    # --- families -----------------------------------------------------------
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # --- RG-LRU (Griffin) recurrent blocks -----------------------------------
    rnn_width: int = 0               # 0 => d_model
    rnn_heads: int = 16              # block-diagonal gate heads
    rnn_conv: int = 4
    rglru_c: float = 8.0
    encoder_layers: int = 0          # >0 => encoder-decoder
    frontend: str = "none"           # none | audio | vision (stubbed per spec)
    frontend_tokens: int = 256       # frames/patches the stub frontend emits
    # --- misc ----------------------------------------------------------------
    tie_embeddings: bool = False
    scale_embeddings: bool = False   # gemma-style sqrt(d_model) input scaling
    norm_kind: str = "rmsnorm"       # rmsnorm | layernorm
    norm_eps: float = 1e-6
    logits_softcap: float = 0.0
    dtype: str = "bfloat16"          # compute dtype
    param_dtype: str = "float32"
    # --- distribution knobs (overridable per run) ----------------------------
    remat: str = "full"              # none | full | dots_saveable
    scan_layers: bool = True
    pipeline_stages: int = 1
    qkv_constraint: str = "none"     # none | batch  (§Perf hillclimb knob)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Mixer kind for every layer (pattern cycled over depth)."""
        p = self.layer_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    def mlp_kind_at(self, layer_idx: int) -> str:
        if self.moe is not None and layer_idx >= self.moe.first_k_dense:
            return "moe"
        return "dense"

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it shards over 16-way TP."""
        return (self.vocab_size + 255) // 256 * 256


# ---------------------------------------------------------------------------
# Input shapes (assigned shape set; identical for all LM-family archs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# Archs whose every layer is full global attention cannot run long_500k
# (see DESIGN.md §4); SSM / hybrid / mostly-local archs run it.
LONG_CONTEXT_ARCHS = ("recurrentgemma-9b", "gemma3-1b", "mamba2-2.7b")


def shape_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "recurrentgemma-9b",
    "deepseek-7b",
    "gemma-7b",
    "stablelm-1.6b",
    "gemma3-1b",
    "seamless-m4t-large-v2",
    "internvl2-76b",
    "deepseek-v2-236b",
    "deepseek-moe-16b",
    "mamba2-2.7b",
)


def _module_for(arch: str):
    mod = arch.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return _module_for(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module_for(arch).smoke()
