"""deepseek-v2-236b [moe]: MLA (kv_lora=512) + 2 shared / 160 routed top-6
experts, first layer dense. [arXiv:2405.04434]"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,          # MLA expands to MHA; spec field kept faithful
    head_dim=128,
    d_ff=1536,                 # per-expert hidden
    vocab_size=102_400,
    layer_pattern=("mla",),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, num_shared=2, top_k=6, d_ff_expert=1536,
                  first_k_dense=1, d_ff_dense=12288, capacity_factor=1.25),
    mlp_kind="swiglu",
    tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=32, vocab_size=512, dtype="float32",
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=8, num_shared=2, top_k=2, d_ff_expert=32,
                      first_k_dense=1, d_ff_dense=128))
