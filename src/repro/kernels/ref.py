"""Pure-jnp oracles for every kernel. Small-shape, O(S^2)/sequential —
ground truth for kernel tests and for the blocked/pallas implementations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None):
    """Naive masked attention.

    q: [B, Sq, H, hd]; k, v: [B, Sk, Kh, hd] with H % Kh == 0.
    ``window`` > 0 restricts key j for query i to i - window < j <= i.
    Query positions are right-aligned: qpos = Sk - Sq + arange(Sq).
    """
    B, Sq, H, hd = q.shape
    _, Sk, Kh, _ = k.shape
    G = H // Kh
    scale = scale if scale is not None else hd ** -0.5
    qf = q.reshape(B, Sq, Kh, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqkgh,bckh->bkgqc", qf, kf) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    qpos = (Sk - Sq) + jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def rglru_ref(x, a_log, gate_a, gate_x, *, c: float = 8.0):
    """RG-LRU (Griffin eq. 2-4), sequential over time.

    x:       [B, S, D]  input
    a_log:   [D]        learnable Lambda (pre-softplus)
    gate_a:  [B, S, D]  recurrence gate pre-activation  r_t
    gate_x:  [B, S, D]  input gate pre-activation       i_t
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    log a_t = -c * softplus(a_log) * sigmoid(r_t).
    Returns (y [B,S,D], h_final [B,D]). Computation in float32.
    """
    xf = x.astype(jnp.float32)
    log_a = -c * jax.nn.softplus(a_log.astype(jnp.float32)) * \
        jax.nn.sigmoid(gate_a.astype(jnp.float32))            # [B,S,D]
    a = jnp.exp(log_a)
    gated_x = jax.nn.sigmoid(gate_x.astype(jnp.float32)) * xf
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bx = beta * gated_x

    def step(h, inp):
        a_t, bx_t = inp
        h = a_t * h + bx_t
        return h, h

    h0 = jnp.zeros((x.shape[0], x.shape[2]), jnp.float32)
    hT, ys = jax.lax.scan(step, h0, (a.swapaxes(0, 1), bx.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(x.dtype), hT


def ssd_ref(x, dt, A_log, B, C, *, D=None, h0=None):
    """Mamba-2 SSD, sequential-over-time oracle.

    x:  [b, S, H, P]   inputs (already post-conv/activation)
    dt: [b, S, H]      softplus'd step sizes (> 0)
    A_log: [H]         per-head decay (a_t = exp(-exp(A_log) * dt))
    B:  [b, S, G, N]   input projections (G groups, H % G == 0)
    C:  [b, S, G, N]   output projections
    D:  [H] or None    skip connection
    h0: [b, H, P, N]   initial state
    Returns (y [b,S,H,P], h_final [b,H,P,N]).
    """
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    a = jnp.exp(-jnp.exp(A_log.astype(jnp.float32))[None, None] * dtf)  # [b,S,H]
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=2)   # [b,S,H,N]
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=2)

    def step(h, inp):
        x_t, dt_t, a_t, B_t, C_t = inp
        # h: [b,H,P,N]
        h = a_t[..., None, None] * h + \
            (dt_t[..., None, None] * x_t[..., None]) * B_t[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, C_t)
        return h, y

    if h0 is None:
        h0 = jnp.zeros((b, H, P, N), jnp.float32)
    xs = (xf.swapaxes(0, 1), dtf.swapaxes(0, 1), a.swapaxes(0, 1),
          Bf.swapaxes(0, 1), Cf.swapaxes(0, 1))
    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    y = ys.swapaxes(0, 1)
    if D is not None:
        y = y + D.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype), hT
