"""Public kernel entry points with implementation dispatch.

Implementations:
  * ``pallas``  — TPU Pallas kernels (``flash_attention.py``, ``rglru.py``,
                  ``ssd.py``). On CPU these run with ``interpret=True`` and
                  are exercised by the kernel tests only.
  * ``blocked`` — chunked pure-jnp paths computing the identical math with
                  flash-style online softmax / chunked state passing. These
                  lower on any backend and never materialise S×S buffers, so
                  dry-run rooflines stay honest. Default on CPU.
  * ``ref``     — naive oracles (``ref.py``), small shapes only.

``schedule`` (attention): "full" computes all (q-chunk × kv-chunk) blocks
with masking (2× causal FLOPs, smallest HLO); "triangular" statically skips
blocks above the diagonal (the §Perf hillclimb flips this).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref

_NEG = -1e30


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "blocked"


def _chunk_of(s: int, want: int) -> int:
    return want if s % want == 0 else math.gcd(s, want)


# ===========================================================================
# Attention
# ===========================================================================


def attention(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None,
              impl=None, schedule="full", chunk_q=512, chunk_k=512):
    """q: [B,Sq,H,hd]; k,v: [B,Sk,Kh,hd]. Queries right-aligned in keys.

    impl:
      * "blocked" — chunked online-softmax; autodiff saves per-chunk
        residuals (baseline; memory-heavy backward).
      * "flash"   — same forward + hand-written flash backward
        (custom_vjp): saves only (out, lse), recomputes scores per block.
      * "pallas" / "ref" — TPU kernel / naive oracle.
    """
    impl = impl or default_impl()
    if impl == "ref":
        return _ref.attention_ref(q, k, v, causal=causal, window=window,
                                  softcap=softcap, scale=scale)
    if impl == "pallas":
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(q, k, v, causal=causal, window=window,
                                  softcap=softcap, scale=scale,
                                  interpret=jax.default_backend() != "tpu")
    if impl == "flash":
        hd = q.shape[-1]
        scale = scale if scale is not None else hd ** -0.5
        cq = _chunk_of(q.shape[1], chunk_q)
        ck = _chunk_of(k.shape[1], chunk_k)
        if window > 0 and k.shape[1] <= window + cq:
            window = 0 if (causal and q.shape[1] == k.shape[1]) else window
            if window > 0:
                return _ref.attention_ref(q, k, v, causal=causal,
                                          window=window, softcap=softcap,
                                          scale=scale)
        return _flash(q, k, v, causal, window, softcap, scale, cq, ck)
    B, Sq, H, hd = q.shape
    _, Sk, Kh, _ = k.shape
    scale = scale if scale is not None else hd ** -0.5
    cq = _chunk_of(Sq, chunk_q)
    ck = _chunk_of(Sk, chunk_k)
    if window > 0:
        if Sk <= window + cq:  # window covers (almost) everything
            return _ref.attention_ref(q, k, v, causal=causal, window=window,
                                      softcap=softcap, scale=scale)
        return _local_blocked(q, k, v, window=window, softcap=softcap,
                              scale=scale, cq=cq)
    if schedule == "triangular" and causal and Sq == Sk:
        return _triangular_blocked(q, k, v, softcap=softcap, scale=scale,
                                   cq=cq, ck=ck)
    return _full_blocked(q, k, v, causal=causal, softcap=softcap,
                         scale=scale, cq=cq, ck=ck)


def _block(qc, kc, vc, qpos, kpos, m, l, acc, *, causal, window, softcap,
           scale):
    """One online-softmax block update. qc:[B,cq,Kh,G,hd] kc:[B,ck,Kh,hd]."""
    s = jnp.einsum("bqkgh,bckh->bkgqc", qc.astype(jnp.float32),
                   kc.astype(jnp.float32)) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, _NEG)
    m_new = jnp.maximum(m, s.max(-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l = l * alpha + p.sum(-1)
    acc = acc * alpha[..., None] + jnp.einsum(
        "bkgqc,bckh->bkgqh", p, vc.astype(jnp.float32))
    return m_new, l, acc


def _finish(l, acc, B, cq_total, H, hd, dtype):
    out = acc / jnp.maximum(l, 1e-30)[..., None]       # [nq?,B,Kh,G,cq,hd]
    return out


def _full_blocked(q, k, v, *, causal, softcap, scale, cq, ck):
    B, Sq, H, hd = q.shape
    _, Sk, Kh, _ = k.shape
    G = H // Kh
    nq, nk = Sq // cq, Sk // ck
    off = Sk - Sq
    qr = q.reshape(B, nq, cq, Kh, G, hd).transpose(1, 0, 2, 3, 4, 5)
    kr = k.reshape(B, nk, ck, Kh, hd).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(B, nk, ck, Kh, hd).transpose(1, 0, 2, 3, 4)

    def q_step(_, qin):
        qi, qc = qin
        qpos = off + qi * cq + jnp.arange(cq)

        def k_step(carry, kin):
            kj, kc, vc = kin
            m, l, acc = carry
            kpos = kj * ck + jnp.arange(ck)
            m, l, acc = _block(qc, kc, vc, qpos, kpos, m, l, acc,
                               causal=causal, window=0, softcap=softcap,
                               scale=scale)
            return (m, l, acc), None

        init = (jnp.full((B, Kh, G, cq), _NEG, jnp.float32),
                jnp.zeros((B, Kh, G, cq), jnp.float32),
                jnp.zeros((B, Kh, G, cq, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(k_step, init,
                                      (jnp.arange(nk), kr, vr))
        return None, acc / jnp.maximum(l, 1e-30)[..., None]

    _, out = jax.lax.scan(q_step, None, (jnp.arange(nq), qr))
    # out: [nq, B, Kh, G, cq, hd]
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def _triangular_blocked(q, k, v, *, softcap, scale, cq, ck):
    """Causal Sq==Sk: statically skip above-diagonal blocks (~2× less work).

    Unrolled over q chunks; HLO size O(nq) — used for the 4k train shape.
    """
    B, S, H, hd = q.shape
    Kh = k.shape[2]
    G = H // Kh
    nq = S // cq
    outs = []
    for qi in range(nq):
        qc = q[:, qi * cq:(qi + 1) * cq].reshape(B, cq, Kh, G, hd)
        qpos = qi * cq + jnp.arange(cq)
        hi = (qi + 1) * cq          # keys strictly needed: [0, hi)
        nkb = hi // ck
        kr = k[:, :hi].reshape(B, nkb, ck, Kh, hd).transpose(1, 0, 2, 3, 4)
        vr = v[:, :hi].reshape(B, nkb, ck, Kh, hd).transpose(1, 0, 2, 3, 4)

        def k_step(carry, kin, qc=qc, qpos=qpos):
            kj, kc, vc = kin
            m, l, acc = carry
            kpos = kj * ck + jnp.arange(ck)
            m, l, acc = _block(qc, kc, vc, qpos, kpos, m, l, acc,
                               causal=True, window=0, softcap=softcap,
                               scale=scale)
            return (m, l, acc), None

        init = (jnp.full((B, Kh, G, cq), _NEG, jnp.float32),
                jnp.zeros((B, Kh, G, cq), jnp.float32),
                jnp.zeros((B, Kh, G, cq, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(k_step, init,
                                      (jnp.arange(nkb), kr, vr))
        o = acc / jnp.maximum(l, 1e-30)[..., None]     # [B,Kh,G,cq,hd]
        outs.append(o.transpose(0, 3, 1, 2, 4).reshape(B, cq, H, hd))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def _local_blocked(q, k, v, *, window, softcap, scale, cq):
    """Sliding-window attention: each q chunk sees a length-(window+cq) slice."""
    B, Sq, H, hd = q.shape
    _, Sk, Kh, _ = k.shape
    G = H // Kh
    nq = Sq // cq
    off = Sk - Sq
    L = window + cq
    qr = q.reshape(B, nq, cq, Kh, G, hd).transpose(1, 0, 2, 3, 4, 5)

    def q_step(_, qin):
        qi, qc = qin
        q0 = off + qi * cq
        start = jnp.clip(q0 + cq - L, 0, Sk - L)
        kc = jax.lax.dynamic_slice(k, (0, start, 0, 0), (B, L, Kh, hd))
        vc = jax.lax.dynamic_slice(v, (0, start, 0, 0), (B, L, Kh, hd))
        qpos = q0 + jnp.arange(cq)
        kpos = start + jnp.arange(L)
        m = jnp.full((B, Kh, G, cq), _NEG, jnp.float32)
        l = jnp.zeros((B, Kh, G, cq), jnp.float32)
        acc = jnp.zeros((B, Kh, G, cq, hd), jnp.float32)
        m, l, acc = _block(qc, kc, vc, qpos, kpos, m, l, acc, causal=True,
                           window=window, softcap=softcap, scale=scale)
        return None, acc / jnp.maximum(l, 1e-30)[..., None]

    _, out = jax.lax.scan(q_step, None, (jnp.arange(nq), qr))
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Flash attention with custom VJP (XLA-level): forward = online softmax,
# backward recomputes scores blockwise from (q, k, v, out, lse). Saves O(S)
# residuals instead of O(S^2) — the standard flash backward, expressed in
# chunked jnp so it lowers on any backend.
# ---------------------------------------------------------------------------


def _fwd_blocked_lse(q, k, v, causal, window, softcap, scale, cq, ck):
    """Forward producing (out, lse). Window path slices; global path scans."""
    B, Sq, H, hd = q.shape
    _, Sk, Kh, _ = k.shape
    G = H // Kh
    nq = Sq // cq
    off = Sk - Sq
    qr = q.reshape(B, nq, cq, Kh, G, hd).transpose(1, 0, 2, 3, 4, 5)

    if window > 0:
        L = window + cq

        def q_step(_, qin):
            qi, qc = qin
            q0 = off + qi * cq
            start = jnp.clip(q0 + cq - L, 0, Sk - L)
            kc = jax.lax.dynamic_slice(k, (0, start, 0, 0), (B, L, Kh, hd))
            vc = jax.lax.dynamic_slice(v, (0, start, 0, 0), (B, L, Kh, hd))
            qpos = q0 + jnp.arange(cq)
            kpos = start + jnp.arange(L)
            m = jnp.full((B, Kh, G, cq), _NEG, jnp.float32)
            l = jnp.zeros((B, Kh, G, cq), jnp.float32)
            acc = jnp.zeros((B, Kh, G, cq, hd), jnp.float32)
            m, l, acc = _block(qc, kc, vc, qpos, kpos, m, l, acc,
                               causal=True, window=window, softcap=softcap,
                               scale=scale)
            o = acc / jnp.maximum(l, 1e-30)[..., None]
            return None, (o, m + jnp.log(jnp.maximum(l, 1e-30)))

        _, (out, lse) = jax.lax.scan(q_step, None, (jnp.arange(nq), qr))
    else:
        nk = Sk // ck
        kr = k.reshape(B, nk, ck, Kh, hd).transpose(1, 0, 2, 3, 4)
        vr = v.reshape(B, nk, ck, Kh, hd).transpose(1, 0, 2, 3, 4)

        def q_step(_, qin):
            qi, qc = qin
            qpos = off + qi * cq + jnp.arange(cq)

            def k_step(carry, kin):
                kj, kc, vc = kin
                m, l, acc = carry
                kpos = kj * ck + jnp.arange(ck)
                return _block(qc, kc, vc, qpos, kpos, m, l, acc,
                              causal=causal, window=0, softcap=softcap,
                              scale=scale), None

            init = (jnp.full((B, Kh, G, cq), _NEG, jnp.float32),
                    jnp.zeros((B, Kh, G, cq), jnp.float32),
                    jnp.zeros((B, Kh, G, cq, hd), jnp.float32))
            (m, l, acc), _ = jax.lax.scan(k_step, init,
                                          (jnp.arange(nk), kr, vr))
            o = acc / jnp.maximum(l, 1e-30)[..., None]
            return None, (o, m + jnp.log(jnp.maximum(l, 1e-30)))

        _, (out, lse) = jax.lax.scan(q_step, None, (jnp.arange(nq), qr))
    # out: [nq,B,Kh,G,cq,hd]; lse: [nq,B,Kh,G,cq]
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, hd)
    lse = lse.transpose(1, 0, 4, 2, 3).reshape(B, Sq, H)
    return out.astype(q.dtype), lse


def _mask_for(qpos, kpos, causal, window):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        m &= (qpos[:, None] - kpos[None, :]) < window
    return m


def _scores(qc, kc, qpos, kpos, causal, window, softcap, scale):
    """Returns (p_unnorm_exp_arg-ready raw scores s, tanh-term for softcap)."""
    s = jnp.einsum("bqkgh,bckh->bkgqc", qc.astype(jnp.float32),
                   kc.astype(jnp.float32)) * scale
    t = None
    if softcap > 0:
        t = jnp.tanh(s / softcap)
        s = t * softcap
    mask = _mask_for(qpos, kpos, causal, window)
    s = jnp.where(mask[None, None, None], s, _NEG)
    return s, t, mask


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, softcap, scale, cq, ck):
    out, _ = _fwd_blocked_lse(q, k, v, causal, window, softcap, scale,
                              cq, ck)
    return out


def _flash_fwd(q, k, v, causal, window, softcap, scale, cq, ck):
    out, lse = _fwd_blocked_lse(q, k, v, causal, window, softcap, scale,
                                cq, ck)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, softcap, scale, cq, ck, res, do):
    q, k, v, out, lse = res
    B, Sq, H, hd = q.shape
    _, Sk, Kh, _ = k.shape
    G = H // Kh
    nq = Sq // cq
    off = Sk - Sq
    dof = do.astype(jnp.float32)
    delta = jnp.sum(dof * out.astype(jnp.float32), -1)          # [B,Sq,H]

    qr = q.reshape(B, nq, cq, Kh, G, hd).transpose(1, 0, 2, 3, 4, 5)
    dor = dof.reshape(B, nq, cq, Kh, G, hd).transpose(1, 0, 2, 3, 4, 5)
    lser = lse.reshape(B, nq, cq, Kh, G).transpose(1, 0, 3, 4, 2)
    dlr = delta.reshape(B, nq, cq, Kh, G).transpose(1, 0, 3, 4, 2)

    def block_grads(qc, kc, vc, doc, lsec, dc, qpos, kpos):
        """One (q-chunk × k-chunk) gradient block."""
        s, t, mask = _scores(qc, kc, qpos, kpos, causal, window, softcap,
                             scale)
        p = jnp.exp(s - lsec[..., None])                        # [B,Kh,G,q,c]
        p = jnp.where(mask[None, None, None], p, 0.0)
        dv = jnp.einsum("bkgqc,bqkgh->bckh", p, doc)
        dp = jnp.einsum("bqkgh,bckh->bkgqc", doc, vc.astype(jnp.float32))
        ds = p * (dp - dc[..., None])
        if softcap > 0:
            ds = ds * (1.0 - jnp.square(t))
        ds = ds * scale
        dq = jnp.einsum("bkgqc,bckh->bqkgh", ds, kc.astype(jnp.float32))
        dk = jnp.einsum("bkgqc,bqkgh->bckh", ds, qc.astype(jnp.float32))
        return dq, dk, dv

    if window > 0:
        L = window + cq
        dk_full = jnp.zeros((B, Sk, Kh, hd), jnp.float32)
        dv_full = jnp.zeros((B, Sk, Kh, hd), jnp.float32)

        def q_step(carry, qin):
            dk_full, dv_full = carry
            qi, qc, doc, lsec, dc = qin
            q0 = off + qi * cq
            start = jnp.clip(q0 + cq - L, 0, Sk - L)
            kc = jax.lax.dynamic_slice(k, (0, start, 0, 0), (B, L, Kh, hd))
            vc = jax.lax.dynamic_slice(v, (0, start, 0, 0), (B, L, Kh, hd))
            qpos = q0 + jnp.arange(cq)
            kpos = start + jnp.arange(L)
            dq, dk, dv = block_grads(qc, kc, vc, doc, lsec, dc, qpos, kpos)
            upd_k = jax.lax.dynamic_slice(dk_full, (0, start, 0, 0),
                                          (B, L, Kh, hd)) + dk
            upd_v = jax.lax.dynamic_slice(dv_full, (0, start, 0, 0),
                                          (B, L, Kh, hd)) + dv
            dk_full = jax.lax.dynamic_update_slice(dk_full, upd_k,
                                                   (0, start, 0, 0))
            dv_full = jax.lax.dynamic_update_slice(dv_full, upd_v,
                                                   (0, start, 0, 0))
            return (dk_full, dv_full), dq

        (dk_full, dv_full), dq = jax.lax.scan(
            q_step, (dk_full, dv_full),
            (jnp.arange(nq), qr, dor, lser, dlr))
        dq = dq.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd)
        return (dq.astype(q.dtype), dk_full.astype(k.dtype),
                dv_full.astype(v.dtype))

    nk = Sk // ck
    kr = k.reshape(B, nk, ck, Kh, hd).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(B, nk, ck, Kh, hd).transpose(1, 0, 2, 3, 4)

    def k_step(dq_acc, kin):
        kj, kc, vc = kin
        kpos = kj * ck + jnp.arange(ck)

        def q_step(carry, qin):
            dk_acc, dv_acc = carry
            qi, qc, doc, lsec, dc = qin
            qpos = off + qi * cq + jnp.arange(cq)
            dq, dk, dv = block_grads(qc, kc, vc, doc, lsec, dc, qpos, kpos)
            return (dk_acc + dk, dv_acc + dv), dq

        init = (jnp.zeros((B, ck, Kh, hd), jnp.float32),
                jnp.zeros((B, ck, Kh, hd), jnp.float32))
        (dk, dv), dq_parts = jax.lax.scan(
            q_step, init, (jnp.arange(nq), qr, dor, lser, dlr))
        return dq_acc + dq_parts, (dk, dv)

    dq0 = jnp.zeros((nq, B, cq, Kh, G, hd), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(k_step, dq0, (jnp.arange(nk), kr, vr))
    dq = dq.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd)
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(B, Sk, Kh, hd)
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(B, Sk, Kh, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def attention_decode(q, k_cache, v_cache, lengths, *, window=0, softcap=0.0,
                     scale=None, slot_positions=None):
    """Single-token decode over a (possibly ring-buffered) KV cache.

    q: [B,1,H,hd]; caches: [B,S,Kh,hd]; lengths: [B] tokens written so far
    (including the current one). ``slot_positions``: [B,S] absolute position
    held by each cache slot (ring buffers); None ⇒ slot i holds position i.
    """
    B, _, H, hd = q.shape
    _, S, Kh, _ = k_cache.shape
    G = H // Kh
    scale = scale if scale is not None else hd ** -0.5
    kpos = (jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            if slot_positions is None else slot_positions)
    valid = (kpos >= 0) & (kpos < lengths[:, None])
    if window > 0:
        valid &= kpos >= (lengths[:, None] - window)
    qf = q.reshape(B, Kh, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qf, k_cache.astype(jnp.float32)) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(valid[:, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


# ===========================================================================
# RG-LRU
# ===========================================================================


def rglru(x, a_log, gate_a, gate_x, *, c=8.0, h0=None, impl=None):
    """Parallel RG-LRU scan. Shapes as in ``ref.rglru_ref``; supports an
    initial state ``h0`` [B,D]. Returns (y, h_final)."""
    impl = impl or default_impl()
    if impl == "pallas":
        from repro.kernels import rglru as _pl
        return _pl.rglru_scan(x, a_log, gate_a, gate_x, c=c, h0=h0,
                              interpret=jax.default_backend() != "tpu")
    if impl == "ref" and h0 is None:
        return _ref.rglru_ref(x, a_log, gate_a, gate_x, c=c)
    xf = x.astype(jnp.float32)
    log_a = -c * jax.nn.softplus(a_log.astype(jnp.float32)) * \
        jax.nn.sigmoid(gate_a.astype(jnp.float32))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * jax.nn.sigmoid(gate_x.astype(jnp.float32)) * xf
    if h0 is not None:
        # fold h0 in as a virtual first step with a=0, b=h0
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], 1)
        b = jnp.concatenate([h0.astype(jnp.float32)[:, None], b], 1)

    def combine(ca, cb):
        a1, b1 = ca
        a2, b2 = cb
        return a2 * a1, a2 * b1 + b2

    aa, bb = jax.lax.associative_scan(combine, (a, b), axis=1)
    ys = bb if h0 is None else bb[:, 1:]
    return ys.astype(x.dtype), bb[:, -1]


def rglru_decode(h, x, a_log, gate_a, gate_x, *, c=8.0):
    """One recurrence step. h: [B,D]; x/gates: [B,D]. Returns (y, h_new)."""
    xf = x.astype(jnp.float32)
    log_a = -c * jax.nn.softplus(a_log.astype(jnp.float32)) * \
        jax.nn.sigmoid(gate_a.astype(jnp.float32))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    h_new = a * h + beta * jax.nn.sigmoid(gate_x.astype(jnp.float32)) * xf
    return h_new.astype(x.dtype), h_new


# ===========================================================================
# Mamba-2 SSD (chunked state-space duality)
# ===========================================================================


def ssd(x, dt, A_log, B, C, *, D=None, h0=None, chunk=256, impl=None):
    """Chunked SSD. Shapes as in ``ref.ssd_ref``. Returns (y, h_final)."""
    impl = impl or default_impl()
    if impl == "pallas":
        from repro.kernels import ssd as _pl
        return _pl.ssd_scan(x, dt, A_log, B, C, D=D, h0=h0, chunk=chunk,
                            interpret=jax.default_backend() != "tpu")
    if impl == "ref":
        return _ref.ssd_ref(x, dt, A_log, B, C, D=D, h0=h0)
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    Q = _chunk_of(S, chunk)
    nc = S // Q
    rep = H // G
    xf = x.astype(jnp.float32).reshape(b, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(b, nc, Q, H)
    Bf = jnp.repeat(B.astype(jnp.float32), rep, 2).reshape(b, nc, Q, H, N)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, 2).reshape(b, nc, Q, H, N)
    la = -jnp.exp(A_log.astype(jnp.float32))[None, None, None] * dtf
    La = jnp.cumsum(la, axis=2)                       # [b,nc,Q,H]
    xb = dtf[..., None] * xf                          # dt-weighted inputs

    # --- intra-chunk (quadratic within chunk) ------------------------------
    idx = jnp.arange(Q)
    tri = idx[:, None] >= idx[None, :]
    # decay(i,j) = exp(La_i - La_j) for i >= j
    dec = jnp.exp(jnp.clip(La[:, :, :, None] - La[:, :, None, :], -60, 0.0))
    gsc = jnp.einsum("bcihn,bcjhn->bchij", Cf, Bf)    # [b,nc,H,Q,Q]
    gsc = gsc * dec.transpose(0, 1, 4, 2, 3)          # [b,nc,i,j,H]->[b,nc,H,i,j]
    gsc = jnp.where(tri[None, None, None], gsc, 0.0)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", gsc, xb)

    # --- per-chunk end states ----------------------------------------------
    dec_end = jnp.exp(La[:, :, -1:, :] - La)          # [b,nc,Q,H]
    st = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", dec_end, Bf, xb)

    # --- inter-chunk recurrence ---------------------------------------------
    A_chunk = jnp.exp(La[:, :, -1])                   # [b,nc,H]

    def step(h, inp):
        a_c, s_c = inp
        h_out = h                                      # state ENTERING chunk
        h = a_c[..., None, None] * h + s_c
        return h, h_out

    if h0 is None:
        h0 = jnp.zeros((b, H, P, N), jnp.float32)
    hT, h_in = jax.lax.scan(step, h0.astype(jnp.float32),
                            (A_chunk.swapaxes(0, 1), st.swapaxes(0, 1)))
    h_in = h_in.swapaxes(0, 1)                        # [b,nc,H,P,N]

    # --- inter-chunk contribution -------------------------------------------
    y_inter = jnp.einsum("bcqh,bcqhn,bchpn->bcqhp", jnp.exp(La), Cf, h_in)

    y = (y_intra + y_inter).reshape(b, S, H, P)
    if D is not None:
        y = y + D.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), hT


def ssd_decode(h, x, dt, A_log, B, C, *, D=None):
    """One SSD step. h: [b,H,P,N]; x: [b,H,P]; dt: [b,H]; B,C: [b,G,N]."""
    b, H, P, N = h.shape
    G = B.shape[1]
    rep = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    a = jnp.exp(-jnp.exp(A_log.astype(jnp.float32))[None] * dtf)   # [b,H]
    Bf = jnp.repeat(B.astype(jnp.float32), rep, 1)                 # [b,H,N]
    Cf = jnp.repeat(C.astype(jnp.float32), rep, 1)
    h = a[..., None, None] * h + \
        (dtf[..., None] * xf)[..., None] * Bf[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", h, Cf)
    if D is not None:
        y = y + D.astype(jnp.float32)[None, :, None] * xf
    return y.astype(x.dtype), h
