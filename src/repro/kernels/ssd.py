"""Pallas TPU Mamba-2 SSD kernel (chunked state-space duality).

Grid (B, H, nc): chunks are the innermost "arbitrary" axis; the SSM state
[P, N] lives in VMEM scratch across chunks. Per chunk the kernel computes
the intra-chunk quadratic term (two MXU matmuls over [Q,N]×[N,Q] and
[Q,Q]×[Q,P]), the inter-chunk contribution from the carried state, and the
state update — the [Q,Q] decay-masked score matrix never leaves VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _kernel(x_ref, dt_ref, al_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref,
            h_scr, *, Q, nc):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0, 0].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0, 0, 0].astype(jnp.float32).reshape(Q)
    al = al_ref[0, 0]                               # scalar A_log
    Bm = b_ref[0, 0, 0].astype(jnp.float32)         # [Q, N]
    Cm = c_ref[0, 0, 0].astype(jnp.float32)         # [Q, N]

    la = -jnp.exp(al.astype(jnp.float32)) * dt      # [Q] log decay
    La = jnp.cumsum(la)                             # [Q]

    xb = dt[:, None] * x                            # [Q, P]

    # intra-chunk: G[i,j] = (C_i · B_j) * exp(La_i - La_j), i >= j
    sc = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # [Q,Q]
    diff = La[:, None] - La[None, :]
    dec = jnp.exp(jnp.clip(diff, -60.0, 0.0))
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    g = jnp.where(ii >= jj, sc * dec, 0.0)
    y = jax.lax.dot_general(g, xb, (((1,), (0,)), ((), ())))     # [Q,P]

    # inter-chunk: y += exp(La_i) * C_i · h_in
    h_in = h_scr[...]                                            # [P,N]
    y = y + jnp.exp(La)[:, None] * jax.lax.dot_general(
        Cm, h_in, (((1,), (1,)), ((), ())))                      # [Q,P]

    # state update: h = exp(La_last) * h_in + sum_j exp(La_last-La_j) B_j xb_j
    dec_end = jnp.exp(La[-1] - La)                               # [Q]
    st = jax.lax.dot_general(xb * dec_end[:, None], Bm,
                             (((0,), (0,)), ((), ())))           # [P,N]
    h_scr[...] = jnp.exp(La[-1]) * h_in + st

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _finish():
        hout_ref[0, 0] = h_scr[...].astype(hout_ref.dtype)


def ssd_scan(x, dt, A_log, B, C, *, D=None, h0=None, chunk=256,
             interpret=False):
    """Shapes as in ``ref.ssd_ref``. Returns (y, h_final)."""
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Q = min(chunk, S)
    if S % Q:
        Q = math.gcd(S, Q)
    nc = S // Q

    # layout: chunk-major per head
    xr = x.reshape(b, nc, Q, H, P).transpose(0, 3, 1, 2, 4)      # [b,H,nc,Q,P]
    dtr = dt.reshape(b, nc, Q, H).transpose(0, 3, 1, 2)[..., None]
    Br = jnp.repeat(B, rep, 2).reshape(b, nc, Q, H, N).transpose(
        0, 3, 1, 2, 4)
    Cr = jnp.repeat(C, rep, 2).reshape(b, nc, Q, H, N).transpose(
        0, 3, 1, 2, 4)
    if h0 is None:
        h0 = jnp.zeros((b, H, P, N), jnp.float32)
    al2 = jnp.broadcast_to(A_log[None].astype(jnp.float32), (1, H))

    kernel = functools.partial(_kernel, Q=Q, nc=nc)
    y, hT = pl.pallas_call(
        kernel,
        grid=(b, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P),
                         lambda bb, h, ci: (bb, h, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, 1),
                         lambda bb, h, ci: (bb, h, ci, 0, 0)),
            pl.BlockSpec((1, 1), lambda bb, h, ci: (0, h)),
            pl.BlockSpec((1, 1, 1, Q, N),
                         lambda bb, h, ci: (bb, h, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, N),
                         lambda bb, h, ci: (bb, h, ci, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bb, h, ci: (bb, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P),
                         lambda bb, h, ci: (bb, h, ci, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bb, h, ci: (bb, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, H, nc, Q, P), x.dtype),
            jax.ShapeDtypeStruct((b, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xr, dtr, al2, Br, Cr, h0)
    y = y.transpose(0, 2, 3, 1, 4).reshape(b, S, H, P)
    if D is not None:
        y = (y.astype(jnp.float32) +
             D.astype(jnp.float32)[None, None, :, None] *
             x.astype(jnp.float32)).astype(x.dtype)
    return y, hT
