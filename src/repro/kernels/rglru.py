"""Pallas TPU RG-LRU scan kernel.

Grid (B, nd, nt): feature-blocked (bd lanes per program), time chunked
(bt steps per grid step, innermost "arbitrary" axis) with the recurrent
state h carried in VMEM scratch across time chunks. Inside a chunk the
recurrence is a dense fori_loop over rows — on TPU this is VPU work
entirely in VMEM; HBM traffic is exactly one read of (x, gates) and one
write of y. The gate math (a = exp(-c·softplus(Λ)·σ(r))) is fused here so
the decay never round-trips to HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _kernel(x_ref, al_ref, ga_ref, gx_ref, h0_ref, y_ref, hout_ref, h_scr,
            *, c, bt, nt):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)          # [bt, bd]
    al = al_ref[0].astype(jnp.float32)        # [1, bd] (broadcast row)
    ga = ga_ref[0].astype(jnp.float32)
    gx = gx_ref[0].astype(jnp.float32)

    log_a = -c * jax.nn.softplus(al) * jax.nn.sigmoid(ga)     # [bt, bd]
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * jax.nn.sigmoid(gx) * x

    def step(t, carry):
        h, ys = carry
        h = a[t] * h + b[t]
        ys = jax.lax.dynamic_update_index_in_dim(ys, h, t, 0)
        return h, ys

    h0 = h_scr[...]
    h, ys = jax.lax.fori_loop(0, bt, step,
                              (h0[0], jnp.zeros_like(x)))
    y_ref[0] = ys.astype(y_ref.dtype)
    h_scr[...] = h[None]

    @pl.when(ti == nt - 1)
    def _finish():
        hout_ref[...] = h_scr[...].astype(hout_ref.dtype)


def rglru_scan(x, a_log, gate_a, gate_x, *, c=8.0, h0=None, block_d=512,
               block_t=256, interpret=False):
    """x/gates: [B,S,D]; a_log: [D]; h0: [B,D] or None -> (y, h_final)."""
    B, S, D = x.shape
    bd = min(block_d, D)
    if D % bd:
        bd = math.gcd(D, bd)
    bt = min(block_t, S)
    if S % bt:
        bt = math.gcd(S, bt)
    nd, nt = D // bd, S // bt
    if h0 is None:
        h0 = jnp.zeros((B, D), jnp.float32)
    al2 = jnp.broadcast_to(a_log[None], (1, D)).astype(jnp.float32)

    kernel = functools.partial(_kernel, c=c, bt=bt, nt=nt)
    y, hT = pl.pallas_call(
        kernel,
        grid=(B, nd, nt),
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, bd), lambda b, d, t: (0, d)),
            pl.BlockSpec((1, bt, bd), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, bt, bd), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, bd), lambda b, d, t: (b, d)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, bd), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, bd), lambda b, d, t: (b, d)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), x.dtype),
            jax.ShapeDtypeStruct((B, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, al2, gate_a, gate_x, h0)
    return y, hT
