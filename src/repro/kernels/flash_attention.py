"""Pallas TPU flash attention (forward).

Grid (B, H, nq, nk): the kv dimension is innermost ("arbitrary" semantics)
so the online-softmax accumulators live in VMEM scratch across kv blocks.
Blocks are MXU-aligned (bq×hd, bk×hd with hd a multiple of 128 where the
model allows; smaller head dims still work, just underfill the MXU).
GQA: kv blocks index with h // group so G query heads share a kv head.
Causal/local masking skips fully-masked kv blocks via early exit.

Validated against ``ref.attention_ref`` in interpret mode (CPU) by
tests/test_kernels.py; on TPU the same code runs compiled.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, softcap, bq, bk, nk, q_off):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    q0 = q_off + qi * bq                  # absolute position of first query
    k0 = kj * bk

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip blocks that the mask rules out entirely
    live = True
    if causal:
        live = k0 <= q0 + bq - 1           # some key <= last query pos
    if window > 0:
        live = jnp.logical_and(live, k0 + bk - 1 > q0 - window)

    @pl.when(live if not isinstance(live, bool) else True)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window > 0:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, block_q=256, block_k=256, interpret=False):
    """q: [B,Sq,H,hd]; k,v: [B,Sk,Kh,hd] -> [B,Sq,H,hd]."""
    B, Sq, H, hd = q.shape
    _, Sk, Kh, _ = k.shape
    G = H // Kh
    scale = scale if scale is not None else hd ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    if Sq % bq:
        bq = math.gcd(Sq, bq)
    if Sk % bk:
        bk = math.gcd(Sk, bk)
    nq, nk = Sq // bq, Sk // bk

    qt = q.transpose(0, 2, 1, 3)       # [B,H,Sq,hd]
    kt = k.transpose(0, 2, 1, 3)       # [B,Kh,Sk,hd]
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, nk=nk, q_off=Sk - Sq)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),        # m
            pltpu.VMEM((bq,), jnp.float32),        # l
            pltpu.VMEM((bq, hd), jnp.float32),     # acc
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
