"""Sharded AdamW with cosine schedule, global-norm clipping, and an optional
gradient-compression hook (int8 stochastic-rounding all-reduce emulation —
the beyond-paper distributed-optimisation knob; see EXPERIMENTS.md §Perf).

State layout is a plain dict pytree — {step, params, m, v} — so the MigrOS
dump/restore machinery serialises it like any other container state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_grads: bool = False     # int8 compression before reduction


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * (0.5 * (1 + jnp.cos(jnp.pi * prog)))


def init_state(params) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    return {"step": jnp.zeros((), jnp.int32), "params": params,
            "m": zeros, "v": jax.tree.map(lambda p: jnp.zeros_like(p),
                                          params)}


def abstract_state(abstract_params) -> Dict[str, Any]:
    z = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                     abstract_params)
    return {"step": jax.ShapeDtypeStruct((), jnp.int32),
            "params": abstract_params, "m": z,
            "v": jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                s.shape, s.dtype), abstract_params)}


def state_logical(param_logical) -> Dict[str, Any]:
    return {"step": (), "params": param_logical, "m": param_logical,
            "v": param_logical}


def _compress(g, key):
    """int8 stochastic-rounding quantise/dequantise (per-tensor scale).

    Emulates compressed gradient reduction: the all-reduce then moves 1/4 of
    the bytes. Unbiased via stochastic rounding.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = g / scale
    noise = jax.random.uniform(key, g.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(q + noise), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def apply_updates(cfg: OptConfig, state, grads, rng=None):
    step = state["step"] + 1
    lr = schedule(cfg, step)

    if cfg.compress_grads:
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        leaves, treedef = jax.tree.flatten(grads)
        keys = jax.random.split(jax.random.fold_in(rng, step), len(leaves))
        grads = jax.tree.unflatten(
            treedef, [_compress(g, k) for g, k in zip(leaves, keys)])

    if cfg.clip_norm > 0:
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-12))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
    else:
        gn = jnp.zeros((), jnp.float32)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * u).astype(p.dtype),
                m.astype(p.dtype), v.astype(p.dtype))

    out = jax.tree.map(upd, state["params"], grads, state["m"], state["v"])
    params = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    m = jax.tree.map(lambda t: t[1], out,
                     is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[2], out,
                     is_leaf=lambda t: isinstance(t, tuple))
    new = {"step": step, "params": params, "m": m, "v": v}
    return new, {"grad_norm": gn, "lr": lr}


def make_train_step(lm, cfg: OptConfig, *, impl=None, schedule_kind="full"):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state, batch):
        def loss_fn(params):
            loss, metrics = lm.loss(params, batch, impl=impl,
                                    schedule=schedule_kind)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        state, om = apply_updates(cfg, state, grads)
        return state, dict(metrics, loss=loss, **om)

    return train_step
