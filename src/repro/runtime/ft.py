"""Fault tolerance for the production (pjit) path.

The MigrOS insight applied at pod scale: worker state (params/opt shards,
data cursor, RNG) is always dumpable between steps; pod-level channels are
modelled with the same Stopped/Paused state machine, so planned migrations
(maintenance, defrag) pause peers instead of crashing them, and unplanned
failures fall back to checkpoint-restart with elastic re-meshing.

Heartbeat-based failure detection + straggler-triggered migration policy
(the paper's motivating use case for HPC schedulers).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.migration import MigrationError, MigrationReport
from repro.core.states import QPState


@dataclass
class WorkerHealth:
    last_heartbeat: float = 0.0
    step_times: List[float] = field(default_factory=list)
    alive: bool = True

    def ema_step(self, window: int = 16) -> float:
        ts = self.step_times[-window:]
        return sum(ts) / len(ts) if ts else 0.0


class FailureDetector:
    def __init__(self, timeout_s: float = 5.0):
        self.timeout = timeout_s
        self.health: Dict[int, WorkerHealth] = {}

    def heartbeat(self, worker: int, step_time: Optional[float] = None,
                  now: Optional[float] = None):
        h = self.health.setdefault(worker, WorkerHealth())
        h.last_heartbeat = now if now is not None else time.monotonic()
        if step_time is not None:
            h.step_times.append(step_time)

    def failed(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.monotonic()
        out = []
        for w, h in self.health.items():
            if h.alive and now - h.last_heartbeat > self.timeout:
                h.alive = False
                out.append(w)
        return out


class CheckpointRestartManager:
    """Coordinates periodic checkpoints + restart-on-failure.

    ``save_fn(step) -> checkpoint_id`` and ``restore_fn(checkpoint_id,
    world)`` are provided by the trainer (see repro.checkpoint). On failure
    the manager restores the latest checkpoint onto the surviving world
    (elastic re-mesh happens inside restore_fn).
    """

    def __init__(self, save_fn: Callable, restore_fn: Callable,
                 interval_steps: int = 100):
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.interval = interval_steps
        self.last_ckpt = None
        self.last_ckpt_step = -1
        self.restarts = 0

    def maybe_checkpoint(self, step: int):
        if step % self.interval == 0 and step != self.last_ckpt_step:
            self.last_ckpt = self.save_fn(step)
            self.last_ckpt_step = step
        return self.last_ckpt

    def restart(self, surviving_world: int):
        if self.last_ckpt is None:
            raise RuntimeError("no checkpoint to restart from")
        self.restarts += 1
        return self.restore_fn(self.last_ckpt, surviving_world)


class MigrationPolicy:
    """Decides when to live-migrate a container (straggler/maintenance).

    Straggler rule: worker whose EMA step time exceeds ``factor`` × the
    cluster median for ``patience`` consecutive checks.
    """

    def __init__(self, detector: FailureDetector, *, factor: float = 1.5,
                 patience: int = 3):
        self.detector = detector
        self.factor = factor
        self.patience = patience
        self._strikes: Dict[int, int] = {}

    def stragglers(self) -> List[int]:
        emas = {w: h.ema_step() for w, h in self.detector.health.items()
                if h.alive and h.step_times}
        if len(emas) < 2:
            return []
        med = sorted(emas.values())[len(emas) // 2]
        out = []
        for w, e in emas.items():
            if med > 0 and e > self.factor * med:
                self._strikes[w] = self._strikes.get(w, 0) + 1
                if self._strikes[w] >= self.patience:
                    out.append(w)
                    self._strikes[w] = 0
            else:
                self._strikes[w] = 0
        return out


class StragglerMigrator:
    """Closes the loop from policy to orchestrator: each straggler the
    ``MigrationPolicy`` flags is live-migrated (pre-copy by default, so
    the rank keeps computing through the copy) to the least-loaded node
    that passes admission. Rejected/failed requests are skipped — the
    orchestrator has already rolled the container back."""

    def __init__(self, cluster, policy: MigrationPolicy, *,
                 strategy: str = "pre_copy",
                 name_of: Callable[[int], str] = lambda w: f"rank{w}"):
        self.cluster = cluster
        self.policy = policy
        self.strategy = strategy
        self.name_of = name_of
        self.migrated: List[tuple] = []    # (worker, dest_gid)

    def _dest_for(self, container):
        candidates = [n for n in self.cluster.nodes
                      if n is not container.node
                      and (n.capacity is None
                           or len(n.containers) < n.capacity)]
        if not candidates:
            return None
        return min(candidates, key=lambda n: (len(n.containers), n.gid))

    def check(self) -> List[MigrationReport]:
        reports = []
        for w in self.policy.stragglers():
            c = self.cluster.containers.get(self.name_of(w))
            if c is None or not c.alive:
                continue
            dest = self._dest_for(c)
            if dest is None:
                continue
            try:
                rep = self.cluster.orchestrator.migrate(
                    c, dest, strategy=self.strategy)
            except MigrationError:
                continue
            reports.append(rep)
            if rep.ok:
                self.migrated.append((w, dest.gid))
        return reports
