"""Collectives over verbs QPs: rank-to-rank channels + ring all-reduce.

Applications hold *numbers* (QPN/MRN), never raw object pointers — numbers
survive migration by design (the paper's ID-preservation requirement), so a
channel keeps working after its peer (or itself) moves nodes.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.packets import Op
from repro.core.verbs import Context, RecvWR, SendWR, SGE
from repro.core.states import QPState


class Handles:
    """Number-based handle table resolving through the current context.

    Lookups memoize against the context's *identity*: numbers are unique
    and stable within one context, and migration transparency is
    implemented by swapping in a whole new ``Context`` on restore (which
    empties the memo via the identity check) — so a memo hit can never
    resolve to a pre-migration object. The linear scans these replace
    ran once per app step and were measurable in every streaming
    benchmark."""

    def __init__(self, ctx: Context):
        self.ctx = ctx
        self._from: Optional[Context] = None    # memo built against
        self._memo: Dict = {}

    def _memo_for(self, ctx: Context) -> Dict:
        if ctx is not self._from:
            self._from = ctx
            self._memo = {}
        return self._memo

    def qp(self, qpn: int):
        memo = self._memo_for(self.ctx)
        q = memo.get(("qp", qpn))
        if q is None:
            for q in self.ctx.qps:
                if q.qpn == qpn:
                    memo[("qp", qpn)] = q
                    return q
            raise KeyError(f"QPN {qpn}")
        return q

    def mr(self, mrn: int):
        memo = self._memo_for(self.ctx)
        m = memo.get(("mr", mrn))
        if m is None:
            for m in self.ctx.mrs:
                if m.mrn == mrn:
                    memo[("mr", mrn)] = m
                    return m
            raise KeyError(f"MRN {mrn}")
        return m

    def cq(self, cqn: int):
        memo = self._memo_for(self.ctx)
        c = memo.get(("cq", cqn))
        if c is None:
            for c in self.ctx.cqs:
                if c.cqn == cqn:
                    memo[("cq", cqn)] = c
                    return c
            raise KeyError(f"CQN {cqn}")
        return c


class Channel:
    """One reliable connection endpoint with send/recv MRs.

    The data-path methods cache their resolved objects against the
    context's identity (the same invalidation rule as ``Handles``): the
    numbers are the durable names, but re-resolving them on every app
    step was measurable in the streaming benchmarks."""

    def __init__(self, ctx: Context, buf_size: int):
        self.h = Handles(ctx)
        pd = ctx.alloc_pd()
        cq = ctx.create_cq()
        qp = pd.create_qp(cq, cq)
        self.cqn = cq.cqn
        self.qpn = qp.qpn
        self.mrn_send = pd.reg_mr(buf_size).mrn
        self.mrn_recv = pd.reg_mr(buf_size).mrn
        self.buf_size = buf_size
        self._wr = 0
        self._cache_ctx: Optional[Context] = None
        self._qp_obj = self._cq_obj = None
        self._mr_send_obj = self._mr_recv_obj = None

    def _refresh(self):
        h = self.h
        self._qp_obj = h.qp(self.qpn)
        self._cq_obj = h.cq(self.cqn)
        self._mr_send_obj = h.mr(self.mrn_send)
        self._mr_recv_obj = h.mr(self.mrn_recv)
        self._cache_ctx = h.ctx

    # -- connection setup (out-of-band exchange, "over TCP") --------------------
    def local_addr(self):
        return (self.h.ctx.device.gid, self.qpn)

    def connect(self, remote_gid: int, remote_qpn: int):
        qp = self.h.qp(self.qpn)
        qp.modify(QPState.INIT)
        qp.modify(QPState.RTR, dest_gid=remote_gid, dest_qpn=remote_qpn,
                  rq_psn=0)
        qp.modify(QPState.RTS, sq_psn=0)

    # -- data path ---------------------------------------------------------------
    def post_send_bytes(self, data: bytes, *, offset: int = 0) -> int:
        if self.h.ctx is not self._cache_ctx:
            self._refresh()
        mr = self._mr_send_obj
        mr.write(offset, data)
        self._wr += 1
        wr = SendWR(self._wr, Op.SEND, SGE(mr, offset, len(data)))
        self._qp_obj.post_send(wr)
        return self._wr

    def post_recv(self, length: int, *, offset: int = 0) -> int:
        if self.h.ctx is not self._cache_ctx:
            self._refresh()
        self._wr += 1
        self._qp_obj.post_recv(
            RecvWR(self._wr, SGE(self._mr_recv_obj, offset, length)))
        return self._wr

    def poll(self, n: int = 16):
        if self.h.ctx is not self._cache_ctx:
            self._refresh()
        return self._cq_obj.poll(n)

    def recv_bytes(self, offset: int, length: int) -> bytes:
        if self.h.ctx is not self._cache_ctx:
            self._refresh()
        return self._mr_recv_obj.read(offset, length)


def connect_pair(a: Channel, b: Channel):
    b_gid, b_qpn = b.local_addr()
    a_gid, a_qpn = a.local_addr()
    a.connect(b_gid, b_qpn)
    b.connect(a_gid, a_qpn)


# ---------------------------------------------------------------------------
# Ring all-reduce (reduce-scatter + all-gather) over channels
# ---------------------------------------------------------------------------


class RingAllreduce:
    """Synchronous ring all-reduce for float32 vectors.

    ``run`` drives the fabric until completion; a ``step_hook`` (called once
    per fabric pump) lets tests inject migrations mid-collective.
    """

    def __init__(self, fabric, ranks: List[dict]):
        # ranks: [{"right": Channel to next rank, "left": Channel to prev}]
        self.fabric = fabric
        self.ranks = ranks
        self.n = len(ranks)

    def run(self, vectors: List[np.ndarray], *, step_hook=None,
            max_steps: int = 2_000_000) -> List[np.ndarray]:
        n = self.n
        vecs = [v.astype(np.float32).copy() for v in vectors]
        length = vecs[0].size
        chunk = -(-length // n)
        padded = [np.concatenate([v, np.zeros(chunk * n - length,
                                              np.float32)]) for v in vecs]

        for phase in range(2):                  # 0: reduce-scatter 1: gather
            for k in range(n - 1):
                pending = set()
                for r in range(n):
                    send_idx = (r - k + (n if phase == 0 else -1)) % n \
                        if phase == 0 else (r - k + 1) % n
                    data = padded[r][send_idx * chunk:(send_idx + 1) *
                                     chunk].tobytes()
                    self.ranks[r]["left"].post_recv(len(data))
                    self.ranks[r]["right"].post_send_bytes(data)
                    pending.add((r, "s"))
                    pending.add((r, "r"))
                steps = 0
                while pending:
                    self.fabric.pump()
                    if step_hook is not None:
                        step_hook(self.fabric.now)
                    steps += 1
                    if steps > max_steps:
                        raise TimeoutError("allreduce stalled")
                    for r in range(n):
                        for wc in self.ranks[r]["right"].poll():
                            if wc.opcode == "SEND":
                                pending.discard((r, "s"))
                        for wc in self.ranks[r]["left"].poll():
                            if wc.opcode == "RECV":
                                recv_idx = ((r - 1) - k + n) % n \
                                    if phase == 0 else (r - k) % n
                                buf = np.frombuffer(
                                    self.ranks[r]["left"].recv_bytes(
                                        0, chunk * 4), np.float32)
                                seg = slice(recv_idx * chunk,
                                            (recv_idx + 1) * chunk)
                                if phase == 0:
                                    padded[r][seg] += buf
                                else:
                                    padded[r][seg] = buf
                                pending.discard((r, "r"))
        return [p[:length] for p in padded]
