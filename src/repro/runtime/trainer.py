"""Data-parallel training over the verbs fabric (the paper's MPI-app role).

``FabricTrainer`` drives N containerised ``DPTrainerApp`` ranks connected
in a ring; each step computes local grads and ring-all-reduces them over
verbs QPs. A live migration can be injected at any step boundary (or
mid-all-reduce via the step hook) — the loss trajectory must be bitwise
identical to an unmigrated run, which is what "transparent" means.
"""
from __future__ import annotations

from typing import Callable, List, Optional

import numpy as np

from repro.runtime.apps import DPTrainerApp
from repro.runtime.cluster import SimCluster
from repro.runtime.collectives import RingAllreduce, connect_pair


class FabricTrainer:
    def __init__(self, n_ranks: int, n_nodes: Optional[int] = None,
                 seed: int = 0, lr: float = 0.1, loss_prob: float = 0.0,
                 d_h: int = 64):
        n_nodes = n_nodes or n_ranks + 1          # spare node for migration
        self.cluster = SimCluster(n_nodes, loss_prob=loss_prob, seed=seed)
        self.apps: List[DPTrainerApp] = []
        for r in range(n_ranks):
            app = DPTrainerApp(r, n_ranks, seed=seed, lr=lr, d_h=d_h)
            c = self.cluster.launch(f"rank{r}", r % n_nodes, app)
            app.attach(c)
            c.app = app
            self.apps.append(app)
        # ring: rank r's "right" connects to rank (r+1)'s "left"
        for r in range(n_ranks):
            nxt = (r + 1) % n_ranks
            connect_pair(self.apps[r].right, self.apps[nxt].left)
        self.allreduce = RingAllreduce(
            self.cluster.fabric,
            [{"right": a.right, "left": a.left} for a in self.apps])
        self.n = n_ranks

    def step(self, *, step_hook=None) -> float:
        locs = [a.local_grads() for a in self.apps]
        grads = [g for (_, g) in locs]
        losses = [l for (l, _) in locs]
        if self.n > 1:
            reduced = self.allreduce.run(grads, step_hook=step_hook)
        else:
            reduced = grads
        for a, g in zip(self.apps, reduced):
            a.apply_flat(g / self.n)
        mean_loss = float(np.mean(losses))
        for a in self.apps:
            a.losses.append(mean_loss)
        return mean_loss

    def train(self, steps: int, *, migrate_at=None,
              migrate_rank: int = 0, migrate_to: Optional[int] = None
              ) -> List[float]:
        """Run `steps`; optionally live-migrate `migrate_rank` at step
        boundary `migrate_at` to node `migrate_to` (default: spare)."""
        out = []
        for s in range(steps):
            if migrate_at is not None and s == migrate_at:
                dest = (migrate_to if migrate_to is not None
                        else len(self.cluster.nodes) - 1)
                self.cluster.migrate(f"rank{migrate_rank}", dest)
            out.append(self.step())
        return out

    def weights(self, rank: int = 0) -> np.ndarray:
        return self.apps[rank].model.flat()
