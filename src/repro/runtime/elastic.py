"""Elastic re-meshing: move a sharded train state onto a different mesh.

Supports both scale-down (node loss: fewer data shards) and scale-up. The
re-shard is a pure ``jax.device_put`` with the new shardings; logical-axis
specs make the state mesh-agnostic, so this works between any two meshes
whose axes divide the shapes (the resolver drops non-divisible axes).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax

from repro.sharding import partition as part


def remesh_state(state, state_logical, old_mesh, new_mesh, rules=None):
    """Re-shard `state` (pytree of arrays) from old_mesh to new_mesh."""
    shardings = jax.tree.map(
        lambda axes, arr: jax.sharding.NamedSharding(
            new_mesh, part.resolve(axes, arr.shape, new_mesh, rules)),
        state_logical, state,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            a is None or isinstance(a, str) for a in t))
    return jax.device_put(state, shardings)


def scaled_batch(global_batch: int, old_world: int, new_world: int) -> int:
    """Keep per-replica batch constant under rescale (sync SGD semantics:
    the optimizer's LR schedule is rescaled by the caller if desired)."""
    per = global_batch // old_world
    return per * new_world


def plan_remesh_migrations(shard_bytes: int, moved_ranks, *,
                           bw_Bps: float, max_downtime_s: float,
                           dirty_rate_Bps: float = 0.0) -> Dict[int, str]:
    """Per-rank migration strategy for an elastic re-mesh.

    A rescale moves each displaced rank's container (params/opt shards in
    its MRs) to a new node; the link-bandwidth budget decides per rank
    whether plain stop-and-copy fits the downtime budget or whether the
    move must be a live pre-copy/post-copy (see
    ``repro.orchestrator.choose_migration_strategy``)."""
    from repro.orchestrator.strategies import choose_migration_strategy
    return {int(r): choose_migration_strategy(shard_bytes, dirty_rate_Bps,
                                              bw_Bps, max_downtime_s)
            for r in moved_ranks}
