"""Simulated cluster: nodes, devices, containers (paper Fig. 3).

A Container owns a verbs Context plus opaque user state, and cooperates via
``step()`` (the containerised application's main-loop iteration). Crucially
— mirroring the paper — the application code inside the container is
completely unaware of migration: it talks plain verbs; MigrOS machinery
(dump/restore/resume) lives entirely outside.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import msgpack

from repro.core.migration import MigrationController
from repro.core.namespace import GlobalNamespace
from repro.core.transport import Fabric
from repro.core.verbs import Context, RdmaDevice


class Node:
    def __init__(self, cluster: "SimCluster", gid: int):
        self.cluster = cluster
        self.gid = gid
        base = cluster.namespace.range_for(gid)
        self.device = RdmaDevice(cluster.fabric, gid, qpn_base=base)
        self.containers: List["Container"] = []

    def __repr__(self):
        return f"Node(gid={self.gid}, containers={len(self.containers)})"


class Container:
    """A containerised application with checkpointable user state."""

    def __init__(self, name: str, node: Node, app=None):
        self.name = name
        self.node = node
        self.app = app                 # object with step()/state accessors
        self.alive = True
        self.ctx: Context = node.device.open_context()
        node.containers.append(self)
        self.restore_session = None

    # -- hooks used by the MigrationController --------------------------------
    def checkpoint_user(self) -> bytes:
        if self.app is None:
            return b""
        return self.app.checkpoint()

    def restore_user(self, blob: bytes):
        if self.app is not None and blob:
            self.app.restore(blob)

    def adopt(self, node: Node, ctx: Context, session):
        if self in self.node.containers:
            self.node.containers.remove(self)
        self.node = node
        self.ctx = ctx
        self.restore_session = session
        node.containers.append(self)
        if self.app is not None:
            self.app.rebind(self, session)

    def step(self):
        if self.app is not None and self.alive:
            self.app.step()


class SimCluster:
    def __init__(self, n_nodes: int, *, loss_prob: float = 0.0,
                 seed: int = 0):
        self.fabric = Fabric(loss_prob=loss_prob, seed=seed)
        self.namespace = GlobalNamespace()
        self.nodes = [Node(self, gid) for gid in range(n_nodes)]
        self.migrator = MigrationController(self.fabric)
        self.containers: Dict[str, Container] = {}

    def launch(self, name: str, node_idx: int, app=None) -> Container:
        c = Container(name, self.nodes[node_idx], app)
        self.containers[name] = c
        return c

    def migrate(self, name: str, dest_idx: int, **kw):
        c = self.containers[name]
        return self.migrator.migrate(c, self.nodes[dest_idx], **kw)

    def pump(self, steps: int = 1):
        self.fabric.pump(steps)

    def run_until_idle(self, max_steps: int = 100_000):
        return self.fabric.run_until_idle(max_steps)

    def step_all(self):
        for c in self.containers.values():
            c.step()
        self.pump()
