"""Simulated cluster: nodes, devices, containers (paper Fig. 3).

A Container owns a verbs Context plus opaque user state, and cooperates via
``step()`` (the containerised application's main-loop iteration). Crucially
— mirroring the paper — the application code inside the container is
completely unaware of migration: it talks plain verbs; MigrOS machinery
(dump/restore/resume) lives entirely outside.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import msgpack

from repro.core.migration import MigrationController
from repro.core.namespace import GlobalNamespace
from repro.core.pagecodec import CodecConfig
from repro.core.qos import (ECNConfig, IngressConfig, PFCConfig,
                            QoSConfig)
from repro.core.transport import Fabric
from repro.core.verbs import Context, RdmaDevice
from repro.orchestrator import Orchestrator


class Node:
    def __init__(self, cluster: "SimCluster", gid: int,
                 capacity: Optional[int] = None):
        self.cluster = cluster
        self.gid = gid
        self.capacity = capacity        # max containers (None = unlimited)
        base = cluster.namespace.range_for(gid)
        self.device = RdmaDevice(cluster.fabric, gid, qpn_base=base)
        self.containers: List["Container"] = []

    def __repr__(self):
        return f"Node(gid={self.gid}, containers={len(self.containers)})"


class Container:
    """A containerised application with checkpointable user state."""

    def __init__(self, name: str, node: Node, app=None):
        self.name = name
        self.node = node
        self.app = app                 # object with step()/state accessors
        self.alive = True
        # the container name is the QoS tenant key: every packet its QPs
        # emit is charged to this name's token bucket at the egress port
        self.ctx: Context = node.device.open_context(tenant=name)
        node.containers.append(self)
        self.restore_session = None

    # -- hooks used by the MigrationController --------------------------------
    def checkpoint_user(self) -> bytes:
        if self.app is None:
            return b""
        return self.app.checkpoint()

    def restore_user(self, blob: bytes):
        if self.app is not None and blob:
            self.app.restore(blob)

    def adopt(self, node: Node, ctx: Context, session):
        if self in self.node.containers:
            self.node.containers.remove(self)
        self.node = node
        self.ctx = ctx
        self.restore_session = session
        node.containers.append(self)
        if self.app is not None:
            self.app.rebind(self, session)

    def step(self):
        if self.app is not None and self.alive:
            self.app.step()


class SimCluster:
    def __init__(self, n_nodes: int, *, loss_prob: float = 0.0,
                 seed: int = 0, link_bandwidth_Bps: Optional[float] = None,
                 node_capacity: Optional[int] = None,
                 qos: Optional[QoSConfig] = None,
                 ingress: Optional[IngressConfig] = None,
                 ecn: Optional[ECNConfig] = None,
                 pfc: Optional[PFCConfig] = None):
        fab_kw = {} if link_bandwidth_Bps is None else \
            {"bandwidth_Bps": link_bandwidth_Bps}
        if qos is not None:
            fab_kw["qos"] = qos
        if ingress is not None:
            fab_kw["ingress"] = ingress
        if ecn is not None:
            fab_kw["ecn"] = ecn
        if pfc is not None:
            fab_kw["pfc"] = pfc
        self.fabric = Fabric(loss_prob=loss_prob, seed=seed, **fab_kw)
        self.namespace = GlobalNamespace()
        self.nodes = [Node(self, gid, capacity=node_capacity)
                      for gid in range(n_nodes)]
        self.migrator = MigrationController(self.fabric)
        # control plane: shares the migrator's `relocated` registry, drives
        # live strategies with step_all so apps keep running mid-migration
        self.orchestrator = Orchestrator(self.migrator,
                                         background=self.step_all)
        self.containers: Dict[str, Container] = {}

    def launch(self, name: str, node_idx: int, app=None, *,
               rate_Bps: Optional[float] = None,
               burst_bytes: Optional[float] = None) -> Container:
        node = self.nodes[node_idx]
        if node.capacity is not None and \
                len(node.containers) >= node.capacity:
            raise ValueError(f"node {node.gid} at capacity "
                             f"({node.capacity})")
        c = Container(name, node, app)
        self.containers[name] = c
        if rate_Bps is not None:
            self.set_tenant_rate(name, rate_Bps, burst_bytes)
        return c

    # -- per-container QoS knobs (operator surface) --------------------------
    def set_tenant_rate(self, name: str, rate_Bps: Optional[float],
                        burst_bytes: Optional[float] = None):
        """(Re)price a container's egress token bucket on every NIC port
        (the bucket follows the container across migrations because the
        tenant key is the container name). ``rate_Bps=None`` unthrottles.
        Requires a QoS-enabled fabric to have any effect."""
        self.fabric.set_tenant_rate(name, rate_Bps, burst_bytes)

    def configure_qos(self, qos: QoSConfig):
        """Swap the fabric-wide scheduler config (class weights,
        migration cap/guarantee, tenant buckets) on every port."""
        self.fabric.configure_qos(qos)

    def configure_ingress(self, *, rx_bandwidth_Bps: Optional[float],
                          queue_bytes: float = 256 * 1024,
                          rnr_nak: bool = True,
                          rnr_nak_interval: int = 32,
                          node: Optional[int] = None):
        """Operator knob: bound a node's receive-processing rate and
        ingress queue (``node=None`` applies cluster-wide).
        ``rx_bandwidth_Bps=None`` restores the unlimited pass-through
        default (receive processing is free, PR 3 wire model)."""
        cfg = IngressConfig(rx_bandwidth_Bps=rx_bandwidth_Bps,
                            queue_bytes=queue_bytes, rnr_nak=rnr_nak,
                            rnr_nak_interval=rnr_nak_interval)
        gid = None if node is None else self.nodes[node].gid
        self.fabric.configure_ingress(cfg, gid=gid)

    def configure_ecn(self, enabled: bool = True, **knobs):
        """Operator knob: ECN/DCQCN congestion control, fabric-wide.
        ``knobs`` are `repro.core.qos.ECNConfig` fields — RED marking
        thresholds (``kmin``/``kmax``/``pmax``, ``egress_queue_bytes``,
        ``mark_egress``/``mark_ingress``), CNP coalescing
        (``cnp_interval``) and the DCQCN reaction-point parameters
        (``g``, ``alpha_timer``, ``increase_timer``, ``byte_counter``,
        ``fast_recovery_events``, ``rai_Bps``/``rhai_Bps``,
        ``min_rate_Bps``, ``burst_bytes``). Disabled by default: ports
        never mark, no CNPs, no per-QP rate state — figures are
        byte-identical to the ECN-less fabric. A QP's learned rate
        survives `migrate` (it rides the verbs dump)."""
        self.fabric.configure_ecn(ECNConfig(enabled=enabled, **knobs))

    def configure_pfc(self, enabled: bool = True, **knobs):
        """Operator knob: PFC link-level flow control, fabric-wide.
        ``knobs`` are `repro.core.qos.PFCConfig` fields — per-class
        XOFF/XON ingress-occupancy watermarks (``xoff``/``xon`` dicts
        keyed ``app``/``mig``), the pause-frame lifetime
        (``pause_steps``) and the re-broadcast cadence
        (``refresh_steps``). Enabling makes the fabric *lossless*:
        bounded ingress queues pause their senders per class instead of
        dropping reliable requests, and congestion feedback rides
        ECN/CNP alone (the RNR rate-cut path goes inert). Disabled by
        default: no watermark is evaluated, no latch exists, and all
        figures are byte-identical to the PFC-less fabric. A sender's
        latched view of a paused peer survives `migrate` (it rides the
        verbs dump)."""
        self.fabric.configure_pfc(PFCConfig(enabled=enabled, **knobs))

    def configure_codec(self, enabled: bool = True, **knobs):
        """Operator knob: delta-aware migration page codec, fabric-wide.
        ``knobs`` are `repro.core.pagecodec.CodecConfig` fields —
        feature gates (``zero_elision``, ``dedup``, ``delta``,
        ``compress_image``), the delta/image compression level
        (``zlib_level``) and the pre-copy convergence-controller
        threshold (``cutover_ratio``). Enabling makes MIG_PAGE batches
        ship encoded (all-zero pages elided, staged-content duplicates
        sent as digest references, re-dirtied pages as XOR+zlib deltas)
        and charges the wire at encoded size, so ``transfer_s`` /
        ``downtime_s`` and migration-class contention genuinely drop.
        Disabled by default: the migration stream is byte-identical to
        the codec-less fabric (pinned by all five benchmark figures).
        Codec state rides the `MigrationAttempt` pause token and is
        invalidated when an attempt resumes onto a new destination."""
        self.fabric.configure_codec(CodecConfig(enabled=enabled, **knobs))

    def configure_tracing(self, enabled: bool = True, *,
                          max_events: Optional[int] = None):
        """Operator knob: fabric-wide event tracing (`repro.obs`), off by
        default. ``enabled`` turns the sim-clock tracer on (returning it)
        or back off; ``max_events`` bounds the in-memory event list —
        overflow is counted in ``tracer.dropped_events``, never silent.
        Disabled, every hook site is a single ``is None`` check and all
        figures stay byte-identical; enabled, the event stream is as
        deterministic as the fabric itself (same seed, same events)."""
        return self.fabric.configure_tracing(enabled,
                                             max_events=max_events)

    def configure_pump(self, event_driven: bool = True):
        """Operator knob: select the fabric pump core. ``True`` (the
        default) is the event/active-set scheduler — pump steps touch
        only ports with queued work and devices whose QP wake deadline
        arrived, and idle stretches are skipped in one sim-clock jump
        (the ``pump_steps_skipped`` gauge counts them). ``False`` falls
        back to the legacy exhaustive per-step scan. Both cores produce
        bit-identical sim-clock trajectories, figures, and counters
        (``tests/test_determinism.py`` pins this), so the knob exists
        for cross-checking and debugging, not for tuning."""
        self.fabric.configure_pump(event_driven)

    def configure_rnr(self, name: Optional[str] = None, *,
                      rnr_retry: Optional[int] = None,
                      min_rnr_timer: Optional[int] = None):
        """Set the IBA RNR attributes on a container's QPs (or, with
        ``name=None``, every container's). ``rnr_retry=7`` is the IBA
        "retry forever" encoding; 0..6 bound the attempts before the QP
        errors out with RNR_RETRY_EXC_ERR. Applies to existing QPs only
        — set it after the app attaches its channels."""
        if rnr_retry is not None and not (0 <= rnr_retry <= 7):
            raise ValueError("rnr_retry must be in [0, 7] (7 = forever)")
        if min_rnr_timer is not None and min_rnr_timer < 1:
            raise ValueError("min_rnr_timer must be >= 1 step")
        targets = ([self.containers[name]] if name is not None
                   else list(self.containers.values()))
        for c in targets:
            for qp in c.ctx.qps:
                if rnr_retry is not None:
                    qp.rnr_retry = rnr_retry
                if min_rnr_timer is not None:
                    qp.min_rnr_timer = min_rnr_timer

    def configure_preemption(self, enabled: bool = True, *,
                             pause_util: float = 0.9,
                             resume_util: float = 0.5,
                             min_paused_steps: int = 200):
        """Operator knob: auto-preemption of in-flight migrations, off
        by default. Armed, the orchestrator pauses a migration at its
        next round/page boundary when the source node's *application*
        egress utilization (migration traffic excluded) exceeds
        ``pause_util``, and the step loop resumes it once app load
        drains below ``resume_util`` after at least ``min_paused_steps``
        parked. Disarmed (the default), the migration path is
        byte-identical to a preemption-free build."""
        return self.orchestrator.configure_preemption(
            enabled, pause_util=pause_util, resume_util=resume_util,
            min_paused_steps=min_paused_steps)

    def migrate(self, name: str, dest_idx: int, *,
                strategy: Optional[str] = None, **kw):
        """Migrate a container. ``strategy=None`` keeps the seed
        stop-and-copy fast path (bare controller, byte-identical);
        naming a strategy ("stop_and_copy" / "pre_copy" / "post_copy" /
        "auto") routes through the orchestrator: admission checks,
        serialised queueing, retry, and rollback on failure."""
        c = self.containers[name]
        dest = self.nodes[dest_idx]
        if strategy is None:
            return self.migrator.migrate(c, dest, **kw)
        return self.orchestrator.migrate(c, dest, strategy=strategy, **kw)

    # -- preemption (operator surface) ---------------------------------------
    def pause_migration(self, name: str, *, at: Optional[int] = None):
        """Pause ``name``'s in-flight (or queued) migration at its next
        round/page boundary — or the first boundary at/after fabric step
        ``at``. See ``Orchestrator.pause``."""
        return self.orchestrator.pause(self.containers[name], at=at)

    def resume_migration(self, name: str,
                         dest_idx: Optional[int] = None):
        """Resume ``name``'s paused migration, optionally re-pointing it
        at node ``dest_idx`` (mandatory if the original destination was
        drained from the fabric). See ``Orchestrator.resume``."""
        dest = None if dest_idx is None else self.nodes[dest_idx]
        return self.orchestrator.resume(self.containers[name], dest)

    def abort_migration(self, name: str):
        """Abort ``name``'s migration wherever it is in the lifecycle
        (running, paused, or queued); the source container rolls back to
        RTS. See ``Orchestrator.abort``."""
        return self.orchestrator.abort(self.containers[name])

    def pump(self, steps: int = 1):
        self.fabric.pump(steps)

    def run_until_idle(self, max_steps: int = 100_000):
        return self.fabric.run_until_idle(max_steps)

    def step_all(self):
        for c in self.containers.values():
            c.step()
        self.pump()
        if self.orchestrator.preemption is not None:
            self.orchestrator.poll_preemption()
