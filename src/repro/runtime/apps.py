"""Containerised applications for the simulated cluster.

* ``SendBwApp``   — ib_send_bw-style streaming benchmark (paper Fig. 11):
                    keeps a window of sends in flight, continuously.
* ``DPTrainerApp``— data-parallel trainer rank: local grads (numpy model) +
                    ring all-reduce over verbs channels. Fully
                    checkpointable; migration must not perturb the loss
                    trajectory bit-for-bit.

Apps speak verbs only (via number-based handles); they contain zero
migration logic — transparency is the whole point.
"""
from __future__ import annotations

import io
from typing import Dict, List, Optional

import msgpack
import numpy as np

from repro.runtime.collectives import Channel, Handles


class SendBwApp:
    """Streams fixed-size messages to a peer, window-limited."""

    def __init__(self, msg_size: int = 4096, window: int = 16,
                 n_qps: int = 1, buf_size: Optional[int] = None):
        self.msg_size = msg_size
        self._payload = b"x" * msg_size     # built once, sent many times
        self.window = window
        self.n_qps = n_qps
        self.buf_size = buf_size or max(msg_size, 4096)
        self.channels: List[Channel] = []
        self.sent = 0
        self.completed = 0
        self.received = 0
        self.inflight = 0
        self.container = None
        self.is_sender = True

    def attach(self, container, *, sender: bool):
        self.container = container
        self.is_sender = sender
        for _ in range(self.n_qps):
            ch = Channel(container.ctx, self.buf_size)
            ch._posted = 0              # receiver-side posted-RR count
            self.channels.append(ch)

    def rebind(self, container, session):
        for ch in self.channels:
            ch.h.ctx = container.ctx

    def step(self):
        for ch in self.channels:
            if self.is_sender:
                while self.inflight < self.window:
                    ch.post_send_bytes(self._payload)
                    self.inflight += 1
                    self.sent += 1
                for wc in ch.poll(64):
                    if wc.opcode == "SEND":
                        self.inflight -= 1
                        self.completed += 1
            else:
                # keep receives posted
                posted = ch._posted
                while posted < self.window:
                    ch.post_recv(self.msg_size)
                    posted += 1
                for wc in ch.poll(64):
                    if wc.opcode == "RECV":
                        posted -= 1
                        self.received += 1
                ch._posted = posted

    # -- checkpoint ----------------------------------------------------------
    def checkpoint(self) -> bytes:
        return msgpack.packb({
            "sent": self.sent, "completed": self.completed,
            "received": self.received, "inflight": self.inflight,
            "is_sender": self.is_sender,
            "posted": [getattr(ch, "_posted", 0) for ch in self.channels]})

    def restore(self, blob: bytes):
        d = msgpack.unpackb(blob, raw=False)
        self.sent = d["sent"]
        self.completed = d["completed"]
        self.received = d["received"]
        self.inflight = d["inflight"]
        self.is_sender = d["is_sender"]
        for ch, p in zip(self.channels, d["posted"]):
            ch._posted = p


class TinyMLP:
    """Deterministic numpy MLP used by the DP trainer demo."""

    def __init__(self, d_in=32, d_h=64, d_out=8, seed=0):
        r = np.random.RandomState(seed)
        self.w1 = (r.randn(d_in, d_h) / np.sqrt(d_in)).astype(np.float32)
        self.w2 = (r.randn(d_h, d_out) / np.sqrt(d_h)).astype(np.float32)

    def loss_and_grads(self, x, y):
        h = np.maximum(x @ self.w1, 0.0)
        logits = h @ self.w2
        z = logits - logits.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        n = x.shape[0]
        loss = -np.mean(np.log(p[np.arange(n), y] + 1e-12))
        dlogits = p
        dlogits[np.arange(n), y] -= 1.0
        dlogits /= n
        dw2 = h.T @ dlogits
        dh = dlogits @ self.w2.T
        dh[h <= 0] = 0.0
        dw1 = x.T @ dh
        return loss, [dw1.astype(np.float32), dw2.astype(np.float32)]

    def apply(self, grads, lr):
        self.w1 -= lr * grads[0]
        self.w2 -= lr * grads[1]

    def flat(self):
        return np.concatenate([self.w1.ravel(), self.w2.ravel()])

    def unflat(self, v):
        n1 = self.w1.size
        self.w1 = v[:n1].reshape(self.w1.shape).copy()
        self.w2 = v[n1:].reshape(self.w2.shape).copy()


class DPTrainerApp:
    """One data-parallel rank. Gradient sync via external RingAllreduce."""

    def __init__(self, rank: int, world: int, seed: int = 0, lr=0.1,
                 batch: int = 32, d_h: int = 64):
        self.rank = rank
        self.world = world
        self.lr = lr
        self.batch = batch
        self.model = TinyMLP(d_h=d_h, seed=seed)
        self.step_no = 0
        self.losses: List[float] = []
        self.left: Optional[Channel] = None
        self.right: Optional[Channel] = None
        self.container = None

    def attach(self, container, buf_size: int = 0):
        if not buf_size:
            # ring all-reduce moves ceil(model/world)-sized chunks
            need = (self.model.flat().size * 4) // max(self.world, 1) + 4096
            buf_size = max(1 << 16, 1 << (need - 1).bit_length())
        self.container = container
        self.left = Channel(container.ctx, buf_size)
        self.right = Channel(container.ctx, buf_size)

    def rebind(self, container, session):
        self.left.h.ctx = container.ctx
        self.right.h.ctx = container.ctx

    def local_grads(self):
        r = np.random.RandomState(1000 + 17 * self.step_no + self.rank)
        x = r.randn(self.batch, 32).astype(np.float32)
        y = r.randint(0, 8, self.batch)
        loss, grads = self.model.loss_and_grads(x, y)
        return loss, np.concatenate([g.ravel() for g in grads])

    def apply_flat(self, flat):
        n1 = self.model.w1.size
        g1 = flat[:n1].reshape(self.model.w1.shape)
        g2 = flat[n1:].reshape(self.model.w2.shape)
        self.model.apply([g1, g2], self.lr)
        self.step_no += 1

    def step(self):
        pass  # training is driven by the cluster trainer loop

    def checkpoint(self) -> bytes:
        return msgpack.packb({
            "rank": self.rank, "step": self.step_no,
            "w": self.model.flat().tobytes(),
            "losses": self.losses})

    def restore(self, blob: bytes):
        d = msgpack.unpackb(blob, raw=False)
        self.step_no = d["step"]
        self.model.unflat(np.frombuffer(d["w"], np.float32))
        self.losses = list(d["losses"])
