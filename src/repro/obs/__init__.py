"""Fabric observability: sim-clock tracing, metrics, and exporters.

Everything here is driven by the fabric sim clock (``fabric.now``,
seconds = ``step * STEP_S``) — never a wall clock — so observability
output is as deterministic as the fabric itself. Tracing is off by
default and every hook in the core is a single ``tracer is None`` check;
``MetricsRegistry`` is always on, but it *is* the old ``fabric.stats``
dict (same object), so the always-on cost is unchanged.

See ``docs/observability.md`` for the event taxonomy, exporter usage,
and the zero-overhead contract.
"""
from repro.obs.export import (build_migration_report, chrome_trace,
                              render_timeline, write_chrome_trace)
from repro.obs.metrics import MetricsRegistry, WindowedHistogram
from repro.obs.trace import EventKind, TraceEvent, Tracer, record_phase

__all__ = [
    "EventKind", "TraceEvent", "Tracer", "record_phase",
    "MetricsRegistry", "WindowedHistogram",
    "chrome_trace", "write_chrome_trace",
    "build_migration_report", "render_timeline",
]
