"""Sim-clock fabric tracer: typed, zero-cost-when-disabled event hooks.

The paper's headline numbers (§5: no overhead without migration, bounded
downtime with it) are scalars; this module records *where* that time
goes. Every layer of the stack carries hooks — packet lifecycle at the
egress/ingress ports, NAK/ECN/retransmit decisions in the QP tasks,
service-channel stream ops, QP state transitions, DCQCN rate cuts, and
migration phase spans from the strategies — all stamped with the fabric
sim clock (``fabric.now``; seconds are ``step * STEP_S``), never a wall
clock, so two seeded runs produce byte-identical event streams.

The zero-overhead contract: ``fabric.tracer`` is ``None`` by default and
every hook site guards with one attribute load + ``is None`` check — no
event objects, no histogram samples, no behavioural difference. The
pinned figures (fig_downtime/fig_contention/fig_incast/fig_ecn) stay
byte-identical with tracing off; ``tests/test_obs.py`` pins this.

Event taxonomy lives in ``EventKind``; ``tools/check_docs.py`` gates
that every kind is documented in ``docs/observability.md``.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.packets import MIG_OPS, Packet


def _cls(pkt: Packet) -> str:
    """Traffic class (duplicates ``repro.core.qos.classify`` to keep this
    module import-light: packets only, no scheduler dependency)."""
    return "mig" if pkt.op in MIG_OPS else "app"


class EventKind(enum.Enum):
    """The event taxonomy. Each member is one trace-event type; the
    value string is what exporters and ``docs/observability.md`` use."""
    # -- packet lifecycle (transport/qos) ---------------------------------
    EGRESS_ENQUEUE = "egress_enqueue"    # packet filed into a port queue
    EGRESS_TX = "egress_tx"              # packet serialised onto the wire
    EGRESS_DROP = "egress_drop"          # loss injection ate it post-tx
    INGRESS_QUEUE = "ingress_queue"      # landed in a bounded rx queue
    INGRESS_DELIVER = "ingress_deliver"  # handed to the device
    INGRESS_DROP = "ingress_drop"        # shed at rx admission (w/ reason)
    # -- congestion / recovery signals (qos/tasks) ------------------------
    ECN_MARK = "ecn_mark"                # RED set the CE codepoint
    CNP_SENT = "cnp_sent"                # notification point fired
    CNP_HANDLED = "cnp_handled"          # reaction point consumed a CNP
    RNR_NAK = "rnr_nak"                  # receiver-not-ready NAK emitted
    PSN_NAK = "psn_nak"                  # sequence-gap NAK emitted
    RETRANSMIT = "retransmit"            # requester re-offered a packet
    RATE_CHANGE = "rate_change"          # DCQCN rate cut (CNP/RNR/READ)
    PFC_PAUSE = "pfc_pause"              # ingress XOFF broadcast a PAUSE
    PFC_RESUME = "pfc_resume"            # ingress XON broadcast UNPAUSE
    # -- QP / service channel (verbs/service) -----------------------------
    QP_STATE = "qp_state"                # verbs state transition
    SVC_POST = "svc_post"                # service message queued (tx)
    SVC_DELIVER = "svc_deliver"          # service message reassembled (rx)
    SVC_ACK = "svc_ack"                  # stream-level MIG_ACK receipt
    PAGE_PULL = "page_pull"              # post-copy demand/prefetch fill
    PAGE_CODEC = "page_codec"            # encoded MIG_PAGE batch stats
    # -- migration phases (migration/strategies/orchestrator) -------------
    PHASE = "phase"                      # completed span [begin, end]
    PAUSED = "paused"                    # preemption gap [pause, resume]


@dataclass
class TraceEvent:
    """One typed event: ``kind`` from the taxonomy, ``step`` the fabric
    sim clock at emission, ``node`` the gid it is attributed to (or
    None), ``data`` the kind-specific payload. Contains only sim-state
    values (steps, gids, PSNs, byte counts) — never object identities or
    wall-clock times — so event streams compare equal across runs."""
    kind: EventKind
    step: int
    node: Optional[int] = None
    data: Dict = field(default_factory=dict)

    @property
    def time_s(self) -> float:
        # populated by exporters via Tracer.step_s; kept here for
        # hand-rolled inspection of a tracer's events
        return self.step * 1e-6


def _pkt_data(pkt: Packet) -> Dict:
    return {"op": pkt.op.value, "psn": pkt.psn, "src": pkt.src_gid,
            "src_qpn": pkt.src_qpn, "dst": pkt.dest_gid,
            "dst_qpn": pkt.dest_qpn, "nbytes": pkt.nbytes(),
            "cls": _cls(pkt), "tenant": pkt.tenant}


class Tracer:
    """Event sink of one fabric. Created by ``Fabric.configure_tracing``
    (off by default). Hooks are plain methods so call sites stay typed:
    a renamed hook fails loudly instead of silently dropping events.

    ``max_events`` bounds memory on long runs: once full, new events are
    counted in ``dropped_events`` instead of stored (the count makes the
    truncation visible — a silently clipped trace reads as a quiet
    fabric)."""

    def __init__(self, fabric=None, *, max_events: Optional[int] = None):
        self.fabric = fabric
        self.step_s = 1e-6 if fabric is None else fabric.step_s()
        self.max_events = max_events
        self.events: List[TraceEvent] = []
        self.dropped_events = 0
        self._enq: Dict[int, int] = {}   # id(pkt) -> last enqueue step

    # -- core --------------------------------------------------------------
    def _emit(self, kind: EventKind, step: int, node: Optional[int],
              data: Dict):
        if self.max_events is not None \
                and len(self.events) >= self.max_events:
            self.dropped_events += 1
            return
        self.events.append(TraceEvent(kind, step, node, data))

    def _observe(self, name: str, step: int, value: float,
                 gid: Optional[int] = None):
        if self.fabric is not None:
            self.fabric.metrics.observe(name, step, value, gid=gid)

    def clear(self):
        self.events.clear()
        self.dropped_events = 0
        self._enq.clear()

    def of_kind(self, kind: EventKind) -> List[TraceEvent]:
        return [e for e in self.events if e.kind is kind]

    # -- packet lifecycle --------------------------------------------------
    def egress_enqueue(self, step: int, pkt: Packet, gid: int,
                       backlog_bytes: int):
        self._enq[id(pkt)] = step
        self._observe("egress_queue_depth", step, backlog_bytes, gid=gid)
        self._emit(EventKind.EGRESS_ENQUEUE, step, gid,
                   {**_pkt_data(pkt), "backlog": backlog_bytes})

    def egress_tx(self, step: int, pkt: Packet, gid: int):
        self._emit(EventKind.EGRESS_TX, step, gid, _pkt_data(pkt))

    def egress_drop(self, step: int, pkt: Packet, gid: int):
        self._emit(EventKind.EGRESS_DROP, step, gid, _pkt_data(pkt))

    def ingress_queue(self, step: int, pkt: Packet, gid: int,
                      backlog_bytes: int):
        self._observe("ingress_queue_depth", step, backlog_bytes, gid=gid)
        self._emit(EventKind.INGRESS_QUEUE, step, gid,
                   {**_pkt_data(pkt), "backlog": backlog_bytes})

    def ingress_deliver(self, step: int, pkt: Packet, gid: int):
        t0 = self._enq.pop(id(pkt), None)
        lat = None if t0 is None else step - t0
        if lat is not None:
            # per-class port-to-port latency (steps), the percentile
            # source for the timeline report's latency table
            self._observe(f"latency_{_cls(pkt)}", step, lat)
        self._emit(EventKind.INGRESS_DELIVER, step, gid,
                   {**_pkt_data(pkt), "latency_steps": lat})

    def ingress_drop(self, step: int, pkt: Packet, gid: int, reason: str):
        self._emit(EventKind.INGRESS_DROP, step, gid,
                   {**_pkt_data(pkt), "reason": reason})

    # -- congestion / recovery ---------------------------------------------
    def ecn_mark(self, step: int, pkt: Packet, gid: int, where: str,
                 occupancy: float):
        self._emit(EventKind.ECN_MARK, step, gid,
                   {**_pkt_data(pkt), "where": where,
                    "occupancy": occupancy})

    def cnp_sent(self, step: int, gid: int, qpn: int, cls: str):
        self._emit(EventKind.CNP_SENT, step, gid,
                   {"qpn": qpn, "cls": cls})

    def cnp_handled(self, step: int, gid: int, qpn: int, cls: str):
        self._emit(EventKind.CNP_HANDLED, step, gid,
                   {"qpn": qpn, "cls": cls})

    def rnr_nak(self, step: int, gid: int, origin: str, to_gid: int,
                to_qpn: int, psn: int):
        self._emit(EventKind.RNR_NAK, step, gid,
                   {"origin": origin, "to": to_gid, "to_qpn": to_qpn,
                    "psn": psn})

    def psn_nak(self, step: int, gid: int, qpn: int, epsn: int):
        self._emit(EventKind.PSN_NAK, step, gid,
                   {"qpn": qpn, "epsn": epsn})

    def retransmit(self, step: int, pkt: Packet, gid: int, qpn: int,
                   reason: str):
        self._emit(EventKind.RETRANSMIT, step, gid,
                   {**_pkt_data(pkt), "qpn": qpn, "reason": reason})

    def rate_change(self, step: int, gid: int, qpn: int, rc: float,
                    rt: float, alpha: float, reason: str):
        if self.fabric is not None:
            self.fabric.metrics.set_gauge(f"dcqcn_rc@{gid}:{qpn}", rc)
        self._emit(EventKind.RATE_CHANGE, step, gid,
                   {"qpn": qpn, "rc": rc, "rt": rt, "alpha": alpha,
                    "reason": reason})

    def pfc_pause(self, step: int, gid: int, cls: str, occupancy: float,
                  targets: int):
        """One XOFF broadcast: ingress ``gid`` paused class ``cls`` on
        ``targets`` sender nodes at the given queue occupancy."""
        self._emit(EventKind.PFC_PAUSE, step, gid,
                   {"cls": cls, "occupancy": occupancy,
                    "targets": targets})

    def pfc_resume(self, step: int, gid: int, cls: str, occupancy: float,
                   targets: int):
        """The matching XON broadcast (UNPAUSE frames)."""
        self._emit(EventKind.PFC_RESUME, step, gid,
                   {"cls": cls, "occupancy": occupancy,
                    "targets": targets})

    # -- QP / service channel ----------------------------------------------
    def qp_state(self, step: int, gid: int, qpn: int, old: str, new: str):
        self._emit(EventKind.QP_STATE, step, gid,
                   {"qpn": qpn, "old": old, "new": new})

    def svc_post(self, step: int, gid: int, peer: int, op: str, xid: int,
                 nbytes: int):
        self._emit(EventKind.SVC_POST, step, gid,
                   {"peer": peer, "op": op, "xid": xid, "nbytes": nbytes})

    def svc_deliver(self, step: int, gid: int, src: int, op: str,
                    nbytes: int):
        self._emit(EventKind.SVC_DELIVER, step, gid,
                   {"src": src, "op": op, "nbytes": nbytes})

    def svc_ack(self, step: int, gid: int, xid: int):
        self._emit(EventKind.SVC_ACK, step, gid, {"xid": xid})

    def page_pull(self, step: int, gid: int, mrn: int, page: int,
                  nbytes: int, fault: bool):
        self._emit(EventKind.PAGE_PULL, step, gid,
                   {"mrn": mrn, "page": page, "nbytes": nbytes,
                    "fault": fault})

    def page_codec(self, step: int, gid: int, stream: int, stats: dict):
        """One codec-encoded MIG_PAGE batch as acked/charged by the
        sender: record mix (full/zero/dup/delta) and the logical vs
        encoded byte counts."""
        self._emit(EventKind.PAGE_CODEC, step, gid,
                   {"stream": stream, **stats})

    # -- migration phases --------------------------------------------------
    def phase(self, name: str, begin: int, end: int,
              node: Optional[int] = None, **attrs):
        """One completed migration phase span ``[begin, end]`` in fabric
        steps. Strategies call this with the *same* ``fab.now`` reads
        their ``MigrationReport`` seconds derive from, so span durations
        and report figures agree exactly (the timeline test pins
        ``sum(transfer spans) == rep.transfer_s``)."""
        self._emit(EventKind.PHASE, end, node,
                   {"name": name, "begin": begin, "end": end,
                    "dur_steps": end - begin, **attrs})

    def paused(self, begin: int, end: int, node: Optional[int] = None,
               **attrs):
        """One preemption gap ``[begin, end]``: the span a migration sat
        parked between its pause yield and the matching resume/abort.
        Phase-shaped payload so exporters render it alongside the real
        phases, but a distinct kind — the downtime/wire attribution maths
        must never sum it into ``transfer``/``live`` spans."""
        self._emit(EventKind.PAUSED, end, node,
                   {"name": "paused", "begin": begin, "end": end,
                    "dur_steps": end - begin, **attrs})

    def phases(self, name: Optional[str] = None) -> List[TraceEvent]:
        return [e for e in self.events
                if (e.kind is EventKind.PHASE
                    or e.kind is EventKind.PAUSED)
                and (name is None or e.data["name"] == name)]


def record_phase(fabric, name: str, begin: int,
                 node: Optional[int] = None, **attrs):
    """Hook-site helper: record a phase span ending *now* iff tracing is
    enabled. One attribute load + None check when disabled."""
    trc = fabric.tracer
    if trc is not None:
        trc.phase(name, begin, fabric.now, node=node, **attrs)
