"""Metrics registry: counters, gauges, windowed histograms.

The fabric used to keep an ad-hoc ``defaultdict(int)`` string-dict
(``fabric.stats``) that grew per-node (``name@gid``) and per-class
(``mig_``/``app_`` prefixed) twins by hand at each call site — and grew
them inconsistently (``dropped`` had no node twin, ``rx_dropped`` did).
``MetricsRegistry`` is the single facade every counter now routes
through: one ``inc(name, gid=..., cls=...)`` updates the bare counter
and its node/class twins with one key grammar, so the per-node
attribution the migration timeline reports need (which *port* paid the
downtime) exists uniformly by construction.

``fabric.stats`` remains the backwards-compatible view: it is literally
the registry's counter dict, so every existing ``fabric.stats[...]``
read (tests, benchmarks, admission) sees exactly the keys it used to.

Gauges and windowed histograms exist for the tracing layer
(``repro.obs.trace``): queue-depth and per-class latency samples are
only ever observed from tracer hooks, so with tracing disabled (the
default) the histogram path costs nothing — the observability analogue
of the paper's no-overhead-when-not-migrating claim (§5).
"""
from __future__ import annotations

from collections import defaultdict, deque
from typing import Deque, Dict, Optional, Tuple

# key grammar, shared with the pre-registry stats dict:
#   <name>            fabric-wide counter
#   <name>@<gid>      per-node twin (sums to the bare counter)
#   <cls>_<name>      per-class twin (app_/mig_; sum to the bare counter)
NODE_SEP = "@"


class WindowedHistogram:
    """Fixed-horizon sample window in fabric-step time: ``observe``
    appends ``(step, value)``, samples older than ``window`` steps fall
    off, and percentiles are computed over whatever remains. Purely
    sim-clock driven — identical runs observe identical samples."""

    __slots__ = ("window", "samples")

    def __init__(self, window: int):
        self.window = window
        self.samples: Deque[Tuple[int, float]] = deque()

    def observe(self, step: int, value: float):
        self.samples.append((step, value))
        self.trim(step)

    def trim(self, now: int):
        while self.samples and self.samples[0][0] <= now - self.window:
            self.samples.popleft()

    def __len__(self) -> int:
        return len(self.samples)

    def percentile(self, q: float, now: Optional[int] = None) -> float:
        """q-th percentile (0..100) of the windowed samples; 0.0 empty.
        Nearest-rank definition, so p50 of one sample is that sample."""
        if now is not None:
            self.trim(now)
        if not self.samples:
            return 0.0
        vals = sorted(v for _, v in self.samples)
        rank = max(0, min(len(vals) - 1,
                          int(q / 100.0 * len(vals) + 0.5) - 1))
        return vals[rank]

    def summary(self, now: Optional[int] = None) -> Dict[str, float]:
        if now is not None:
            self.trim(now)
        if not self.samples:
            return {"count": 0, "min": 0.0, "max": 0.0, "mean": 0.0,
                    "p50": 0.0, "p90": 0.0, "p99": 0.0}
        vals = [v for _, v in self.samples]
        return {"count": len(vals), "min": min(vals), "max": max(vals),
                "mean": sum(vals) / len(vals),
                "p50": self.percentile(50), "p90": self.percentile(90),
                "p99": self.percentile(99)}


class MetricsRegistry:
    """Counter/gauge/histogram facade of one fabric.

    ``counters`` is the raw dict — the object ``fabric.stats`` aliases,
    so the registry subsumes the old surface instead of breaking it.
    ``node_counters`` records every counter name that was ever
    incremented with a ``gid``: the per-node-twin invariant
    (``sum(name@gid) == name``) holds for exactly that set, by
    construction, and ``tests/test_obs.py`` asserts it."""

    def __init__(self, window: int = 1000):
        self.window = window
        self.counters: Dict[str, int] = defaultdict(int)
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, WindowedHistogram] = {}
        self.node_counters: set = set()
        # twin-key memo: (name, gid) / (cls, name) -> formatted key.
        # ``inc`` sits on the per-packet fast path (every send, every
        # ingress admit), and re-formatting the same handful of key
        # strings millions of times was measurable in profiles.
        self._twin_keys: Dict[Tuple, str] = {}

    # -- counters ----------------------------------------------------------
    def inc(self, name: str, value: int = 1, *,
            gid: Optional[int] = None, cls: Optional[str] = None):
        """Increment ``name`` and its twins: ``name@gid`` when the event
        is attributable to one node's port/NIC, ``<cls>_name`` when it is
        attributable to a traffic class. One call site, every view."""
        c = self.counters
        c[name] += value
        if gid is not None:
            memo = self._twin_keys
            k = memo.get((name, gid))
            if k is None:
                k = memo[(name, gid)] = f"{name}{NODE_SEP}{gid}"
                self.node_counters.add(name)
            c[k] += value
        if cls is not None:
            memo = self._twin_keys
            k = memo.get((cls, name))
            if k is None:
                k = memo[(cls, name)] = f"{cls}_{name}"
            c[k] += value

    def node_twin_sums(self) -> Dict[str, Tuple[int, int]]:
        """(bare value, sum of @gid twins) for every node-attributable
        counter — the invariant surface: the two must always match."""
        out = {}
        for name in sorted(self.node_counters):
            twin = sum(v for k, v in self.counters.items()
                       if k.startswith(name + NODE_SEP)
                       and k[len(name) + 1:].isdigit())
            out[name] = (self.counters[name], twin)
        return out

    # -- gauges ------------------------------------------------------------
    def set_gauge(self, name: str, value: float,
                  gid: Optional[int] = None):
        if gid is not None:
            name = f"{name}{NODE_SEP}{gid}"
        self.gauges[name] = value

    # -- histograms --------------------------------------------------------
    def observe(self, name: str, step: int, value: float,
                gid: Optional[int] = None):
        if gid is not None:
            name = f"{name}{NODE_SEP}{gid}"
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = WindowedHistogram(self.window)
        h.observe(step, value)

    def histogram(self, name: str,
                  gid: Optional[int] = None) -> Optional[WindowedHistogram]:
        if gid is not None:
            name = f"{name}{NODE_SEP}{gid}"
        return self.histograms.get(name)

    # -- export ------------------------------------------------------------
    def snapshot(self, now: Optional[int] = None) -> Dict:
        """Plain-dict view for reports/JSON: counters, gauges, and
        histogram summaries (trimmed to ``now`` when given)."""
        return {"counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "histograms": {k: h.summary(now)
                               for k, h in self.histograms.items()}}
