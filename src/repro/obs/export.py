"""Trace exporters: Chrome trace-event JSON and migration timeline reports.

Two consumers of one ``Tracer``:

* ``chrome_trace`` / ``write_chrome_trace`` — the Chrome trace-event
  format (the ``{"traceEvents": [...]}`` JSON that Perfetto and
  ``chrome://tracing`` load). Migration phase spans become complete
  ("X") events grouped per node; everything else becomes instant ("i")
  events. Timestamps are sim-clock microseconds: one fabric step is
  ``STEP_S`` seconds (1 µs), so ``ts`` is literally the step count.

* ``build_migration_report`` / ``render_timeline`` — the attribution
  the paper's scalars lack: where ``downtime_s``/``transfer_s`` went,
  by phase, by port (per-node egress bytes inside each phase window),
  and by traffic class. Phase durations are computed with the same
  ``step * step_s`` arithmetic, in the same order, as the strategies'
  ``MigrationReport`` fields — so span sums equal the reported figures
  exactly, which ``tests/test_obs.py`` and ``tools/trace_report.py``
  both assert.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.obs.trace import EventKind, TraceEvent, Tracer

# phases whose spans make up the stop-the-world window (the strategies
# compute downtime_s = checkpoint_s + transfer_s + restore_s)
DOWNTIME_PHASES = ("checkpoint", "transfer", "restore")


# ---------------------------------------------------------------------------
# Chrome trace-event JSON
# ---------------------------------------------------------------------------


def chrome_trace(tracer: Tracer) -> Dict:
    """Render the tracer's events as a Chrome trace-event JSON object.

    Layout: one trace "process" per fabric node (pid = gid), phase spans
    on a ``migration`` thread, packet/congestion/service instants on a
    per-kind thread — so Perfetto's timeline groups a node's egress
    activity, NAK storms, and migration phases into adjacent tracks."""
    us = tracer.step_s * 1e6            # microseconds per fabric step
    events: List[Dict] = []
    nodes = sorted({e.node for e in tracer.events if e.node is not None})
    for gid in nodes:
        events.append({"ph": "M", "name": "process_name", "pid": gid,
                       "tid": 0, "args": {"name": f"node {gid}"}})
    for e in tracer.events:
        pid = e.node if e.node is not None else -1
        if e.kind is EventKind.PHASE or e.kind is EventKind.PAUSED:
            events.append({
                "ph": "X", "name": e.data["name"], "cat": "migration",
                "pid": pid, "tid": "migration",
                "ts": e.data["begin"] * us,
                "dur": e.data["dur_steps"] * us,
                "args": {k: v for k, v in e.data.items()
                         if k not in ("begin", "end")},
            })
        else:
            events.append({
                "ph": "i", "s": "t", "name": e.kind.value,
                "cat": e.kind.value.split("_")[0],
                "pid": pid, "tid": e.kind.value,
                "ts": e.step * us, "args": dict(e.data),
            })
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"sim_step_s": tracer.step_s,
                          "dropped_events": tracer.dropped_events}}


def write_chrome_trace(tracer: Tracer, path: str) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(tracer), f)
    return path


# ---------------------------------------------------------------------------
# migration timeline report
# ---------------------------------------------------------------------------


def _phase_dicts(tracer: Tracer) -> List[Dict]:
    out = []
    for e in tracer.phases():
        d = e.data
        out.append({"name": d["name"], "node": e.node,
                    "begin": d["begin"], "end": d["end"],
                    "begin_s": d["begin"] * tracer.step_s,
                    "end_s": d["end"] * tracer.step_s,
                    # same arithmetic as the strategies' rep fields:
                    # (end - begin) steps, scaled once
                    "dur_s": d["dur_steps"] * tracer.step_s,
                    "attrs": {k: v for k, v in d.items()
                              if k not in ("name", "begin", "end",
                                           "dur_steps")}})
    return out


def build_migration_report(tracer: Tracer,
                           now: Optional[int] = None) -> Dict:
    """Attribute migration time to phases, ports, and traffic classes.

    ``downtime_s`` is the sum of checkpoint/transfer/restore spans and
    ``transfer_s`` the sum of transfer spans — accumulated in event
    order with the same float operations the strategies use, so the
    totals equal the ``MigrationReport`` fields exactly. ``ports`` and
    ``classes`` attribute wire traffic (EGRESS_TX events) to the phase
    window each byte was transmitted in; bytes outside every downtime
    phase land in ``"live"``."""
    phases = _phase_dicts(tracer)
    totals: Dict[str, float] = {}
    for p in phases:
        totals[p["name"]] = totals.get(p["name"], 0.0) + p["dur_s"]
    downtime_s = 0.0
    for name in DOWNTIME_PHASES:
        downtime_s += totals.get(name, 0.0)

    # wire attribution: which phase window was each transmitted packet
    # inside (half-open (begin, end]: a packet sent at the step a phase
    # ended belongs to it — fab.now advanced before the send ran)
    windows = [(p["begin"], p["end"], p["name"]) for p in phases
               if p["name"] in DOWNTIME_PHASES or p["name"] == "live"
               or p["name"] == "precopy_round"]

    def window_of(step: int) -> str:
        for b, e, name in windows:
            if b < step <= e:
                return name
        return "live"

    ports: Dict[int, Dict] = {}
    classes: Dict[str, Dict] = {}
    by_phase: Dict[str, Dict] = {}
    for e in tracer.of_kind(EventKind.EGRESS_TX):
        n = e.data["nbytes"]
        cls = e.data["cls"]
        ph = window_of(e.step)
        port = ports.setdefault(e.node, {"tx_bytes": 0, "tx_packets": 0,
                                         "phases": {}})
        port["tx_bytes"] += n
        port["tx_packets"] += 1
        port["phases"][ph] = port["phases"].get(ph, 0) + n
        c = classes.setdefault(cls, {"tx_bytes": 0, "tx_packets": 0,
                                     "phases": {}})
        c["tx_bytes"] += n
        c["tx_packets"] += 1
        c["phases"][ph] = c["phases"].get(ph, 0) + n
        d = by_phase.setdefault(ph, {"tx_bytes": 0, "app": 0, "mig": 0})
        d["tx_bytes"] += n
        d[cls] += n

    counts = {}
    for e in tracer.events:
        counts[e.kind.value] = counts.get(e.kind.value, 0) + 1

    fab = tracer.fabric
    hists = {}
    if fab is not None:
        hists = {k: h.summary(now)
                 for k, h in fab.metrics.histograms.items()}
    return {
        "phases": phases,
        "phase_totals_s": totals,
        "downtime_s": downtime_s,
        "transfer_s": totals.get("transfer", 0.0),
        "live_s": totals.get("live", 0.0),
        "rounds": [p for p in phases if p["name"] == "precopy_round"],
        "ports": ports,
        "classes": classes,
        "wire_by_phase": by_phase,
        "event_counts": counts,
        "histograms": hists,
        "dropped_events": tracer.dropped_events,
    }


def render_timeline(report: Dict, width: int = 48) -> str:
    """Text timeline of a migration report: one bar per phase span
    (scaled to the longest), then the port/class attribution tables."""
    lines = ["migration timeline (sim clock)", ""]
    phases = report["phases"]
    if not phases:
        return "no phase spans recorded (was tracing enabled?)"
    t0 = min(p["begin"] for p in phases)
    longest = max(max(p["end"] for p in phases) - t0, 1)
    for p in sorted(phases, key=lambda p: (p["begin"], p["end"])):
        lo = int((p["begin"] - t0) / longest * width)
        hi = max(int((p["end"] - t0) / longest * width), lo + 1)
        bar = " " * lo + "#" * (hi - lo)
        extra = "".join(f" {k}={v}" for k, v in p["attrs"].items()
                        if k != "node")
        lines.append(f"  {p['name']:>14} |{bar:<{width}}| "
                     f"{p['dur_s'] * 1e6:9.1f} us{extra}")
    lines.append("")
    lines.append(f"  downtime_s={report['downtime_s']:.6f} "
                 f"transfer_s={report['transfer_s']:.6f} "
                 f"live_s={report['live_s']:.6f}")
    for name in ("checkpoint", "restore"):
        if name in report["phase_totals_s"]:
            lines[-1] += (f" {name}_s="
                          f"{report['phase_totals_s'][name]:.6f}")
    if report["ports"]:
        lines.append("")
        lines.append("  wire bytes by egress port (per phase window):")
        for gid in sorted(report["ports"]):
            p = report["ports"][gid]
            per = " ".join(f"{k}={v}" for k, v in
                           sorted(p["phases"].items()))
            lines.append(f"    node {gid}: {p['tx_bytes']} B "
                         f"/ {p['tx_packets']} pkts  [{per}]")
    if report["classes"]:
        lines.append("  wire bytes by traffic class:")
        for cls in sorted(report["classes"]):
            c = report["classes"][cls]
            per = " ".join(f"{k}={v}" for k, v in
                           sorted(c["phases"].items()))
            lines.append(f"    {cls}: {c['tx_bytes']} B "
                         f"/ {c['tx_packets']} pkts  [{per}]")
    if report["dropped_events"]:
        lines.append(f"  WARNING: {report['dropped_events']} events "
                     f"dropped (max_events hit) — totals are partial")
    return "\n".join(lines)
