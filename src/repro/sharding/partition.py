"""Logical-axis sharding: rules mapping logical names -> mesh axes,
spec resolution with divisibility checks, and activation constraints.

The model code annotates parameters/activations with *logical* axis names
(``vocab``, ``embed``, ``ffn``, ``heads``, ``experts``, ``batch`` ...).
``resolve()`` turns those into ``PartitionSpec``s for the active mesh,
dropping any assignment that does not divide the actual dimension (e.g. a
single KV head can't shard 16-way). ``activate(mesh, rules)`` installs the
mesh for ``constrain`` so model code stays mesh-agnostic; without an active
mesh, ``constrain`` is the identity (smoke tests, single-device runs).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

# Default rules: FSDP over "data" (weights' embed dim), TP/EP over "model".
DEFAULT_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "seq": None,
    "vocab": "model",
    "embed": "data",          # FSDP shard dim of 2-D weights
    "ffn": "model",           # TP shard dim (mlp hidden, heads*hd, rnn width)
    "heads": "model",
    "experts": "model",       # EP
    "lora": None,
    "norm": None,
    "layers": None,
    "stage": None,
    # decode-cache axes
    "seq_kv": ("data", "model"),   # falls back to unused subset
    "seq_data": "data",
}

_state = threading.local()


def _active() -> Tuple[Optional[Mesh], Dict[str, Axis]]:
    return (getattr(_state, "mesh", None),
            getattr(_state, "rules", DEFAULT_RULES))


@contextlib.contextmanager
def activate(mesh: Mesh, rules: Optional[Dict[str, Axis]] = None):
    prev = _active()
    _state.mesh = mesh
    _state.rules = dict(DEFAULT_RULES, **(rules or {}))
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis] if axis in mesh.shape else 0
    n = 1
    for a in axis:
        s = mesh.shape.get(a, 0) if hasattr(mesh.shape, "get") else (
            mesh.shape[a] if a in mesh.shape else 0)
        if s == 0:
            return 0
        n *= s
    return n


def resolve(logical: Sequence[Optional[str]],
            shape: Optional[Sequence[int]] = None,
            mesh: Optional[Mesh] = None,
            rules: Optional[Dict[str, Axis]] = None) -> P:
    """Logical axes (+ concrete shape for divisibility checks) -> spec."""
    m, r = _active()
    mesh = mesh or m
    rules = dict(DEFAULT_RULES, **(rules or {})) if rules else r
    out, used = [], set()
    for i, name in enumerate(logical):
        axis = rules.get(name) if name else None
        if axis is None:
            out.append(None)
            continue
        flat = (axis,) if isinstance(axis, str) else tuple(axis)
        # keep only axes that exist in the mesh and are not already used
        flat = tuple(a for a in flat if a not in used and
                     (mesh is None or a in mesh.shape))
        if not flat:
            out.append(None)
            continue
        if mesh is not None:
            sz = _axis_size(mesh, flat)
            if sz <= 1 or (shape is not None and shape[i] % max(sz, 1)):
                out.append(None)
                continue
        used.update(flat)
        out.append(flat[0] if len(flat) == 1 else flat)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def constrain(x, logical: Sequence[Optional[str]]):
    """with_sharding_constraint against the active mesh (identity if none)."""
    mesh, rules = _active()
    if mesh is None:
        return x
    spec = resolve(logical, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_shardings(spec_tree, shape_tree, mesh: Mesh,
                    rules: Optional[Dict[str, Axis]] = None):
    """Tree of logical-axes tuples + shapes -> tree of NamedShardings."""
    return jax.tree.map(
        lambda axes, arr: NamedSharding(
            mesh, resolve(axes, arr.shape, mesh, rules)),
        spec_tree, shape_tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            a is None or isinstance(a, str) for a in t))


def batch_spec(mesh: Mesh, ndim: int,
               rules: Optional[Dict[str, Axis]] = None) -> P:
    axes = ["batch"] + [None] * (ndim - 1)
    return resolve(axes, None, mesh, rules)
