"""Cluster migration orchestrator: control plane + live-migration engine.

Layering (fabric → verbs → dump/migration → **orchestrator** → cluster
runtime): this package sits above the per-container ``MigrationController``
and below ``SimCluster``. ``strategies`` holds the pluggable engines
(stop-and-copy / pre-copy / post-copy), ``orchestrator`` the cluster-wide
control plane (admission, queueing, retry, rollback).
"""
from repro.core.migration import MigrationAttempt  # noqa: F401
from repro.orchestrator.orchestrator import (AdmissionError,  # noqa: F401
                                             MigrationPlan,
                                             MigrationRequest, Orchestrator,
                                             PausedMigration,
                                             PreemptionPolicy)
from repro.orchestrator.strategies import (STRATEGIES,  # noqa: F401
                                           DemandPager, MigrationStrategy,
                                           PostCopy, PreCopy, StopAndCopy,
                                           choose_migration_strategy,
                                           make_strategy)
