"""Pluggable live-migration strategies (the engine under the orchestrator).

The seed ``MigrationController`` is a full stop-and-copy: downtime scales
with total MR footprint. Production live migration bounds downtime instead:

* ``StopAndCopy`` — the seed flow, preserved verbatim (it delegates to the
  controller, so results stay byte-identical to the seed).
* ``PreCopy``     — iterative rounds: stream all MR pages over the service
  channel while the app keeps running (the page stream and the app's own
  traffic share link bandwidth), then re-send only dirtied pages until the
  delta converges below a threshold or a round cap, then a short
  stop-and-copy of the residual + verbs state. Downtime scales with the
  residual dirty set, not the footprint.
* ``PostCopy``    — restore verbs state immediately at the destination and
  fault MR pages in on demand (``DemandPager``); downtime scales with the
  verbs image alone. Every pulled page is charged to the wire as a
  ``MIG_PAGE`` message from the source's service channel.

Every strategy produces a ``MigrationReport`` whose ``downtime_s`` /
``transfer_s`` / ``live_s`` are sim-clock deltas (``fabric.now * STEP_S``)
measured around the actual streams — deterministic across runs. The
``simulated_*`` figures remain the analytic bytes/bandwidth estimates for
comparison. Failed transfers leave a retry token in ``report.attempt``;
the orchestrator hands it back to ``resume()`` to redo the move from the
last completed round (staged pages already live at the destination's
service channel and are not re-sent).
"""
from __future__ import annotations

import zlib
from typing import Callable, Dict, List, Optional, Tuple

import msgpack

from repro.core import dump as dumplib
from repro.core.migration import MigrationAttempt, MigrationReport
from repro.core.packets import Op
from repro.core.pagecodec import PageCodec
from repro.core.service import StreamPreempted
from repro.core.transport import STEP_S
from repro.core.verbs import PAGE_SIZE, MemoryRegion
from repro.obs.trace import record_phase

# pages per MIG_PAGE message: bounds the service scratch MR while keeping
# per-message overhead small (64 pages = 256 KiB per WQE)
PAGE_BATCH = 64


def _sim_transfer_s(ctl, attempt: Dict) -> float:
    """Analytic wire time for (re-)moving an attempt's image, honouring
    the docker runtime's via-storage double cost."""
    sim = len(attempt["image"]) / ctl.bw
    if attempt.get("runtime") == "docker":
        sim *= 2
    return sim


def _sim_attempt_s(ctl, attempt: MigrationAttempt) -> float:
    """As ``_sim_transfer_s``, for a pause token."""
    sim = len(attempt.image) / ctl.bw
    if attempt.runtime == "docker":
        sim *= 2
    return sim


class _RoundPreempted(Exception):
    """Internal: a page round yielded mid-way. Carries what the round
    still owes (``remaining``) and the bytes that DID cross the wire —
    logical and encoded — so the split round's accounting stays exact
    across the pause."""

    def __init__(self, reason: str,
                 remaining: List[Tuple[MemoryRegion, int]],
                 sent_bytes: int, wire_bytes: int):
        super().__init__(f"page round preempted ({reason})")
        self.reason = reason
        self.remaining = remaining
        self.sent_bytes = sent_bytes
        self.wire_bytes = wire_bytes


def _page(mr: MemoryRegion, pg: int) -> bytes:
    return bytes(mr.buf[pg * PAGE_SIZE:(pg + 1) * PAGE_SIZE])


def _page_len(mr: MemoryRegion, pg: int) -> int:
    return min(PAGE_SIZE, mr.size - pg * PAGE_SIZE)


def _codec_stats(fab, gid: int, stream: int, stats: Dict):
    """Account one encoded batch: node-attributed counters (``@gid``
    twins by construction) plus the typed trace hook."""
    m = fab.metrics
    if stats["zero"]:
        m.inc("pages_zero_elided", stats["zero"], gid=gid)
    if stats["dup"]:
        m.inc("pages_dedup_hits", stats["dup"], gid=gid)
    if stats["delta_saved"]:
        m.inc("delta_bytes_saved", stats["delta_saved"], gid=gid)
    trc = fab.tracer
    if trc is not None:
        trc.page_codec(fab.now, gid, stream, stats)


def _stream_pages(ctl, src_dev, dest_gid: int, stream: int,
                  pages: List[Tuple[MemoryRegion, int]], tick,
                  preempt: Optional[Callable] = None,
                  codec: Optional[PageCodec] = None) -> Tuple[int, int]:
    """Stream a page set over the service channel in MIG_PAGE batches;
    blocks (pumping via ``tick``) until each batch is receipt-acked.
    Returns ``(logical_bytes, wire_bytes)`` — without a codec the two
    are equal; with one, ``wire_bytes`` is the encoded payload that
    actually crossed the links.

    ``preempt`` makes every batch boundary (and, via the service
    channel, every pump step inside a batch) a yield point: a truthy
    verdict raises ``_RoundPreempted`` with the round's remaining pages.
    A batch cut off mid-transfer counts as unsent — its receipt was
    never acked, so the resend is idempotent (legacy staging overwrites
    the same keys with the same bytes; codec batches re-encode from the
    last *committed* state, and their records decode through the
    receiver's append-only content store). Codec state advances only on
    the ack (``commit``), so a dropped batch never poisons the digest
    cache with content the destination does not hold."""
    svc = src_dev.service
    fab = ctl.fabric
    total = 0
    wire = 0
    lo = 0
    while lo < len(pages):
        if preempt is not None:
            r = preempt()
            if r:
                raise _RoundPreempted(r, pages[lo:], total, wire)
        batch = pages[lo:lo + PAGE_BATCH]
        if codec is None:
            metas, datas = [], []
            for mr, pg in batch:
                data = _page(mr, pg)
                metas.append((mr.mrn, pg, len(data)))
                datas.append(data)
            payload = b"".join(datas)
            logical = encoded = sum(m[2] for m in metas)
            pending = stats = None
        else:
            metas, payload, pending, stats = codec.encode_batch(
                [(mr.mrn, pg, _page(mr, pg)) for mr, pg in batch])
            logical = stats["bytes_in"]
            encoded = stats["bytes_out"]
        try:
            svc.transfer(dest_gid, Op.MIG_PAGE,
                         {"stream": stream, "pages": metas},
                         payload, tick=tick, preempt=preempt)
        except StreamPreempted as e:
            raise _RoundPreempted(e.reason, pages[lo:], total,
                                  wire) from None
        if codec is not None:
            codec.commit(pending)
            _codec_stats(fab, src_dev.gid, stream, stats)
        total += logical
        wire += encoded
        lo += PAGE_BATCH
    return total, wire


class MigrationStrategy:
    """Interface: ``run`` performs a migration end to end; ``resume``
    retries the transfer+restore half from a captured attempt token;
    ``resume_paused`` re-enters a migration the orchestrator preempted
    mid-flight (a ``MigrationAttempt`` pause token, possibly re-pointed
    at a new destination)."""

    name = "base"

    def run(self, ctl, container, dest_node, *, runtime: str = "crx",
            fail_at: Optional[str] = None,
            background: Optional[Callable] = None,
            preempt: Optional[Callable] = None) -> MigrationReport:
        raise NotImplementedError

    def resume(self, ctl, container, dest_node, attempt: Dict,
               rep: MigrationReport) -> MigrationReport:
        raise NotImplementedError

    def resume_paused(self, ctl, container, dest_node,
                      attempt: MigrationAttempt, rep: MigrationReport, *,
                      background: Optional[Callable] = None,
                      preempt: Optional[Callable] = None
                      ) -> MigrationReport:
        raise NotImplementedError

    def _resume_stopped(self, ctl, container, dest_node, attempt, rep,
                        install, *, preempt=None) -> MigrationReport:
        """Shared ``resume_paused`` core for stopped-phase tokens: the
        container is checkpoint-frozen and the complete image rides the
        token, so resuming is re-streaming it (re-preemptible) and
        installing. The service QP's learned wire state is re-applied
        when the destination is unchanged (RTO/rate are path-learned —
        a re-pointed attempt starts fresh)."""
        fab = ctl.fabric
        src_dev = container.ctx.device
        dest_gid = dest_node.device.gid
        if dest_gid == attempt.dest_gid and attempt.service_qp:
            src_dev.service.apply_wire_state(dest_gid, attempt.service_qp)
            attempt.service_qp = {}
        t1 = fab.now
        try:
            moved = ctl.stream_image(src_dev, dest_gid, attempt.image,
                                     runtime=attempt.runtime,
                                     preempt=preempt)
        except StreamPreempted as e:
            rep.transfer_s += (fab.now - t1) * STEP_S
            record_phase(fab, "transfer", t1, node=src_dev.gid,
                         suspended=True)
            if e.reason == "abort":
                rep.stage_failed = "aborted"
                rep.attempt = None
                return rep
            rep.stage_failed = "paused"
            rep.preemptions += 1
            attempt.dest_gid = dest_gid
            attempt.reason = e.reason
            attempt.paused_at = fab.now
            attempt.service_qp = \
                src_dev.service.take_suspend_state(dest_gid)
            rep.attempt = attempt
            return rep
        rep.simulated_transfer_s += _sim_attempt_s(ctl, attempt)
        rep.transfer_s += (fab.now - t1) * STEP_S
        record_phase(fab, "transfer", t1, node=dest_gid, resumed=True)
        t2 = fab.now
        install(moved)
        rep.restore_s += (fab.now - t2) * STEP_S
        record_phase(fab, "restore", t2, node=dest_gid)
        ctl.clear_cleanups(container)
        container.alive = True
        rep.ok = True
        rep.stage_failed = None
        rep.attempt = None
        return rep

    def _stream_and_install(self, ctl, container, dest_node, attempt,
                            rep: MigrationReport, install) -> MigrationReport:
        """Shared resume() core: re-stream the attempt's image over the
        wire (sim-clock accounted), hand the delivered bytes to the
        strategy's ``install`` callback, and revive the container."""
        fab = ctl.fabric
        t1 = fab.now
        moved = ctl.stream_image(container.ctx.device,
                                 dest_node.device.gid, attempt["image"],
                                 runtime=attempt.get("runtime", "crx"))
        rep.simulated_transfer_s += _sim_transfer_s(ctl, attempt)
        rep.transfer_s += (fab.now - t1) * STEP_S
        record_phase(fab, "transfer", t1,
                     node=dest_node.device.gid, retry=True)
        t2 = fab.now
        install(moved)
        rep.restore_s += (fab.now - t2) * STEP_S
        record_phase(fab, "restore", t2, node=dest_node.device.gid)
        ctl.clear_cleanups(container)
        container.alive = True
        rep.ok = True
        rep.stage_failed = None
        rep.attempt = None
        return rep


# ---------------------------------------------------------------------------
# stop-and-copy (seed behaviour, preserved)
# ---------------------------------------------------------------------------


class StopAndCopy(MigrationStrategy):
    name = "stop_and_copy"

    def run(self, ctl, container, dest_node, *, runtime="crx", fail_at=None,
            background=None, preempt=None):
        # delegate to the controller so the flow (pump counts, staging,
        # image layout) is exactly the seed's
        return ctl.migrate(container, dest_node, runtime=runtime,
                           fail_at=fail_at, preempt=preempt)

    def resume(self, ctl, container, dest_node, attempt, rep):
        def install(moved):
            ctl._teardown_source(container)
            ctl._restore(container, moved, dest_node)

        rep = self._stream_and_install(ctl, container, dest_node, attempt,
                                       rep, install)
        rep.pages_sent = rep.pages_total   # the retry moved every page
        rep.downtime_s = rep.total_s
        rep.simulated_downtime_s = rep.simulated_transfer_s
        return rep

    def resume_paused(self, ctl, container, dest_node, attempt, rep, *,
                      background=None, preempt=None):
        def install(moved):
            ctl._teardown_source(container)
            ctl._restore(container, moved, dest_node)

        rep = self._resume_stopped(ctl, container, dest_node, attempt,
                                   rep, install, preempt=preempt)
        if rep.ok:
            rep.pages_sent = rep.pages_total
            rep.downtime_s = rep.total_s
            rep.simulated_downtime_s = rep.simulated_transfer_s
        return rep


# ---------------------------------------------------------------------------
# pre-copy
# ---------------------------------------------------------------------------


class PreCopy(MigrationStrategy):
    name = "pre_copy"

    def __init__(self, *, threshold_bytes: int = 2 * PAGE_SIZE,
                 max_rounds: int = 8, pump_per_round: int = 40):
        assert max_rounds >= 1
        self.threshold_bytes = threshold_bytes
        self.max_rounds = max_rounds
        self.pump_per_round = pump_per_round

    # -- live phase helpers -----------------------------------------------
    def _live(self, ctl, background):
        """Settle window between rounds: the app keeps running and the
        fabric keeps pumping, dirtying pages (the page streams themselves
        also run under ``background``, so the app dirties pages *while*
        each round is on the wire)."""
        for _ in range(self.pump_per_round):
            if background is not None:
                background()
            else:
                ctl.fabric.pump()

    def run(self, ctl, container, dest_node, *, runtime="crx", fail_at=None,
            background=None, preempt=None):
        if dest_node is container.node:
            return MigrationReport(strategy="noop")
        rep = MigrationReport(strategy=self.name)
        ctx = container.ctx
        src_dev = ctx.device
        mrs = list(ctx.mrs)
        ctl.run_cleanups(container)     # release any earlier dead attempt
        stream = src_dev.service.next_stream()
        # from the first streamed page on, the destination service holds
        # state that must be released if this attempt dies at ANY stage
        dest_svc = dest_node.device.service
        ctl.register_cleanup(container,
                             lambda: dest_svc.discard_stream(stream))

        for mr in mrs:
            mr.start_dirty_tracking()
        # round 0: the full footprint streams to the destination's service
        # channel while the app keeps running — dirty tracking records
        # exactly the pages touched while the copy was on the wire
        all_pages = [(mr, pg) for mr in mrs for pg in range(mr.n_pages)]
        rep.pages_total = len(all_pages)
        fab = ctl.fabric
        st = {"stream": stream, "round": 0, "pending": all_pages,
              "round_pages": 0, "round_bytes": 0, "round_steps": 0,
              "round_wire": 0,
              "codec": PageCodec(fab.codec) if fab.codec.enabled
              else None}
        return self._rounds(ctl, container, dest_node, rep, st,
                            runtime=runtime, fail_at=fail_at,
                            background=background, preempt=preempt)

    def _rounds(self, ctl, container, dest_node, rep, st, *, runtime,
                fail_at, background, preempt):
        """Round engine shared by ``run`` and live-phase ``resume_paused``:
        stream (the rest of) round ``st["round"]``, then iterate delta
        rounds — re-sending only what got dirtied while the previous
        round's copy was in flight — until the delta converges below the
        threshold or the round cap. Any preemption verdict inside a round
        yields a pause token carrying the split round's exact progress."""
        fab = ctl.fabric
        ctx = container.ctx
        src_dev = ctx.device
        dest_gid = dest_node.device.gid
        mrs = list(ctx.mrs)
        live_tick = background if background is not None else fab.pump
        codec = st["codec"]
        t_leg = fab.now
        residual = []
        while True:
            pending = st["pending"]
            rt = fab.now
            try:
                sent, wired = _stream_pages(ctl, src_dev, dest_gid,
                                            st["stream"], pending,
                                            live_tick, preempt=preempt,
                                            codec=codec)
            except _RoundPreempted as e:
                done = len(pending) - len(e.remaining)
                st["pending"] = e.remaining
                st["round_pages"] += done
                st["round_bytes"] += e.sent_bytes
                st["round_wire"] += e.wire_bytes
                st["round_steps"] += fab.now - rt
                rep.pages_sent += done
                record_phase(fab, "precopy_round", rt, node=src_dev.gid,
                             round=st["round"], suspended=True)
                return self._yield(ctl, container, dest_node, rep, st,
                                   e.reason, runtime, t_leg)
            pages_rnd = st["round_pages"] + len(pending)
            bytes_rnd = st["round_bytes"] + sent
            wire_rnd = st["round_wire"] + wired
            rep.pages_sent += len(pending)
            rnd = {"round": st["round"], "pages": pages_rnd,
                   "bytes": bytes_rnd,
                   "sim_s": bytes_rnd / ctl.bw,
                   "wire_s": (st["round_steps"] +
                              fab.now - rt) * STEP_S}
            if codec is not None:
                # encoded bytes only exist with a codec; codec-off round
                # records stay byte-identical to the pre-codec engine
                rnd["wire_bytes"] = wire_rnd
            rep.rounds.append(rnd)
            record_phase(fab, "precopy_round", rt, node=src_dev.gid,
                         round=st["round"], pages=pages_rnd,
                         bytes=bytes_rnd)
            self._live(ctl, background)
            st["round"] += 1
            st["round_pages"] = st["round_bytes"] = st["round_steps"] = 0
            st["round_wire"] = 0
            dirty = [(mr, pg) for mr in mrs
                     for pg in sorted(mr.collect_dirty())]
            dirty_bytes = sum(_page_len(mr, pg) for mr, pg in dirty)
            if dirty_bytes <= self.threshold_bytes \
                    or st["round"] == self.max_rounds:
                # converged (or round cap): fall back to stop-and-copy of
                # exactly this residual
                residual = dirty
                break
            if codec is not None and st["round"] >= 2 and wire_rnd > 0:
                # convergence controller: project the next round's
                # encoded cost from this round's achieved encode ratio.
                # Both rounds would drain at the same achieved send rate,
                # so comparing encoded *bytes* compares wire *time* — if
                # the projection is within cutover_ratio of the round
                # just sent, rounds have stopped shrinking (the
                # non-converging writable working set) and the residual
                # stop-and-copy is cheaper than burning the round budget.
                projected = dirty_bytes * (wire_rnd / max(bytes_rnd, 1))
                if projected >= codec.cfg.cutover_ratio * wire_rnd:
                    rep.rounds[-1]["cutover"] = True
                    fab.metrics.inc("codec_cutovers", gid=src_dev.gid)
                    residual = dirty
                    break
            st["pending"] = dirty
        rep.live_s += (fab.now - t_leg) * STEP_S
        record_phase(fab, "live", t_leg, node=src_dev.gid,
                     rounds=len(rep.rounds))
        return self._finish(ctl, container, dest_node, rep, st, residual,
                            runtime=runtime, fail_at=fail_at,
                            preempt=preempt)

    def _yield(self, ctl, container, dest_node, rep, st, reason, runtime,
               t_leg):
        """Capture a live-phase pause token. The container keeps running —
        dirty tracking stays armed, so pages touched while paused are
        swept into the next delta collection — while the service stream
        to the destination is suspended with its wire state snapshotted
        into the token."""
        fab = ctl.fabric
        src_dev = container.ctx.device
        dest_gid = dest_node.device.gid
        svc = src_dev.service
        rep.live_s += (fab.now - t_leg) * STEP_S
        record_phase(fab, "live", t_leg, node=src_dev.gid, suspended=True)
        rep.ok = False
        if reason == "abort":
            # nothing to park: the orchestrator's rollback stops dirty
            # tracking and releases the staged pages via cleanups
            rep.stage_failed = "aborted"
            rep.attempt = None
            return rep
        if dest_gid in svc._peers:
            # the preempt verdict landed at a batch boundary, so the
            # stream was never torn mid-flight — suspend it here
            svc.suspend_peer(dest_gid, reason)
        svc._suspended.pop(dest_gid, None)
        rep.stage_failed = "paused"
        rep.preemptions += 1
        rep.attempt = MigrationAttempt(
            container=container.name, strategy=self.name, runtime=runtime,
            src_gid=src_dev.gid, dest_gid=dest_gid, phase="live",
            reason=reason, rounds_done=len(rep.rounds),
            pages_sent=rep.pages_sent, stream=st["stream"],
            pending=[(mr.mrn, pg) for mr, pg in st["pending"]],
            round_pages=st["round_pages"], round_bytes=st["round_bytes"],
            round_steps=st["round_steps"],
            round_wire=st["round_wire"],
            service_qp=svc.take_suspend_state(dest_gid),
            paused_at=fab.now,
            codec=st["codec"].dump() if st["codec"] is not None else {})
        return rep

    def _finish(self, ctl, container, dest_node, rep, st, residual, *,
                runtime, fail_at, preempt):
        fab = ctl.fabric
        ctx = container.ctx
        src_dev = ctx.device
        dest_gid = dest_node.device.gid
        mrs = list(ctx.mrs)
        stream = st["stream"]
        # -- stop-the-world: residual pages + verbs state + user state ----
        t_stop = fab.now
        verbs_image = dumplib.dump_context(ctx, stop=True)       # [MIGR]
        fab.pump(ctl.stop_pump_steps)   # peers see NAK_STOPPED
        residual_pages: Dict[int, Dict[int, bytes]] = {}
        for mr, pg in residual:
            residual_pages.setdefault(mr.mrn, {})[pg] = _page(mr, pg)
        for mr in mrs:
            mr.stop_dirty_tracking()
        user = container.checkpoint_user()
        image = msgpack.packb({"verbs": verbs_image,
                               "residual": residual_pages, "user": user},
                              use_bin_type=True)
        if runtime == "docker":
            image = zlib.decompress(zlib.compress(image, level=1))
        rep.image_bytes = len(image)
        rep.checkpoint_s = (fab.now - t_stop) * STEP_S
        record_phase(fab, "checkpoint", t_stop, node=src_dev.gid,
                     image_bytes=len(image),
                     residual_pages=len(residual))
        if fail_at == "checkpoint":
            rep.ok = False
            rep.stage_failed = "checkpoint"
            return rep

        t1 = fab.now
        rep.simulated_downtime_s = len(image) / ctl.bw
        if runtime == "docker":
            rep.simulated_downtime_s *= 2
        rep.simulated_transfer_s = rep.simulated_downtime_s + \
            sum(r["sim_s"] for r in rep.rounds)
        if fail_at == "transfer":
            # the staged pages already arrived at the destination's
            # service channel; only the residual image is lost
            container.alive = False
            rep.ok = False
            rep.stage_failed = "transfer"
            rep.attempt = {"image": bytes(image), "stream": stream,
                           "runtime": runtime}
            return rep
        try:
            moved = ctl.stream_image(src_dev, dest_gid, image,
                                     runtime=runtime, preempt=preempt)
        except StreamPreempted as e:
            # paused inside the stop window: the source QPs stay STOPPED
            # (peers parked on NAK_STOPPED) and the residual image rides
            # the token — the staged rounds stay put at the destination
            container.alive = False
            rep.ok = False
            rep.transfer_s += (fab.now - t1) * STEP_S
            record_phase(fab, "transfer", t1, node=src_dev.gid,
                         suspended=True)
            if e.reason == "abort":
                rep.stage_failed = "aborted"
                rep.attempt = None
                return rep
            rep.stage_failed = "paused"
            rep.preemptions += 1
            rep.attempt = MigrationAttempt(
                container=container.name, strategy=self.name,
                runtime=runtime, src_gid=src_dev.gid, dest_gid=dest_gid,
                phase="stopped", reason=e.reason,
                rounds_done=len(rep.rounds), pages_sent=rep.pages_sent,
                stream=stream, image=bytes(image),
                service_qp=src_dev.service.take_suspend_state(dest_gid),
                paused_at=fab.now)
            return rep
        rep.transfer_s += (fab.now - t1) * STEP_S
        record_phase(fab, "transfer", t1, node=src_dev.gid,
                     bytes=len(image))

        t2 = fab.now
        staged = self._claim_staging(dest_node, stream)
        self._install(ctl, container, moved, staged, dest_node)
        rep.restore_s += (fab.now - t2) * STEP_S
        record_phase(fab, "restore", t2, node=dest_gid)
        rep.downtime_s = rep.checkpoint_s + rep.transfer_s + rep.restore_s
        ctl.clear_cleanups(container)
        rep.ok = True
        rep.stage_failed = None
        rep.attempt = None
        return rep

    def resume(self, ctl, container, dest_node, attempt, rep):
        """Retry from the last completed round: every staged page already
        arrived at the destination service channel; only the residual
        image needs to move again."""
        def install(moved):
            staged = self._claim_staging(dest_node, attempt["stream"])
            self._install(ctl, container, moved, staged, dest_node)

        rep = self._stream_and_install(ctl, container, dest_node, attempt,
                                       rep, install)
        rep.simulated_downtime_s += _sim_transfer_s(ctl, attempt)
        rep.downtime_s = rep.checkpoint_s + rep.transfer_s + rep.restore_s
        return rep

    def resume_paused(self, ctl, container, dest_node, attempt, rep, *,
                      background=None, preempt=None):
        fab = ctl.fabric
        ctx = container.ctx
        src_dev = ctx.device
        dest_gid = dest_node.device.gid
        if attempt.phase == "stopped":
            if dest_gid != attempt.dest_gid:
                # the staged rounds died with the old destination; the QPs
                # are stopped so memory is static — fold the full footprint
                # into the residual and point the stream at the new node
                img = msgpack.unpackb(attempt.image, raw=False,
                                      strict_map_key=False)
                img["residual"] = {
                    mr.mrn: {pg: _page(mr, pg)
                             for pg in range(mr.n_pages)}
                    for mr in ctx.mrs}
                attempt.image = msgpack.packb(img, use_bin_type=True)
                rep.image_bytes = len(attempt.image)
                self._redirect_stream(ctl, container, dest_node, attempt)

            def install(moved):
                staged = self._claim_staging(dest_node, attempt.stream)
                self._install(ctl, container, moved, staged, dest_node)

            rep = self._resume_stopped(ctl, container, dest_node, attempt,
                                       rep, install, preempt=preempt)
            if rep.ok:
                rep.simulated_downtime_s += _sim_attempt_s(ctl, attempt)
                rep.downtime_s = rep.checkpoint_s + rep.transfer_s \
                    + rep.restore_s
            return rep
        # live phase: the container never stopped — re-enter the round
        # engine exactly where the split round yielded
        if dest_gid != attempt.dest_gid:
            # nothing staged survives the old destination: restart the
            # current round over the full footprint (later delta rounds
            # still shrink it — dirty tracking never stopped). The codec
            # state is invalidated WITH the staging: its digest cache
            # describes content only the old destination held, and a
            # stale dedup/delta-base hit against the new one would
            # silently corrupt the restored image — the fresh codec
            # starts with nothing staged, so every page ships decodable.
            self._redirect_stream(ctl, container, dest_node, attempt)
            pending = [(mr, pg) for mr in ctx.mrs
                       for pg in range(mr.n_pages)]
            st = {"stream": attempt.stream, "round": attempt.rounds_done,
                  "pending": pending, "round_pages": 0, "round_bytes": 0,
                  "round_steps": 0, "round_wire": 0,
                  "codec": PageCodec(fab.codec) if fab.codec.enabled
                  else None}
        else:
            if attempt.service_qp:
                src_dev.service.apply_wire_state(dest_gid,
                                                 attempt.service_qp)
                attempt.service_qp = {}
            mr_by_n = {mr.mrn: mr for mr in ctx.mrs}
            st = {"stream": attempt.stream, "round": attempt.rounds_done,
                  "pending": [(mr_by_n[mrn], pg)
                              for mrn, pg in attempt.pending],
                  "round_pages": attempt.round_pages,
                  "round_bytes": attempt.round_bytes,
                  "round_steps": attempt.round_steps,
                  "round_wire": attempt.round_wire,
                  "codec": PageCodec.restore(fab.codec, attempt.codec)
                  if fab.codec.enabled else None}
        return self._rounds(ctl, container, dest_node, rep, st,
                            runtime=attempt.runtime, fail_at=None,
                            background=background, preempt=preempt)

    def _redirect_stream(self, ctl, container, dest_node, attempt):
        """The original destination is gone (or drained): discard its
        staged state via the registered cleanup and re-register against
        the new destination's service channel."""
        ctl.run_cleanups(container)
        dest_svc = dest_node.device.service
        stream = attempt.stream
        ctl.register_cleanup(container,
                             lambda: dest_svc.discard_stream(stream))
        attempt.dest_gid = dest_node.device.gid
        attempt.service_qp = {}

    @staticmethod
    def _claim_staging(dest_node, stream):
        return dest_node.device.service.take_staging(stream)

    def _install(self, ctl, container, image_bytes, staged, dest_node):
        image = msgpack.unpackb(image_bytes, raw=False,
                                strict_map_key=False)
        ctl._teardown_source(container)
        ctx = dest_node.device.open_context(tenant=container.name)
        session = dumplib.restore_context(ctx, image["verbs"],
                                          relocated=ctl.relocated)
        for qp in ctx.qps:
            ctl.relocated[qp.qpn] = dest_node.device.gid
        for (mrn, pg), data in staged.items():
            mr = session.mr_by_n[int(mrn)]
            mr.buf[pg * PAGE_SIZE:pg * PAGE_SIZE + len(data)] = data
        for mrn, pages in image["residual"].items():
            mr = session.mr_by_n[int(mrn)]
            for pg, data in pages.items():
                off = int(pg) * PAGE_SIZE
                mr.buf[off:off + len(data)] = data
        container.adopt(dest_node, ctx, session)
        container.restore_user(image["user"])


# ---------------------------------------------------------------------------
# post-copy
# ---------------------------------------------------------------------------


class DemandPager:
    """Serves destination page faults from the source's frozen memory.

    The frozen pages live in the *source* device's service channel
    (``page_store``) until the destination has pulled them all (demand
    faults on access + optional background ``prefetch``). Each pulled
    page is charged to the wire as a fire-and-forget ``MIG_PAGE`` message
    from the source's service QP — the bytes really cross the shared link
    and contend with application traffic, while the fill itself is applied
    synchronously (the sim clock only advances on pump, so "instant fill +
    link charge" is the step-accurate model of a kernel-served fault).
    Once an MR is fully resident its pager hook is detached, restoring the
    branch-free fast path."""

    def __init__(self, bw_Bps: float,
                 report: Optional[MigrationReport] = None, *,
                 service=None, dest_gid: Optional[int] = None,
                 stream: Optional[int] = None):
        self.bw = bw_Bps
        self.report = report          # pages pulled count as pages_sent
        self.service = service        # SOURCE device's service channel
        self.dest_gid = dest_gid
        self.stream = stream
        self.source: Dict[int, bytes] = {}       # mrn -> frozen source buf
        self.missing: Dict[int, set] = {}        # mrn -> absent page set
        self.mrs: Dict[int, MemoryRegion] = {}   # mrn -> destination MR
        self.faults = 0
        self.fault_bytes = 0
        self.simulated_pull_s = 0.0
        # operator pause: background prefetch stops, but demand faults
        # keep serving — a paused post-copy must never wedge the running
        # destination container on an absent page
        self.paused = False
        # lazy page codec for the pull wire charges; keyed to the
        # destination it encoded against so a resume onto a new node
        # starts a fresh one (same invalidation rule as pre-copy)
        self._codec: Optional[PageCodec] = None
        self._codec_dest: Optional[int] = None

    def capture(self, mrs):
        for mr in mrs:
            self.source[mr.mrn] = bytes(mr.buf)
            self.missing[mr.mrn] = set(range(mr.n_pages))
        if self.service is not None and self.stream is not None:
            # the frozen store outlives the source container's teardown:
            # it is kernel-owned until the destination drains it
            self.service.page_store[self.stream] = self.source

    def attach(self, mr: MemoryRegion):
        if self.missing.get(mr.mrn):
            self.mrs[mr.mrn] = mr
            mr.pager = self

    def _charge_wire(self, mr: MemoryRegion, pg: int, data: bytes):
        if self.service is None or self.dest_gid is None:
            return
        fab = self.service.device.fabric
        if fab.codec.enabled:
            # the pull really is applied before this message (the fill is
            # synchronous), so the wire charge is the *encoded* cost —
            # dedup/delta against what this destination already pulled.
            # Fire-and-forget: there is no ack to gate on, and the
            # receive path ignores postcopy payloads, so committing at
            # send is exact.
            if self._codec is None or self._codec_dest != self.dest_gid:
                self._codec = PageCodec(fab.codec)
                self._codec_dest = self.dest_gid
            metas, payload, pending, stats = self._codec.encode_batch(
                [(mr.mrn, pg, data)])
            self._codec.commit(pending)
            _codec_stats(fab, self.service.device.gid, self.stream, stats)
        else:
            metas = [(mr.mrn, pg, len(data))]
            payload = data
        self.service.post(self.dest_gid, Op.MIG_PAGE,
                          {"stream": self.stream, "postcopy": True,
                           "noack": True, "pages": metas}, payload)

    def _fill(self, mr: MemoryRegion, pg: int, *, fault: bool):
        lo = pg * PAGE_SIZE
        data = self.source[mr.mrn][lo:lo + PAGE_SIZE]
        mr.buf[lo:lo + len(data)] = data
        self.missing[mr.mrn].discard(pg)
        if fault:
            self.faults += 1
            self.fault_bytes += len(data)
        if self.report is not None:
            self.report.pages_sent += 1
        self.simulated_pull_s += len(data) / self.bw
        if self.service is not None:
            fab = self.service.device.fabric
            trc = fab.tracer
            if trc is not None:
                trc.page_pull(fab.now, self.dest_gid, mr.mrn, pg,
                              len(data), fault)
        self._charge_wire(mr, pg, data)
        if not self.missing[mr.mrn]:
            mr.pager = None                      # fully resident
            self.mrs.pop(mr.mrn, None)
            if not any(self.missing.values()) and self.service is not None:
                self.service.page_store.pop(self.stream, None)

    def ensure(self, mr: MemoryRegion, off: int, length: int):
        """Demand fault: pull every absent page the access touches."""
        if length <= 0:
            return
        miss = self.missing.get(mr.mrn)
        if not miss:
            mr.pager = None
            return
        for pg in range(off // PAGE_SIZE,
                        (off + length - 1) // PAGE_SIZE + 1):
            if pg in miss:
                self._fill(mr, pg, fault=True)

    def prefetch(self, n_pages: int = 1) -> int:
        """Background pull of up to ``n_pages``; returns pages moved."""
        if self.paused:
            return 0
        moved = 0
        for mrn in list(self.mrs):
            mr = self.mrs.get(mrn)
            while mr is not None and moved < n_pages \
                    and self.missing.get(mrn):
                self._fill(mr, min(self.missing[mrn]), fault=False)
                moved += 1
                mr = self.mrs.get(mrn)
            if moved >= n_pages:
                break
        return moved

    @property
    def remaining_pages(self) -> int:
        return sum(len(s) for s in self.missing.values())

    def release(self):
        """Drop the frozen source store without draining it (rollback of
        a failed attempt): detach every destination hook and free the
        kernel-parked copy so repeated failures don't leak footprints."""
        for mr in self.mrs.values():
            mr.pager = None
        self.mrs.clear()
        self.missing.clear()
        self.source = {}
        if self.service is not None and self.stream is not None:
            self.service.discard_stream(self.stream)


class PostCopy(MigrationStrategy):
    name = "post_copy"

    def run(self, ctl, container, dest_node, *, runtime="crx", fail_at=None,
            background=None, preempt=None):
        if dest_node is container.node:
            return MigrationReport(strategy="noop")
        rep = MigrationReport(strategy=self.name)
        fab = ctl.fabric
        ctx = container.ctx
        src_dev = ctx.device
        dest_gid = dest_node.device.gid
        ctl.run_cleanups(container)     # release any earlier dead attempt
        rep.pages_total = sum(mr.n_pages for mr in ctx.mrs)

        # -- stop-the-world: verbs + user state only (no MR contents) -----
        t0 = fab.now
        verbs_image = dumplib.dump_context(ctx, stop=True)       # [MIGR]
        fab.pump(ctl.stop_pump_steps)   # peers see NAK_STOPPED
        user = container.checkpoint_user()
        image = msgpack.packb({"verbs": verbs_image, "user": user},
                              use_bin_type=True)
        if runtime == "docker":
            image = zlib.decompress(zlib.compress(image, level=1))
        rep.image_bytes = len(image)
        rep.checkpoint_s = (fab.now - t0) * STEP_S
        record_phase(fab, "checkpoint", t0, node=src_dev.gid,
                     image_bytes=len(image))
        if fail_at == "checkpoint":
            rep.ok = False
            rep.stage_failed = "checkpoint"
            return rep

        # freeze source pages before any teardown can clear them; the
        # store parks in the source service channel until fully drained
        pager = DemandPager(ctl.bw, report=rep, service=src_dev.service,
                            dest_gid=dest_gid,
                            stream=src_dev.service.next_stream())
        pager.capture(ctx.mrs)
        # the frozen store must be released if this attempt dies at any
        # stage; a SUCCESSFUL migration clears the token instead (the
        # pager keeps serving faults until it drains itself)
        ctl.register_cleanup(container, pager.release)

        t1 = fab.now
        rep.simulated_downtime_s = len(image) / ctl.bw
        if runtime == "docker":
            rep.simulated_downtime_s *= 2
        rep.simulated_transfer_s = rep.simulated_downtime_s
        if fail_at == "transfer":
            container.alive = False
            rep.ok = False
            rep.stage_failed = "transfer"
            rep.attempt = {"image": bytes(image), "pager": pager,
                           "runtime": runtime}
            return rep
        try:
            moved = ctl.stream_image(src_dev, dest_gid, image,
                                     runtime=runtime, preempt=preempt)
        except StreamPreempted as e:
            # paused inside the (short) stop window: the verbs image rides
            # the token; the frozen page store stays parked in the source
            # service channel, referenced by the stream cursor
            container.alive = False
            rep.ok = False
            rep.transfer_s += (fab.now - t1) * STEP_S
            record_phase(fab, "transfer", t1, node=src_dev.gid,
                         suspended=True)
            if e.reason == "abort":
                rep.stage_failed = "aborted"
                rep.attempt = None
                return rep
            rep.stage_failed = "paused"
            rep.preemptions += 1
            rep.attempt = MigrationAttempt(
                container=container.name, strategy=self.name,
                runtime=runtime, src_gid=src_dev.gid, dest_gid=dest_gid,
                phase="stopped", reason=e.reason,
                pages_sent=rep.pages_sent, stream=pager.stream,
                image=bytes(image),
                service_qp=src_dev.service.take_suspend_state(dest_gid),
                paused_at=fab.now, refs={"pager": pager})
            return rep
        rep.transfer_s += (fab.now - t1) * STEP_S
        record_phase(fab, "transfer", t1, node=src_dev.gid,
                     bytes=len(image))

        t2 = fab.now
        self._install(ctl, container, moved, pager, dest_node)
        rep.restore_s = (fab.now - t2) * STEP_S
        record_phase(fab, "restore", t2, node=dest_gid)
        rep.downtime_s = rep.total_s
        rep.pager = pager
        ctl.clear_cleanups(container)
        return rep

    def resume(self, ctl, container, dest_node, attempt, rep):
        def install(moved):
            self._install(ctl, container, moved, attempt["pager"],
                          dest_node)

        rep = self._stream_and_install(ctl, container, dest_node, attempt,
                                       rep, install)
        rep.simulated_downtime_s += _sim_transfer_s(ctl, attempt)
        rep.downtime_s = rep.total_s
        rep.pager = attempt["pager"]
        return rep

    def resume_paused(self, ctl, container, dest_node, attempt, rep, *,
                      background=None, preempt=None):
        src_dev = container.ctx.device
        pager = attempt.refs.get("pager")
        if pager is None:
            # the token crossed a serialisation boundary: rebuild the
            # pager around the kernel-parked page store. No page was
            # installed before the pause (install is what drains pulls),
            # so "everything missing" is exact.
            pager = DemandPager(ctl.bw, service=src_dev.service,
                                dest_gid=dest_node.device.gid,
                                stream=attempt.stream)
            store = src_dev.service.page_store.get(attempt.stream)
            if store is not None:
                pager.source = store
                for mr in container.ctx.mrs:
                    pager.missing[mr.mrn] = set(range(mr.n_pages))
            else:
                pager.capture(container.ctx.mrs)
            ctl.clear_cleanups(container)
            ctl.register_cleanup(container, pager.release)
        pager.dest_gid = dest_node.device.gid
        pager.report = rep
        attempt.refs["pager"] = pager

        def install(moved):
            self._install(ctl, container, moved, pager, dest_node)

        rep = self._resume_stopped(ctl, container, dest_node, attempt,
                                   rep, install, preempt=preempt)
        if rep.ok:
            rep.simulated_downtime_s += _sim_attempt_s(ctl, attempt)
            rep.downtime_s = rep.total_s
            rep.pager = pager
        return rep

    def _install(self, ctl, container, image_bytes, pager, dest_node):
        image = msgpack.unpackb(image_bytes, raw=False,
                                strict_map_key=False)
        ctl._teardown_source(container)
        ctx = dest_node.device.open_context(tenant=container.name)
        session = dumplib.restore_context(ctx, image["verbs"],
                                          relocated=ctl.relocated)
        for qp in ctx.qps:
            ctl.relocated[qp.qpn] = dest_node.device.gid
        # MR buffers stay empty: every page is faulted in on first touch
        for mr in session.mr_by_n.values():
            pager.attach(mr)
        container.adopt(dest_node, ctx, session)
        container.restore_user(image["user"])


# ---------------------------------------------------------------------------
# registry / policy helpers
# ---------------------------------------------------------------------------


STRATEGIES = {
    StopAndCopy.name: StopAndCopy,
    PreCopy.name: PreCopy,
    PostCopy.name: PostCopy,
}


def make_strategy(spec, **params) -> MigrationStrategy:
    """Resolve a strategy name / class / instance to an instance."""
    if isinstance(spec, MigrationStrategy):
        return spec
    if isinstance(spec, type) and issubclass(spec, MigrationStrategy):
        return spec(**params)
    try:
        cls = STRATEGIES[spec]
    except KeyError:
        raise ValueError(f"unknown migration strategy {spec!r}; "
                         f"have {sorted(STRATEGIES)}") from None
    return cls(**params)


def choose_migration_strategy(image_bytes: int, dirty_rate_Bps: float,
                              bw_Bps: float,
                              max_downtime_s: float) -> str:
    """Link-bandwidth-budget strategy selection (used by the orchestrator's
    ``strategy="auto"`` and by elastic re-mesh planning):

    * whole image moves within the downtime budget -> stop-and-copy;
    * dirty rate low enough for deltas to converge  -> pre-copy;
    * otherwise post-copy (stop window bounded by the verbs image alone).
    """
    if bw_Bps <= 0:
        return PostCopy.name
    if image_bytes / bw_Bps <= max_downtime_s:
        return StopAndCopy.name
    if dirty_rate_Bps < 0.5 * bw_Bps:
        return PreCopy.name
    return PostCopy.name
