"""Pluggable live-migration strategies (the engine under the orchestrator).

The seed ``MigrationController`` is a full stop-and-copy: downtime scales
with total MR footprint. Production live migration bounds downtime instead:

* ``StopAndCopy`` — the seed flow, preserved verbatim (it delegates to the
  controller, so results stay byte-identical to the seed).
* ``PreCopy``     — iterative rounds: snapshot all MR pages while the app
  keeps running and the fabric keeps pumping, then re-send only dirtied
  pages until the delta converges below a threshold or a round cap, then a
  short stop-and-copy of the residual + verbs state. Downtime scales with
  the residual dirty set, not the footprint.
* ``PostCopy``    — restore verbs state immediately at the destination and
  fault MR pages in on demand (``DemandPager``); downtime scales with the
  verbs image alone.

Every strategy produces a ``MigrationReport`` with ``downtime_s`` (wall
time the QPs were actually stopped) split from ``total_s``, plus
``simulated_*`` figures derived from the link bandwidth so comparisons are
deterministic. Failed transfers leave a retry token in ``report.attempt``;
the orchestrator hands it back to ``resume()`` to redo the move from the
last completed round.
"""
from __future__ import annotations

import time
import zlib
from typing import Callable, Dict, Optional

import msgpack

from repro.core import dump as dumplib
from repro.core.migration import MigrationReport
from repro.core.verbs import PAGE_SIZE, MemoryRegion


def _sim_transfer_s(ctl, attempt: Dict) -> float:
    """Simulated wire time for (re-)moving an attempt's image, honouring
    the docker runtime's via-storage double cost."""
    sim = len(attempt["image"]) / ctl.bw
    if attempt.get("runtime") == "docker":
        sim *= 2
    return sim


class MigrationStrategy:
    """Interface: ``run`` performs a migration end to end; ``resume``
    retries the transfer+restore half from a captured attempt token."""

    name = "base"

    def run(self, ctl, container, dest_node, *, runtime: str = "crx",
            fail_at: Optional[str] = None,
            background: Optional[Callable] = None) -> MigrationReport:
        raise NotImplementedError

    def resume(self, ctl, container, dest_node, attempt: Dict,
               rep: MigrationReport) -> MigrationReport:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# stop-and-copy (seed behaviour, preserved)
# ---------------------------------------------------------------------------


class StopAndCopy(MigrationStrategy):
    name = "stop_and_copy"

    def run(self, ctl, container, dest_node, *, runtime="crx", fail_at=None,
            background=None):
        # delegate to the controller so the flow (pump counts, staging,
        # image layout) is exactly the seed's
        return ctl.migrate(container, dest_node, runtime=runtime,
                           fail_at=fail_at)

    def resume(self, ctl, container, dest_node, attempt, rep):
        t1 = time.perf_counter()
        image = attempt["image"]
        rep.simulated_transfer_s += _sim_transfer_s(ctl, attempt)
        rep.transfer_s += time.perf_counter() - t1
        t2 = time.perf_counter()
        ctl._teardown_source(container)
        ctl._restore(container, image, dest_node)
        rep.restore_s += time.perf_counter() - t2
        container.alive = True
        rep.ok = True
        rep.stage_failed = None
        rep.attempt = None
        rep.downtime_s = rep.total_s
        rep.simulated_downtime_s = rep.simulated_transfer_s
        return rep


# ---------------------------------------------------------------------------
# pre-copy
# ---------------------------------------------------------------------------


class PreCopy(MigrationStrategy):
    name = "pre_copy"

    def __init__(self, *, threshold_bytes: int = 2 * PAGE_SIZE,
                 max_rounds: int = 8, pump_per_round: int = 40):
        assert max_rounds >= 1
        self.threshold_bytes = threshold_bytes
        self.max_rounds = max_rounds
        self.pump_per_round = pump_per_round

    # -- live phase helpers -----------------------------------------------
    def _live(self, ctl, background):
        """One round's worth of 'the page copy is on the wire': the app
        keeps running and the fabric keeps pumping, dirtying pages."""
        for _ in range(self.pump_per_round):
            if background is not None:
                background()
            else:
                ctl.fabric.pump()

    @staticmethod
    def _page(mr: MemoryRegion, pg: int) -> bytes:
        return bytes(mr.buf[pg * PAGE_SIZE:(pg + 1) * PAGE_SIZE])

    def run(self, ctl, container, dest_node, *, runtime="crx", fail_at=None,
            background=None):
        rep = MigrationReport(strategy=self.name)
        if dest_node is container.node:
            return rep
        ctx = container.ctx
        mrs = list(ctx.mrs)

        t_live = time.perf_counter()
        for mr in mrs:
            mr.start_dirty_tracking()
        # staged = the destination's copy of MR memory, page-granular; in
        # the simulation it simply lives here until restore applies it.
        staged: Dict = {}
        for mr in mrs:
            for pg in range(mr.n_pages):
                staged[(mr.mrn, pg)] = self._page(mr, pg)
        rep.pages_total = len(staged)
        rep.pages_sent = len(staged)
        r0_bytes = sum(len(v) for v in staged.values())
        rep.rounds.append({"round": 0, "pages": len(staged),
                           "bytes": r0_bytes, "sim_s": r0_bytes / ctl.bw})
        self._live(ctl, background)

        # iterative delta rounds: re-send only what got dirtied while the
        # previous round's copy was in flight
        residual = []
        for rnd in range(1, self.max_rounds + 1):
            dirty = [(mr, pg) for mr in mrs
                     for pg in sorted(mr.collect_dirty())]
            dirty_bytes = sum(len(self._page(mr, pg)) for mr, pg in dirty)
            if dirty_bytes <= self.threshold_bytes \
                    or rnd == self.max_rounds:
                # converged (or round cap): fall back to stop-and-copy of
                # exactly this residual
                residual = dirty
                break
            for mr, pg in dirty:
                staged[(mr.mrn, pg)] = self._page(mr, pg)
            rep.pages_sent += len(dirty)
            rep.rounds.append({"round": rnd, "pages": len(dirty),
                               "bytes": dirty_bytes,
                               "sim_s": dirty_bytes / ctl.bw})
            self._live(ctl, background)
        rep.live_s = time.perf_counter() - t_live

        # -- stop-the-world: residual pages + verbs state + user state ----
        t_stop = time.perf_counter()
        verbs_image = dumplib.dump_context(ctx, stop=True)       # [MIGR]
        ctl.fabric.pump(ctl.stop_pump_steps)   # peers see NAK_STOPPED
        residual_pages: Dict[int, Dict[int, bytes]] = {}
        for mr, pg in residual:
            residual_pages.setdefault(mr.mrn, {})[pg] = self._page(mr, pg)
        for mr in mrs:
            mr.stop_dirty_tracking()
        user = container.checkpoint_user()
        image = msgpack.packb({"verbs": verbs_image,
                               "residual": residual_pages, "user": user},
                              use_bin_type=True)
        if runtime == "docker":
            image = zlib.decompress(zlib.compress(image, level=1))
        rep.image_bytes = len(image)
        rep.checkpoint_s = time.perf_counter() - t_stop
        if fail_at == "checkpoint":
            rep.ok = False
            rep.stage_failed = "checkpoint"
            return rep

        t1 = time.perf_counter()
        rep.simulated_downtime_s = len(image) / ctl.bw
        if runtime == "docker":
            rep.simulated_downtime_s *= 2
        rep.simulated_transfer_s = rep.simulated_downtime_s + \
            sum(r["sim_s"] for r in rep.rounds)
        moved = bytes(image)
        rep.transfer_s = time.perf_counter() - t1
        if fail_at == "transfer":
            container.alive = False
            rep.ok = False
            rep.stage_failed = "transfer"
            rep.attempt = {"image": moved, "staged": staged,
                           "runtime": runtime}
            return rep

        t2 = time.perf_counter()
        self._install(ctl, container, moved, staged, dest_node)
        rep.restore_s = time.perf_counter() - t2
        rep.downtime_s = rep.checkpoint_s + rep.transfer_s + rep.restore_s
        return rep

    def resume(self, ctl, container, dest_node, attempt, rep):
        """Retry from the last completed round: every staged page already
        'arrived'; only the residual image needs to move again."""
        t1 = time.perf_counter()
        image = attempt["image"]
        sim = _sim_transfer_s(ctl, attempt)
        rep.simulated_transfer_s += sim
        rep.simulated_downtime_s += sim
        rep.transfer_s += time.perf_counter() - t1
        t2 = time.perf_counter()
        self._install(ctl, container, image, attempt["staged"], dest_node)
        rep.restore_s += time.perf_counter() - t2
        container.alive = True
        rep.ok = True
        rep.stage_failed = None
        rep.attempt = None
        rep.downtime_s = rep.checkpoint_s + rep.transfer_s + rep.restore_s
        return rep

    def _install(self, ctl, container, image_bytes, staged, dest_node):
        image = msgpack.unpackb(image_bytes, raw=False,
                                strict_map_key=False)
        ctl._teardown_source(container)
        ctx = dest_node.device.open_context()
        session = dumplib.restore_context(ctx, image["verbs"],
                                          relocated=ctl.relocated)
        for qp in ctx.qps:
            ctl.relocated[qp.qpn] = dest_node.device.gid
        for (mrn, pg), data in staged.items():
            mr = session.mr_by_n[int(mrn)]
            mr.buf[pg * PAGE_SIZE:pg * PAGE_SIZE + len(data)] = data
        for mrn, pages in image["residual"].items():
            mr = session.mr_by_n[int(mrn)]
            for pg, data in pages.items():
                off = int(pg) * PAGE_SIZE
                mr.buf[off:off + len(data)] = data
        container.adopt(dest_node, ctx, session)
        container.restore_user(image["user"])


# ---------------------------------------------------------------------------
# post-copy
# ---------------------------------------------------------------------------


class DemandPager:
    """Serves destination page faults from the source's frozen memory.

    The source node keeps the checkpointed pages in RAM until the
    destination has pulled them all (demand faults on access + optional
    background ``prefetch``); once an MR is fully resident its pager hook
    is detached, restoring the branch-free fast path."""

    def __init__(self, bw_Bps: float, report: Optional[MigrationReport] = None):
        self.bw = bw_Bps
        self.report = report          # pages pulled count as pages_sent
        self.source: Dict[int, bytes] = {}       # mrn -> frozen source buf
        self.missing: Dict[int, set] = {}        # mrn -> absent page set
        self.mrs: Dict[int, MemoryRegion] = {}   # mrn -> destination MR
        self.faults = 0
        self.fault_bytes = 0
        self.simulated_pull_s = 0.0

    def capture(self, mrs):
        for mr in mrs:
            self.source[mr.mrn] = bytes(mr.buf)
            self.missing[mr.mrn] = set(range(mr.n_pages))

    def attach(self, mr: MemoryRegion):
        if self.missing.get(mr.mrn):
            self.mrs[mr.mrn] = mr
            mr.pager = self

    def _fill(self, mr: MemoryRegion, pg: int, *, fault: bool):
        lo = pg * PAGE_SIZE
        data = self.source[mr.mrn][lo:lo + PAGE_SIZE]
        mr.buf[lo:lo + len(data)] = data
        self.missing[mr.mrn].discard(pg)
        if fault:
            self.faults += 1
            self.fault_bytes += len(data)
        if self.report is not None:
            self.report.pages_sent += 1
        self.simulated_pull_s += len(data) / self.bw
        if not self.missing[mr.mrn]:
            mr.pager = None                      # fully resident
            self.mrs.pop(mr.mrn, None)

    def ensure(self, mr: MemoryRegion, off: int, length: int):
        """Demand fault: pull every absent page the access touches."""
        if length <= 0:
            return
        miss = self.missing.get(mr.mrn)
        if not miss:
            mr.pager = None
            return
        for pg in range(off // PAGE_SIZE,
                        (off + length - 1) // PAGE_SIZE + 1):
            if pg in miss:
                self._fill(mr, pg, fault=True)

    def prefetch(self, n_pages: int = 1) -> int:
        """Background pull of up to ``n_pages``; returns pages moved."""
        moved = 0
        for mrn in list(self.mrs):
            mr = self.mrs.get(mrn)
            while mr is not None and moved < n_pages \
                    and self.missing.get(mrn):
                self._fill(mr, min(self.missing[mrn]), fault=False)
                moved += 1
                mr = self.mrs.get(mrn)
            if moved >= n_pages:
                break
        return moved

    @property
    def remaining_pages(self) -> int:
        return sum(len(s) for s in self.missing.values())


class PostCopy(MigrationStrategy):
    name = "post_copy"

    def run(self, ctl, container, dest_node, *, runtime="crx", fail_at=None,
            background=None):
        rep = MigrationReport(strategy=self.name)
        if dest_node is container.node:
            return rep
        ctx = container.ctx
        rep.pages_total = sum(mr.n_pages for mr in ctx.mrs)

        # -- stop-the-world: verbs + user state only (no MR contents) -----
        t0 = time.perf_counter()
        verbs_image = dumplib.dump_context(ctx, stop=True)       # [MIGR]
        ctl.fabric.pump(ctl.stop_pump_steps)   # peers see NAK_STOPPED
        user = container.checkpoint_user()
        image = msgpack.packb({"verbs": verbs_image, "user": user},
                              use_bin_type=True)
        if runtime == "docker":
            image = zlib.decompress(zlib.compress(image, level=1))
        rep.image_bytes = len(image)
        rep.checkpoint_s = time.perf_counter() - t0
        if fail_at == "checkpoint":
            rep.ok = False
            rep.stage_failed = "checkpoint"
            return rep

        # freeze source pages before any teardown can clear them
        pager = DemandPager(ctl.bw, report=rep)
        pager.capture(ctx.mrs)

        t1 = time.perf_counter()
        rep.simulated_downtime_s = len(image) / ctl.bw
        if runtime == "docker":
            rep.simulated_downtime_s *= 2
        rep.simulated_transfer_s = rep.simulated_downtime_s
        moved = bytes(image)
        rep.transfer_s = time.perf_counter() - t1
        if fail_at == "transfer":
            container.alive = False
            rep.ok = False
            rep.stage_failed = "transfer"
            rep.attempt = {"image": moved, "pager": pager,
                           "runtime": runtime}
            return rep

        t2 = time.perf_counter()
        self._install(ctl, container, moved, pager, dest_node)
        rep.restore_s = time.perf_counter() - t2
        rep.downtime_s = rep.total_s
        rep.pager = pager
        return rep

    def resume(self, ctl, container, dest_node, attempt, rep):
        t1 = time.perf_counter()
        image = attempt["image"]
        sim = _sim_transfer_s(ctl, attempt)
        rep.simulated_transfer_s += sim
        rep.simulated_downtime_s += sim
        rep.transfer_s += time.perf_counter() - t1
        t2 = time.perf_counter()
        self._install(ctl, container, image, attempt["pager"], dest_node)
        rep.restore_s += time.perf_counter() - t2
        container.alive = True
        rep.ok = True
        rep.stage_failed = None
        rep.attempt = None
        rep.downtime_s = rep.total_s
        rep.pager = attempt["pager"]
        return rep

    def _install(self, ctl, container, image_bytes, pager, dest_node):
        image = msgpack.unpackb(image_bytes, raw=False,
                                strict_map_key=False)
        ctl._teardown_source(container)
        ctx = dest_node.device.open_context()
        session = dumplib.restore_context(ctx, image["verbs"],
                                          relocated=ctl.relocated)
        for qp in ctx.qps:
            ctl.relocated[qp.qpn] = dest_node.device.gid
        # MR buffers stay empty: every page is faulted in on first touch
        for mr in session.mr_by_n.values():
            pager.attach(mr)
        container.adopt(dest_node, ctx, session)
        container.restore_user(image["user"])


# ---------------------------------------------------------------------------
# registry / policy helpers
# ---------------------------------------------------------------------------


STRATEGIES = {
    StopAndCopy.name: StopAndCopy,
    PreCopy.name: PreCopy,
    PostCopy.name: PostCopy,
}


def make_strategy(spec, **params) -> MigrationStrategy:
    """Resolve a strategy name / class / instance to an instance."""
    if isinstance(spec, MigrationStrategy):
        return spec
    if isinstance(spec, type) and issubclass(spec, MigrationStrategy):
        return spec(**params)
    try:
        cls = STRATEGIES[spec]
    except KeyError:
        raise ValueError(f"unknown migration strategy {spec!r}; "
                         f"have {sorted(STRATEGIES)}") from None
    return cls(**params)


def choose_migration_strategy(image_bytes: int, dirty_rate_Bps: float,
                              bw_Bps: float,
                              max_downtime_s: float) -> str:
    """Link-bandwidth-budget strategy selection (used by the orchestrator's
    ``strategy="auto"`` and by elastic re-mesh planning):

    * whole image moves within the downtime budget -> stop-and-copy;
    * dirty rate low enough for deltas to converge  -> pre-copy;
    * otherwise post-copy (stop window bounded by the verbs image alone).
    """
    if bw_Bps <= 0:
        return PostCopy.name
    if image_bytes / bw_Bps <= max_downtime_s:
        return StopAndCopy.name
    if dirty_rate_Bps < 0.5 * bw_Bps:
        return PreCopy.name
    return PostCopy.name
