"""Cluster-wide migration control plane (admission, queueing, retry,
rollback).

The seed's control plane was a single dict (``MigrationController
.relocated``). Production migration needs more: a request is *admitted*
(destination capacity, QPN/MRN-range collision, link-bandwidth budget)
before any QP is stopped, concurrent requests are serialised through a
FIFO queue, failed transfers are retried from the last completed round,
and a migration that dies mid-flight is *rolled back* — the still-attached
source QPs leave STOPPED, re-arm, and send RESUME so paused peers recover
instead of hanging on NAK_STOPPED forever (the failure mode the paper
accepts in §3.4, and the one ``test_failed_migration_leaves_peer_paused``
pins for the bare controller).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.migration import (MigrationController, MigrationError,
                                  MigrationReport)
from repro.core.states import QPState
from repro.core.transport import STEP_S
from repro.core.verbs import PAGE_SIZE
from repro.obs.trace import record_phase
from repro.orchestrator.strategies import (MigrationStrategy,
                                           choose_migration_strategy,
                                           make_strategy)


class AdmissionError(MigrationError):
    """Pre-migration validation failed; nothing was stopped or moved."""


@dataclass
class MigrationPlan:
    """Outcome of admission: what will move, where, and the cost estimate."""
    container: str
    src_gid: int
    dest_gid: int
    est_image_bytes: int
    est_transfer_s: float
    checks: List[str] = field(default_factory=list)


@dataclass
class MigrationRequest:
    container: object
    dest_node: object
    strategy: object = "stop_and_copy"      # name | class | instance
    strategy_params: Dict = field(default_factory=dict)
    runtime: str = "crx"
    fail_at: Optional[str] = None
    retries: int = 1


class Orchestrator:
    """Owns the cluster migration state: the ``relocated`` registry (shared
    with the wrapped controller so bare-controller migrations stay
    coherent), the request queue, and per-request retry/rollback."""

    def __init__(self, controller: MigrationController, *,
                 background: Optional[Callable] = None,
                 max_transfer_s: Optional[float] = None,
                 max_downtime_s: float = 1e-3):
        self.controller = controller
        self.background = background      # steps apps + pumps once (live)
        self.max_transfer_s = max_transfer_s
        self.max_downtime_s = max_downtime_s   # budget for strategy="auto"
        self.queue: deque = deque()
        self.history: List[MigrationReport] = []

    @property
    def relocated(self) -> Dict[int, int]:
        return self.controller.relocated

    # -- admission -----------------------------------------------------------
    def admit(self, container, dest_node) -> MigrationPlan:
        if dest_node is container.node:
            raise AdmissionError("destination is the source node")
        if not container.alive:
            raise AdmissionError(f"container {container.name!r} not alive")
        checks = []
        cap = getattr(dest_node, "capacity", None)
        if cap is not None and len(dest_node.containers) >= cap:
            raise AdmissionError(
                f"node {dest_node.gid} at capacity ({cap})")
        checks.append("capacity")
        dev = dest_node.device
        for qp in container.ctx.qps:
            if qp.qpn in dev.qps:
                raise AdmissionError(
                    f"QPN {qp.qpn} already allocated on node {dev.gid}")
        taken_mrns = {m.mrn for c in dev.contexts for m in c.mrs}
        for mr in container.ctx.mrs:
            if mr.mrn in taken_mrns:
                raise AdmissionError(
                    f"MRN {mr.mrn} already allocated on node {dev.gid}")
        checks.append("qpn_range")
        est = sum(mr.size for mr in container.ctx.mrs) + 4096
        # The migration stream leaves through the source node's NIC port,
        # shared with every other flow that node originates: budget
        # against the *measured* port headroom from the fabric's
        # utilization window, not the raw port rate. With QoS enabled the
        # scheduler reshapes that headroom — a migration guarantee floors
        # the stream's share regardless of app backlog, and a migration
        # cap ceilings it regardless of idle capacity.
        fabric = self.controller.fabric
        util = fabric.port_utilization(container.node.gid)
        share = max(1e-6, 1.0 - util)
        qos = getattr(fabric, "qos", None)
        if qos is not None and qos.enabled:
            if qos.migration_guarantee is not None:
                share = max(share, qos.migration_guarantee)
            if qos.migration_cap is not None:
                share = min(share, qos.migration_cap)
        effective_bw = self.controller.bw * share
        # The stream *lands* in the destination's ingress port, shared
        # with everything else the cluster is throwing at that node: an
        # incast-loaded or undersized receive path bounds the transfer
        # exactly like a congested source port, so price the worse of
        # the two ends (today's egress-only estimate admitted transfers
        # a saturated receiver would stall into RNR backoff).
        rx_cap = fabric.ingress_capacity_Bps(dest_node.gid)
        rx_util = fabric.ingress_utilization(dest_node.gid)
        if rx_cap is not None:
            effective_bw = min(effective_bw,
                               rx_cap * max(1e-6, 1.0 - rx_util))
        # ECN: observed marking rates on both ports are a *leading*
        # congestion signal — utilization says how full the pipe is,
        # marking says the queues are already deep enough that DCQCN is
        # actively slowing senders down. A migration admitted into a
        # marking port would both crawl and steal the headroom the
        # congested flows are converging toward, so discount the
        # estimate by the marked fraction at each end (0.0 with ECN
        # off: the estimate is unchanged).
        mark_src = fabric.marking_rate(container.node.gid)
        mark_dst = fabric.ingress_marking_rate(dest_node.gid)
        for frac in (mark_src, mark_dst):
            if frac > 0.0:
                effective_bw *= max(1e-6, 1.0 - frac)
        est_s = est / effective_bw
        if self.max_transfer_s is not None and est_s > self.max_transfer_s:
            raise AdmissionError(
                f"estimated transfer {est_s:.4f}s (egress-port util "
                f"{util:.0%}, dest ingress util {rx_util:.0%}, ECN "
                f"marking src {mark_src:.0%} / dest {mark_dst:.0%}) "
                f"exceeds budget {self.max_transfer_s:.4f}s")
        checks.append("bandwidth")
        checks.append("ingress")
        if getattr(fabric, "ecn", None) is not None and fabric.ecn.enabled:
            checks.append("ecn")
        return MigrationPlan(container.name, container.node.gid,
                             dest_node.gid, est, est_s, checks)

    def estimate_dirty_rate(self, container, probe_steps: int = 20) -> float:
        """Probe the container's write rate (bytes/s of dirtied pages) by
        running it briefly under dirty tracking — feeds strategy='auto'.
        MRs already being tracked keep their accumulated dirty set: it is
        parked during the probe and merged back (with the probe's pages)
        afterwards."""
        mrs = list(container.ctx.mrs)
        parked = {}
        for mr in mrs:
            if mr._dirty is not None:
                parked[mr.mrn] = mr.collect_dirty(clear=True)
            else:
                mr.start_dirty_tracking()
        for _ in range(probe_steps):
            if self.background is not None:
                self.background()
            else:
                self.controller.fabric.pump()
        dirtied = 0
        for mr in mrs:
            probed = mr.collect_dirty(clear=True)
            dirtied += len(probed) * PAGE_SIZE
            if mr.mrn in parked:
                mr._dirty = parked[mr.mrn] | probed
            else:
                mr.stop_dirty_tracking()
        return dirtied / (probe_steps * STEP_S)

    # -- queueing ------------------------------------------------------------
    def submit(self, container, dest_node, *, strategy="stop_and_copy",
               strategy_params: Optional[Dict] = None, runtime: str = "crx",
               fail_at: Optional[str] = None,
               retries: int = 1) -> MigrationRequest:
        req = MigrationRequest(container, dest_node, strategy,
                               dict(strategy_params or {}), runtime,
                               fail_at, retries)
        self.queue.append(req)
        return req

    def drain(self) -> List[MigrationReport]:
        """Execute queued requests one at a time (migrations are
        serialised; admission re-runs at execution time, so a request
        invalidated by an earlier one is rejected, not corrupted). A
        rejected request yields a failed report — it never aborts the
        rest of the queue."""
        out = []
        while self.queue:
            req = self.queue.popleft()
            try:
                out.append(self._execute(req))
            except AdmissionError as e:
                rep = MigrationReport(ok=False, stage_failed="admission")
                rep.admission_error = e
                self.history.append(rep)
                out.append(rep)
        return out

    def migrate(self, container, dest_node, **kw) -> MigrationReport:
        """Submit + drain. FIFO: earlier queued requests run first; an
        admission rejection of *this* request re-raises here."""
        self.submit(container, dest_node, **kw)
        rep = self.drain()[-1]
        err = getattr(rep, "admission_error", None)
        if err is not None:
            raise err
        return rep

    # -- execution -----------------------------------------------------------
    def _execute(self, req: MigrationRequest) -> MigrationReport:
        fab = self.controller.fabric
        t_adm = fab.now
        self.admit(req.container, req.dest_node)
        record_phase(fab, "admission", t_adm,
                     node=req.dest_node.device.gid,
                     container=req.container.name)
        strategy = req.strategy
        if strategy == "auto":
            est = sum(mr.size for mr in req.container.ctx.mrs)
            rate = self.estimate_dirty_rate(req.container)
            strategy = choose_migration_strategy(
                est, rate, self.controller.bw, self.max_downtime_s)
        try:
            strat = make_strategy(strategy, **req.strategy_params)
        except (ValueError, TypeError) as e:
            # bad strategy name/params: nothing was stopped or moved, so
            # classify as admission — drain() converts it to a failed
            # report and keeps the queue moving; migrate() re-raises
            raise AdmissionError(f"strategy rejected: {e}") from e
        # the data plane can fail for real (stream timeout on a dead or
        # hopelessly contended link, corrupted image): convert to a failed
        # report so rollback still runs and the queue keeps draining
        from repro.core.service import ServiceError
        rep = MigrationReport(ok=False, strategy=strat.name,
                              stage_failed="transfer")
        try:
            rep = strat.run(self.controller, req.container, req.dest_node,
                            runtime=req.runtime, fail_at=req.fail_at,
                            background=self.background)
            while (not rep.ok and rep.stage_failed == "transfer"
                   and rep.attempt is not None
                   and rep.retries < req.retries):
                rep.retries += 1
                rep = strat.resume(self.controller, req.container,
                                   req.dest_node, rep.attempt, rep)
        except (MigrationError, ServiceError) as e:
            rep.ok = False
            rep.transfer_error = e
        if not rep.ok:
            self.rollback(req.container, rep)
        self.history.append(rep)
        return rep

    # -- rollback ------------------------------------------------------------
    def rollback(self, container,
                 rep: Optional[MigrationReport] = None) -> None:
        """Abort a mid-flight migration: the source QPs were stopped but
        never destroyed, so re-arm them in place. ``resume_pending`` makes
        each QP announce itself (same address) so peers parked in PAUSED
        leave it via the normal RESUME handshake, and go-back-N recovers
        whatever was NAK_STOPPED-dropped in the stop window. Data-plane
        state the dead attempt parked in service channels (staged pre-copy
        pages at the destination, the post-copy frozen store at the
        source) is released so repeated failures don't leak footprints."""
        fab = self.controller.fabric
        t_rb = fab.now
        for qp in container.ctx.qps:
            if qp.state == QPState.STOPPED:
                qp.modify(QPState.RTS, system=True)              # [MIGR]
                qp.resume_pending = True
                qp.last_resume_tx = -10 ** 9    # announce immediately
        for mr in container.ctx.mrs:
            mr.stop_dirty_tracking()      # a mid-round abort leaves it on
        # release whatever the dead attempt parked in service channels —
        # strategies register these tokens before any step that can fail
        # (or raise), so even an exception mid-stream cannot leak them
        self.controller.run_cleanups(container)
        container.alive = True
        record_phase(fab, "rollback", t_rb,
                     node=container.ctx.device.gid,
                     container=container.name)
        if rep is not None:
            rep.rolled_back = True
            rep.attempt = None            # the token is dead with the QPs
