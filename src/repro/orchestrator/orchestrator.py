"""Cluster-wide migration control plane (admission, queueing, retry,
rollback).

The seed's control plane was a single dict (``MigrationController
.relocated``). Production migration needs more: a request is *admitted*
(destination capacity, QPN/MRN-range collision, link-bandwidth budget)
before any QP is stopped, concurrent requests are serialised through a
FIFO queue, failed transfers are retried from the last completed round,
and a migration that dies mid-flight is *rolled back* — the still-attached
source QPs leave STOPPED, re-arm, and send RESUME so paused peers recover
instead of hanging on NAK_STOPPED forever (the failure mode the paper
accepts in §3.4, and the one ``test_failed_migration_leaves_peer_paused``
pins for the bare controller).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.migration import (MigrationAttempt, MigrationController,
                                  MigrationError, MigrationReport)
from repro.core.states import QPState
from repro.core.transport import STEP_S
from repro.core.verbs import PAGE_SIZE
from repro.obs.trace import record_phase
from repro.orchestrator.strategies import (MigrationStrategy,
                                           choose_migration_strategy,
                                           make_strategy)


class AdmissionError(MigrationError):
    """Pre-migration validation failed; nothing was stopped or moved."""


@dataclass
class MigrationPlan:
    """Outcome of admission: what will move, where, and the cost estimate."""
    container: str
    src_gid: int
    dest_gid: int
    est_image_bytes: int
    est_transfer_s: float
    checks: List[str] = field(default_factory=list)


@dataclass
class MigrationRequest:
    container: object
    dest_node: object
    strategy: object = "stop_and_copy"      # name | class | instance
    strategy_params: Dict = field(default_factory=dict)
    runtime: str = "crx"
    fail_at: Optional[str] = None
    retries: int = 1
    # lifecycle: queued | held | running | paused | done | failed | aborted
    state: str = "queued"
    # the instance actually executing (resolved from ``strategy`` at run
    # time); a paused request resumes on the SAME instance so strategy
    # tunables (round caps, thresholds) survive the pause
    resolved_strategy: Optional[object] = field(default=None, repr=False)


@dataclass
class PreemptionPolicy:
    """Auto-preemption knobs: pause an in-flight migration when the
    source node's *application* egress utilization crosses
    ``pause_util`` (the migration's own stream is excluded from the
    signal, so a migration can never pause itself), resume a policy-
    paused one once the app load drains below ``resume_util`` and it has
    been parked at least ``min_paused_steps``."""
    pause_util: float = 0.9
    resume_util: float = 0.5
    min_paused_steps: int = 200


@dataclass
class PausedMigration:
    """A parked in-flight migration: the request, its partial report,
    and the serialisable attempt token to re-enter the strategy from."""
    req: MigrationRequest
    rep: MigrationReport
    attempt: MigrationAttempt


class Orchestrator:
    """Owns the cluster migration state: the ``relocated`` registry (shared
    with the wrapped controller so bare-controller migrations stay
    coherent), the request queue, and per-request retry/rollback."""

    def __init__(self, controller: MigrationController, *,
                 background: Optional[Callable] = None,
                 max_transfer_s: Optional[float] = None,
                 max_downtime_s: float = 1e-3):
        self.controller = controller
        self.background = background      # steps apps + pumps once (live)
        self.max_transfer_s = max_transfer_s
        self.max_downtime_s = max_downtime_s   # budget for strategy="auto"
        self.queue: deque = deque()
        self.history: List[MigrationReport] = []
        # -- preemption state ------------------------------------------ [PRE]
        self.paused: Dict[str, PausedMigration] = {}   # name -> parked
        # name -> (reason, deadline step | None): a pending pause/abort
        # verdict the running strategy picks up at its next yield point
        self._preempt: Dict[str, Tuple[str, Optional[int]]] = {}
        self._active: Optional[MigrationRequest] = None
        # post-copy reports whose pager is still draining (pause/resume
        # of the pull phase operates on these after migrate() returned)
        self._pagers: Dict[str, MigrationReport] = {}
        self._pager_paused: Dict[str, int] = {}        # name -> pause step
        self.preemption: Optional[PreemptionPolicy] = None
        self._auto_last: Tuple[int, Optional[str]] = (-1, None)

    def configure_preemption(self, enabled: bool = True, *,
                             pause_util: float = 0.9,
                             resume_util: float = 0.5,
                             min_paused_steps: int = 200):
        """Arm (or disarm) the auto-preemption policy; see
        ``PreemptionPolicy`` for the knob semantics."""
        self.preemption = PreemptionPolicy(
            pause_util=pause_util, resume_util=resume_util,
            min_paused_steps=min_paused_steps) if enabled else None
        return self.preemption

    @property
    def relocated(self) -> Dict[int, int]:
        return self.controller.relocated

    # -- admission -----------------------------------------------------------
    def admit(self, container, dest_node, *,
              resuming: bool = False) -> MigrationPlan:
        if dest_node is container.node:
            raise AdmissionError("destination is the source node")
        if not container.alive and not resuming:
            # a stopped-phase pause token legitimately re-admits a
            # checkpoint-frozen (not-alive) container
            raise AdmissionError(f"container {container.name!r} not alive")
        checks = []
        cap = getattr(dest_node, "capacity", None)
        if cap is not None and len(dest_node.containers) >= cap:
            raise AdmissionError(
                f"node {dest_node.gid} at capacity ({cap})")
        checks.append("capacity")
        dev = dest_node.device
        for qp in container.ctx.qps:
            if qp.qpn in dev.qps:
                raise AdmissionError(
                    f"QPN {qp.qpn} already allocated on node {dev.gid}")
        taken_mrns = {m.mrn for c in dev.contexts for m in c.mrs}
        for mr in container.ctx.mrs:
            if mr.mrn in taken_mrns:
                raise AdmissionError(
                    f"MRN {mr.mrn} already allocated on node {dev.gid}")
        checks.append("qpn_range")
        est = sum(mr.size for mr in container.ctx.mrs) + 4096
        # The migration stream leaves through the source node's NIC port,
        # shared with every other flow that node originates: budget
        # against the *measured* port headroom from the fabric's
        # utilization window, not the raw port rate. With QoS enabled the
        # scheduler reshapes that headroom — a migration guarantee floors
        # the stream's share regardless of app backlog, and a migration
        # cap ceilings it regardless of idle capacity.
        fabric = self.controller.fabric
        util = fabric.port_utilization(container.node.gid)
        share = max(1e-6, 1.0 - util)
        qos = getattr(fabric, "qos", None)
        if qos is not None and qos.enabled:
            if qos.migration_guarantee is not None:
                share = max(share, qos.migration_guarantee)
            if qos.migration_cap is not None:
                share = min(share, qos.migration_cap)
        effective_bw = self.controller.bw * share
        # The stream *lands* in the destination's ingress port, shared
        # with everything else the cluster is throwing at that node: an
        # incast-loaded or undersized receive path bounds the transfer
        # exactly like a congested source port, so price the worse of
        # the two ends (today's egress-only estimate admitted transfers
        # a saturated receiver would stall into RNR backoff).
        rx_cap = fabric.ingress_capacity_Bps(dest_node.gid)
        rx_util = fabric.ingress_utilization(dest_node.gid)
        if rx_cap is not None:
            effective_bw = min(effective_bw,
                               rx_cap * max(1e-6, 1.0 - rx_util))
        # ECN: observed marking rates on both ports are a *leading*
        # congestion signal — utilization says how full the pipe is,
        # marking says the queues are already deep enough that DCQCN is
        # actively slowing senders down. A migration admitted into a
        # marking port would both crawl and steal the headroom the
        # congested flows are converging toward, so discount the
        # estimate by the marked fraction at each end (0.0 with ECN
        # off: the estimate is unchanged).
        mark_src = fabric.marking_rate(container.node.gid)
        mark_dst = fabric.ingress_marking_rate(dest_node.gid)
        for frac in (mark_src, mark_dst):
            if frac > 0.0:
                effective_bw *= max(1e-6, 1.0 - frac)
        est_s = est / effective_bw
        if self.max_transfer_s is not None and est_s > self.max_transfer_s:
            raise AdmissionError(
                f"estimated transfer {est_s:.4f}s (egress-port util "
                f"{util:.0%}, dest ingress util {rx_util:.0%}, ECN "
                f"marking src {mark_src:.0%} / dest {mark_dst:.0%}) "
                f"exceeds budget {self.max_transfer_s:.4f}s")
        checks.append("bandwidth")
        checks.append("ingress")
        if getattr(fabric, "ecn", None) is not None and fabric.ecn.enabled:
            checks.append("ecn")
        return MigrationPlan(container.name, container.node.gid,
                             dest_node.gid, est, est_s, checks)

    def estimate_dirty_rate(self, container, probe_steps: int = 20) -> float:
        """Probe the container's write rate (bytes/s of dirtied pages) by
        running it briefly under dirty tracking — feeds strategy='auto'.
        MRs already being tracked keep their accumulated dirty set: it is
        parked during the probe and merged back (with the probe's pages)
        afterwards."""
        mrs = list(container.ctx.mrs)
        parked = {}
        for mr in mrs:
            if mr._dirty is not None:
                parked[mr.mrn] = mr.collect_dirty(clear=True)
            else:
                mr.start_dirty_tracking()
        for _ in range(probe_steps):
            if self.background is not None:
                self.background()
            else:
                self.controller.fabric.pump()
        dirtied = 0
        for mr in mrs:
            probed = mr.collect_dirty(clear=True)
            dirtied += len(probed) * PAGE_SIZE
            if mr.mrn in parked:
                mr._dirty = parked[mr.mrn] | probed
            else:
                mr.stop_dirty_tracking()
        return dirtied / (probe_steps * STEP_S)

    # -- queueing ------------------------------------------------------------
    def submit(self, container, dest_node, *, strategy="stop_and_copy",
               strategy_params: Optional[Dict] = None, runtime: str = "crx",
               fail_at: Optional[str] = None,
               retries: int = 1) -> MigrationRequest:
        req = MigrationRequest(container, dest_node, strategy,
                               dict(strategy_params or {}), runtime,
                               fail_at, retries)
        self.queue.append(req)
        return req

    def drain(self) -> List[MigrationReport]:
        """Execute queued requests one at a time (migrations are
        serialised; admission re-runs at execution time, so a request
        invalidated by an earlier one is rejected, not corrupted). A
        rejected request yields a failed report — it never aborts the
        rest of the queue. Requests an operator ``pause``d while still
        queued (state ``"held"``) are skipped and stay queued until
        ``resume``d."""
        out = []
        held = []
        while self.queue:
            req = self.queue.popleft()
            if req.state == "held":
                held.append(req)
                continue
            try:
                out.append(self._execute(req))
            except AdmissionError as e:
                rep = MigrationReport(ok=False, stage_failed="admission")
                rep.admission_error = e
                self.history.append(rep)
                out.append(rep)
        self.queue.extend(held)
        return out

    def migrate(self, container, dest_node, **kw) -> MigrationReport:
        """Submit + drain. FIFO: earlier queued requests run first; an
        admission rejection of *this* request re-raises here."""
        self.submit(container, dest_node, **kw)
        rep = self.drain()[-1]
        err = getattr(rep, "admission_error", None)
        if err is not None:
            raise err
        return rep

    # -- execution -----------------------------------------------------------
    def _execute(self, req: MigrationRequest) -> MigrationReport:
        fab = self.controller.fabric
        t_adm = fab.now
        self.admit(req.container, req.dest_node)
        record_phase(fab, "admission", t_adm,
                     node=req.dest_node.device.gid,
                     container=req.container.name)
        strategy = req.strategy
        if strategy == "auto":
            est = sum(mr.size for mr in req.container.ctx.mrs)
            rate = self.estimate_dirty_rate(req.container)
            strategy = choose_migration_strategy(
                est, rate, self.controller.bw, self.max_downtime_s)
        try:
            strat = make_strategy(strategy, **req.strategy_params)
        except (ValueError, TypeError) as e:
            # bad strategy name/params: nothing was stopped or moved, so
            # classify as admission — drain() converts it to a failed
            # report and keeps the queue moving; migrate() re-raises
            raise AdmissionError(f"strategy rejected: {e}") from e
        # the data plane can fail for real (stream timeout on a dead or
        # hopelessly contended link, corrupted image): convert to a failed
        # report so rollback still runs and the queue keeps draining
        from repro.core.service import ServiceError
        rep = MigrationReport(ok=False, strategy=strat.name,
                              stage_failed="transfer")
        req.resolved_strategy = strat
        self._active = req
        req.state = "running"
        try:
            rep = strat.run(self.controller, req.container, req.dest_node,
                            runtime=req.runtime, fail_at=req.fail_at,
                            background=self.background,
                            preempt=self._preempt_check(req))
            while (not rep.ok and rep.stage_failed == "transfer"
                   and rep.attempt is not None
                   and rep.retries < req.retries):
                rep.retries += 1
                rep = strat.resume(self.controller, req.container,
                                   req.dest_node, rep.attempt, rep)
        except (MigrationError, ServiceError) as e:
            rep.ok = False
            rep.transfer_error = e
        finally:
            self._active = None
            self._preempt.pop(req.container.name, None)
        return self._settle(req, rep)

    def _settle(self, req: MigrationRequest,
                rep: MigrationReport) -> MigrationReport:
        """Classify a strategy's outcome: park a paused attempt, roll
        back a failed/aborted one, record the rest. The single exit path
        for both ``_execute`` and ``resume``."""
        name = req.container.name
        rep.container = name
        fab = self.controller.fabric
        if not rep.ok and rep.stage_failed == "paused" \
                and rep.attempt is not None:
            req.state = "paused"
            self.paused[name] = PausedMigration(req, rep, rep.attempt)
            fab.metrics.inc("migration_pauses", gid=rep.attempt.src_gid)
            return rep
        if not rep.ok:
            self.rollback(req.container, rep)
            if rep.stage_failed == "aborted":
                req.state = "aborted"
                fab.metrics.inc("migration_aborts",
                                gid=req.container.ctx.device.gid)
            else:
                req.state = "failed"
        else:
            req.state = "done"
            pager = rep.pager
            if pager is not None and pager.remaining_pages:
                self._pagers[name] = rep
        self.history.append(rep)
        return rep

    # -- preemption ----------------------------------------------------------
    def _preempt_check(self, req: MigrationRequest) -> Callable:
        """Build the yield-point predicate the strategy polls at every
        round/page boundary (and the service channel at every pump):
        a pending operator verdict wins; otherwise the auto-preemption
        policy compares the source node's app-class egress utilization
        (the migration's own stream is excluded, so it never pauses
        itself) against ``pause_util``. The policy read is memoised per
        fabric step — boundaries are far denser than the clock."""
        fab = self.controller.fabric
        name = req.container.name

        def check() -> Optional[str]:
            v = self._preempt.get(name)
            if v is not None:
                reason, at = v
                if at is None or fab.now >= at:
                    return reason
            pol = self.preemption
            if pol is not None:
                step, verdict = self._auto_last
                if step != fab.now:
                    util = fab.app_utilization(req.container.node.gid)
                    verdict = "auto" if util > pol.pause_util else None
                    self._auto_last = (fab.now, verdict)
                return verdict
            return None

        return check

    def pause(self, container, *, at: Optional[int] = None) -> bool:
        """Operator pause. The active in-flight migration yields at its
        next round/page boundary (or the first boundary at/after step
        ``at``); a still-queued request is held in place; a post-copy
        pager still draining after a completed migration stops
        prefetching (demand faults keep serving). Returns True if there
        was anything to pause."""
        name = container.name
        if self._active is not None and self._active.container is container:
            self._preempt[name] = ("pause", at)
            return True
        if at is not None:
            # deadline pause may be armed BEFORE the (synchronous)
            # migrate call that it targets: the flag is only consulted
            # at in-flight yield points and is cleared when the request
            # settles, so arming early is harmless
            self._preempt[name] = ("pause", at)
            return True
        for req in self.queue:
            if req.container is container and req.state == "queued":
                req.state = "held"
                return True
        rep = self._pagers.get(name)
        if rep is not None and rep.pager.remaining_pages:
            rep.pager.paused = True
            self._pager_paused.setdefault(name, self.controller.fabric.now)
            return True
        return name in self.paused

    def abort(self, container) -> bool:
        """Abort the container's migration wherever it is in the
        lifecycle: a running one yields and rolls back, a paused one is
        rolled back immediately (source QPs re-arm, admission budget and
        parked service-channel state released), a queued one is dropped.
        Returns True if there was anything to abort."""
        name = container.name
        fab = self.controller.fabric
        if self._active is not None and self._active.container is container:
            self._preempt[name] = ("abort", None)
            return True
        pm = self.paused.pop(name, None)
        if pm is not None:
            self._account_pause(pm.rep, pm.attempt)
            pm.rep.stage_failed = "aborted"
            pm.rep.container = name
            self.rollback(container, pm.rep)
            pm.req.state = "aborted"
            fab.metrics.inc("migration_aborts",
                            gid=container.ctx.device.gid)
            self.history.append(pm.rep)
            return True
        for req in list(self.queue):
            if req.container is container:
                self.queue.remove(req)
                req.state = "aborted"
                return True
        return False

    def resume(self, container, dest_node=None) -> Optional[MigrationReport]:
        """Resume the container's paused migration — on the original
        destination, or on ``dest_node`` if given (mandatory when the
        original left the fabric). Re-admits against *current* cluster
        state, re-applies the service QP's parked congestion/RTO state,
        and re-enters the strategy from the attempt token. Also unpauses
        a held queued request (returns None) or a paused post-copy
        pager (returns its report)."""
        name = container.name
        fab = self.controller.fabric
        for req in self.queue:
            if req.container is container and req.state == "held":
                req.state = "queued"
                if dest_node is not None:
                    req.dest_node = dest_node
                return None
        rep = self._pagers.get(name)
        if rep is not None and rep.pager.paused:
            rep.pager.paused = False
            t0 = self._pager_paused.pop(name, None)
            if t0 is not None:
                rep.paused_s += (fab.now - t0) * STEP_S
                trc = fab.tracer
                if trc is not None:
                    trc.paused(t0, fab.now,
                               node=container.ctx.device.gid,
                               container=name, reason="pager")
            return rep
        pm = self.paused.get(name)
        if pm is None:
            raise MigrationError(f"no paused migration for {name!r}")
        req, rep, attempt = pm.req, pm.rep, pm.attempt
        if dest_node is not None:
            req.dest_node = dest_node
        elif fab.device(req.dest_node.device.gid) is None:
            raise MigrationError(
                f"original destination {req.dest_node.device.gid} left "
                f"the fabric; resume {name!r} with a new destination")
        del self.paused[name]
        t_adm = fab.now
        try:
            self.admit(container, req.dest_node, resuming=True)
        except AdmissionError:
            # stay parked: the pause span keeps running until a resume
            # actually goes through
            self.paused[name] = pm
            raise
        record_phase(fab, "admission", t_adm,
                     node=req.dest_node.device.gid, container=name)
        self._account_pause(rep, attempt)
        fab.metrics.inc("migration_resumes", gid=attempt.src_gid)
        strat = req.resolved_strategy
        if strat is None:
            # a deserialised token crossed orchestrator instances
            strat = make_strategy(attempt.strategy or req.strategy,
                                  **req.strategy_params)
            req.resolved_strategy = strat
        from repro.core.service import ServiceError
        self._active = req
        req.state = "running"
        try:
            rep = strat.resume_paused(self.controller, container,
                                      req.dest_node, attempt, rep,
                                      background=self.background,
                                      preempt=self._preempt_check(req))
        except (MigrationError, ServiceError) as e:
            rep.ok = False
            rep.stage_failed = "transfer"
            rep.transfer_error = e
        finally:
            self._active = None
            self._preempt.pop(name, None)
        return self._settle(req, rep)

    def _account_pause(self, rep: MigrationReport,
                       attempt: MigrationAttempt):
        """Attribute the parked gap to ``paused_s`` (and a PAUSED trace
        span) — never to transfer/live/downtime, which sum only spans
        the migration was actively working."""
        fab = self.controller.fabric
        rep.paused_s += (fab.now - attempt.paused_at) * STEP_S
        trc = fab.tracer
        if trc is not None:
            trc.paused(attempt.paused_at, fab.now, node=attempt.src_gid,
                       container=attempt.container, reason=attempt.reason)

    def poll_preemption(self):
        """Policy tick (the cluster step loop calls this once per step
        when a policy is armed): resume auto-paused migrations whose
        source app load has drained below ``resume_util`` after at least
        ``min_paused_steps`` parked. One resume per tick — the resumed
        migration runs synchronously inside the tick."""
        pol = self.preemption
        if pol is None or self._active is not None or not self.paused:
            return
        fab = self.controller.fabric
        for name in list(self.paused):
            pm = self.paused[name]
            att = pm.attempt
            if att.reason != "auto":
                continue               # operator pauses need an operator
            if fab.now - att.paused_at < pol.min_paused_steps:
                continue
            if fab.device(pm.req.dest_node.device.gid) is None:
                continue               # destination gone: operator call
            if fab.app_utilization(att.src_gid) < pol.resume_util:
                self.resume(pm.req.container)
                return

    # -- rollback ------------------------------------------------------------
    def rollback(self, container,
                 rep: Optional[MigrationReport] = None) -> None:
        """Abort a mid-flight migration: the source QPs were stopped but
        never destroyed, so re-arm them in place. ``resume_pending`` makes
        each QP announce itself (same address) so peers parked in PAUSED
        leave it via the normal RESUME handshake, and go-back-N recovers
        whatever was NAK_STOPPED-dropped in the stop window. Data-plane
        state the dead attempt parked in service channels (staged pre-copy
        pages at the destination, the post-copy frozen store at the
        source) is released so repeated failures don't leak footprints."""
        fab = self.controller.fabric
        t_rb = fab.now
        for qp in container.ctx.qps:
            if qp.state == QPState.STOPPED:
                qp.modify(QPState.RTS, system=True)              # [MIGR]
                qp.resume_pending = True
                qp.last_resume_tx = -10 ** 9    # announce immediately
        for mr in container.ctx.mrs:
            mr.stop_dirty_tracking()      # a mid-round abort leaves it on
        # release whatever the dead attempt parked in service channels —
        # strategies register these tokens before any step that can fail
        # (or raise), so even an exception mid-stream cannot leak them
        self.controller.run_cleanups(container)
        container.alive = True
        record_phase(fab, "rollback", t_rb,
                     node=container.ctx.device.gid,
                     container=container.name)
        if rep is not None:
            rep.rolled_back = True
            rep.attempt = None            # the token is dead with the QPs
